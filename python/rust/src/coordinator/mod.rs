pub mod fedhc;

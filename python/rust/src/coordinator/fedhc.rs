pub struct FedHc;

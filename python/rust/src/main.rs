fn main() { println!("fedhc"); }

"""AOT pipeline sanity: manifest structure, HLO text parseability markers,
and init binary size. Runs against the artifacts/ produced by `make
artifacts` when present; otherwise lowers tiny_mlp into a temp dir."""

import json
import os
import struct
import subprocess
import sys

import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.fixture(scope="module")
def artifacts_dir(tmp_path_factory):
    if os.path.exists(os.path.join(ART, "manifest.json")):
        return ART
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out),
         "--variants", "tiny_mlp"],
        check=True, cwd=os.path.join(os.path.dirname(__file__), ".."))
    return str(out)


@pytest.fixture(scope="module")
def manifest(artifacts_dir):
    with open(os.path.join(artifacts_dir, "manifest.json")) as f:
        return json.load(f)


class TestManifest:
    def test_format_and_variants(self, manifest):
        assert manifest["format"] == 1
        assert "tiny_mlp" in manifest["variants"]

    def test_entry_shapes_consistent(self, manifest):
        for name, v in manifest["variants"].items():
            p = v["param_count"]
            b = v["batch"]
            c, h, w = v["input_chw"]
            d = c * h * w
            e = v["entries"]
            assert e["train_step"]["inputs"] == [[p], [b, d], [b], [1]]
            assert e["train_step"]["outputs"] == [[p], []]
            assert e["eval_step"]["inputs"] == [[p], [b, d], [b]]
            s = v["chunk_steps"]
            assert e["train_chunk"]["inputs"] == [[p], [s, b, d], [s, b], [1]]
            n = v["agg_slots"]
            assert e["aggregate"]["inputs"] == [[n, p], [n]]
            assert e["maml_step"]["inputs"][0] == [p]

    def test_hlo_files_exist_and_look_like_hlo(self, manifest, artifacts_dir):
        for v in manifest["variants"].values():
            for e in v["entries"].values():
                path = os.path.join(artifacts_dir, e["file"])
                assert os.path.exists(path), path
                with open(path) as f:
                    head = f.read(400)
                assert "HloModule" in head, f"{path} missing HloModule header"

    def test_init_binary_matches_param_count(self, manifest, artifacts_dir):
        for v in manifest["variants"].values():
            path = os.path.join(artifacts_dir, v["init_file"])
            size = os.path.getsize(path)
            assert size == 4 * v["param_count"]
            # spot-check the floats are finite
            with open(path, "rb") as f:
                data = f.read(4 * min(v["param_count"], 256))
            vals = struct.unpack(f"<{len(data) // 4}f", data)
            assert all(abs(x) < 10.0 for x in vals)

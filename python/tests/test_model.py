"""L2 model/training graph tests: shapes, flatten/unflatten, learning on a
separable toy task, and chunked-vs-stepwise equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import VARIANTS, apply_model
from compile.train import (CHUNK_STEPS, cross_entropy, make_eval_step,
                           make_train_chunk, make_train_step)


@pytest.fixture(scope="module")
def tiny():
    return VARIANTS["tiny_mlp"]


def toy_batch(spec, seed=0):
    """Linearly separable 10-class toy batch in the model's input geometry."""
    rng = np.random.default_rng(seed)
    b = spec.batch
    d = spec.input_chw[0] * spec.input_chw[1] * spec.input_chw[2]
    y = rng.integers(0, 10, size=b)
    x = 0.1 * rng.standard_normal((b, d), dtype=np.float32)
    # class-dependent spike makes the task trivially learnable
    for i, c in enumerate(y):
        x[i, c] += 2.0
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))


class TestSpecs:
    def test_param_counts(self):
        # LeNet-5 with valid convs on 28×28: 44,426 params (the classic
        # 61,706 figure assumes 32×32 inputs; CIFAR hits that regime)
        assert VARIANTS["mnist_lenet"].param_count == 44_426
        assert VARIANTS["cifar_lenet"].param_count == 62_006
        assert VARIANTS["tiny_mlp"].param_count == 64 * 32 + 32 + 32 * 10 + 10
        assert VARIANTS["cifar_lenet"].param_count > VARIANTS["mnist_lenet"].param_count

    def test_unflatten_shapes(self, tiny):
        flat = tiny.init(seed=0)
        assert flat.shape == (tiny.param_count,)
        parts = tiny.unflatten(flat)
        assert parts["fc1_w"].shape == (64, 32)
        assert parts["fc2_b"].shape == (10,)

    def test_unflatten_roundtrip(self, tiny):
        flat = tiny.init(seed=1)
        parts = tiny.unflatten(flat)
        rebuilt = jnp.concatenate([parts[n].reshape(-1) for n, _ in tiny.shapes])
        np.testing.assert_array_equal(np.asarray(flat), np.asarray(rebuilt))

    def test_init_deterministic(self, tiny):
        a, b = tiny.init(seed=3), tiny.init(seed=3)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        c = tiny.init(seed=4)
        assert not np.array_equal(np.asarray(a), np.asarray(c))


class TestForward:
    @pytest.mark.parametrize("name", ["tiny_mlp", "mnist_lenet"])
    def test_logit_shapes(self, name):
        spec = VARIANTS[name]
        flat = spec.init(seed=0)
        x, _ = toy_batch(spec)
        logits = apply_model(spec, flat, x)
        assert logits.shape == (spec.batch, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_cifar_forward_shape(self):
        spec = VARIANTS["cifar_lenet"]
        flat = spec.init(seed=0)
        x, _ = toy_batch(spec)
        assert apply_model(spec, flat, x).shape == (spec.batch, 10)

    def test_cross_entropy_uniform_logits(self):
        logits = jnp.zeros((4, 10))
        y = jnp.asarray([0.0, 3.0, 7.0, 9.0])
        np.testing.assert_allclose(float(cross_entropy(logits, y)),
                                   np.log(10.0), rtol=1e-5)


class TestTraining:
    def test_train_step_reduces_loss(self, tiny):
        step = jax.jit(make_train_step(tiny))
        flat = tiny.init(seed=0)
        x, y = toy_batch(tiny)
        lr = jnp.asarray([0.5], jnp.float32)
        losses = []
        for _ in range(30):
            flat, loss = step(flat, x, y, lr)
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0], losses[::10]

    def test_chunk_equals_stepwise(self, tiny):
        """train_chunk(S batches) must equal S sequential train_steps."""
        step = jax.jit(make_train_step(tiny))
        chunk = jax.jit(make_train_chunk(tiny))
        flat0 = tiny.init(seed=5)
        lr = jnp.asarray([0.1], jnp.float32)
        xs, ys = [], []
        for s in range(CHUNK_STEPS):
            x, y = toy_batch(tiny, seed=100 + s)
            xs.append(x)
            ys.append(y)
        # stepwise
        flat_a = flat0
        losses_a = []
        for s in range(CHUNK_STEPS):
            flat_a, l = step(flat_a, xs[s], ys[s], lr)
            losses_a.append(float(l))
        # chunked
        flat_b, mean_loss = chunk(flat0, jnp.stack(xs), jnp.stack(ys), lr)
        np.testing.assert_allclose(np.asarray(flat_a), np.asarray(flat_b),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(float(mean_loss), np.mean(losses_a), rtol=1e-4)

    def test_eval_step_counts_correct(self, tiny):
        ev = jax.jit(make_eval_step(tiny))
        step = jax.jit(make_train_step(tiny))
        flat = tiny.init(seed=0)
        x, y = toy_batch(tiny)
        lr = jnp.asarray([0.5], jnp.float32)
        _, correct0 = ev(flat, x, y)
        for _ in range(40):
            flat, _ = step(flat, x, y, lr)
        loss1, correct1 = ev(flat, x, y)
        assert float(correct1) > float(correct0)
        assert float(correct1) >= 0.9 * tiny.batch
        assert 0 <= float(correct1) <= tiny.batch
        assert float(loss1) >= 0.0

    def test_lenet_one_step_runs_and_improves(self):
        spec = VARIANTS["mnist_lenet"]
        step = jax.jit(make_train_step(spec))
        flat = spec.init(seed=0)
        x, y = toy_batch(spec)
        lr = jnp.asarray([0.05], jnp.float32)
        flat1, l0 = step(flat, x, y, lr)
        _, l1 = step(flat1, x, y, lr)
        assert float(l1) < float(l0)
        assert flat1.shape == flat.shape

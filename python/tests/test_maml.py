"""MAML graph semantics (Eq. 16–17, first-order)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.maml import make_maml_step
from compile.models import VARIANTS
from compile.train import make_loss
from compile.kernels.sgd import sgd_update


@pytest.fixture(scope="module")
def tiny():
    return VARIANTS["tiny_mlp"]


def task_batch(spec, classes, seed):
    """Batch restricted to a subset of classes (a 'task')."""
    rng = np.random.default_rng(seed)
    b = spec.batch
    d = spec.input_chw[0] * spec.input_chw[1] * spec.input_chw[2]
    y = rng.choice(classes, size=b)
    x = 0.1 * rng.standard_normal((b, d), dtype=np.float32)
    for i, c in enumerate(y):
        x[i, c] += 2.0
    return jnp.asarray(x), jnp.asarray(y.astype(np.float32))


class TestMamlStep:
    def test_matches_manual_fomaml(self, tiny):
        """maml_step must equal the hand-rolled two-stage update."""
        maml = jax.jit(make_maml_step(tiny))
        loss_fn = make_loss(tiny)
        flat = tiny.init(seed=0)
        sx, sy = task_batch(tiny, [0, 1, 2], seed=1)
        qx, qy = task_batch(tiny, [0, 1, 2], seed=2)
        alpha = jnp.asarray([0.01], jnp.float32)
        beta = jnp.asarray([0.02], jnp.float32)

        got, q_loss = maml(flat, sx, sy, qx, qy, alpha, beta)

        g_in = jax.grad(loss_fn)(flat, sx, sy)
        adapted = sgd_update(flat, g_in, alpha)
        want_qloss, g_out = jax.value_and_grad(loss_fn)(adapted, qx, qy)
        want = sgd_update(flat, g_out, beta)

        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(q_loss), float(want_qloss), rtol=1e-5)

    def test_zero_rates_are_identity(self, tiny):
        maml = jax.jit(make_maml_step(tiny))
        flat = tiny.init(seed=3)
        sx, sy = task_batch(tiny, [3, 4], seed=4)
        z = jnp.asarray([0.0], jnp.float32)
        got, _ = maml(flat, sx, sy, sx, sy, z, z)
        np.testing.assert_allclose(np.asarray(got), np.asarray(flat), atol=1e-7)

    def test_adaptation_helps_on_task(self, tiny):
        """Repeated MAML steps on a task should lower that task's loss —
        the warm-start property the re-clustering algorithm relies on."""
        maml = jax.jit(make_maml_step(tiny))
        loss_fn = jax.jit(make_loss(tiny))
        flat = tiny.init(seed=5)
        alpha = jnp.asarray([0.1], jnp.float32)
        beta = jnp.asarray([0.1], jnp.float32)
        sx, sy = task_batch(tiny, [5, 6, 7], seed=6)
        qx, qy = task_batch(tiny, [5, 6, 7], seed=7)
        before = float(loss_fn(flat, qx, qy))
        for _ in range(20):
            flat, _ = maml(flat, sx, sy, qx, qy, alpha, beta)
        after = float(loss_fn(flat, qx, qy))
        assert after < 0.6 * before, (before, after)

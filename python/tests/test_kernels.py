"""L1 kernel correctness: Pallas vs pure-jnp oracle, including hypothesis
shape sweeps — the CORE build-time correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import aggregate, dense, matmul, sgd_update
from compile.kernels import ref
from compile.kernels.matmul import vmem_bytes


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (8, 8, 8), (64, 256, 128), (64, 400, 120), (1, 64, 10),
        (65, 33, 17),   # non-tile-multiple shapes exercise the padding path
        (128, 128, 128),
        (3, 7, 5),
    ])
    def test_matches_ref(self, m, k, n):
        rng = np.random.default_rng(0)
        x, w = rand(rng, m, k), rand(rng, k, n)
        np.testing.assert_allclose(
            np.asarray(matmul(x, w)), np.asarray(ref.matmul_ref(x, w)),
            rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(m=st.integers(1, 96), k=st.integers(1, 96), n=st.integers(1, 96),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shape_sweep(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w = rand(rng, m, k), rand(rng, k, n)
        np.testing.assert_allclose(
            np.asarray(matmul(x, w)), np.asarray(x) @ np.asarray(w),
            rtol=2e-4, atol=2e-4)

    def test_block_size_invariance(self):
        rng = np.random.default_rng(1)
        x, w = rand(rng, 64, 96), rand(rng, 96, 48)
        a = matmul(x, w, bm=16, bn=16)
        b = matmul(x, w, bm=128, bn=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)

    def test_vmem_estimate_positive_and_monotone(self):
        small = vmem_bytes(64, 256, 120, bm=32, bn=32)
        big = vmem_bytes(64, 256, 120, bm=128, bn=128)
        assert 0 < small <= big


class TestDense:
    @pytest.mark.parametrize("activation", ["relu", "none"])
    @pytest.mark.parametrize("m,k,n", [(16, 64, 32), (64, 256, 120), (5, 13, 11)])
    def test_forward_matches_ref(self, activation, m, k, n):
        rng = np.random.default_rng(2)
        x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
        got = dense(x, w, b, activation)
        want = ref.dense_ref(x, w, b, activation)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("activation", ["relu", "none"])
    def test_vjp_matches_ref(self, activation):
        rng = np.random.default_rng(3)
        x, w, b = rand(rng, 8, 24), rand(rng, 24, 12), rand(rng, 12)
        dy = rand(rng, 8, 12)
        _, vjp = jax.vjp(lambda *a: dense(*a, activation), x, w, b)
        dx, dw, db = vjp(dy)
        rx, rw, rb = ref.dense_grads_ref(x, w, b, dy, activation)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(rx), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(dw), np.asarray(rw), rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(db), np.asarray(rb), rtol=1e-4, atol=1e-4)

    def test_grad_through_loss_matches_autodiff_of_ref(self):
        """End-to-end: grad of a scalar loss through the Pallas dense must
        equal grad through the pure-jnp reference implementation."""
        rng = np.random.default_rng(4)
        x, w, b = rand(rng, 8, 20), rand(rng, 20, 10), rand(rng, 10)

        def loss_pallas(w, b):
            return jnp.sum(dense(x, w, b, "relu") ** 2)

        def loss_ref(w, b):
            return jnp.sum(ref.dense_ref(x, w, b, "relu") ** 2)

        gp = jax.grad(loss_pallas, argnums=(0, 1))(w, b)
        gr = jax.grad(loss_ref, argnums=(0, 1))(w, b)
        for a, c in zip(gp, gr):
            np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                       rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 48), k=st.integers(1, 48), n=st.integers(1, 48),
           seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_forward(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
        np.testing.assert_allclose(
            np.asarray(dense(x, w, b, "relu")),
            np.asarray(ref.dense_ref(x, w, b, "relu")),
            rtol=2e-4, atol=2e-4)


class TestAggregate:
    @pytest.mark.parametrize("n,p", [(2, 64), (16, 2410), (16, 61706), (7, 999)])
    def test_matches_ref(self, n, p):
        rng = np.random.default_rng(5)
        stack, w = rand(rng, n, p), rand(rng, n)
        np.testing.assert_allclose(
            np.asarray(aggregate(stack, w)),
            np.asarray(ref.aggregate_ref(stack, w)),
            rtol=1e-4, atol=1e-4)

    def test_zero_padded_slots_are_inert(self):
        """The coordinator pads unused slots with zero weight — the result
        must equal aggregation over only the live rows."""
        rng = np.random.default_rng(6)
        live = rand(rng, 5, 301)
        stack = jnp.concatenate([live, rand(rng, 11, 301)], axis=0)
        w_live = jnp.asarray(np.random.default_rng(7).random(5, dtype=np.float32))
        w = jnp.concatenate([w_live, jnp.zeros(11, jnp.float32)])
        np.testing.assert_allclose(
            np.asarray(aggregate(stack, w)),
            np.asarray(ref.aggregate_ref(live, w_live)),
            rtol=1e-4, atol=1e-4)

    def test_convexity_preserved(self):
        """A convex combination of identical vectors is the vector itself."""
        v = jnp.linspace(-2, 2, 137, dtype=jnp.float32)
        stack = jnp.tile(v[None, :], (4, 1))
        w = jnp.asarray([0.1, 0.2, 0.3, 0.4], jnp.float32)
        np.testing.assert_allclose(np.asarray(aggregate(stack, w)), np.asarray(v),
                                   rtol=1e-5, atol=1e-5)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(1, 24), p=st.integers(1, 4096), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, n, p, seed):
        rng = np.random.default_rng(seed)
        stack, w = rand(rng, n, p), rand(rng, n)
        np.testing.assert_allclose(
            np.asarray(aggregate(stack, w)),
            np.asarray(w) @ np.asarray(stack),
            rtol=2e-4, atol=2e-4)


class TestSgd:
    @pytest.mark.parametrize("p", [1, 64, 2410, 61706, 8193])
    def test_matches_ref(self, p):
        rng = np.random.default_rng(8)
        w, g = rand(rng, p), rand(rng, p)
        lr = jnp.asarray([0.05], jnp.float32)
        np.testing.assert_allclose(
            np.asarray(sgd_update(w, g, lr)),
            np.asarray(ref.sgd_ref(w, g, lr)),
            rtol=1e-6, atol=1e-6)

    def test_zero_lr_is_identity(self):
        rng = np.random.default_rng(9)
        w, g = rand(rng, 500), rand(rng, 500)
        out = sgd_update(w, g, jnp.asarray([0.0], jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(w))

    @settings(max_examples=15, deadline=None)
    @given(p=st.integers(1, 20000), lr=st.floats(0.0, 1.0), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_sweep(self, p, lr, seed):
        rng = np.random.default_rng(seed)
        w, g = rand(rng, p), rand(rng, p)
        out = sgd_update(w, g, jnp.asarray([lr], jnp.float32))
        np.testing.assert_allclose(
            np.asarray(out), np.asarray(w) - np.float32(lr) * np.asarray(g),
            rtol=1e-5, atol=1e-5)

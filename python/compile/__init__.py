"""Build-time compile path: L2 JAX models + L1 Pallas kernels + AOT lowering.

Nothing in this package is imported at runtime — `make artifacts` runs
`compile.aot` once, and the Rust coordinator only touches the HLO text and
manifest it emits.
"""

"""L2 training graphs: loss, SGD train step (paper Eq. 3–4), chunked
multi-step training (one PJRT call = S SGD steps via lax.scan), and
evaluation. All entry points take/return the flat parameter vector and are
AOT-lowered by ``aot.py``.
"""

import jax
import jax.numpy as jnp

from .kernels.sgd import sgd_update
from .models import ModelSpec, apply_model

# steps folded into one train_chunk call (fixed at AOT time)
CHUNK_STEPS = 4


def cross_entropy(logits, labels_f):
    """Mean softmax cross-entropy; labels arrive as f32 class ids."""
    labels = labels_f.astype(jnp.int32)
    logz = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logz, axis=-1))


def make_loss(spec: ModelSpec):
    def loss_fn(flat, x, y):
        return cross_entropy(apply_model(spec, flat, x), y)
    return loss_fn


def make_train_step(spec: ModelSpec):
    """(params[P], x[B,D], y[B], lr[1]) -> (params'[P], loss[])."""
    loss_fn = make_loss(spec)

    def train_step(flat, x, y, lr):
        loss, grad = jax.value_and_grad(loss_fn)(flat, x, y)
        new = sgd_update(flat, grad, lr)
        return new, loss

    return train_step


def make_train_chunk(spec: ModelSpec, steps: int = CHUNK_STEPS):
    """(params[P], xs[S,B,D], ys[S,B], lr[1]) -> (params'[P], mean_loss[]).

    S consecutive SGD steps in one executable — amortises the PJRT call
    and keeps the whole loop inside XLA where it fuses.
    """
    loss_fn = make_loss(spec)

    def train_chunk(flat, xs, ys, lr):
        def step(carry, batch):
            x, y = batch
            loss, grad = jax.value_and_grad(loss_fn)(carry, x, y)
            return sgd_update(carry, grad, lr), loss

        new, losses = jax.lax.scan(step, flat, (xs, ys), length=steps)
        return new, jnp.mean(losses)

    return train_chunk


def make_eval_step(spec: ModelSpec):
    """(params[P], x[B,D], y[B]) -> (loss[], correct[]) with correct = #hits."""
    def eval_step(flat, x, y):
        logits = apply_model(spec, flat, x)
        loss = cross_entropy(logits, y)
        pred = jnp.argmax(logits, axis=-1)
        correct = jnp.sum((pred == y.astype(jnp.int32)).astype(jnp.float32))
        return loss, correct

    return eval_step

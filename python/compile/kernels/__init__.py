"""Layer-1 Pallas kernels (build-time only; lowered into the L2 HLO)."""

from .aggregate import aggregate
from .dense import dense
from .matmul import matmul
from .sgd import sgd_update

__all__ = ["aggregate", "dense", "matmul", "sgd_update"]

"""L1 Pallas kernel: fused dense layer ``act(x @ W + b)`` with a custom VJP.

Forward fuses the bias add and activation into the matmul tile while the
output block is still VMEM-resident (one HBM round-trip instead of three).
Backward is expressed with the same Pallas matmul kernel:

    dz = dy * act'(z)
    dx = dz @ W^T        (Pallas matmul)
    dW = x^T @ dz        (Pallas matmul)
    db = sum_rows(dz)

so the L1 kernel is on the hot path of both the forward and backward pass
of every dense layer in the model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_to, matmul


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation: str):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...][None, :]
    if activation == "relu":
        z = jnp.maximum(z, 0.0)
    o_ref[...] = z


def _dense_forward(x, w, b, activation: str, bm: int, bn: int):
    m, k = x.shape
    _, n = w.shape
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    bp = jnp.pad(b, (0, np_ - n)) if np_ != n else b
    out = pl.pallas_call(
        functools.partial(_dense_kernel, activation=activation),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation: str = "relu"):
    """Fused dense layer. ``activation`` in {"relu", "none"}."""
    return _dense_forward(x, w, b, activation, 128, 128)


def _dense_fwd(x, w, b, activation):
    y = _dense_forward(x, w, b, activation, 128, 128)
    return y, (x, w, y)


def _dense_bwd(activation, res, dy):
    x, w, y = res
    if activation == "relu":
        dz = dy * (y > 0.0).astype(dy.dtype)
    else:
        dz = dy
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)

"""L1 Pallas kernel: weighted parameter aggregation (paper Eq. 5 / Eq. 12).

Given a stack of client parameter vectors ``stack[N, P]`` and aggregation
weights ``w[N]`` (already normalised by the coordinator — data-size weights
for FedAvg, inverse-loss quality weights for FedHC), produce the aggregated
vector ``out[P] = w @ stack``.

Grid tiles the parameter axis: each program instance holds an (N, bp)
panel of the stack and the full weight vector in VMEM and contracts on the
MXU. N is fixed at AOT time (the coordinator zero-pads weights for smaller
clusters, which is exact since padded weights are 0).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_to

DEFAULT_BP = 4096


def _agg_kernel(stack_ref, w_ref, o_ref):
    # (N, bp) contracted with (N,) -> (bp,)
    o_ref[...] = jnp.dot(w_ref[...], stack_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bp",))
def aggregate(stack, w, bp: int = DEFAULT_BP):
    """``w @ stack`` for ``stack[N, P]``, ``w[N]`` → ``[P]``."""
    n, p = stack.shape
    assert w.shape == (n,)
    bp = min(bp, _ceil_to(p, 8))
    pp = _ceil_to(p, bp)
    sp = jnp.pad(stack, ((0, 0), (0, pp - p))) if pp != p else stack
    out = pl.pallas_call(
        _agg_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((n, bp), lambda i: (0, i)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(sp, w)
    return out[:p] if pp != p else out

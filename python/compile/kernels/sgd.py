"""L1 Pallas kernel: fused SGD update ``w' = w - lr * g`` (paper Eq. 4).

Element-wise over the flat parameter vector, tiled so each program
instance updates one VMEM-resident block. The learning rate arrives as a
length-1 array so it stays a runtime input (no re-AOT for lr sweeps).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _ceil_to

DEFAULT_BP = 8192


def _sgd_kernel(w_ref, g_ref, lr_ref, o_ref):
    o_ref[...] = w_ref[...] - lr_ref[0] * g_ref[...]


@functools.partial(jax.jit, static_argnames=("bp",))
def sgd_update(w, g, lr, bp: int = DEFAULT_BP):
    """``w - lr[0] * g`` for flat f32 vectors ``w``, ``g`` and ``lr[1]``."""
    (p,) = w.shape
    assert g.shape == (p,)
    assert lr.shape == (1,)
    bp = min(bp, _ceil_to(p, 8))
    pp = _ceil_to(p, bp)
    wp = jnp.pad(w, (0, pp - p)) if pp != p else w
    gp = jnp.pad(g, (0, pp - p)) if pp != p else g
    out = pl.pallas_call(
        _sgd_kernel,
        grid=(pp // bp,),
        in_specs=[
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((bp,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((pp,), jnp.float32),
        interpret=True,
    )(wp, gp, lr)
    return out[:p] if pp != p else out

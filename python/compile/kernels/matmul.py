"""L1 Pallas kernel: tiled matrix multiply.

The workhorse behind every dense layer in the L2 model (forward *and*
backward — see ``dense.py``). The grid tiles the output matrix; each
program instance keeps an (bm, K) row-panel of ``x`` and a (K, bn)
column-panel of ``w`` resident in VMEM and contracts them on the MXU
(``jnp.dot`` with float32 accumulation).

TPU mapping (DESIGN.md §Hardware-Adaptation): block sizes default to
128×128 — the MXU systolic-array native tile — and the K panel streams
through VMEM via the BlockSpec index map. On this CPU image the kernel
runs under ``interpret=True`` (real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute); numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile. Shapes smaller than a tile collapse to a single program
# instance (the wrapper pads, see `matmul`).
DEFAULT_BM = 128
DEFAULT_BN = 128


def _matmul_kernel(x_ref, w_ref, o_ref):
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("bm", "bn"))
def matmul(x, w, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN):
    """``x @ w`` for 2-D float32 operands via the Pallas kernel.

    Arbitrary (M, K) @ (K, N): operands are zero-padded to tile multiples,
    the kernel runs on the padded grid, and the result is sliced back.
    Zero padding is exact for matmul (no renormalisation needed).
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    mp, np_ = _ceil_to(m, bm), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, 0))) if mp != m else x
    wp = jnp.pad(w, ((0, 0), (0, np_ - n))) if np_ != n else w
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


def vmem_bytes(m: int, k: int, n: int, bm: int = DEFAULT_BM, bn: int = DEFAULT_BN) -> int:
    """Estimated per-instance VMEM footprint of the kernel in bytes
    (x panel + w panel + output tile, f32). Used by the §Perf analysis."""
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    return 4 * (bm * k + k * bn + bm * bn)

"""Pure-jnp oracles for every L1 kernel — the build-time correctness signal.

Each function computes the same quantity as its Pallas counterpart with
plain jax.numpy; pytest asserts allclose across shape/dtype sweeps
(``python/tests/test_kernels.py``).
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dense_ref(x, w, b, activation: str = "relu"):
    z = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if activation == "relu":
        z = jnp.maximum(z, 0.0)
    return z


def dense_grads_ref(x, w, b, dy, activation: str = "relu"):
    """Reference VJP of the dense layer."""
    z = jnp.dot(x, w) + b[None, :]
    if activation == "relu":
        dz = dy * (z > 0.0).astype(dy.dtype)
    else:
        dz = dy
    return dz @ w.T, x.T @ dz, jnp.sum(dz, axis=0)


def aggregate_ref(stack, w):
    return jnp.dot(w, stack, preferred_element_type=jnp.float32)


def sgd_ref(w, g, lr):
    return w - lr[0] * g

"""L2 MAML graphs (paper §III-C, Eq. 16–17).

First-order MAML (FOMAML): the inner loop adapts the global model on the
satellite's support data (Eq. 16, via the Pallas SGD kernel); the outer
meta-update applies the gradient of the *query* loss evaluated at the
adapted parameters (Eq. 17 with the first-order approximation — the
standard practical choice; second-order terms are dropped, which Finn et
al. showed costs little accuracy and which avoids double-backward through
the custom-VJP dense kernels).

The coordinator calls ``maml_step`` once per newly-(re)assigned satellite
after a re-clustering event, using the new cluster PS's recent batch as the
support set and the satellite's own data as the query set.
"""

import jax
import jax.numpy as jnp

from .kernels.sgd import sgd_update
from .models import ModelSpec
from .train import make_loss


def make_maml_step(spec: ModelSpec):
    """(params[P], sx[B,D], sy[B], qx[B,D], qy[B], alpha[1], beta[1])
    -> (params'[P], query_loss[])."""
    loss_fn = make_loss(spec)

    def maml_step(flat, sx, sy, qx, qy, alpha, beta):
        # inner-loop adaptation on the support task (Eq. 16)
        g_inner = jax.grad(loss_fn)(flat, sx, sy)
        adapted = sgd_update(flat, g_inner, alpha)
        # outer meta-update from the query loss at the adapted params (Eq. 17, FO)
        q_loss, g_outer = jax.value_and_grad(loss_fn)(adapted, qx, qy)
        new = sgd_update(flat, g_outer, beta)
        return new, q_loss

    return maml_step

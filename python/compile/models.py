"""L2 model definitions: LeNet-5 and an MLP over flat parameter vectors.

Parameters cross the Rust↔HLO boundary as a single flat f32 vector, so the
model is defined by a *spec*: an ordered list of (name, shape) arrays plus
pure functions ``apply(flat_params, x_flat) -> logits``. Convolutions use
``lax.conv_general_dilated`` (XLA-native, fused by the compiler); every
dense layer goes through the L1 Pallas ``dense`` kernel, which therefore
sits on the forward AND backward hot path of all three model variants.
"""

import math
from typing import List, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import dense


class ModelSpec:
    """Ordered parameter layout + apply function for one model variant."""

    def __init__(self, name: str, input_chw: Tuple[int, int, int], classes: int,
                 shapes: List[Tuple[str, Tuple[int, ...]]], batch: int):
        self.name = name
        self.input_chw = input_chw
        self.classes = classes
        self.shapes = shapes
        self.batch = batch
        self.sizes = [int(math.prod(s)) for _, s in shapes]
        self.param_count = sum(self.sizes)

    def unflatten(self, flat):
        """Split the flat vector into the named arrays."""
        out = {}
        off = 0
        for (name, shape), size in zip(self.shapes, self.sizes):
            out[name] = flat[off:off + size].reshape(shape)
            off += size
        return out

    def init(self, seed: int):
        """He-uniform init, returned as the flat vector (numpy for AOT dump)."""
        key = jax.random.PRNGKey(seed)
        parts = []
        for name, shape in self.shapes:
            key, sub = jax.random.split(key)
            if name.endswith("_b"):
                parts.append(jnp.zeros(shape, jnp.float32))
            else:
                fan_in = int(math.prod(shape[:-1])) if len(shape) > 1 else shape[0]
                bound = math.sqrt(6.0 / max(fan_in, 1))
                parts.append(jax.random.uniform(sub, shape, jnp.float32, -bound, bound))
        return jnp.concatenate([p.reshape(-1) for p in parts])


def _conv(x, w, b):
    """NCHW valid conv + bias + relu."""
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    y = y + b[None, :, None, None]
    return jnp.maximum(y, 0.0)


def _avgpool2(x):
    """2x2 average pool, NCHW."""
    return lax.reduce_window(
        x, 0.0, lax.add, (1, 1, 2, 2), (1, 1, 2, 2), "VALID") * 0.25


def lenet_spec(name: str, in_ch: int, side: int, batch: int) -> ModelSpec:
    """LeNet-5 (paper's model): conv(6,5x5) → pool → conv(16,5x5) → pool →
    fc120 → fc84 → fc10. 61,706 params for MNIST geometry."""
    s1 = side - 4          # after conv1 (valid 5x5)
    s2 = s1 // 2           # after pool
    s3 = s2 - 4            # after conv2
    s4 = s3 // 2           # after pool
    flat = 16 * s4 * s4
    shapes = [
        ("conv1_w", (6, in_ch, 5, 5)), ("conv1_b", (6,)),
        ("conv2_w", (16, 6, 5, 5)), ("conv2_b", (16,)),
        ("fc1_w", (flat, 120)), ("fc1_b", (120,)),
        ("fc2_w", (120, 84)), ("fc2_b", (84,)),
        ("fc3_w", (84, 10)), ("fc3_b", (10,)),
    ]
    return ModelSpec(name, (in_ch, side, side), 10, shapes, batch)


def mlp_spec(name: str, in_dim: int, hidden: int, batch: int, side: int) -> ModelSpec:
    shapes = [
        ("fc1_w", (in_dim, hidden)), ("fc1_b", (hidden,)),
        ("fc2_w", (hidden, 10)), ("fc2_b", (10,)),
    ]
    return ModelSpec(name, (1, side, side), 10, shapes, batch)


def apply_model(spec: ModelSpec, flat, x_flat):
    """Forward pass: ``x_flat[B, C*H*W]`` → logits ``[B, 10]``."""
    p = spec.unflatten(flat)
    b = x_flat.shape[0]
    c, h, w = spec.input_chw
    if spec.name.endswith("mlp"):
        y = dense(x_flat, p["fc1_w"], p["fc1_b"], "relu")
        return dense(y, p["fc2_w"], p["fc2_b"], "none")
    x = x_flat.reshape(b, c, h, w)
    x = _avgpool2(_conv(x, p["conv1_w"], p["conv1_b"]))
    x = _avgpool2(_conv(x, p["conv2_w"], p["conv2_b"]))
    x = x.reshape(b, -1)
    x = dense(x, p["fc1_w"], p["fc1_b"], "relu")
    x = dense(x, p["fc2_w"], p["fc2_b"], "relu")
    return dense(x, p["fc3_w"], p["fc3_b"], "none")


# The three variants the experiments use. Batch sizes: paper uses 64;
# tiny_mlp is the fast-test variant.
VARIANTS = {
    "tiny_mlp": mlp_spec("tiny_mlp", 64, 32, 16, 8),
    "mnist_lenet": lenet_spec("mnist_lenet", 1, 28, 64),
    "cifar_lenet": lenet_spec("cifar_lenet", 3, 32, 64),
}

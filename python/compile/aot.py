"""AOT lowering: every L2 entry point × model variant → HLO text + manifest.

HLO *text* (not ``.serialize()``) is the interchange format: the image's
xla_extension 0.5.1 rejects jax≥0.5's 64-bit-instruction-id protos, while
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs under ``--out`` (default ``../artifacts``):
  <variant>_<entry>.hlo.txt   one per entry point
  init_<variant>.bin          initial flat parameters, little-endian f32
  manifest.json               shapes/dtypes/param counts for the Rust loader

Run via ``make artifacts``; python never runs after that.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .maml import make_maml_step
from .models import VARIANTS, ModelSpec
from .train import CHUNK_STEPS, make_eval_step, make_train_chunk, make_train_step
from .kernels.aggregate import aggregate

# aggregation stack height fixed at AOT time (coordinator zero-pads)
AGG_SLOTS = 16


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec_f32(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def entry_points(spec: ModelSpec):
    """(name, fn, input_shapes, output_shapes) per entry point."""
    p = spec.param_count
    b = spec.batch
    d = spec.input_chw[0] * spec.input_chw[1] * spec.input_chw[2]
    s = CHUNK_STEPS
    return [
        ("train_step", make_train_step(spec),
         [(p,), (b, d), (b,), (1,)], [(p,), ()]),
        ("train_chunk", make_train_chunk(spec),
         [(p,), (s, b, d), (s, b), (1,)], [(p,), ()]),
        ("eval_step", make_eval_step(spec),
         [(p,), (b, d), (b,)], [(), ()]),
        ("maml_step", make_maml_step(spec),
         [(p,), (b, d), (b,), (b, d), (b,), (1,), (1,)], [(p,), ()]),
        ("aggregate", lambda stack, w: (aggregate(stack, w),),
         [(AGG_SLOTS, p), (AGG_SLOTS,)], [(p,)]),
    ]


def lower_variant(spec: ModelSpec, out_dir: str, manifest: dict) -> None:
    entries = {}
    for name, fn, in_shapes, out_shapes in entry_points(spec):
        args = [spec_f32(*s) for s in in_shapes]
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{spec.name}_{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[name] = {
            "file": fname,
            "inputs": [list(s) for s in in_shapes],
            "outputs": [list(s) for s in out_shapes],
        }
        print(f"  {fname}: {len(text)} chars", file=sys.stderr)

    init = spec.init(seed=0)
    init_file = f"init_{spec.name}.bin"
    with open(os.path.join(out_dir, init_file), "wb") as f:
        import numpy as np
        f.write(np.asarray(init, dtype="<f4").tobytes())

    manifest["variants"][spec.name] = {
        "param_count": spec.param_count,
        "batch": spec.batch,
        "chunk_steps": CHUNK_STEPS,
        "agg_slots": AGG_SLOTS,
        "input_chw": list(spec.input_chw),
        "classes": spec.classes,
        "init_file": init_file,
        "entries": entries,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--variants", default=",".join(VARIANTS.keys()),
                    help="comma-separated subset to lower")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    # merge with an existing manifest so per-variant lowering composes
    manifest_path = os.path.join(args.out, "manifest.json")
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    else:
        manifest = {"format": 1, "chunk_steps": CHUNK_STEPS,
                    "agg_slots": AGG_SLOTS, "variants": {}}
    for name in args.variants.split(","):
        spec = VARIANTS[name]
        print(f"lowering {name} (P={spec.param_count})", file=sys.stderr)
        lower_variant(spec, args.out, manifest)
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {args.out}/manifest.json", file=sys.stderr)


if __name__ == "__main__":
    main()

//! C-FedAvg baseline (§IV-A, [7]): "all data collected from each client is
//! transmitted to a designated central satellite server for centralized
//! learning."
//!
//! Cost structure per round: satellites continuously collect data, so each
//! round every client ships its current shard to the central satellite
//! (time = slowest upload, energy = Eq. 8 over the raw-data payloads) and
//! the central node then runs its training epoch *sequentially* over the
//! union dataset (time + Eq. 9 energy on one CPU — no cluster parallelism,
//! which is exactly the inefficiency the paper's hierarchy removes).
//! Independent of K by construction — Table I reports one column
//! replicated across K.

use crate::config::AggregationMode;
use crate::coordinator::fedhc::RunResult;
use crate::coordinator::round::{data_upload_with, throttle_cpu, upload_cost};
use crate::coordinator::stages::{EngineLocalTrain, LocalTrainStage, RoundPools};
use crate::coordinator::trial::Trial;
use crate::data::Dataset;
use crate::fl::client::SatClient;
use crate::fl::evaluate::evaluate;
use crate::network::retry::transfer_with_retries;
use crate::network::Payload;
use crate::sim::engine::Engine;
use crate::sim::scenario::CORRUPT_GROUND_SALT;
use crate::util::rng::stream_seed;
use crate::util::Rng;
use anyhow::Result;

/// Pick the central satellite: the client nearest any ground station at
/// t=0 (a well-connected hub, mirroring "designated central server").
fn pick_central(trial: &Trial) -> usize {
    let positions = trial.positions();
    let t = trial.clock.now();
    (0..trial.clients.len())
        .min_by(|&a, &b| {
            let da = trial
                .ground
                .iter()
                .map(|g| positions[a].dist(g.eci(t)))
                .fold(f64::INFINITY, f64::min);
            let db = trial
                .ground
                .iter()
                .map(|g| positions[b].dist(g.eci(t)))
                .fold(f64::INFINITY, f64::min);
            da.partial_cmp(&db).unwrap()
        })
        .unwrap()
}

/// Run centralised FedAvg to target accuracy or the round budget.
pub fn run_cfedavg(trial: &mut Trial) -> Result<RunResult> {
    let cfg = trial.cfg.clone();
    let rt = trial.rt;
    let engine = Engine::new(cfg.workers);
    let pools = RoundPools::new(rt);
    let retry = cfg.retry_policy();
    let mut central = pick_central(trial);
    // raw-data plane: one sample on the wire is its f32 features plus a
    // one-byte label, billed through the same [`Payload`] seam as model
    // uploads (`--compress` shrinks *parameter* uploads only — raw data
    // ships dense, which is exactly the cost the hierarchy removes)
    let sample_payload = Payload {
        values: trial.clients[0].shard.kind.sample_len(),
        value_bits: 32,
        indices: 0,
        index_bits: 0,
        header_bytes: 1,
    };
    let bits_per_sample = sample_payload.bits();
    // recovery plane: a central failover ships the model checkpoint to the
    // promoted satellite, dense on the wire (raw-data collection has no
    // compressed parameter plane to ride)
    let model_payload = Payload {
        values: rt.spec.param_count,
        value_bits: 32,
        indices: 0,
        index_bits: 0,
        header_bytes: 0,
    };

    // union dataset at the central node
    let kind = trial.clients[0].shard.kind;
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for c in &trial.clients {
        images.extend_from_slice(&c.shard.images);
        labels.extend_from_slice(&c.shard.labels);
    }
    let union = Dataset::new(kind, images, labels);
    let mut cpu_hz = trial.clients[central].cpu_hz;
    // every client starts from the same init, so the trial-level copy is
    // the central model too (and the only source in the bounded-memory
    // mode, where clients hold no resident parameters)
    let init = trial.init.clone();
    let mut node = SatClient::new(central, union, init, cpu_hz);
    // the central epoch reuses the shared local-training stage (same
    // stateless (seed, round, sat) RNG discipline as the clustered runs)
    let train_stage = EngineLocalTrain;

    // ---- per-round: raw-data collection upload, then centralised epochs
    let mut converged_at = None;
    for round in 1..=cfg.rounds {
        let positions = trial.positions();
        // scenario plane: the centralised baseline observes the same fault
        // trajectory as the clustered methods — unreachable clients skip
        // their upload, degraded ISLs stretch it, and a round in which the
        // central satellite itself is down does no collection or training
        // (the evaluation cadence below still runs on the stale model, so
        // record counts and convergence checks stay comparable)
        let avail = trial.scenario.advance_round(round as u64, &positions);
        trial.ledger.add_faults(avail.faults_injected);
        // recovery plane: when any sender sees a nonzero effective BER the
        // shipments below run detect/retry/backoff; otherwise the plane is
        // skipped entirely (no RNG streams, no float ops) and the nominal
        // accounting stays bit-identical
        let noisy = cfg.ber > 0.0 || avail.ber.iter().any(|&b| b > 0.0);
        // recovery plane: the central *server process* can crash mid-run
        // (`Fault::PsFailure`) — the satellite survives and still holds
        // its model checkpoint, and the union archive is long since
        // collected, so the role deterministically moves to the live
        // client nearest any ground station (the criterion that picked
        // the original central) and the checkpoint ships to it, billed as
        // one dense model transfer. No live candidate ⇒ the round skips
        // collection and training exactly like an unreachable central.
        if avail.ps_failed[central] {
            let t = trial.clock.now();
            let gs_dist = |i: usize| -> f64 {
                trial
                    .ground
                    .iter()
                    .map(|g| positions[i].dist(g.eci(t)))
                    .fold(f64::INFINITY, f64::min)
            };
            let candidate = (0..trial.clients.len())
                .filter(|&i| i != central && !avail.ps_failed[i] && !avail.unreachable[i])
                .min_by(|&a, &b| gs_dist(a).total_cmp(&gs_dist(b)));
            if let Some(next) = candidate {
                let d = positions[central].dist(positions[next]);
                let t_x = trial.link.comm_time(model_payload.bits(), d);
                trial
                    .ledger
                    .add_energy(trial.energy.tx_energy(model_payload.bits(), d));
                trial
                    .ledger
                    .add_wire_bytes(trial.link.upload_bytes(&model_payload));
                trial.ledger.add_failover();
                trial.ledger.add_time(t_x);
                trial.clock.advance(t_x);
                central = next;
                cpu_hz = trial.clients[central].cpu_hz;
                // the central epoch now trains on the promoted satellite:
                // its CPU rate and its `(seed, round, sat)` draw stream
                node.sat = central;
                node.cpu_hz = cpu_hz;
            }
        }
        if !avail.unreachable[central] && !avail.ps_failed[central] {
            // every reachable client ships the data it collected this round
            let uploads: Vec<(usize, usize, crate::orbit::Vec3, f64)> = trial
                .clients
                .iter()
                .enumerate()
                .filter(|(i, _)| *i != central && !avail.unreachable[*i])
                .map(|(i, c)| (i, c.data_size(), positions[i], avail.link_factor[i]))
                .collect();
            let mut resent_samples = 0usize;
            // per-uploader link costs fanned out on the engine (order-stable)
            let (t_up, e_up) = if !noisy && cfg.aggregation == AggregationMode::Sync {
                let legacy: Vec<(usize, crate::orbit::Vec3, f64)> =
                    uploads.iter().map(|&(_, s, p, f)| (s, p, f)).collect();
                data_upload_with(
                    &engine,
                    &trial.link,
                    &trial.energy,
                    &legacy,
                    bits_per_sample,
                    positions[central],
                )
            } else {
                // per-uploader costs on the coordinator thread; under
                // noise each shipment stretches to its attempts plus
                // backoff waits drawn from its own `CORRUPT_GROUND_SALT`
                // stream (the direct-to-hub analogue of the member→PS
                // streams), with uplink energy billed once per attempt. A
                // shipment whose retries exhaust costs its full retry
                // time and energy but loses nothing from the union epoch
                // — the archive already holds the shard from earlier
                // collection rounds, so the degradation is pure Eq. 6/7
                // cost, not a learning-trajectory change.
                let costs: Vec<(f64, f64)> = uploads
                    .iter()
                    .map(|&(i, samples, pos, factor)| {
                        let (t_i, e_i) = upload_cost(
                            &trial.link,
                            &trial.energy,
                            samples,
                            pos,
                            factor,
                            bits_per_sample,
                            positions[central],
                        );
                        let eff_ber = if noisy { cfg.ber + avail.ber[i] } else { 0.0 };
                        if eff_ber > 0.0 {
                            let mut rng = Rng::new(stream_seed(
                                cfg.seed ^ CORRUPT_GROUND_SALT,
                                round as u64,
                                i as u64,
                            ));
                            let bits = samples as f64 * bits_per_sample;
                            let out =
                                transfer_with_retries(&retry, eff_ber, bits, t_i, &mut rng);
                            trial.ledger.add_retransmits(out.retransmits());
                            trial.ledger.add_corrupted_uploads(out.corrupted());
                            trial.ledger.add_retry_wait(out.wait_s);
                            resent_samples += samples * out.retransmits();
                            (out.total_time(t_i), e_i * out.attempts as f64)
                        } else {
                            (t_i, e_i)
                        }
                    })
                    .collect();
                if cfg.aggregation == AggregationMode::Sync {
                    // the sync barrier over the (stretched) shipments
                    let mut t_max = 0.0f64;
                    let mut e_total = 0.0f64;
                    for &(t_i, e_i) in &costs {
                        t_max = t_max.max(t_i);
                        e_total += e_i;
                    }
                    (t_max, e_total)
                } else {
                    // buffered/async collection: each shard arrives at its
                    // own offset and the central epoch starts at the
                    // goal-th arrival instead of the slowest upload
                    // (`--buffer-size`, 0 = wait for everyone — which is
                    // bit-for-bit the sync fold). Early arrivals idle
                    // until the start; later ones still join the union
                    // epoch but their data is one collection round stale.
                    // Energy is payload-determined and unchanged.
                    let mut e_total = 0.0f64;
                    for &(_, e_i) in &costs {
                        e_total += e_i;
                    }
                    let mut times: Vec<f64> = costs.iter().map(|&(t, _)| t).collect();
                    times.sort_by(f64::total_cmp);
                    let goal = if cfg.buffer_size == 0 {
                        times.len()
                    } else {
                        cfg.buffer_size.min(times.len())
                    };
                    let t_start = goal
                        .checked_sub(1)
                        .and_then(|i| times.get(i))
                        .copied()
                        .unwrap_or(0.0);
                    if !times.is_empty() {
                        for &t_i in &times {
                            if t_i <= t_start {
                                trial.ledger.add_idle(t_start - t_i);
                            } else {
                                trial.ledger.add_staleness(t_i - t_start, 1);
                            }
                        }
                        trial.ledger.add_buffered_merge();
                    }
                    (t_start, e_total)
                }
            };
            let round_samples: usize = uploads.iter().map(|&(_, s, _, _)| s).sum();
            trial.ledger.add_wire_bytes(
                trial.link.upload_bytes(&sample_payload)
                    * (round_samples + resent_samples) as f64,
            );
            trial.ledger.add_time(t_up);
            trial.ledger.add_energy(e_up);
            trial.clock.advance(t_up);

            let samples = {
                let mut models = [std::mem::take(&mut node.params)];
                let mut outs = train_stage.train(
                    &engine,
                    rt,
                    &cfg,
                    std::slice::from_ref(&node),
                    &models,
                    &[(0, 0)],
                    round as u64,
                    &pools,
                )?;
                let out = outs.pop().expect("central training job lost");
                // the trained pooled buffer becomes the node's model; the
                // pre-round vector goes back to the pool for the next round
                node.params = out.params;
                pools.params.put(std::mem::take(&mut models[0]));
                node.last_loss = out.mean_loss;
                node.rounds_trained += 1;
                out.samples
            };
            // Eq. 9 compute at the central node; one epoch is sequential
            // over the union data — no parallelism to exploit (the paper's
            // point). A scenario-plane slowdown throttles the effective
            // CPU rate via the shared helper (exact identity at 1.0)
            let cpu_eff = throttle_cpu(
                &trial.link,
                &mut trial.ledger,
                samples,
                cpu_hz,
                avail.compute_slowdown[central],
            );
            let t_cmp = trial.link.compute_time(samples, cpu_eff);
            trial.ledger.add_time(t_cmp);
            trial.ledger.add_energy(trial.energy.compute_energy(samples, cpu_eff));
            trial.clock.advance(t_cmp);
        }

        if round % cfg.eval_every == 0 || round == cfg.rounds {
            let eval = evaluate(rt, &node.params, &trial.test, cfg.eval_batches)?;
            trial.ledger.record(round, eval.accuracy, eval.loss, false);
            if let Some(target) = cfg.target_accuracy {
                if eval.accuracy >= target {
                    converged_at = Some((round, trial.ledger.time_s, trial.ledger.energy_j));
                    break;
                }
            }
        }
    }

    let final_accuracy = trial.ledger.best_accuracy();
    Ok(RunResult {
        name: "C-FedAvg",
        ledger: std::mem::take(&mut trial.ledger),
        converged_at,
        final_accuracy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::runtime::{Manifest, ModelRuntime};

    fn with_runtime<F: FnOnce(&Manifest, &ModelRuntime)>(f: F) {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        f(&m, &rt);
    }

    #[test]
    fn centralised_run_learns() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 8;
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_cfedavg(&mut trial).unwrap();
            assert_eq!(res.name, "C-FedAvg");
            let first = res.ledger.records.first().unwrap().accuracy;
            assert!(res.final_accuracy > first);
        });
    }

    #[test]
    fn upload_cost_precedes_training() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 1;
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_cfedavg(&mut trial).unwrap();
            // even the first record carries the data-upload time
            let first = res.ledger.records.first().unwrap();
            assert!(first.time_s > 0.0);
            assert!(first.energy_j > 0.0);
        });
    }

    /// The buffered collection plane: the auto goal (wait for every
    /// upload) is bit-for-bit the sync fold, with the waiting billed as
    /// idleness; a sub-goal start cuts collection time and marks the late
    /// shards stale — without changing the learning trajectory (the union
    /// epoch still trains on all collected data).
    #[test]
    fn buffered_collection_degenerates_to_sync_at_the_auto_goal() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.target_accuracy = None;
        let mut sync_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let sync = run_cfedavg(&mut sync_t).unwrap();
        cfg.aggregation = AggregationMode::Buffered;
        let mut buf_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let buffered = run_cfedavg(&mut buf_t).unwrap();
        assert_eq!(sync.ledger.time_s.to_bits(), buffered.ledger.time_s.to_bits());
        assert_eq!(sync.ledger.energy_j.to_bits(), buffered.ledger.energy_j.to_bits());
        assert_eq!(sync.final_accuracy.to_bits(), buffered.final_accuracy.to_bits());
        assert!(buffered.ledger.idle_s > 0.0, "waiting on the slowest upload is idleness");
        assert!(buffered.ledger.buffered_merges > 0);
        assert_eq!(buffered.ledger.stale_s, 0.0, "the auto goal leaves nothing late");
        cfg.buffer_size = 4;
        let mut sub_t = Trial::new(cfg, &m, &rt).unwrap();
        let sub = run_cfedavg(&mut sub_t).unwrap();
        assert!(
            sub.ledger.time_s < sync.ledger.time_s,
            "a sub-goal start must shorten collection: {} vs {}",
            sub.ledger.time_s,
            sync.ledger.time_s
        );
        assert!(sub.ledger.stale_s > 0.0, "late shards must register as stale");
        assert_eq!(
            sub.final_accuracy.to_bits(),
            sync.final_accuracy.to_bits(),
            "collection timing must not change the learning trajectory"
        );
    }

    #[test]
    fn costlier_than_fedhc_per_round() {
        with_runtime(|m, rt| {
            // same budget, same data: the centralised method's sequential
            // training + raw-data uploads must cost more simulated time
            // than FedHC's parallel clusters (the paper's headline claim)
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 6;
            cfg.target_accuracy = None;
            let mut t1 = Trial::new(cfg.clone(), m, rt).unwrap();
            let central = run_cfedavg(&mut t1).unwrap();
            let mut t2 = Trial::new(cfg, m, rt).unwrap();
            let fedhc = crate::coordinator::run_clustered(
                &mut t2,
                crate::coordinator::Strategy::fedhc(),
            )
            .unwrap();
            assert!(
                central.ledger.time_s > fedhc.ledger.time_s,
                "central {} s vs fedhc {} s",
                central.ledger.time_s,
                fedhc.ledger.time_s
            );
        });
    }
}

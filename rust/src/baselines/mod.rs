//! Comparison methods from §IV-A.
//!
//! * [`cfedavg`] — C-FedAvg [7]: raw client data is shipped to one central
//!   satellite which learns alone (the paper's centralised reference; flat
//!   across K by construction).
//! * H-BASE [11] and FedCE [12] share the clustered driver — see
//!   [`crate::coordinator::Strategy::hbase`] / [`Strategy::fedce`].

pub mod cfedavg;

pub use crate::coordinator::fedhc::Strategy;
pub use cfedavg::run_cfedavg;

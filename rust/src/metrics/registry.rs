//! Per-entity metrics registry: per-satellite and per-cluster counters
//! plus fixed-bucket histograms, populated by the coordinator while a
//! run executes.
//!
//! Where the [`super::Ledger`] answers "how much did the whole run
//! cost", the registry answers "*which* satellite or cluster is the
//! hotspot": per-satellite upload counts, retransmits, cumulative comm
//! time, wire bytes and relay hops; per-cluster merge/failover/stale
//! counts and contact-window seconds; and run-wide histograms over comm
//! time, retry counts, staleness, hop counts, and transfer bytes. The
//! bucket edges are fixed at compile time so two runs' dumps are always
//! comparable bucket-for-bucket.
//!
//! Disabled (the default), every record call is an inlined `None` check
//! — no allocation, no counters, goldens untouched. `fedhc run
//! --metrics <path>` enables it, dumps [`MetricsRegistry::to_json`] to
//! `<path>`, and prints the top-k hotspot table
//! (`report::format_hotspots`) after the run summary.
//!
//! ```
//! use fedhc::metrics::registry::MetricsRegistry;
//! let mut reg = MetricsRegistry::disabled();
//! reg.record_upload(3, 0.5, 1e4, 0, 1); // no-op while disabled
//! assert!(!reg.is_enabled());
//! reg.enable(8, 2);
//! reg.record_upload(3, 0.5, 1e4, 1, 2);
//! assert_eq!(reg.sats()[3].uploads, 1);
//! ```

use crate::util::json::Json;

/// Histogram bucket edges (ascending). A value lands in bucket
/// `partition_point(edges, v >= e)`, so `counts` has `edges.len() + 1`
/// entries: `(-inf, e0), [e0, e1), ..., [e_last, +inf)`.
const COMM_S_EDGES: &[f64] = &[0.01, 0.1, 1.0, 10.0, 60.0];
const RETRY_EDGES: &[f64] = &[1.0, 2.0, 3.0, 4.0];
const STALENESS_EDGES: &[f64] = &[1.0, 2.0, 4.0, 8.0];
const HOPS_EDGES: &[f64] = &[2.0, 3.0, 4.0, 6.0];
const BYTES_EDGES: &[f64] = &[1e3, 1e4, 1e5, 1e6, 1e7];

/// A fixed-bucket histogram.
#[derive(Clone, Debug)]
pub struct Hist {
    edges: &'static [f64],
    counts: Vec<u64>,
}

impl Hist {
    fn new(edges: &'static [f64]) -> Self {
        Hist {
            edges,
            counts: vec![0; edges.len() + 1],
        }
    }

    #[inline]
    fn add(&mut self, v: f64) {
        let i = self.edges.partition_point(|&e| v >= e);
        self.counts[i] += 1;
    }

    /// Bucket counts, `edges.len() + 1` entries.
    pub fn counts(&self) -> &[u64] {
        &self.counts
    }

    /// Total samples recorded.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("edges", Json::arr_f64(self.edges)),
            (
                "counts",
                Json::Arr(self.counts.iter().map(|&c| Json::num(c as f64)).collect()),
            ),
        ])
    }
}

/// Per-satellite counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct SatStats {
    /// Uploads that reached (or attempted to reach) the PS.
    pub uploads: u64,
    /// Extra attempts beyond the first, summed over transfers.
    pub retransmits: u64,
    /// Cumulative simulated communication seconds (retries included).
    pub comm_s: f64,
    /// Wire bytes sent (every attempt bills a full payload).
    pub bytes: f64,
    /// ISL hops traversed by this satellite's uploads.
    pub hops: u64,
}

/// Per-cluster counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClusterStats {
    /// Aggregations folded into this cluster's model.
    pub merges: u64,
    /// PS fail-overs this cluster survived.
    pub failovers: u64,
    /// Merged contributions with integer staleness ≥ 1.
    pub stale_merges: u64,
    /// Ground contact-window seconds granted to this cluster.
    pub window_s: f64,
}

#[derive(Clone, Debug)]
struct RegistryInner {
    sats: Vec<SatStats>,
    clusters: Vec<ClusterStats>,
    comm_s: Hist,
    retries: Hist,
    staleness: Hist,
    hops: Hist,
    bytes: Hist,
}

/// The per-entity registry. `None` inner state means disabled: record
/// calls return immediately without touching memory.
#[derive(Clone, Debug, Default)]
pub struct MetricsRegistry {
    inner: Option<Box<RegistryInner>>,
}

impl MetricsRegistry {
    /// A disabled registry (the default on every trial).
    pub fn disabled() -> Self {
        MetricsRegistry { inner: None }
    }

    /// Allocate per-entity slots and start recording. Idempotent.
    pub fn enable(&mut self, n_sats: usize, n_clusters: usize) {
        if self.inner.is_none() {
            self.inner = Some(Box::new(RegistryInner {
                sats: vec![SatStats::default(); n_sats],
                clusters: vec![ClusterStats::default(); n_clusters],
                comm_s: Hist::new(COMM_S_EDGES),
                retries: Hist::new(RETRY_EDGES),
                staleness: Hist::new(STALENESS_EDGES),
                hops: Hist::new(HOPS_EDGES),
                bytes: Hist::new(BYTES_EDGES),
            }));
        }
    }

    /// Whether record calls count anything.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// One upload transfer by satellite `sat`: `comm_s` simulated
    /// seconds on the wire (retries included), `bytes` sent across all
    /// attempts, `retransmits` extra attempts, `hops` ISL edges.
    #[inline]
    pub fn record_upload(
        &mut self,
        sat: usize,
        comm_s: f64,
        bytes: f64,
        retransmits: usize,
        hops: usize,
    ) {
        if let Some(inner) = self.inner.as_mut() {
            if let Some(s) = inner.sats.get_mut(sat) {
                s.uploads += 1;
                s.retransmits += retransmits as u64;
                s.comm_s += comm_s;
                s.bytes += bytes;
                s.hops += hops as u64;
            }
            inner.comm_s.add(comm_s);
            inner.retries.add(retransmits as f64);
            inner.hops.add(hops as f64);
            inner.bytes.add(bytes);
        }
    }

    /// One aggregation folded into `cluster`'s model.
    #[inline]
    pub fn record_merge(&mut self, cluster: usize) {
        if let Some(inner) = self.inner.as_mut() {
            if let Some(c) = inner.clusters.get_mut(cluster) {
                c.merges += 1;
            }
        }
    }

    /// One merged contribution with integer staleness `tau`.
    #[inline]
    pub fn record_staleness(&mut self, cluster: usize, tau: f64) {
        if let Some(inner) = self.inner.as_mut() {
            inner.staleness.add(tau);
            if tau >= 1.0 {
                if let Some(c) = inner.clusters.get_mut(cluster) {
                    c.stale_merges += 1;
                }
            }
        }
    }

    /// One PS fail-over in `cluster`.
    #[inline]
    pub fn record_failover(&mut self, cluster: usize) {
        if let Some(inner) = self.inner.as_mut() {
            if let Some(c) = inner.clusters.get_mut(cluster) {
                c.failovers += 1;
            }
        }
    }

    /// `dur_s` seconds of ground contact window granted to `cluster`.
    #[inline]
    pub fn record_window(&mut self, cluster: usize, dur_s: f64) {
        if let Some(inner) = self.inner.as_mut() {
            if let Some(c) = inner.clusters.get_mut(cluster) {
                c.window_s += dur_s;
            }
        }
    }

    /// Per-satellite stats (empty while disabled).
    pub fn sats(&self) -> &[SatStats] {
        self.inner.as_ref().map_or(&[], |i| &i.sats)
    }

    /// Per-cluster stats (empty while disabled).
    pub fn clusters(&self) -> &[ClusterStats] {
        self.inner.as_ref().map_or(&[], |i| &i.clusters)
    }

    /// Run-wide histograms as `(name, hist)` pairs, fixed order.
    pub fn histograms(&self) -> Vec<(&'static str, &Hist)> {
        match self.inner.as_ref() {
            None => Vec::new(),
            Some(i) => vec![
                ("comm_s", &i.comm_s),
                ("retries", &i.retries),
                ("staleness", &i.staleness),
                ("hops", &i.hops),
                ("bytes", &i.bytes),
            ],
        }
    }

    /// The `--metrics <path>` dump: per-sat and per-cluster arrays
    /// (indexed by entity id) plus every histogram.
    pub fn to_json(&self) -> Json {
        let sats = Json::Arr(
            self.sats()
                .iter()
                .map(|s| {
                    Json::obj(vec![
                        ("uploads", Json::num(s.uploads as f64)),
                        ("retransmits", Json::num(s.retransmits as f64)),
                        ("comm_s", Json::num(s.comm_s)),
                        ("bytes", Json::num(s.bytes)),
                        ("hops", Json::num(s.hops as f64)),
                    ])
                })
                .collect(),
        );
        let clusters = Json::Arr(
            self.clusters()
                .iter()
                .map(|c| {
                    Json::obj(vec![
                        ("merges", Json::num(c.merges as f64)),
                        ("failovers", Json::num(c.failovers as f64)),
                        ("stale_merges", Json::num(c.stale_merges as f64)),
                        ("window_s", Json::num(c.window_s)),
                    ])
                })
                .collect(),
        );
        let hists = Json::Obj(
            self.histograms()
                .into_iter()
                .map(|(name, h)| (name.to_string(), h.to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("sats", sats),
            ("clusters", clusters),
            ("histograms", hists),
        ])
    }

    /// Indices of the `k` satellites with the most cumulative comm
    /// time, busiest first (ties break to the lower index).
    pub fn top_sats_by_comm(&self, k: usize) -> Vec<usize> {
        let sats = self.sats();
        let mut idx: Vec<usize> = (0..sats.len()).collect();
        idx.sort_by(|&a, &b| {
            sats[b]
                .comm_s
                .partial_cmp(&sats[a].comm_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_is_inert() {
        let mut reg = MetricsRegistry::disabled();
        reg.record_upload(0, 1.0, 10.0, 2, 1);
        reg.record_merge(0);
        reg.record_staleness(0, 3.0);
        reg.record_failover(0);
        reg.record_window(0, 5.0);
        assert!(!reg.is_enabled());
        assert!(reg.sats().is_empty());
        assert!(reg.clusters().is_empty());
        assert!(reg.histograms().is_empty());
    }

    #[test]
    fn counters_accumulate() {
        let mut reg = MetricsRegistry::disabled();
        reg.enable(4, 2);
        reg.record_upload(1, 0.5, 1e4, 0, 1);
        reg.record_upload(1, 1.5, 2e4, 2, 3);
        reg.record_merge(0);
        reg.record_staleness(0, 0.0);
        reg.record_staleness(0, 2.0);
        reg.record_failover(1);
        reg.record_window(1, 120.0);
        let s = &reg.sats()[1];
        assert_eq!(s.uploads, 2);
        assert_eq!(s.retransmits, 2);
        assert_eq!(s.hops, 4);
        assert!((s.comm_s - 2.0).abs() < 1e-12);
        assert!((s.bytes - 3e4).abs() < 1e-9);
        assert_eq!(reg.clusters()[0].merges, 1);
        assert_eq!(reg.clusters()[0].stale_merges, 1);
        assert_eq!(reg.clusters()[1].failovers, 1);
        assert!((reg.clusters()[1].window_s - 120.0).abs() < 1e-12);
        // out-of-range entities are ignored, not a panic
        reg.record_upload(99, 1.0, 1.0, 0, 0);
        reg.record_merge(99);
    }

    #[test]
    fn histogram_bucketing() {
        let mut h = Hist::new(&[1.0, 10.0]);
        h.add(0.5); // below first edge
        h.add(1.0); // exactly on an edge -> upper bucket
        h.add(5.0);
        h.add(100.0); // overflow bucket
        assert_eq!(h.counts(), &[1, 2, 1]);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn top_k_orders_by_comm_time() {
        let mut reg = MetricsRegistry::disabled();
        reg.enable(3, 1);
        reg.record_upload(0, 1.0, 1.0, 0, 0);
        reg.record_upload(1, 5.0, 1.0, 0, 0);
        reg.record_upload(2, 3.0, 1.0, 0, 0);
        assert_eq!(reg.top_sats_by_comm(2), vec![1, 2]);
        assert_eq!(reg.top_sats_by_comm(10), vec![1, 2, 0]);
    }

    #[test]
    fn json_dump_shape() {
        let mut reg = MetricsRegistry::disabled();
        reg.enable(2, 1);
        reg.record_upload(0, 0.25, 1e5, 1, 2);
        let j = reg.to_json();
        assert_eq!(j.get("sats").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("clusters").as_arr().unwrap().len(), 1);
        let h = j.get("histograms").get("comm_s");
        assert_eq!(h.get("edges").as_arr().unwrap().len(), 5);
        assert_eq!(h.get("counts").as_arr().unwrap().len(), 6);
        // the dump is valid JSON end to end
        let reparsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(&reparsed, &j);
    }
}

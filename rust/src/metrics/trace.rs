//! Sim-time structured tracing: spans and instants on the simulated
//! clock, exported as JSON-lines and Chrome `trace_event` JSON.
//!
//! The tracer records what the coordinator *scheduled*, on the
//! deterministic simulated timeline: round and cluster-stage spans,
//! per-transfer upload spans, retry/relay-hop/failover instants, ground
//! contact windows, merges, re-clusters, and evaluations. Every event is
//! keyed by `(t_sim, kind, entity)` with stable entity IDs (`run`,
//! `sat:<i>`, `cluster:<c>`, `gs:<g>`), appended in coordinator order —
//! sim times and fold orders are worker-count invariant, so a given
//! `--trace` file is byte-identical across `--workers 1|4`.
//!
//! Disabled (the default), every emit method is an inlined `None` check
//! that touches no memory: the steady-state round path stays
//! zero-allocation and committed goldens are byte-identical.
//!
//! Two exports from the same event list:
//! - [`Tracer::to_jsonl`] — one JSON object per line with `t` (sim
//!   seconds), `kind`, `entity`, and `dur` for spans; grep/jq friendly.
//! - [`Tracer::to_chrome`] — Chrome `trace_event` format (`ph:"X"`
//!   complete spans, `ph:"i"` instants, microsecond timestamps, one
//!   named pseudo-thread per entity), loadable directly in Perfetto or
//!   `chrome://tracing`.
//!
//! ```
//! use fedhc::metrics::trace::{Entity, Tracer};
//! let mut tr = Tracer::disabled();
//! tr.instant(1.0, "merge", Entity::Cluster(0)); // no-op while disabled
//! assert!(tr.is_empty());
//! tr.enable();
//! tr.span(0.0, 2.5, "round", Entity::Run);
//! tr.instant(1.5, "retry", Entity::Sat(7));
//! assert_eq!(tr.to_jsonl().lines().count(), 2);
//! ```

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// Who an event belongs to. IDs are stable across runs and worker
/// counts: `run`, `sat:<global satellite index>`, `cluster:<label>`,
/// `gs:<ground station index>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Entity {
    /// The whole run (rounds, re-clusters, evaluations).
    Run,
    /// One satellite, by global constellation index.
    Sat(usize),
    /// One cluster, by label.
    Cluster(usize),
    /// One ground station, by station index.
    Ground(usize),
}

impl Entity {
    /// The stable ID string.
    pub fn id(self) -> String {
        match self {
            Entity::Run => "run".to_string(),
            Entity::Sat(i) => format!("sat:{i}"),
            Entity::Cluster(c) => format!("cluster:{c}"),
            Entity::Ground(g) => format!("gs:{g}"),
        }
    }
}

/// One recorded event: an instant (`dur == None`) or a span.
#[derive(Clone, Copy, Debug)]
pub struct TraceEvent {
    /// Simulated start time, seconds.
    pub t: f64,
    /// Span duration in simulated seconds; `None` for instants.
    pub dur: Option<f64>,
    /// Event kind (static snake_case vocabulary, e.g. `upload`,
    /// `retry`, `relay_hop`, `window_open`, `merge`, `failover`).
    pub kind: &'static str,
    /// Owning entity.
    pub entity: Entity,
}

/// The sim-time tracer. `None` inner state means disabled: emit calls
/// return immediately without allocating.
#[derive(Clone, Debug, Default)]
pub struct Tracer {
    inner: Option<Vec<TraceEvent>>,
}

impl Tracer {
    /// A disabled tracer (the default on every [`crate::coordinator::Trial`]).
    pub fn disabled() -> Self {
        Tracer { inner: None }
    }

    /// Start recording. Idempotent; already-recorded events are kept.
    pub fn enable(&mut self) {
        if self.inner.is_none() {
            self.inner = Some(Vec::new());
        }
    }

    /// Whether emit calls record anything.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Number of recorded events (0 while disabled).
    pub fn len(&self) -> usize {
        self.inner.as_ref().map_or(0, Vec::len)
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The recorded events, in emission order.
    pub fn events(&self) -> &[TraceEvent] {
        self.inner.as_deref().unwrap_or(&[])
    }

    /// Record a span `[t, t + dur]` in simulated seconds.
    #[inline]
    pub fn span(&mut self, t: f64, dur: f64, kind: &'static str, entity: Entity) {
        if let Some(ev) = self.inner.as_mut() {
            ev.push(TraceEvent {
                t,
                dur: Some(dur),
                kind,
                entity,
            });
        }
    }

    /// Record an instantaneous event at simulated time `t`.
    #[inline]
    pub fn instant(&mut self, t: f64, kind: &'static str, entity: Entity) {
        if let Some(ev) = self.inner.as_mut() {
            ev.push(TraceEvent {
                t,
                dur: None,
                kind,
                entity,
            });
        }
    }

    /// JSON-lines export: one object per event, emission order, keys
    /// `t`/`kind`/`entity` (+ `dur` on spans). Rust's shortest-roundtrip
    /// float formatting keeps the bytes deterministic.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for ev in self.events() {
            let _ = write!(
                out,
                "{{\"t\":{},\"kind\":\"{}\",\"entity\":\"{}\"",
                ev.t,
                ev.kind,
                ev.entity.id()
            );
            if let Some(d) = ev.dur {
                let _ = write!(out, ",\"dur\":{d}");
            }
            out.push_str("}\n");
        }
        out
    }

    /// Chrome `trace_event` export. Each entity becomes a named
    /// pseudo-thread (`tid` assigned by first appearance, so the layout
    /// is deterministic), spans become `ph:"X"` complete events and
    /// instants `ph:"i"`, with timestamps in microseconds of simulated
    /// time. The result opens directly in Perfetto.
    pub fn to_chrome(&self) -> Json {
        let mut tids: BTreeMap<String, usize> = BTreeMap::new();
        let mut order: Vec<String> = Vec::new();
        let mut events: Vec<Json> = Vec::new();
        for ev in self.events() {
            let id = ev.entity.id();
            let tid = match tids.get(&id) {
                Some(&t) => t,
                None => {
                    let t = order.len() + 1;
                    tids.insert(id.clone(), t);
                    order.push(id);
                    t
                }
            };
            let mut fields = vec![
                ("cat", Json::str("sim")),
                ("name", Json::str(ev.kind)),
                ("pid", Json::num(1.0)),
                ("tid", Json::num(tid as f64)),
                ("ts", Json::num(ev.t * 1e6)),
            ];
            match ev.dur {
                Some(d) => {
                    fields.push(("ph", Json::str("X")));
                    fields.push(("dur", Json::num(d * 1e6)));
                }
                None => {
                    fields.push(("ph", Json::str("i")));
                    fields.push(("s", Json::str("t")));
                }
            }
            events.push(Json::obj(fields));
        }
        let mut all: Vec<Json> = order
            .iter()
            .enumerate()
            .map(|(i, id)| {
                Json::obj(vec![
                    ("ph", Json::str("M")),
                    ("pid", Json::num(1.0)),
                    ("tid", Json::num((i + 1) as f64)),
                    ("name", Json::str("thread_name")),
                    ("args", Json::obj(vec![("name", Json::str(id))])),
                ])
            })
            .collect();
        all.extend(events);
        Json::obj(vec![("traceEvents", Json::Arr(all))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let mut tr = Tracer::disabled();
        tr.span(0.0, 1.0, "round", Entity::Run);
        tr.instant(0.5, "merge", Entity::Cluster(2));
        assert!(tr.is_empty());
        assert!(!tr.is_enabled());
        assert_eq!(tr.to_jsonl(), "");
        let chrome = tr.to_chrome();
        assert_eq!(chrome.get("traceEvents").as_arr().unwrap().len(), 0);
    }

    #[test]
    fn entity_ids_are_stable() {
        assert_eq!(Entity::Run.id(), "run");
        assert_eq!(Entity::Sat(12).id(), "sat:12");
        assert_eq!(Entity::Cluster(3).id(), "cluster:3");
        assert_eq!(Entity::Ground(0).id(), "gs:0");
    }

    #[test]
    fn jsonl_lines_are_valid_json_with_required_keys() {
        let mut tr = Tracer::disabled();
        tr.enable();
        tr.span(0.0, 2.5, "round", Entity::Run);
        tr.instant(1.25, "retry", Entity::Sat(7));
        tr.span(0.5, 0.125, "upload", Entity::Sat(7));
        let text = tr.to_jsonl();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in &lines {
            let j = Json::parse(line).expect("every trace line parses");
            assert!(j.get("t").as_f64().is_some(), "t missing: {line}");
            assert!(j.get("kind").as_str().is_some(), "kind missing: {line}");
            assert!(j.get("entity").as_str().is_some(), "entity missing: {line}");
        }
        assert_eq!(Json::parse(lines[1]).unwrap().get("entity").as_str(), Some("sat:7"));
        assert_eq!(Json::parse(lines[0]).unwrap().get("dur").as_f64(), Some(2.5));
        assert_eq!(Json::parse(lines[1]).unwrap().get("dur"), &Json::Null);
    }

    #[test]
    fn chrome_export_shape() {
        let mut tr = Tracer::disabled();
        tr.enable();
        tr.span(1.0, 0.5, "upload", Entity::Sat(4));
        tr.instant(1.5, "merge", Entity::Cluster(0));
        tr.span(1.0, 0.25, "upload", Entity::Sat(4));
        let chrome = tr.to_chrome();
        let evs = chrome.get("traceEvents").as_arr().unwrap();
        // 2 thread_name metadata records (sat:4, cluster:0) + 3 events
        assert_eq!(evs.len(), 5);
        assert_eq!(evs[0].get("ph").as_str(), Some("M"));
        assert_eq!(evs[0].get("args").get("name").as_str(), Some("sat:4"));
        assert_eq!(evs[1].get("args").get("name").as_str(), Some("cluster:0"));
        let span = &evs[2];
        assert_eq!(span.get("ph").as_str(), Some("X"));
        assert_eq!(span.get("ts").as_f64(), Some(1e6));
        assert_eq!(span.get("dur").as_f64(), Some(5e5));
        assert_eq!(span.get("tid").as_usize(), Some(1));
        let instant = &evs[3];
        assert_eq!(instant.get("ph").as_str(), Some("i"));
        assert_eq!(instant.get("s").as_str(), Some("t"));
        assert_eq!(instant.get("tid").as_usize(), Some(2));
        // serialised form parses back (what `--trace` writes to disk)
        let reparsed = Json::parse(&chrome.to_pretty()).unwrap();
        assert_eq!(&reparsed, &chrome);
    }

    #[test]
    fn emission_order_is_preserved() {
        let mut tr = Tracer::disabled();
        tr.enable();
        tr.instant(5.0, "b", Entity::Run);
        tr.instant(1.0, "a", Entity::Run);
        let ev = tr.events();
        assert_eq!(ev[0].kind, "b");
        assert_eq!(ev[1].kind, "a");
    }
}

//! Cumulative time/energy/accuracy ledger for one FL run.
//!
//! Time semantics (DESIGN.md §5): within the cluster stage, clusters train
//! in parallel, so the clock advances by the *max* cluster round time
//! (that parallelism is the paper's headline mechanism); the ground stage
//! adds the Eq. 7 sum over the participating PS↔GS links. Energy is the
//! unambiguous Eq. 10 total of Eq. 8 transmission + Eq. 9 computation.

/// One recorded evaluation point.
#[derive(Clone, Copy, Debug)]
pub struct RoundRecord {
    /// Intra-cluster round index (1-based).
    pub round: usize,
    /// Cumulative simulated processing time, seconds (Eq. 7 discipline).
    pub time_s: f64,
    /// Cumulative energy, joules (Eq. 10).
    pub energy_j: f64,
    /// Global-model test accuracy at this point.
    pub accuracy: f64,
    /// Global-model test loss.
    pub loss: f64,
    /// Whether a re-clustering event fired in this round.
    pub reclustered: bool,
    /// Wire bytes billed since the previous record (telemetry plane;
    /// serialised only under `--record-extended`).
    pub d_wire_bytes: f64,
    /// Retransmissions since the previous record (see `d_wire_bytes`).
    pub d_retransmits: usize,
    /// ISL up-hops billed since the previous record (see `d_wire_bytes`).
    pub d_route_hops: usize,
}

/// Accumulating ledger.
#[derive(Clone, Debug, Default)]
pub struct Ledger {
    pub time_s: f64,
    pub energy_j: f64,
    pub records: Vec<RoundRecord>,
    /// Count of re-clustering events.
    pub reclusters: usize,
    /// Count of MAML warm-starts applied.
    pub maml_adaptations: usize,
    /// Event timeline: ground passes a PS missed entirely — no visibility
    /// window within the staleness bound, or the ground antenna stayed
    /// busy past its window — so the cluster kept a stale model.
    pub stale_passes: usize,
    /// Event timeline: cumulative time PSes spent waiting for a ground
    /// visibility window to open (already included in `time_s`).
    pub ground_wait_s: f64,
    /// Scenario plane: fault onsets injected over the run (hard failures,
    /// ground outages, link degradations, straggler slowdowns, eclipse
    /// entries, transient outages).
    pub faults_injected: usize,
    /// Scenario plane: extra simulated compute time attributable to
    /// straggler slowdowns (already included in `time_s` when the slowed
    /// member was on its cluster's critical path).
    pub straggler_wait_s: f64,
    /// Aggregation plane: staleness-weighted merges performed under
    /// `--aggregation buffered|async` (sync runs keep this at 0).
    pub buffered_merges: usize,
    /// Aggregation plane: cumulative time contributions sat in a PS's
    /// merge buffer waiting for the goal count — satellite *idleness*
    /// (the FedSpace tradeoff's first axis; diagnostic, already inside
    /// `time_s`).
    pub idle_s: f64,
    /// Aggregation plane: cumulative model-version lag of merged
    /// contributions, expressed in publish-timestamp seconds — model
    /// *staleness* (the tradeoff's second axis).
    pub stale_s: f64,
    /// Aggregation plane: merged contributions bucketed by integer
    /// staleness τ = 0, 1, 2, 3, ≥ 4 (fixed-size — no allocation on the
    /// round path).
    pub staleness_hist: [usize; 5],
    /// Wire plane: cumulative uplink payload bytes actually billed
    /// (member → PS uploads plus PS → GS uploads, at the `--compress`
    /// mode's encoded size). Diagnostic — deliberately **not** part of
    /// the recorded JSON series, so compression sweeps leave the
    /// golden-trajectory files untouched.
    pub wire_bytes: f64,
    /// Recovery plane: corrupted uploads retransmitted after the
    /// receiver's checksum rejected them (every retransmission re-bills
    /// the Eq. 6/7 uplink time and Eq. 8 transmit energy).
    pub retransmits: usize,
    /// Recovery plane: upload attempts the receiver detected as corrupted
    /// (≥ `retransmits`; the gap is attempts whose retry budget was
    /// already exhausted, dropping the contribution on the stale path).
    pub corrupted_uploads: usize,
    /// Recovery plane: mid-round PS failovers — a crashed server process
    /// deterministically promoted a backup PS (or C-FedAvg central).
    pub failovers: usize,
    /// Recovery plane: cumulative exponential-backoff wait before
    /// retransmissions (already included in `time_s` when the retrying
    /// member sat on its stage's critical path).
    pub retry_wait_s: f64,
    /// Routing plane: billed ISL up-hop traversals — one per edge a
    /// payload crossed on its way to the PS (or per ring step), excluding
    /// retransmissions of the same hop. Direct runs keep this at 0.
    /// Diagnostic — like `wire_bytes`, deliberately **not** part of the
    /// recorded JSON series, so routing sweeps leave the
    /// golden-trajectory files untouched.
    pub route_hops: usize,
    /// Routing plane: partial aggregations performed at non-PS relays —
    /// contributions folded into a relay's pooled buffer before
    /// forwarding (diagnostic, not serialised; see `route_hops`).
    pub relay_merges: usize,
    /// Cumulative totals at the previous [`Ledger::record`] call, used to
    /// derive the per-record `d_*` deltas (telemetry plane).
    last_wire_bytes: f64,
    last_retransmits: usize,
    last_route_hops: usize,
}

impl Ledger {
    pub fn new() -> Ledger {
        Ledger::default()
    }

    /// Add elapsed processing time (cluster-stage max or ground-stage sum).
    pub fn add_time(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad time increment {dt}");
        self.time_s += dt;
    }

    /// Advance the cumulative clock to an absolute event timestamp. The
    /// event timeline feeds the ledger from event-queue timestamps rather
    /// than per-round max/sum folds; time stays monotone by construction.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t.is_finite(), "non-finite ledger timestamp");
        assert!(
            t >= self.time_s,
            "ledger time went backwards: {} -> {t}",
            self.time_s
        );
        self.time_s = t;
    }

    /// Record ground passes PSes missed entirely (event timeline): no
    /// visibility window within the staleness bound, or the ground antenna
    /// stayed busy past the window they had.
    pub fn add_stale_passes(&mut self, n: usize) {
        self.stale_passes += n;
    }

    /// Record time spent waiting on a visibility window (diagnostic; the
    /// wait itself reaches `time_s` via [`Ledger::advance_to`]).
    pub fn add_ground_wait(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad wait increment {dt}");
        self.ground_wait_s += dt;
    }

    /// Record fault onsets the scenario plane injected this round.
    pub fn add_faults(&mut self, n: usize) {
        self.faults_injected += n;
    }

    /// Record extra compute time a straggler slowdown cost (diagnostic;
    /// the slowdown itself reaches `time_s` through the Eq. 7 fold).
    pub fn add_straggler_wait(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad straggler wait {dt}");
        self.straggler_wait_s += dt;
    }

    /// Record one staleness-weighted merge.
    pub fn add_buffered_merge(&mut self) {
        self.buffered_merges += 1;
    }

    /// Record buffer-wait idleness (contribution arrival → merge).
    pub fn add_idle(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad idle increment {dt}");
        self.idle_s += dt;
    }

    /// Record model-version staleness of a merged contribution, both as
    /// publish-lag seconds and as an integer-τ histogram bump.
    pub fn add_staleness(&mut self, lag_s: f64, tau: usize) {
        assert!(lag_s >= 0.0 && lag_s.is_finite(), "bad staleness lag {lag_s}");
        self.stale_s += lag_s;
        self.staleness_hist[tau.min(4)] += 1;
    }

    /// Add consumed energy.
    pub fn add_energy(&mut self, de: f64) {
        assert!(de >= 0.0 && de.is_finite(), "bad energy increment {de}");
        self.energy_j += de;
    }

    /// Record uplink payload bytes billed on the wire.
    pub fn add_wire_bytes(&mut self, bytes: f64) {
        assert!(bytes >= 0.0 && bytes.is_finite(), "bad wire bytes {bytes}");
        self.wire_bytes += bytes;
    }

    /// Record retransmissions of checksum-rejected uploads.
    pub fn add_retransmits(&mut self, n: usize) {
        self.retransmits += n;
    }

    /// Record upload attempts the receiver's checksum rejected.
    pub fn add_corrupted_uploads(&mut self, n: usize) {
        self.corrupted_uploads += n;
    }

    /// Record one mid-round PS (or central-server) failover.
    pub fn add_failover(&mut self) {
        self.failovers += 1;
    }

    /// Record exponential-backoff wait before retransmissions (diagnostic;
    /// the wait reaches `time_s` through the stage folds).
    pub fn add_retry_wait(&mut self, dt: f64) {
        assert!(dt >= 0.0 && dt.is_finite(), "bad retry wait {dt}");
        self.retry_wait_s += dt;
    }

    /// Record billed ISL up-hop traversals (routing plane).
    pub fn add_route_hops(&mut self, n: usize) {
        self.route_hops += n;
    }

    /// Record partial aggregations performed at non-PS relays.
    pub fn add_relay_merges(&mut self, n: usize) {
        self.relay_merges += n;
    }

    /// Record an evaluation point at the current totals, with per-record
    /// deltas of the wire/recovery/routing counters since the previous
    /// record.
    pub fn record(&mut self, round: usize, accuracy: f64, loss: f64, reclustered: bool) {
        self.records.push(RoundRecord {
            round,
            time_s: self.time_s,
            energy_j: self.energy_j,
            accuracy,
            loss,
            reclustered,
            d_wire_bytes: self.wire_bytes - self.last_wire_bytes,
            d_retransmits: self.retransmits - self.last_retransmits,
            d_route_hops: self.route_hops - self.last_route_hops,
        });
        self.last_wire_bytes = self.wire_bytes;
        self.last_retransmits = self.retransmits;
        self.last_route_hops = self.route_hops;
    }

    /// First record meeting the target accuracy, if any.
    pub fn time_to_accuracy(&self, target: f64) -> Option<&RoundRecord> {
        self.records.iter().find(|r| r.accuracy >= target)
    }

    /// Best accuracy seen.
    pub fn best_accuracy(&self) -> f64 {
        self.records
            .iter()
            .map(|r| r.accuracy)
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_monotonically() {
        let mut l = Ledger::new();
        l.add_time(10.0);
        l.add_energy(5.0);
        l.record(1, 0.3, 2.0, false);
        l.add_time(10.0);
        l.add_energy(7.0);
        l.record(2, 0.5, 1.5, true);
        assert_eq!(l.records.len(), 2);
        assert!(l.records[1].time_s > l.records[0].time_s);
        assert_eq!(l.records[1].energy_j, 12.0);
        assert!(l.records[1].reclustered);
    }

    #[test]
    fn time_to_accuracy_finds_first_crossing() {
        let mut l = Ledger::new();
        for (i, acc) in [0.2, 0.5, 0.81, 0.85].iter().enumerate() {
            l.add_time(100.0);
            l.record(i + 1, *acc, 1.0, false);
        }
        let r = l.time_to_accuracy(0.8).unwrap();
        assert_eq!(r.round, 3);
        assert_eq!(r.time_s, 300.0);
        assert!(l.time_to_accuracy(0.99).is_none());
        assert_eq!(l.best_accuracy(), 0.85);
    }

    #[test]
    #[should_panic(expected = "bad time")]
    fn rejects_negative_time() {
        Ledger::new().add_time(-1.0);
    }

    #[test]
    fn advance_to_follows_event_timestamps() {
        let mut l = Ledger::new();
        l.advance_to(12.5);
        l.advance_to(12.5); // same instant is fine
        l.advance_to(80.0);
        assert_eq!(l.time_s, 80.0);
        l.add_ground_wait(30.0);
        l.add_stale_passes(2);
        assert_eq!(l.ground_wait_s, 30.0);
        assert_eq!(l.stale_passes, 2);
    }

    #[test]
    fn scenario_counters_accumulate() {
        let mut l = Ledger::new();
        l.add_faults(3);
        l.add_faults(2);
        l.add_straggler_wait(1.5);
        l.add_straggler_wait(0.5);
        assert_eq!(l.faults_injected, 5);
        assert_eq!(l.straggler_wait_s, 2.0);
    }

    #[test]
    #[should_panic(expected = "bad straggler wait")]
    fn rejects_negative_straggler_wait() {
        Ledger::new().add_straggler_wait(-1.0);
    }

    #[test]
    fn aggregation_counters_accumulate_and_saturate() {
        let mut l = Ledger::new();
        l.add_buffered_merge();
        l.add_buffered_merge();
        l.add_idle(3.0);
        l.add_idle(1.5);
        l.add_staleness(0.0, 0);
        l.add_staleness(12.5, 2);
        l.add_staleness(40.0, 9); // deep staleness saturates the last bucket
        assert_eq!(l.buffered_merges, 2);
        assert_eq!(l.idle_s, 4.5);
        assert_eq!(l.stale_s, 52.5);
        assert_eq!(l.staleness_hist, [1, 0, 1, 0, 1]);
    }

    #[test]
    #[should_panic(expected = "bad idle increment")]
    fn rejects_negative_idle() {
        Ledger::new().add_idle(-0.5);
    }

    #[test]
    fn wire_bytes_accumulate() {
        let mut l = Ledger::new();
        l.add_wire_bytes(9768.0);
        l.add_wire_bytes(1342.5);
        assert_eq!(l.wire_bytes, 11110.5);
    }

    #[test]
    #[should_panic(expected = "bad wire bytes")]
    fn rejects_negative_wire_bytes() {
        Ledger::new().add_wire_bytes(-1.0);
    }

    #[test]
    fn recovery_counters_accumulate() {
        let mut l = Ledger::new();
        l.add_corrupted_uploads(3);
        l.add_retransmits(2);
        l.add_corrupted_uploads(1);
        l.add_retransmits(1);
        l.add_failover();
        l.add_failover();
        l.add_retry_wait(1.5);
        l.add_retry_wait(0.25);
        assert_eq!(l.corrupted_uploads, 4);
        assert_eq!(l.retransmits, 3);
        assert_eq!(l.failovers, 2);
        assert_eq!(l.retry_wait_s, 1.75);
    }

    #[test]
    #[should_panic(expected = "bad retry wait")]
    fn rejects_negative_retry_wait() {
        Ledger::new().add_retry_wait(-0.1);
    }

    #[test]
    fn record_deltas_reset_between_records() {
        let mut l = Ledger::new();
        l.add_wire_bytes(100.0);
        l.add_retransmits(2);
        l.add_route_hops(3);
        l.record(1, 0.1, 2.0, false);
        l.add_wire_bytes(50.0);
        l.add_route_hops(1);
        l.record(2, 0.2, 1.5, false);
        l.record(3, 0.3, 1.0, false);
        assert_eq!(l.records[0].d_wire_bytes, 100.0);
        assert_eq!(l.records[0].d_retransmits, 2);
        assert_eq!(l.records[0].d_route_hops, 3);
        assert_eq!(l.records[1].d_wire_bytes, 50.0);
        assert_eq!(l.records[1].d_retransmits, 0);
        assert_eq!(l.records[1].d_route_hops, 1);
        assert_eq!(l.records[2].d_wire_bytes, 0.0);
        assert_eq!(l.records[2].d_retransmits, 0);
        assert_eq!(l.records[2].d_route_hops, 0);
        // cumulative totals are untouched by recording
        assert_eq!(l.wire_bytes, 150.0);
        assert_eq!(l.route_hops, 4);
    }

    #[test]
    fn routing_counters_accumulate() {
        let mut l = Ledger::new();
        l.add_route_hops(3);
        l.add_relay_merges(2);
        l.add_route_hops(1);
        l.add_relay_merges(1);
        assert_eq!(l.route_hops, 4);
        assert_eq!(l.relay_merges, 3);
    }

    #[test]
    #[should_panic(expected = "ledger time went backwards")]
    fn advance_to_rejects_past_timestamps() {
        let mut l = Ledger::new();
        l.advance_to(10.0);
        l.advance_to(9.0);
    }
}

//! Serialise run results to CSV and JSON for plotting / regression diffing.

use super::ledger::Ledger;
use crate::util::json::Json;
use std::io::Write;
use std::path::Path;

/// CSV of the per-round series (round,time_s,energy_j,accuracy,loss,reclustered).
pub fn to_csv(ledger: &Ledger) -> String {
    let mut s = String::from("round,time_s,energy_j,accuracy,loss,reclustered\n");
    for r in &ledger.records {
        s.push_str(&format!(
            "{},{:.3},{:.3},{:.4},{:.4},{}\n",
            r.round, r.time_s, r.energy_j, r.accuracy, r.loss, r.reclustered as u8
        ));
    }
    s
}

/// JSON document of the whole ledger.
///
/// `Ledger::wire_bytes` — and the routing plane's `route_hops` /
/// `relay_merges` — are deliberately **not** serialised: the committed
/// golden-trajectory JSON predates the wire and routing planes, and
/// keeping the document shape fixed lets `--compress` and `--routing`
/// sweeps diff against the same goldens. Benches report bytes-on-the-wire
/// and hop counts through their own `bytes_per_round` /
/// `hops_per_round` columns instead. `fedhc run --record-extended`
/// opts into [`to_json_extended`], which adds the per-record
/// `d_wire_bytes` / `d_retransmits` / `d_route_hops` deltas without
/// touching this default shape.
pub fn to_json(ledger: &Ledger) -> Json {
    to_json_with(ledger, false)
}

/// [`to_json`] plus per-record telemetry deltas (`--record-extended`).
pub fn to_json_extended(ledger: &Ledger) -> Json {
    to_json_with(ledger, true)
}

fn to_json_with(ledger: &Ledger, extended: bool) -> Json {
    Json::obj(vec![
        ("time_s", Json::num(ledger.time_s)),
        ("energy_j", Json::num(ledger.energy_j)),
        ("reclusters", Json::num(ledger.reclusters as f64)),
        ("maml_adaptations", Json::num(ledger.maml_adaptations as f64)),
        ("stale_passes", Json::num(ledger.stale_passes as f64)),
        ("ground_wait_s", Json::num(ledger.ground_wait_s)),
        ("faults_injected", Json::num(ledger.faults_injected as f64)),
        ("straggler_wait_s", Json::num(ledger.straggler_wait_s)),
        ("buffered_merges", Json::num(ledger.buffered_merges as f64)),
        ("idle_s", Json::num(ledger.idle_s)),
        ("stale_s", Json::num(ledger.stale_s)),
        (
            "staleness_hist",
            Json::Arr(
                ledger
                    .staleness_hist
                    .iter()
                    .map(|&n| Json::num(n as f64))
                    .collect(),
            ),
        ),
        (
            "records",
            Json::Arr(
                ledger
                    .records
                    .iter()
                    .map(|r| {
                        let mut fields = vec![
                            ("round", Json::num(r.round as f64)),
                            ("time_s", Json::num(r.time_s)),
                            ("energy_j", Json::num(r.energy_j)),
                            ("accuracy", Json::num(r.accuracy)),
                            ("loss", Json::num(r.loss)),
                            ("reclustered", Json::Bool(r.reclustered)),
                        ];
                        if extended {
                            fields.push(("d_wire_bytes", Json::num(r.d_wire_bytes)));
                            fields.push((
                                "d_retransmits",
                                Json::num(r.d_retransmits as f64),
                            ));
                            fields.push(("d_route_hops", Json::num(r.d_route_hops as f64)));
                        }
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write both formats under `dir` with the given stem.
pub fn write_series(ledger: &Ledger, dir: &Path, stem: &str) -> std::io::Result<()> {
    write_series_with(ledger, dir, stem, false)
}

/// [`write_series`] with the extended (telemetry-delta) JSON shape.
pub fn write_series_extended(ledger: &Ledger, dir: &Path, stem: &str) -> std::io::Result<()> {
    write_series_with(ledger, dir, stem, true)
}

fn write_series_with(
    ledger: &Ledger,
    dir: &Path,
    stem: &str,
    extended: bool,
) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    let mut c = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
    c.write_all(to_csv(ledger).as_bytes())?;
    let mut j = std::fs::File::create(dir.join(format!("{stem}.json")))?;
    let doc = to_json_with(ledger, extended);
    j.write_all(doc.to_pretty().as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Ledger {
        let mut l = Ledger::new();
        l.add_time(5.0);
        l.add_energy(2.0);
        l.record(1, 0.42, 1.9, false);
        l.reclusters = 1;
        l
    }

    #[test]
    fn csv_shape() {
        let csv = to_csv(&sample());
        let lines: Vec<&str> = csv.trim().lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].starts_with("round,"));
        assert!(lines[1].starts_with("1,5.000,2.000,0.4200"));
    }

    #[test]
    fn json_roundtrips() {
        let j = to_json(&sample());
        let parsed = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(parsed.get("reclusters").as_usize(), Some(1));
        assert_eq!(parsed.get("records").as_arr().unwrap().len(), 1);
        // scenario counters ride along for golden-trajectory diffs
        assert_eq!(parsed.get("faults_injected").as_usize(), Some(0));
        assert_eq!(parsed.get("straggler_wait_s").as_f64(), Some(0.0));
        // aggregation-plane counters too (sync runs serialise zeros)
        assert_eq!(parsed.get("buffered_merges").as_usize(), Some(0));
        assert_eq!(parsed.get("idle_s").as_f64(), Some(0.0));
        assert_eq!(parsed.get("stale_s").as_f64(), Some(0.0));
        assert_eq!(parsed.get("staleness_hist").as_arr().unwrap().len(), 5);
    }

    #[test]
    fn extended_adds_deltas_without_touching_default_shape() {
        let mut l = Ledger::new();
        l.add_time(5.0);
        l.add_wire_bytes(128.0);
        l.add_retransmits(1);
        l.add_route_hops(2);
        l.record(1, 0.42, 1.9, false);
        let default_doc = to_json(&l).to_pretty();
        assert!(!default_doc.contains("d_wire_bytes"));
        let rec = &to_json_extended(&l).get("records").as_arr().unwrap()[0];
        assert_eq!(rec.get("d_wire_bytes").as_f64(), Some(128.0));
        assert_eq!(rec.get("d_retransmits").as_usize(), Some(1));
        assert_eq!(rec.get("d_route_hops").as_usize(), Some(2));
        // top level still excludes the cumulative wire/routing counters
        let top = to_json_extended(&l);
        assert_eq!(top.get("wire_bytes"), &crate::util::json::Json::Null);
        assert_eq!(top.get("route_hops"), &crate::util::json::Json::Null);
    }

    #[test]
    fn writes_files() {
        let dir = std::env::temp_dir().join("fedhc_recorder_test");
        write_series(&sample(), &dir, "unit").unwrap();
        assert!(dir.join("unit.csv").exists());
        assert!(dir.join("unit.json").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}

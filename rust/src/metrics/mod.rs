//! Metrics: the per-round time/energy/accuracy ledger (paper Eq. 7 & 10),
//! recorders that emit the CSV/JSON series behind Table I and Fig. 3, the
//! telemetry plane's sim-time tracer and per-entity registry, and report
//! formatters.

pub mod ledger;
pub mod recorder;
pub mod registry;
pub mod report;
pub mod trace;

pub use ledger::{Ledger, RoundRecord};
pub use registry::MetricsRegistry;
pub use trace::{Entity, Tracer};

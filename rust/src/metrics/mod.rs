//! Metrics: the per-round time/energy/accuracy ledger (paper Eq. 7 & 10)
//! and recorders that emit the CSV/JSON series behind Table I and Fig. 3.

pub mod ledger;
pub mod recorder;
pub mod report;

pub use ledger::{Ledger, RoundRecord};

//! Table/figure formatting: prints the same rows Table I reports and the
//! Fig. 3 accuracy-vs-round series, in aligned ASCII, plus the telemetry
//! plane's per-entity hotspot table.

use super::ledger::Ledger;
use super::registry::MetricsRegistry;

/// One Table-I cell pair for a (method, K) configuration.
#[derive(Clone, Copy, Debug)]
pub struct TimeEnergy {
    pub time_s: f64,
    pub energy_j: f64,
    /// Whether the run reached the target accuracy (cells are annotated
    /// with '*' when the budget ran out first, like a DNF).
    pub converged: bool,
}

/// Render the Table I block for one dataset.
/// `methods` rows × `ks` columns of (time, energy).
pub fn format_table1(
    dataset: &str,
    target: f64,
    ks: &[usize],
    methods: &[(&str, Vec<TimeEnergy>)],
) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "Table I ({dataset}, target accuracy {:.0}%)\n",
        target * 100.0
    ));
    s.push_str(&format!("{:<12}", "Method"));
    for k in ks {
        s.push_str(&format!("{:>11}{:>11}", format!("K={k} Time"), "Energy"));
    }
    s.push('\n');
    for (name, cells) in methods {
        s.push_str(&format!("{name:<12}"));
        for c in cells {
            let star = if c.converged { "" } else { "*" };
            s.push_str(&format!(
                "{:>11}{:>11}",
                format!("{:.0}{star}", c.time_s),
                format!("{:.0}{star}", c.energy_j)
            ));
        }
        s.push('\n');
    }
    s
}

/// Render a Fig. 3 style accuracy table: rows = sampled rounds, one column
/// per method.
pub fn format_fig3(
    dataset: &str,
    k: usize,
    series: &[(&str, &Ledger)],
    sample_every: usize,
) -> String {
    let mut s = String::new();
    s.push_str(&format!("Fig. 3 ({dataset}, K={k}) accuracy vs round\n"));
    s.push_str(&format!("{:<8}", "round"));
    for (name, _) in series {
        s.push_str(&format!("{name:>12}"));
    }
    s.push('\n');
    let max_round = series
        .iter()
        .flat_map(|(_, l)| l.records.iter().map(|r| r.round))
        .max()
        .unwrap_or(0);
    let mut round = sample_every.max(1);
    while round <= max_round {
        s.push_str(&format!("{round:<8}"));
        for (_, l) in series {
            // last record at or before this round
            let acc = l
                .records
                .iter()
                .take_while(|r| r.round <= round)
                .last()
                .map(|r| r.accuracy)
                .unwrap_or(0.0);
            s.push_str(&format!("{:>12.4}", acc));
        }
        s.push('\n');
        round += sample_every.max(1);
    }
    s
}

/// Render the scenario-matrix counter table: one row per
/// `(scenario, method)` cell with the per-scenario ledger counters —
/// faults injected, reclusters fired, stale passes, straggler wait, the
/// recovery plane's retransmissions and PS failovers, wire traffic — next
/// to the headline accuracy/time/energy numbers.
pub fn format_scenario_matrix(rows: &[(&str, &str, &Ledger)]) -> String {
    let mut s = String::new();
    s.push_str("Scenario matrix (per-run ledger counters)\n");
    s.push_str(&format!(
        "{:<14}{:<12}{:>8}{:>8}{:>7}{:>7}{:>11}{:>7}{:>7}{:>13}{:>12}{:>12}\n",
        "scenario",
        "method",
        "faults",
        "reclst",
        "maml",
        "stale",
        "stragl_s",
        "retx",
        "failov",
        "wire_b",
        "time_s",
        "acc"
    ));
    for (scenario, method, ledger) in rows {
        s.push_str(&format!(
            "{:<14}{:<12}{:>8}{:>8}{:>7}{:>7}{:>11.1}{:>7}{:>7}{:>13.0}{:>12.0}{:>12.4}\n",
            scenario,
            method,
            ledger.faults_injected,
            ledger.reclusters,
            ledger.maml_adaptations,
            ledger.stale_passes,
            ledger.straggler_wait_s,
            ledger.retransmits,
            ledger.failovers,
            ledger.wire_bytes,
            ledger.time_s,
            ledger.best_accuracy(),
        ));
    }
    s
}

/// Render the telemetry plane's hotspot table: the `k` satellites with
/// the most cumulative communication time (uploads, retransmits, hops,
/// bytes, comm seconds), then every cluster's merge/failover/staleness
/// counters. Empty string while the registry is disabled, so `fedhc run`
/// can print it unconditionally.
pub fn format_hotspots(registry: &MetricsRegistry, k: usize) -> String {
    if !registry.is_enabled() {
        return String::new();
    }
    let mut s = String::new();
    let top = registry.top_sats_by_comm(k);
    s.push_str(&format!("Hotspots (top-{} satellites by comm time)\n", top.len()));
    s.push_str(&format!(
        "{:<12}{:>9}{:>9}{:>7}{:>13}{:>11}\n",
        "sat", "uploads", "retx", "hops", "bytes", "comm_s"
    ));
    let sats = registry.sats();
    for i in top {
        let st = &sats[i];
        s.push_str(&format!(
            "{:<12}{:>9}{:>9}{:>7}{:>13.0}{:>11.2}\n",
            format!("sat:{i}"),
            st.uploads,
            st.retransmits,
            st.hops,
            st.bytes,
            st.comm_s,
        ));
    }
    s.push_str(&format!(
        "{:<12}{:>9}{:>9}{:>7}{:>13}\n",
        "cluster", "merges", "failov", "stale", "window_s"
    ));
    for (c, st) in registry.clusters().iter().enumerate() {
        s.push_str(&format!(
            "{:<12}{:>9}{:>9}{:>7}{:>13.1}\n",
            format!("cluster:{c}"),
            st.merges,
            st.failovers,
            st.stale_merges,
            st.window_s,
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_formatting() {
        let cells = vec![
            TimeEnergy { time_s: 8184.0, energy_j: 3697.0, converged: true },
            TimeEnergy { time_s: 8184.0, energy_j: 3697.0, converged: false },
        ];
        let out = format_table1("mnist", 0.8, &[3, 4], &[("C-FedAvg", cells)]);
        assert!(out.contains("K=3 Time"));
        assert!(out.contains("8184"));
        assert!(out.contains("8184*"), "DNF marker missing:\n{out}");
    }

    #[test]
    fn scenario_matrix_formatting() {
        let mut l = Ledger::new();
        l.add_faults(7);
        l.reclusters = 2;
        l.add_stale_passes(1);
        l.add_straggler_wait(12.5);
        l.add_time(100.0);
        l.record(1, 0.55, 1.0, true);
        l.add_retransmits(9);
        l.add_failover();
        l.add_wire_bytes(2048.0);
        let out = format_scenario_matrix(&[("churn", "FedHC", &l)]);
        assert!(out.contains("churn"));
        assert!(out.contains("FedHC"));
        assert!(out.contains("retx") && out.contains("failov") && out.contains("wire_b"));
        let row = out.trim().lines().last().unwrap();
        assert!(row.contains('7') && row.contains('2'), "counters missing:\n{out}");
        assert!(row.contains("12.5"), "straggler wait missing:\n{out}");
        assert!(row.contains('9'), "retransmits missing:\n{out}");
        assert!(row.contains("2048"), "wire bytes missing:\n{out}");
        assert!(row.contains("0.5500"), "accuracy missing:\n{out}");
    }

    #[test]
    fn hotspots_formatting() {
        let mut reg = MetricsRegistry::disabled();
        assert_eq!(format_hotspots(&reg, 4), "");
        reg.enable(3, 2);
        reg.record_upload(2, 7.5, 4096.0, 3, 2);
        reg.record_upload(0, 1.0, 1024.0, 0, 1);
        reg.record_merge(1);
        reg.record_failover(1);
        reg.record_staleness(1, 2.0);
        reg.record_window(0, 90.0);
        let out = format_hotspots(&reg, 2);
        let lines: Vec<&str> = out.trim().lines().collect();
        // title + sat header + 2 sat rows + cluster header + 2 cluster rows
        assert_eq!(lines.len(), 7, "unexpected shape:\n{out}");
        assert!(lines[2].starts_with("sat:2"), "busiest sat first:\n{out}");
        assert!(lines[2].contains("4096") && lines[2].contains("7.50"));
        assert!(lines[3].starts_with("sat:0"));
        assert!(lines[6].starts_with("cluster:1"));
        assert!(lines[6].contains('1'), "cluster counters missing:\n{out}");
        assert!(lines[5].contains("90.0"), "window seconds missing:\n{out}");
    }

    #[test]
    fn fig3_formatting() {
        let mut a = Ledger::new();
        a.record(1, 0.1, 2.0, false);
        a.record(2, 0.5, 1.0, false);
        let mut b = Ledger::new();
        b.record(1, 0.2, 2.0, false);
        b.record(2, 0.6, 1.0, false);
        let out = format_fig3("mnist", 3, &[("FedHC", &a), ("H-BASE", &b)], 1);
        assert!(out.contains("FedHC"));
        let lines: Vec<&str> = out.trim().lines().collect();
        assert_eq!(lines.len(), 4); // title + header + 2 rounds
        assert!(lines[3].contains("0.5000") && lines[3].contains("0.6000"));
    }
}

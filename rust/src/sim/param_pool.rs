//! Recycled parameter-vector pool and a generic scratch recycler.
//!
//! Steady-state rounds hand every member a parameter buffer overwritten
//! from its cluster model; cloning the model per member per round
//! (`models[c].clone()`) was the single largest allocation source in the
//! round loop. [`ParamPool`] keeps returned buffers on a thread-safe free
//! list so the engine's scatter jobs can take them concurrently, and
//! [`ScratchPool`] does the same for arbitrary worker scratch (training
//! buffers survive across rounds even though [`crate::sim::engine::Engine`]
//! re-creates its workers on every `run_with` call).
//!
//! Pooling never touches the numerics: a taken buffer is always fully
//! overwritten before use, so results are bit-identical to the cloning
//! path regardless of which recycled allocation a member happens to get,
//! and regardless of the worker schedule that returned it (pinned by
//! `tests/engine_determinism.rs`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Thread-safe free list of `param_count`-sized `Vec<f32>` buffers.
pub struct ParamPool {
    param_count: usize,
    free: Mutex<Vec<Vec<f32>>>,
    allocated: AtomicUsize,
    recycled: AtomicUsize,
}

impl ParamPool {
    pub fn new(param_count: usize) -> ParamPool {
        ParamPool {
            param_count,
            free: Mutex::new(Vec::new()),
            allocated: AtomicUsize::new(0),
            recycled: AtomicUsize::new(0),
        }
    }

    /// Buffer length this pool recycles.
    pub fn param_count(&self) -> usize {
        self.param_count
    }

    /// Take a buffer holding a copy of `src` (which must be `param_count`
    /// long): recycled off the free list when possible, freshly allocated
    /// otherwise. Either way the contents are exactly `src`.
    pub fn take_copy(&self, src: &[f32]) -> Vec<f32> {
        assert_eq!(src.len(), self.param_count, "pool geometry mismatch");
        let recycled = self.free.lock().expect("param pool poisoned").pop();
        match recycled {
            Some(mut buf) => {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                buf.copy_from_slice(src);
                buf
            }
            None => {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                src.to_vec()
            }
        }
    }

    /// Take an all-zero buffer (recycled and cleared, or freshly
    /// allocated). The wire plane uses these for error-feedback residuals,
    /// which must start from exact zeros.
    pub fn take_zeroed(&self) -> Vec<f32> {
        let recycled = self.free.lock().expect("param pool poisoned").pop();
        match recycled {
            Some(mut buf) => {
                self.recycled.fetch_add(1, Ordering::Relaxed);
                buf.fill(0.0);
                buf
            }
            None => {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                vec![0.0f32; self.param_count]
            }
        }
    }

    /// Grow the free list until it holds at least `n` buffers, so a
    /// bounded scatter of `n` concurrent takes recycles instead of
    /// allocating. The per-cluster sharded round path calls this at
    /// topology-(re)build time with the largest cluster size: warm-up cost
    /// is paid once, and steady-state rounds stay free of parameter-sized
    /// allocations no matter how per-round availability fluctuates.
    pub fn ensure_free(&self, n: usize) {
        let mut free = self.free.lock().expect("param pool poisoned");
        while free.len() < n {
            self.allocated.fetch_add(1, Ordering::Relaxed);
            free.push(vec![0.0f32; self.param_count]);
        }
    }

    /// Check a buffer back in for reuse. Buffers of the wrong length
    /// (e.g. an empty vector left by `std::mem::take`) are dropped rather
    /// than poisoning the free list.
    pub fn put(&self, buf: Vec<f32>) {
        if buf.len() == self.param_count {
            self.free.lock().expect("param pool poisoned").push(buf);
        }
    }

    /// `(fresh_allocations, recycled_takes)` so far. A steady-state round
    /// loop only grows the first during its warm-up round.
    pub fn stats(&self) -> (usize, usize) {
        (
            self.allocated.load(Ordering::Relaxed),
            self.recycled.load(Ordering::Relaxed),
        )
    }
}

/// Generic free list for worker scratch state that must outlive one
/// `Engine::run_with` call. [`ScratchPool::take_or`] hands back a
/// [`Recycled`] guard that returns the item to the pool on drop, so the
/// engine's per-worker `init` closures recycle scratch across rounds
/// without any explicit check-in.
pub struct ScratchPool<T> {
    free: Mutex<Vec<T>>,
}

impl<T> ScratchPool<T> {
    pub fn new() -> ScratchPool<T> {
        ScratchPool {
            free: Mutex::new(Vec::new()),
        }
    }

    /// Pop a pooled item, or build one with `make` when the pool is dry.
    pub fn take_or(&self, make: impl FnOnce() -> T) -> Recycled<'_, T> {
        let item = self
            .free
            .lock()
            .expect("scratch pool poisoned")
            .pop()
            .unwrap_or_else(make);
        Recycled {
            pool: self,
            item: Some(item),
        }
    }

    /// Pooled items currently on the free list.
    pub fn idle(&self) -> usize {
        self.free.lock().expect("scratch pool poisoned").len()
    }

    fn put(&self, item: T) {
        self.free.lock().expect("scratch pool poisoned").push(item);
    }
}

impl<T> Default for ScratchPool<T> {
    fn default() -> Self {
        ScratchPool::new()
    }
}

/// Guard around a pooled scratch item: derefs to `T` and returns the item
/// to its [`ScratchPool`] when dropped.
pub struct Recycled<'p, T> {
    pool: &'p ScratchPool<T>,
    item: Option<T>,
}

impl<T> std::ops::Deref for Recycled<'_, T> {
    type Target = T;

    fn deref(&self) -> &T {
        self.item.as_ref().expect("recycled item already returned")
    }
}

impl<T> std::ops::DerefMut for Recycled<'_, T> {
    fn deref_mut(&mut self) -> &mut T {
        self.item.as_mut().expect("recycled item already returned")
    }
}

impl<T> Drop for Recycled<'_, T> {
    fn drop(&mut self) {
        if let Some(item) = self.item.take() {
            self.pool.put(item);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_copy_matches_source_and_recycles() {
        let pool = ParamPool::new(8);
        let src: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let a = pool.take_copy(&src);
        assert_eq!(a, src);
        assert_eq!(pool.stats(), (1, 0));
        pool.put(a);
        let other: Vec<f32> = (0..8).map(|i| -(i as f32)).collect();
        let b = pool.take_copy(&other);
        assert_eq!(b, other, "recycled buffer must be fully overwritten");
        assert_eq!(pool.stats(), (1, 1), "second take must reuse the buffer");
    }

    #[test]
    fn ensure_free_prefills_once() {
        let pool = ParamPool::new(8);
        pool.ensure_free(3);
        assert_eq!(pool.stats().0, 3, "three warm-up allocations");
        pool.ensure_free(3);
        assert_eq!(pool.stats().0, 3, "already satisfied: no growth");
        let src = [0.5f32; 8];
        let a = pool.take_copy(&src);
        let b = pool.take_copy(&src);
        let c = pool.take_copy(&src);
        assert_eq!(pool.stats(), (3, 3), "all takes recycle the prefill");
        assert_eq!(a, src);
        pool.put(a);
        pool.put(b);
        pool.put(c);
    }

    #[test]
    fn wrong_length_buffers_are_dropped_not_pooled() {
        let pool = ParamPool::new(4);
        pool.put(Vec::new());
        pool.put(vec![0.0; 3]);
        let src = [1.0f32, 2.0, 3.0, 4.0];
        let buf = pool.take_copy(&src);
        assert_eq!(buf, src);
        assert_eq!(pool.stats(), (1, 0), "bad buffers must not be recycled");
    }

    #[test]
    #[should_panic(expected = "pool geometry mismatch")]
    fn take_copy_rejects_wrong_source_length() {
        ParamPool::new(4).take_copy(&[0.0; 3]);
    }

    #[test]
    fn take_zeroed_clears_recycled_buffers() {
        let pool = ParamPool::new(4);
        let a = pool.take_zeroed();
        assert_eq!(a, vec![0.0; 4]);
        assert_eq!(pool.stats(), (1, 0));
        pool.put(vec![1.0f32, 2.0, 3.0, 4.0]);
        let b = pool.take_zeroed();
        assert_eq!(b, vec![0.0; 4], "recycled residual must be re-zeroed");
        assert_eq!(pool.stats(), (1, 1));
    }

    #[test]
    fn scratch_guard_returns_on_drop() {
        let pool: ScratchPool<Vec<u8>> = ScratchPool::new();
        {
            let mut guard = pool.take_or(|| vec![0u8; 16]);
            guard[0] = 7;
            assert_eq!(pool.idle(), 0);
        }
        assert_eq!(pool.idle(), 1, "guard drop must return the item");
        let guard = pool.take_or(|| panic!("pool should have an item"));
        assert_eq!(guard[0], 7);
    }

    #[test]
    fn pools_are_shareable_across_threads() {
        let pool = ParamPool::new(32);
        let src: Vec<f32> = (0..32).map(|i| (i as f32).sin()).collect();
        std::thread::scope(|scope| {
            let workers: Vec<_> = (0..4)
                .map(|_| {
                    scope.spawn(|| {
                        for _ in 0..50 {
                            let buf = pool.take_copy(&src);
                            assert_eq!(buf, src);
                            pool.put(buf);
                        }
                    })
                })
                .collect();
            for w in workers {
                w.join().expect("pool worker panicked");
            }
        });
        let (fresh, recycled) = pool.stats();
        assert_eq!(fresh + recycled, 200);
        assert!(fresh <= 4, "at most one fresh buffer per concurrent taker");
    }
}

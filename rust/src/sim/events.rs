//! Discrete-event substrate: a time-ordered event queue.
//!
//! The event timeline (`--timeline event`) schedules the durations that the
//! analytic timeline folds in closed form: local training occupies
//! [`Event::ComputeDone`] intervals, uplinks and PS↔GS transfers occupy
//! [`Event::TxDone`] intervals, and ground exchanges are gated by
//! [`Event::WindowOpen`]/[`Event::WindowClose`] pairs derived from
//! `orbit::visibility`. Events carry **offsets from the enclosing stage's
//! start**, not absolute sim time: offsets keep the floating-point
//! operation order identical to the analytic folds, which is what makes
//! the two timelines bit-identical when every window is open (pinned by
//! `tests/timeline_equivalence.rs`).
//!
//! Determinism: ties in time pop in insertion order (a strictly increasing
//! sequence number), so a drain is a pure function of the push sequence —
//! never of hash ordering or the worker schedule.

use super::faults::Fault;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Payload of a scheduled simulation event.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Event {
    /// A cluster member finished local training; its uplink may start.
    ComputeDone { member: usize, cluster: usize },
    /// A transmission completed (member→PS uplink or PS↔GS exchange,
    /// depending on the scheduling context).
    TxDone { member: usize, cluster: usize },
    /// A ground-station visibility window opened for a cluster's PS.
    WindowOpen { cluster: usize },
    /// The visibility window closed again. Marks the interval end on the
    /// timeline; the serving decision itself reads the close offset when
    /// the matching [`Event::WindowOpen`] pops (that is when the antenna
    /// commits), so a transfer never starts after this.
    WindowClose { cluster: usize },
    /// A member's buffered/async contribution reached its PS: compute plus
    /// uplink finished and the parameters sit in the PS's merge buffer.
    /// Only scheduled under `--aggregation buffered|async`.
    UploadReady { member: usize, cluster: usize },
    /// A cluster PS's merge buffer reached its goal count; the
    /// staleness-weighted fold runs at this timestamp. Only scheduled
    /// under `--aggregation buffered|async`.
    MergeDue { cluster: usize },
    /// An evaluation point is due. Under `--aggregation buffered|async`
    /// the eval cadence decouples from the round barrier: evaluation fires
    /// when this pops, not when a round index divides `eval_every`.
    EvalDue { round: usize },
    /// A typed fault onset or recovery ([`crate::sim::faults::Fault`]).
    /// Scheduled by the scenario engine at **round-indexed** timestamps
    /// (the fault plane advances per round, not per second) on its own
    /// queue — never interleaved with the stage-offset events above.
    Fault { fault: Fault },
}

impl Event {
    /// Stable snake_case name of the variant, used by the telemetry
    /// plane as the `kind` of instants emitted at event pops.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::ComputeDone { .. } => "compute_done",
            Event::TxDone { .. } => "tx_done",
            Event::WindowOpen { .. } => "window_open",
            Event::WindowClose { .. } => "window_close",
            Event::UploadReady { .. } => "upload_ready",
            Event::MergeDue { .. } => "merge_due",
            Event::EvalDue { .. } => "eval_due",
            Event::Fault { .. } => "fault",
        }
    }
}

/// A timestamped event: ordered by time, ties broken by insertion order.
#[derive(Clone, Copy, Debug)]
pub struct Scheduled {
    /// Offset from the enclosing stage's start, seconds (≥ 0, finite).
    pub at: f64,
    /// Insertion sequence number (unique per queue).
    pub seq: u64,
    /// The payload.
    pub event: Event,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    /// Reversed so the `BinaryHeap` max-heap pops the *earliest* event;
    /// equal times pop in insertion (`seq`) order.
    fn cmp(&self, other: &Self) -> Ordering {
        match other.at.partial_cmp(&self.at) {
            Some(Ordering::Equal) | None => other.seq.cmp(&self.seq),
            Some(ord) => ord,
        }
    }
}

/// Min-queue of [`Scheduled`] events with FIFO tie-breaking.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at offset `at` seconds (must be finite and ≥ 0).
    pub fn push(&mut self, at: f64, event: Event) {
        assert!(at.is_finite() && at >= 0.0, "bad event time {at}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { at, seq, event });
    }

    /// Pop the earliest event (insertion order among ties).
    pub fn pop(&mut self) -> Option<Scheduled> {
        self.heap.pop()
    }

    /// Time of the earliest pending event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(5.0, Event::EvalDue { round: 1 });
        q.push(1.0, Event::WindowOpen { cluster: 0 });
        q.push(3.0, Event::TxDone { member: 2, cluster: 0 });
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|s| s.at)).collect();
        assert_eq!(times, vec![1.0, 3.0, 5.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        for c in 0..5 {
            q.push(2.0, Event::WindowOpen { cluster: c });
        }
        q.push(0.0, Event::EvalDue { round: 9 });
        assert_eq!(q.peek_time(), Some(0.0));
        assert_eq!(q.pop().unwrap().event, Event::EvalDue { round: 9 });
        for c in 0..5 {
            assert_eq!(q.pop().unwrap().event, Event::WindowOpen { cluster: c });
        }
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn interleaved_push_pop_is_stable() {
        // the ground stage pushes TxDone events while draining WindowOpens
        let mut q = EventQueue::new();
        q.push(0.0, Event::WindowOpen { cluster: 0 });
        q.push(0.0, Event::WindowOpen { cluster: 1 });
        let first = q.pop().unwrap();
        assert_eq!(first.event, Event::WindowOpen { cluster: 0 });
        q.push(4.0, Event::TxDone { member: 7, cluster: 0 });
        assert_eq!(q.pop().unwrap().event, Event::WindowOpen { cluster: 1 });
        assert_eq!(q.pop().unwrap().at, 4.0);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn upload_and_merge_events_flow_through_the_queue() {
        // the buffered plane's event shapes ride the same queue: uploads
        // arrive at their compute+uplink offsets, the merge goal fires last
        let mut q = EventQueue::new();
        q.push(7.5, Event::MergeDue { cluster: 1 });
        q.push(2.5, Event::UploadReady { member: 3, cluster: 1 });
        q.push(2.5, Event::UploadReady { member: 4, cluster: 1 });
        assert_eq!(q.pop().unwrap().event, Event::UploadReady { member: 3, cluster: 1 });
        assert_eq!(q.pop().unwrap().event, Event::UploadReady { member: 4, cluster: 1 });
        assert_eq!(q.pop().unwrap().event, Event::MergeDue { cluster: 1 });
        assert!(q.is_empty());
    }

    #[test]
    fn random_interleaving_pops_non_decreasing_with_fifo_ties() {
        use crate::util::quickprop::{property, Gen};
        property("queue pops non-decreasing, FIFO among ties", 128, |g: &mut Gen| {
            let mut q = EventQueue::new();
            let mut popped: Vec<Scheduled> = Vec::new();
            let ops = g.usize_in(1, 64);
            for _ in 0..ops {
                if g.bool() || q.is_empty() {
                    // a coarse grid of times forces plenty of exact ties
                    let at = g.usize_in(0, 8) as f64;
                    let member = g.usize_in(0, 31);
                    q.push(at, Event::UploadReady { member, cluster: 0 });
                } else {
                    popped.push(q.pop().unwrap());
                }
            }
            while let Some(s) = q.pop() {
                popped.push(s);
            }
            // interleaved pushes may rewind time between drains, so the
            // definitive check replays every event into a fresh queue and
            // verifies the full drain is sorted with FIFO tie-breaks
            let mut replay = EventQueue::new();
            for s in &popped {
                replay.push(s.at, s.event);
            }
            let mut last: Option<Scheduled> = None;
            while let Some(s) = replay.pop() {
                if let Some(prev) = last {
                    assert!(
                        s.at >= prev.at,
                        "time went backwards: {} after {}",
                        s.at,
                        prev.at
                    );
                    if s.at == prev.at {
                        assert!(s.seq > prev.seq, "tie broke FIFO order");
                    }
                }
                last = Some(s);
            }
        });
    }

    #[test]
    fn kinds_are_stable_snake_case() {
        assert_eq!(Event::ComputeDone { member: 0, cluster: 0 }.kind(), "compute_done");
        assert_eq!(Event::TxDone { member: 0, cluster: 0 }.kind(), "tx_done");
        assert_eq!(Event::WindowOpen { cluster: 0 }.kind(), "window_open");
        assert_eq!(Event::WindowClose { cluster: 0 }.kind(), "window_close");
        assert_eq!(Event::UploadReady { member: 0, cluster: 0 }.kind(), "upload_ready");
        assert_eq!(Event::MergeDue { cluster: 0 }.kind(), "merge_due");
        assert_eq!(Event::EvalDue { round: 0 }.kind(), "eval_due");
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_negative_times() {
        EventQueue::new().push(-1.0, Event::EvalDue { round: 0 });
    }

    #[test]
    #[should_panic(expected = "bad event time")]
    fn rejects_non_finite_times() {
        EventQueue::new().push(f64::NAN, Event::EvalDue { round: 0 });
    }
}

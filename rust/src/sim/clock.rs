//! Simulated wall clock.
//!
//! FL time in the paper is *simulated*: a round's duration is computed from
//! the Eq. 7 max over clients, not from host wall-clock. The clock
//! accumulates those durations so orbital positions, visibility and churn
//! all evolve consistently with training progress.

/// Monotonic simulated clock (seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct SimClock {
    now: f64,
}

impl SimClock {
    pub fn new() -> SimClock {
        SimClock { now: 0.0 }
    }

    pub fn at(t: f64) -> SimClock {
        assert!(t >= 0.0);
        SimClock { now: t }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance by `dt` seconds (panics on negative dt — time is monotonic).
    pub fn advance(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative time step {dt}");
        assert!(dt.is_finite(), "non-finite time step");
        self.now += dt;
    }

    /// Jump to an absolute event timestamp (event timeline: the clock
    /// follows the event queue). Panics if `t` precedes the current time.
    pub fn advance_to(&mut self, t: f64) {
        assert!(t.is_finite(), "non-finite time target");
        assert!(t >= self.now, "time went backwards: {} -> {t}", self.now);
        self.now = t;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_accumulates() {
        let mut c = SimClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(12.5);
        c.advance(0.5);
        assert!((c.now() - 13.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "negative")]
    fn rejects_negative_steps() {
        SimClock::new().advance(-1.0);
    }

    #[test]
    fn at_constructor() {
        assert_eq!(SimClock::at(100.0).now(), 100.0);
    }

    #[test]
    fn advance_to_jumps_to_event_timestamps() {
        let mut c = SimClock::at(10.0);
        c.advance_to(10.0); // same instant is fine
        c.advance_to(42.5);
        assert_eq!(c.now(), 42.5);
    }

    #[test]
    #[should_panic(expected = "time went backwards")]
    fn advance_to_rejects_past_timestamps() {
        SimClock::at(100.0).advance_to(99.0);
    }
}

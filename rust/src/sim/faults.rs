//! Typed fault events and the event-sourced availability state they drive.
//!
//! A fault is a *delta* applied to the constellation's availability state:
//! a satellite hard-failure (and its recovery), a ground-station outage
//! window, a link-rate degradation, a compute-straggler slowdown, an ISL
//! bit-noise burst, or a PS-process crash (the recovery plane's two fault
//! processes). The
//! scenario engine ([`crate::sim::scenario`]) schedules these through the
//! shared [`crate::sim::events::EventQueue`] at round-indexed timestamps
//! and replays them into a [`FaultState`]; the coordinator only ever sees
//! the folded per-round availability, never the raw event stream.
//!
//! Multiplicative factors are carried as integer **milli-units** (a factor
//! of 0.4 is `milli: 400`) so fault events stay `Copy + Eq` like every
//! other [`crate::sim::events::Event`] payload, and so the matching
//! restore event can undo exactly the delta its onset applied (the state
//! divides by the same factor the onset multiplied by).

use anyhow::{bail, Result};

/// One typed fault delta. Onset events (`SatFail`, `GroundOutage`,
/// `LinkDegrade`, `SlowdownStart`) are always scheduled together with the
/// matching restore event, so availability is a pure fold of the stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Satellite hard-failure (radiation upset, subsystem loss): the
    /// satellite is unreachable until the matching [`Fault::SatRecover`].
    SatFail { sat: usize },
    /// Recovery from a hard failure.
    SatRecover { sat: usize },
    /// A ground station goes dark (weather, maintenance): PS↔GS passes
    /// cannot use it until the matching [`Fault::GroundRestore`].
    GroundOutage { station: usize },
    /// The station comes back.
    GroundRestore { station: usize },
    /// ISL rate degradation: the satellite's achievable link rate is
    /// multiplied by `milli / 1000` (< 1) until the matching restore.
    LinkDegrade { sat: usize, milli: u32 },
    /// Undo of the matching [`Fault::LinkDegrade`] (same `milli`).
    LinkRestore { sat: usize, milli: u32 },
    /// Compute straggler: local-training time is multiplied by
    /// `milli / 1000` (> 1) until the matching end event.
    SlowdownStart { sat: usize, milli: u32 },
    /// Undo of the matching [`Fault::SlowdownStart`] (same `milli`).
    SlowdownEnd { sat: usize, milli: u32 },
    /// ISL bit-noise burst (recovery plane): uploads transmitted by this
    /// satellite corrupt with a bit-error rate of `ber_nano / 1e9` until
    /// the matching clear. Carried in integer **nano-units** so bursts
    /// compose additively and the clear undoes exactly its onset's delta.
    LinkNoise { sat: usize, ber_nano: u32 },
    /// Undo of the matching [`Fault::LinkNoise`] (same `ber_nano`).
    LinkNoiseClear { sat: usize, ber_nano: u32 },
    /// The parameter-server *process* on this satellite crashes (recovery
    /// plane): the satellite still trains as a member, but a cluster it
    /// serves as PS must fail over to a backup until the matching restore.
    PsFailure { sat: usize },
    /// The server process comes back.
    PsRestore { sat: usize },
}

impl Fault {
    /// Whether this event *injects* a fault (vs restoring from one) — the
    /// ledger's `faults_injected` counter counts onsets only.
    pub fn is_onset(&self) -> bool {
        matches!(
            self,
            Fault::SatFail { .. }
                | Fault::GroundOutage { .. }
                | Fault::LinkDegrade { .. }
                | Fault::SlowdownStart { .. }
                | Fault::LinkNoise { .. }
                | Fault::PsFailure { .. }
        )
    }

    /// The restore event that undoes this onset (identity for restores).
    /// The scenario engine schedules every onset paired with exactly this
    /// event, which is what keeps [`FaultState::apply`] total over the
    /// replayed stream at any (round-indexed *or* continuous) timestamps.
    pub fn recovery(&self) -> Fault {
        match *self {
            Fault::SatFail { sat } => Fault::SatRecover { sat },
            Fault::GroundOutage { station } => Fault::GroundRestore { station },
            Fault::LinkDegrade { sat, milli } => Fault::LinkRestore { sat, milli },
            Fault::SlowdownStart { sat, milli } => Fault::SlowdownEnd { sat, milli },
            Fault::LinkNoise { sat, ber_nano } => Fault::LinkNoiseClear { sat, ber_nano },
            Fault::PsFailure { sat } => Fault::PsRestore { sat },
            restore => restore,
        }
    }
}

/// Convert a milli-unit factor to the f64 multiplier it encodes.
pub fn milli_factor(milli: u32) -> f64 {
    milli as f64 / 1000.0
}

/// Event-sourced availability state: the fold of every applied [`Fault`].
///
/// Outage depths are counters, not booleans, so overlapping failure
/// windows compose correctly; rate/slowdown factors compose
/// multiplicatively, and a restore divides by exactly the factor its onset
/// multiplied by.
#[derive(Clone, Debug)]
pub struct FaultState {
    /// Per-satellite hard-failure depth (> 0 means down).
    pub sat_down: Vec<u32>,
    /// Per-station outage depth (> 0 means dark).
    pub ground_down: Vec<u32>,
    /// Per-satellite ISL rate multiplier (1.0 = nominal, < 1 degraded).
    pub link_factor: Vec<f64>,
    /// Per-satellite compute-time multiplier (1.0 = nominal, > 1 slower).
    pub compute_slowdown: Vec<f64>,
    /// Per-satellite upload bit-error rate, nano-units (0 = clean).
    /// Integer state so overlapping noise bursts compose additively and
    /// every clear subtracts exactly its onset's delta — bit-exact
    /// round-trips with no float reassociation.
    pub ber_nano: Vec<u32>,
    /// Per-satellite PS-process crash depth (> 0 means the satellite
    /// cannot act as a parameter server).
    pub ps_failed: Vec<u32>,
}

impl FaultState {
    pub fn new(n_sats: usize, n_stations: usize) -> FaultState {
        FaultState {
            sat_down: vec![0; n_sats],
            ground_down: vec![0; n_stations],
            link_factor: vec![1.0; n_sats],
            compute_slowdown: vec![1.0; n_sats],
            ber_nano: vec![0; n_sats],
            ps_failed: vec![0; n_sats],
        }
    }

    /// Apply one fault delta. Restores of faults that were never applied
    /// are rejected — the scenario engine always schedules onset/restore
    /// in pairs, so an unmatched restore is a scheduling bug.
    pub fn apply(&mut self, fault: Fault) -> Result<()> {
        match fault {
            Fault::SatFail { sat } => self.sat_down[sat] += 1,
            Fault::SatRecover { sat } => {
                if self.sat_down[sat] == 0 {
                    bail!("recovery for satellite {sat} that never failed");
                }
                self.sat_down[sat] -= 1;
            }
            Fault::GroundOutage { station } => self.ground_down[station] += 1,
            Fault::GroundRestore { station } => {
                if self.ground_down[station] == 0 {
                    bail!("restore for station {station} that never went dark");
                }
                self.ground_down[station] -= 1;
            }
            Fault::LinkDegrade { sat, milli } => {
                if milli == 0 || milli >= 1000 {
                    bail!("link degradation factor must be in (0, 1), got {milli} milli");
                }
                self.link_factor[sat] *= milli_factor(milli);
            }
            Fault::LinkRestore { sat, milli } => {
                self.link_factor[sat] /= milli_factor(milli);
            }
            Fault::SlowdownStart { sat, milli } => {
                if milli <= 1000 {
                    bail!("straggler slowdown must exceed 1.0, got {milli} milli");
                }
                self.compute_slowdown[sat] *= milli_factor(milli);
            }
            Fault::SlowdownEnd { sat, milli } => {
                self.compute_slowdown[sat] /= milli_factor(milli);
            }
            Fault::LinkNoise { sat, ber_nano } => {
                if ber_nano == 0 || ber_nano >= 1_000_000_000 {
                    bail!("link-noise BER must be in (0, 1), got {ber_nano} nano");
                }
                self.ber_nano[sat] = match self.ber_nano[sat].checked_add(ber_nano) {
                    Some(v) => v,
                    None => bail!("stacked noise bursts on satellite {sat} overflow"),
                };
            }
            Fault::LinkNoiseClear { sat, ber_nano } => {
                if self.ber_nano[sat] < ber_nano {
                    bail!("noise clear for satellite {sat} exceeds its active burst");
                }
                self.ber_nano[sat] -= ber_nano;
            }
            Fault::PsFailure { sat } => self.ps_failed[sat] += 1,
            Fault::PsRestore { sat } => {
                if self.ps_failed[sat] == 0 {
                    bail!("restore for a PS process on satellite {sat} that never crashed");
                }
                self.ps_failed[sat] -= 1;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn onset_classification() {
        assert!(Fault::SatFail { sat: 0 }.is_onset());
        assert!(Fault::GroundOutage { station: 1 }.is_onset());
        assert!(Fault::LinkDegrade { sat: 0, milli: 500 }.is_onset());
        assert!(Fault::SlowdownStart { sat: 0, milli: 2000 }.is_onset());
        assert!(Fault::LinkNoise { sat: 0, ber_nano: 500 }.is_onset());
        assert!(Fault::PsFailure { sat: 0 }.is_onset());
        assert!(!Fault::SatRecover { sat: 0 }.is_onset());
        assert!(!Fault::GroundRestore { station: 1 }.is_onset());
        assert!(!Fault::LinkRestore { sat: 0, milli: 500 }.is_onset());
        assert!(!Fault::SlowdownEnd { sat: 0, milli: 2000 }.is_onset());
        assert!(!Fault::LinkNoiseClear { sat: 0, ber_nano: 500 }.is_onset());
        assert!(!Fault::PsRestore { sat: 0 }.is_onset());
    }

    #[test]
    fn recovery_pairs_with_its_onset() {
        let onsets = [
            Fault::SatFail { sat: 3 },
            Fault::GroundOutage { station: 1 },
            Fault::LinkDegrade { sat: 2, milli: 400 },
            Fault::SlowdownStart { sat: 0, milli: 2000 },
            Fault::LinkNoise { sat: 1, ber_nano: 750 },
            Fault::PsFailure { sat: 2 },
        ];
        for onset in onsets {
            let rec = onset.recovery();
            assert!(!rec.is_onset(), "{onset:?} paired with onset {rec:?}");
            assert_eq!(rec.recovery(), rec, "recovery of a restore is itself");
            // applying the pair round-trips the state to nominal
            let mut s = FaultState::new(4, 2);
            s.apply(onset).unwrap();
            s.apply(rec).unwrap();
            assert_eq!(s.sat_down, vec![0; 4]);
            assert_eq!(s.ground_down, vec![0; 2]);
            assert_eq!(s.link_factor, vec![1.0; 4]);
            assert_eq!(s.compute_slowdown, vec![1.0; 4]);
            assert_eq!(s.ber_nano, vec![0; 4]);
            assert_eq!(s.ps_failed, vec![0; 4]);
        }
    }

    #[test]
    fn overlapping_failures_compose_by_depth() {
        let mut s = FaultState::new(2, 1);
        s.apply(Fault::SatFail { sat: 0 }).unwrap();
        s.apply(Fault::SatFail { sat: 0 }).unwrap();
        s.apply(Fault::SatRecover { sat: 0 }).unwrap();
        assert_eq!(s.sat_down[0], 1, "still down until the second recovery");
        s.apply(Fault::SatRecover { sat: 0 }).unwrap();
        assert_eq!(s.sat_down[0], 0);
        assert!(s.apply(Fault::SatRecover { sat: 0 }).is_err());
        assert!(s.apply(Fault::GroundRestore { station: 0 }).is_err());
    }

    #[test]
    fn factor_restore_undoes_onset_exactly() {
        let mut s = FaultState::new(1, 0);
        s.apply(Fault::LinkDegrade { sat: 0, milli: 400 }).unwrap();
        assert!(s.link_factor[0] < 1.0);
        s.apply(Fault::LinkRestore { sat: 0, milli: 400 }).unwrap();
        assert_eq!(s.link_factor[0], 1.0, "restore must undo the onset bit-exactly");
        s.apply(Fault::SlowdownStart { sat: 0, milli: 3000 }).unwrap();
        assert_eq!(s.compute_slowdown[0], 3.0);
        s.apply(Fault::SlowdownEnd { sat: 0, milli: 3000 }).unwrap();
        assert_eq!(s.compute_slowdown[0], 1.0);
    }

    #[test]
    fn bad_factors_rejected() {
        let mut s = FaultState::new(1, 0);
        assert!(s.apply(Fault::LinkDegrade { sat: 0, milli: 0 }).is_err());
        assert!(s.apply(Fault::LinkDegrade { sat: 0, milli: 1000 }).is_err());
        assert!(s.apply(Fault::SlowdownStart { sat: 0, milli: 1000 }).is_err());
        assert!(s.apply(Fault::LinkNoise { sat: 0, ber_nano: 0 }).is_err());
        assert!(s
            .apply(Fault::LinkNoise { sat: 0, ber_nano: 1_000_000_000 })
            .is_err());
    }

    #[test]
    fn noise_bursts_stack_additively_and_clear_exactly() {
        let mut s = FaultState::new(2, 0);
        s.apply(Fault::LinkNoise { sat: 0, ber_nano: 300 }).unwrap();
        s.apply(Fault::LinkNoise { sat: 0, ber_nano: 500 }).unwrap();
        assert_eq!(s.ber_nano[0], 800, "overlapping bursts compose additively");
        s.apply(Fault::LinkNoiseClear { sat: 0, ber_nano: 300 }).unwrap();
        assert_eq!(s.ber_nano[0], 500, "each clear undoes exactly its onset");
        s.apply(Fault::LinkNoiseClear { sat: 0, ber_nano: 500 }).unwrap();
        assert_eq!(s.ber_nano, vec![0, 0]);
        assert!(
            s.apply(Fault::LinkNoiseClear { sat: 0, ber_nano: 1 }).is_err(),
            "a clear larger than the active burst is a scheduling bug"
        );
    }

    #[test]
    fn ps_crashes_compose_by_depth() {
        let mut s = FaultState::new(2, 0);
        s.apply(Fault::PsFailure { sat: 1 }).unwrap();
        s.apply(Fault::PsFailure { sat: 1 }).unwrap();
        s.apply(Fault::PsRestore { sat: 1 }).unwrap();
        assert_eq!(s.ps_failed[1], 1, "still crashed until the second restore");
        s.apply(Fault::PsRestore { sat: 1 }).unwrap();
        assert_eq!(s.ps_failed, vec![0, 0]);
        assert!(s.apply(Fault::PsRestore { sat: 1 }).is_err());
        // a crashed server process does not take the satellite down
        s.apply(Fault::PsFailure { sat: 0 }).unwrap();
        assert_eq!(s.sat_down, vec![0, 0]);
    }
}

//! Discrete-event simulation substrate: the simulated clock the FL rounds
//! advance, the time-ordered event queue behind the event timeline
//! (`--timeline event`), the mobility process that turns orbital motion
//! into cluster-membership churn (join/leave events that drive the paper's
//! re-clustering trigger), the scenario plane (typed fault events folded
//! into per-round availability — hard failures, ground outages, link
//! degradation, stragglers, eclipse power-save), the deterministic
//! parallel round engine that fans local training out across OS threads
//! without perturbing the simulated numerics, and the recycled buffer
//! pools that keep the steady-state round loop free of parameter-sized
//! allocations.

pub mod clock;
pub mod engine;
pub mod events;
pub mod faults;
pub mod mobility;
pub mod param_pool;
pub mod scenario;

pub use clock::SimClock;
pub use engine::Engine;
pub use events::{Event, EventQueue};
pub use faults::{Fault, FaultState};
pub use mobility::MobilityModel;
pub use param_pool::{ParamPool, Recycled, ScratchPool};
pub use scenario::{Availability, ScenarioConfig, ScenarioEngine, ScenarioKind};

//! Discrete-time simulation substrate: the simulated clock the FL rounds
//! advance, and the mobility process that turns orbital motion into
//! cluster-membership churn (join/leave events that drive the paper's
//! re-clustering trigger).

pub mod clock;
pub mod mobility;

pub use clock::SimClock;
pub use mobility::MobilityModel;

//! Deterministic scatter-gather executor — the parallel round engine.
//!
//! Per-satellite local training is embarrassingly parallel (each client
//! trains on its own shard from the cluster model it was handed), yet the
//! seed coordinator trained every client sequentially inside the round
//! loop. This engine fans that work out across OS threads
//! (`std::thread::scope`) while keeping runs **bit-for-bit deterministic
//! in the worker count**:
//!
//! * Tasks are claimed from a shared atomic cursor (work stealing), but
//!   every result is returned **in task order**, so downstream reductions
//!   (weighted aggregation, time/energy folds) always see the same
//!   operand order.
//! * Jobs must not share mutable state; per-client randomness is derived
//!   statelessly from `(seed, round, sat_id)` via
//!   [`crate::util::rng::stream_seed`], never from a shared generator, so
//!   the schedule cannot leak into the numerics.
//!
//! The worker count comes from `ExperimentConfig::workers`
//! (`--workers N`; `0` means all available cores). `bench_runtime` sweeps
//! workers vs wall-clock over both a synthetic load and the full round
//! loop.
//!
//! ```
//! use fedhc::sim::engine::Engine;
//!
//! let engine = Engine::new(4);
//! let squares = engine.run(&[1u64, 2, 3, 4], |_, &x| x * x);
//! assert_eq!(squares, vec![1, 4, 9, 16]);
//! ```

use std::sync::atomic::{AtomicUsize, Ordering};

/// A fixed-width thread pool for deterministic scatter-gather rounds.
#[derive(Clone, Copy, Debug)]
pub struct Engine {
    workers: usize,
}

impl Default for Engine {
    fn default() -> Engine {
        Engine::new(0)
    }
}

impl Engine {
    /// `workers == 0` selects all available cores (at least 1).
    pub fn new(workers: usize) -> Engine {
        let workers = if workers == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            workers
        };
        Engine { workers }
    }

    /// Configured worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Map `job` over `tasks`, returning results in task order.
    pub fn run<T, R, F>(&self, tasks: &[T], job: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        F: Fn(usize, &T) -> R + Sync,
    {
        self.run_with(tasks, || (), |_, i, t| job(i, t))
    }

    /// Like [`Engine::run`], but each worker first builds private scratch
    /// state with `init` (e.g. training buffers) that is reused across all
    /// tasks that worker claims.
    pub fn run_with<T, R, S, I, F>(&self, tasks: &[T], init: I, job: F) -> Vec<R>
    where
        T: Sync,
        R: Send,
        I: Fn() -> S + Sync,
        F: Fn(&mut S, usize, &T) -> R + Sync,
    {
        let n = tasks.len();
        let workers = self.workers.min(n);
        if workers <= 1 {
            let mut state = init();
            return tasks
                .iter()
                .enumerate()
                .map(|(i, t)| job(&mut state, i, t))
                .collect();
        }

        let cursor = AtomicUsize::new(0);
        let mut parts: Vec<Vec<(usize, R)>> = Vec::with_capacity(workers);
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    scope.spawn(|| {
                        let mut state = init();
                        let mut out = Vec::new();
                        loop {
                            let i = cursor.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            out.push((i, job(&mut state, i, &tasks[i])));
                        }
                        out
                    })
                })
                .collect();
            for h in handles {
                parts.push(h.join().expect("engine worker panicked"));
            }
        });

        // gather back into task order
        let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
        slots.resize_with(n, || None);
        for (i, r) in parts.into_iter().flatten() {
            debug_assert!(slots[i].is_none(), "task {i} produced twice");
            slots[i] = Some(r);
        }
        slots
            .into_iter()
            .map(|s| s.expect("engine lost a task result"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::stream_seed;
    use crate::util::Rng;

    #[test]
    fn preserves_task_order() {
        let tasks: Vec<usize> = (0..100).collect();
        let want: Vec<usize> = (0..100).map(|t| t * t).collect();
        for workers in [1usize, 2, 7, 16] {
            let out = Engine::new(workers).run(&tasks, |i, &t| {
                assert_eq!(i, t);
                t * t
            });
            assert_eq!(out, want, "workers={workers}");
        }
    }

    #[test]
    fn identical_results_for_any_worker_count() {
        // per-task seeded RNG: results are schedule-independent by design
        let tasks: Vec<u64> = (0..64).collect();
        let run = |w: usize| {
            Engine::new(w).run(&tasks, |_, &t| {
                let mut rng = Rng::new(stream_seed(42, 1, t));
                (0..100).map(|_| rng.uniform()).sum::<f64>()
            })
        };
        let base = run(1);
        assert_eq!(base, run(3));
        assert_eq!(base, run(8));
    }

    #[test]
    fn per_worker_state_is_built_once_per_worker() {
        use std::sync::atomic::AtomicUsize;
        let inits = AtomicUsize::new(0);
        let tasks: Vec<usize> = (0..32).collect();
        let engine = Engine::new(4);
        let out = engine.run_with(
            &tasks,
            || {
                inits.fetch_add(1, Ordering::Relaxed);
                0usize
            },
            |state, _, &t| {
                *state += 1;
                t
            },
        );
        assert_eq!(out, tasks);
        assert!(inits.load(Ordering::Relaxed) <= 4, "state built per task?");
    }

    #[test]
    fn auto_worker_count_is_positive() {
        assert!(Engine::new(0).workers() >= 1);
        assert_eq!(Engine::new(3).workers(), 3);
    }

    #[test]
    fn empty_task_list() {
        let tasks: [u32; 0] = [];
        let out = Engine::new(8).run(&tasks, |_, &t| t);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_tasks() {
        let tasks = [10u32, 20];
        let out = Engine::new(16).run(&tasks, |_, &t| t + 1);
        assert_eq!(out, vec![11, 21]);
    }
}

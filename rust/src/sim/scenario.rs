//! Deterministic fault-injection scenarios.
//!
//! The scenario engine turns a [`ScenarioConfig`] into a per-round
//! [`Availability`] fold that the coordinator consumes: which satellites
//! are unreachable (hard failure, eclipse power-save, transient outage),
//! how much each satellite's ISL rate and compute speed are degraded, and
//! which ground stations are dark. It replaces the old per-round
//! `outage_prob` coin flip with **event-sourced** availability: fault
//! onsets and their recoveries are typed [`Fault`] events scheduled
//! through the shared [`EventQueue`] at round-indexed timestamps, so a
//! failure injected in round `r` keeps its satellite down until the
//! matching recovery pops in round `r + d`.
//!
//! Determinism: every draw comes from a stateless
//! [`stream_seed`]`(seed ^ SALT, round, sat)` stream — never from the
//! trial's stateful generator — so the fault trajectory is a pure function
//! of `(seed, round, entity)` and is bit-identical for any `--workers`
//! count, any evaluation cadence, and any method sharing the seed.
//!
//! Scope of each degradation (documented here, asserted by the scenario
//! tests): unreachable satellites skip local training, count as dropouts
//! toward the re-clustering trigger `d_r`, and — when the unreachable
//! satellite is a cluster's PS — stale that cluster's ground pass (a dead
//! hub cannot exchange); link factors scale intra-cluster model uplinks
//! (and C-FedAvg raw-data uploads), not the ground link; slowdowns scale
//! local compute time; dark stations are removed from the ground plan for
//! the round — a round with **no** live station skips the pass entirely
//! and every PS goes stale.

use crate::orbit::{Vec3, EARTH_RADIUS};
use crate::sim::events::{Event, EventQueue};
use crate::sim::faults::{Fault, FaultState};
use crate::util::rng::stream_seed;
use crate::util::Rng;
use anyhow::{bail, Result};

/// Domain-separation salts for the per-entity fault streams (arbitrary
/// constants; they only need to differ from each other and from the
/// training streams, which use the unsalted master seed).
const SAT_FAULT_SALT: u64 = 0xFA01_7E5C_11D0_0001;
const GROUND_FAULT_SALT: u64 = 0xFA01_7E5C_11D0_0002;
const TRANSIENT_SALT: u64 = 0xFA01_7E5C_11D0_0003;
/// Recovery plane: link-noise burst onsets. A fresh salt (rather than
/// extra draws on the `SAT_FAULT_SALT` stream) so enabling the noise
/// process cannot shift the churn/flaky/straggler trigger or duration
/// draws of existing presets.
const NOISE_FAULT_SALT: u64 = 0xFA01_7E5C_11D0_0004;
/// Recovery plane: PS-process crash onsets (same isolation argument).
const PS_FAULT_SALT: u64 = 0xFA01_7E5C_11D0_0005;
/// Recovery plane: per-transfer corruption draws for member → PS uploads
/// (consumed by the coordinator, one stream per `(round, sender)`).
pub const CORRUPT_SALT: u64 = 0xFA01_7E5C_11D0_0006;
/// Recovery plane: per-transfer corruption draws for PS → GS uploads — a
/// separate salt because the PS satellite's `(round, sat)` stream is
/// already consumed by its own member upload.
pub const CORRUPT_GROUND_SALT: u64 = 0xFA01_7E5C_11D0_0007;
/// Routing plane: per-hop corruption draws on multi-hop ISL relays. A
/// fresh salt keyed by the *transmitting* satellite so routed runs cannot
/// perturb the direct path's `CORRUPT_SALT` streams (and vice versa) —
/// `--routing direct` stays bit-identical to the committed goldens.
pub const RELAY_CORRUPT_SALT: u64 = 0xFA01_7E5C_11D0_0008;

/// Named scenario preset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScenarioKind {
    /// Baseline: only the transient per-round outage process runs.
    Nominal,
    /// Satellite hard-failures with multi-round recoveries — the regime
    /// that drives `d_r` past `Z` and fires re-clustering.
    Churn,
    /// Ground-station outage windows plus ISL rate degradation.
    FlakyGround,
    /// Compute stragglers: multi-round slowdowns on random satellites.
    Stragglers,
    /// Eclipse power-save: satellites in Earth's shadow skip the round.
    Eclipse,
    /// Recovery plane: ISL bit-noise bursts — uploads corrupt, receivers
    /// checksum-reject, senders retry with exponential backoff.
    NoisyLinks,
    /// Recovery plane: PS-process crashes — clusters fail over to a
    /// backup PS mid-round.
    PsCrash,
}

impl ScenarioKind {
    /// Every preset, in CLI order.
    pub const ALL: [ScenarioKind; 7] = [
        ScenarioKind::Nominal,
        ScenarioKind::Churn,
        ScenarioKind::FlakyGround,
        ScenarioKind::Stragglers,
        ScenarioKind::Eclipse,
        ScenarioKind::NoisyLinks,
        ScenarioKind::PsCrash,
    ];

    /// Parse the `--scenario` flag value.
    pub fn parse(s: &str) -> Option<ScenarioKind> {
        match s {
            "nominal" => Some(ScenarioKind::Nominal),
            "churn" => Some(ScenarioKind::Churn),
            "flaky-ground" => Some(ScenarioKind::FlakyGround),
            "stragglers" => Some(ScenarioKind::Stragglers),
            "eclipse" => Some(ScenarioKind::Eclipse),
            "noisy-links" => Some(ScenarioKind::NoisyLinks),
            "ps-crash" => Some(ScenarioKind::PsCrash),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ScenarioKind::Nominal => "nominal",
            ScenarioKind::Churn => "churn",
            ScenarioKind::FlakyGround => "flaky-ground",
            ScenarioKind::Stragglers => "stragglers",
            ScenarioKind::Eclipse => "eclipse",
            ScenarioKind::NoisyLinks => "noisy-links",
            ScenarioKind::PsCrash => "ps-crash",
        }
    }
}

/// Fault-process knobs for one run. Presets set the defaults; every knob
/// is individually overridable from the CLI / config file
/// (`--scenario-sat-fail 0.1`, `scenario-slowdown = 4.0`, ...).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioConfig {
    /// Which preset the knobs started from (for reporting).
    pub kind: ScenarioKind,
    /// Per-satellite per-round hard-failure probability.
    pub sat_fail_prob: f64,
    /// Max failure duration, rounds (drawn uniform in `1..=max`).
    pub sat_fail_rounds: u64,
    /// Per-station per-round outage probability.
    pub ground_outage_prob: f64,
    /// Max station outage duration, rounds.
    pub ground_outage_rounds: u64,
    /// Per-satellite per-round link-degradation probability.
    pub link_degrade_prob: f64,
    /// Floor of the degraded rate factor, milli-units (drawn uniform in
    /// `floor..1000`, i.e. a factor in `[floor/1000, 1)`).
    pub link_degrade_milli: u32,
    /// Max link-degradation duration, rounds.
    pub link_degrade_rounds: u64,
    /// Per-satellite per-round straggler probability.
    pub straggler_prob: f64,
    /// Ceiling of the compute slowdown, milli-units (drawn uniform in
    /// `1001..=ceiling`, i.e. a factor in `(1, ceiling/1000]`).
    pub straggler_milli: u32,
    /// Max straggler duration, rounds.
    pub straggler_rounds: u64,
    /// Geometric eclipse power-save: a satellite inside Earth's shadow
    /// cylinder (sun fixed along +X) skips the round.
    pub eclipse: bool,
    /// Per-satellite per-round link-noise burst probability (the
    /// recovery plane's corruption process).
    pub link_noise_prob: f64,
    /// Ceiling of the drawn burst BER, nano-units (drawn uniform in
    /// `1..=ceiling`, i.e. a bit-error rate in `(0, ceiling/1e9]`).
    pub link_noise_ber_nano: u32,
    /// Max link-noise burst duration, rounds.
    pub link_noise_rounds: u64,
    /// Per-satellite per-round PS-process crash probability (only
    /// crashes on a satellite currently serving as a PS trigger a
    /// failover; the rest are harmless process restarts).
    pub ps_fail_prob: f64,
    /// Max PS-process outage duration, rounds.
    pub ps_fail_rounds: u64,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig::preset(ScenarioKind::Nominal)
    }
}

impl ScenarioConfig {
    /// The knob defaults for a named preset. Each preset turns on exactly
    /// one fault process (they compose via the individual knobs).
    pub fn preset(kind: ScenarioKind) -> ScenarioConfig {
        let off = ScenarioConfig {
            kind,
            sat_fail_prob: 0.0,
            sat_fail_rounds: 4,
            ground_outage_prob: 0.0,
            ground_outage_rounds: 2,
            link_degrade_prob: 0.0,
            link_degrade_milli: 400,
            link_degrade_rounds: 2,
            straggler_prob: 0.0,
            straggler_milli: 5000,
            straggler_rounds: 3,
            eclipse: false,
            link_noise_prob: 0.0,
            link_noise_ber_nano: 500,
            link_noise_rounds: 2,
            ps_fail_prob: 0.0,
            ps_fail_rounds: 2,
        };
        match kind {
            ScenarioKind::Nominal => off,
            ScenarioKind::Churn => ScenarioConfig { sat_fail_prob: 0.08, ..off },
            ScenarioKind::FlakyGround => ScenarioConfig {
                ground_outage_prob: 0.25,
                link_degrade_prob: 0.10,
                ..off
            },
            ScenarioKind::Stragglers => ScenarioConfig { straggler_prob: 0.15, ..off },
            ScenarioKind::Eclipse => ScenarioConfig { eclipse: true, ..off },
            ScenarioKind::NoisyLinks => ScenarioConfig { link_noise_prob: 0.25, ..off },
            ScenarioKind::PsCrash => ScenarioConfig { ps_fail_prob: 0.2, ..off },
        }
    }

    /// Sanity-check the knobs (CLI/config error-handling style: usage
    /// errors, not panics).
    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("scenario-sat-fail", self.sat_fail_prob),
            ("scenario-ground-outage", self.ground_outage_prob),
            ("scenario-link-degrade", self.link_degrade_prob),
            ("scenario-straggler", self.straggler_prob),
            ("scenario-link-noise", self.link_noise_prob),
            ("scenario-ps-fail", self.ps_fail_prob),
        ] {
            if !(0.0..1.0).contains(&p) {
                bail!("{name} must be a probability in [0, 1), got {p}");
            }
        }
        if self.sat_fail_prob > 0.0 && self.sat_fail_rounds < 1 {
            bail!("scenario-fail-rounds must be at least 1");
        }
        if self.ground_outage_prob > 0.0 && self.ground_outage_rounds < 1 {
            bail!("scenario-ground-rounds must be at least 1");
        }
        if self.link_degrade_prob > 0.0 {
            if !(1..1000).contains(&self.link_degrade_milli) {
                bail!(
                    "scenario-link-factor must be in (0, 1), got {}",
                    self.link_degrade_milli as f64 / 1000.0
                );
            }
            if self.link_degrade_rounds < 1 {
                bail!("scenario-link-rounds must be at least 1");
            }
        }
        if self.straggler_prob > 0.0 {
            if self.straggler_milli <= 1000 {
                bail!(
                    "scenario-slowdown must exceed 1.0, got {}",
                    self.straggler_milli as f64 / 1000.0
                );
            }
            if self.straggler_rounds < 1 {
                bail!("scenario-straggler-rounds must be at least 1");
            }
        }
        if self.link_noise_prob > 0.0 {
            if !(1..1_000_000_000).contains(&self.link_noise_ber_nano) {
                bail!(
                    "scenario-noise-ber must be in (0, 1), got {:e}",
                    self.link_noise_ber_nano as f64 / 1e9
                );
            }
            if self.link_noise_rounds < 1 {
                bail!("scenario-noise-rounds must be at least 1");
            }
        }
        if self.ps_fail_prob > 0.0 && self.ps_fail_rounds < 1 {
            bail!("scenario-ps-rounds must be at least 1");
        }
        Ok(())
    }
}

/// The folded availability the coordinator consumes for one round.
#[derive(Clone, Debug)]
pub struct Availability {
    /// Satellites that skip this round entirely (hard failure, eclipse
    /// power-save, or transient outage) — these count as dropouts toward
    /// the re-clustering trigger.
    pub unreachable: Vec<bool>,
    /// Per-satellite ISL rate multiplier (1.0 nominal).
    pub link_factor: Vec<f64>,
    /// Per-satellite compute-time multiplier (1.0 nominal).
    pub compute_slowdown: Vec<f64>,
    /// Ground stations dark this round.
    pub ground_down: Vec<bool>,
    /// Per-satellite additive bit-error rate from active noise bursts
    /// (0.0 nominal; the coordinator adds the global `--ber` floor on
    /// top before drawing per-transfer corruption).
    pub ber: Vec<f64>,
    /// Satellites whose PS *process* is crashed this round. The
    /// satellite itself still trains as a member; only a cluster whose
    /// elected PS appears here fails over.
    pub ps_failed: Vec<bool>,
    /// Fault onsets injected this round (feeds the ledger counter).
    pub faults_injected: usize,
}

/// Per-run fault-injection engine: owns the fault event queue and the
/// event-sourced [`FaultState`], and folds both with the stateless
/// transient-outage and eclipse processes into one [`Availability`] per
/// advance. Construct once per trial; drive it either per round
/// ([`ScenarioEngine::advance_round`], the sync coordinator) or at
/// arbitrary non-decreasing event times
/// ([`ScenarioEngine::advance_to`], the buffered/async plane). Both are
/// the same machine: round `r` is event time `r` seconds of round-time,
/// and the per-round onset draws fire exactly once per integer boundary
/// no matter how finely the interval is sampled.
#[derive(Debug)]
pub struct ScenarioEngine {
    cfg: ScenarioConfig,
    /// Transient per-round outage probability (the legacy
    /// `MobilityModel::outage_prob` process, now event-stream seeded).
    outage_prob: f64,
    seed: u64,
    n_sats: usize,
    n_stations: usize,
    queue: EventQueue,
    state: FaultState,
    in_eclipse: Vec<bool>,
    /// Highest integer round boundary whose onset draws have run — the
    /// cursor that guarantees each boundary's draws happen exactly once.
    drawn_to: u64,
    /// Monotone clock of the last `advance_to` (round-time units).
    advanced_to: f64,
    /// Transient-outage fold of the last crossed boundary, reused by
    /// fractional advances inside the same round (a transient outage
    /// lasts its whole round; re-drawing it mid-round would double-fire).
    transient: Vec<bool>,
}

impl ScenarioEngine {
    pub fn new(
        cfg: ScenarioConfig,
        outage_prob: f64,
        seed: u64,
        n_sats: usize,
        n_stations: usize,
    ) -> Result<ScenarioEngine> {
        cfg.validate()?;
        if !(0.0..1.0).contains(&outage_prob) {
            bail!("outage probability must be in [0, 1), got {outage_prob}");
        }
        Ok(ScenarioEngine {
            cfg,
            outage_prob,
            seed,
            n_sats,
            n_stations,
            queue: EventQueue::new(),
            state: FaultState::new(n_sats, n_stations),
            in_eclipse: vec![false; n_sats],
            drawn_to: 0,
            advanced_to: 0.0,
            transient: vec![false; n_sats],
        })
    }

    /// The scenario knobs this engine runs.
    pub fn config(&self) -> &ScenarioConfig {
        &self.cfg
    }

    /// Inject this round's new faults, replay every due fault event, and
    /// fold the availability the round runs under. `positions` are the
    /// satellites' ECI positions at the round start (drives the eclipse
    /// geometry; ignored unless the eclipse process is on). Exactly
    /// [`ScenarioEngine::advance_to`] at event time `round` — the
    /// round-indexed schedule lands every fault at the precise timestamp
    /// the old round boundary implied (pinned by `tests/scenarios.rs`).
    pub fn advance_round(&mut self, round: u64, positions: &[Vec3]) -> Availability {
        self.advance_to(round as f64, positions)
    }

    /// Advance the fault plane to continuous event time `rtime`
    /// (round-time units; must be non-decreasing across calls). Crossing
    /// an integer round boundary runs that boundary's onset draws and
    /// transient coin flips exactly once — fractional re-samples inside a
    /// round replay only queued events, so no onset, recovery or
    /// transient outage can ever double-fire.
    pub fn advance_to(&mut self, rtime: f64, positions: &[Vec3]) -> Availability {
        assert!(rtime.is_finite() && rtime >= 0.0, "bad scenario time {rtime}");
        assert!(
            rtime >= self.advanced_to,
            "scenario time went backwards: {rtime} after {}",
            self.advanced_to
        );
        self.advanced_to = rtime;

        let mut injected = 0usize;
        // 1. cross every integer boundary up to rtime in order: draw that
        //    boundary's onsets, apply its due events, refresh transients.
        //    Draws run before the boundary's own events apply, exactly as
        //    the round-indexed engine did (a satellite recovering at
        //    round r is still down for round r's onset guard).
        let hi = rtime.floor() as u64;
        while self.drawn_to < hi {
            let round = self.drawn_to + 1;
            self.draw_onsets(round);
            injected += self.replay_due(round as f64);
            injected += self.refresh_transients(round);
            self.drawn_to = round;
        }
        // 2. the fractional tail: anything `push_at` scheduled strictly
        //    between the last boundary and rtime
        injected += self.replay_due(rtime);
        // 3. eclipse power-save tracks the sampled geometry continuously;
        //    the in/out latch counts each shadow entry exactly once
        injected += self.refresh_eclipse(positions);

        // 4. fold
        let mut unreachable = self.transient.clone();
        for sat in 0..self.n_sats {
            unreachable[sat] =
                unreachable[sat] || self.state.sat_down[sat] > 0 || self.in_eclipse[sat];
        }
        Availability {
            unreachable,
            link_factor: self.state.link_factor.clone(),
            compute_slowdown: self.state.compute_slowdown.clone(),
            ground_down: self.state.ground_down.iter().map(|&d| d > 0).collect(),
            ber: self.state.ber_nano.iter().map(|&n| n as f64 / 1e9).collect(),
            ps_failed: self.state.ps_failed.iter().map(|&d| d > 0).collect(),
            faults_injected: injected,
        }
    }

    /// Schedule new fault onsets (and their recoveries) for one round
    /// boundary from the stateless per-(round, entity) streams.
    fn draw_onsets(&mut self, round: u64) {
        let c = self.cfg;
        let sat_processes =
            c.sat_fail_prob > 0.0 || c.link_degrade_prob > 0.0 || c.straggler_prob > 0.0;
        if sat_processes {
            for sat in 0..self.n_sats {
                let mut rng = Rng::new(stream_seed(self.seed ^ SAT_FAULT_SALT, round, sat as u64));
                // fixed draw order keeps each process's trigger stream
                // independent of the other processes' knobs
                let u_fail = rng.uniform();
                let u_link = rng.uniform();
                let u_slow = rng.uniform();
                if u_fail < c.sat_fail_prob && self.state.sat_down[sat] == 0 {
                    let dur = 1 + rng.below(c.sat_fail_rounds);
                    self.push(round, Fault::SatFail { sat });
                    self.push(round + dur, Fault::SatRecover { sat });
                }
                if u_link < c.link_degrade_prob && self.state.link_factor[sat] == 1.0 {
                    let span = (1000 - c.link_degrade_milli) as u64;
                    let milli = c.link_degrade_milli + rng.below(span.max(1)) as u32;
                    let dur = 1 + rng.below(c.link_degrade_rounds);
                    self.push(round, Fault::LinkDegrade { sat, milli });
                    self.push(round + dur, Fault::LinkRestore { sat, milli });
                }
                if u_slow < c.straggler_prob && self.state.compute_slowdown[sat] == 1.0 {
                    let span = (c.straggler_milli - 1000) as u64;
                    let milli = 1001 + rng.below(span.max(1)) as u32;
                    let dur = 1 + rng.below(c.straggler_rounds);
                    self.push(round, Fault::SlowdownStart { sat, milli });
                    self.push(round + dur, Fault::SlowdownEnd { sat, milli });
                }
            }
        }
        if c.ground_outage_prob > 0.0 {
            for station in 0..self.n_stations {
                let mut rng =
                    Rng::new(stream_seed(self.seed ^ GROUND_FAULT_SALT, round, station as u64));
                if rng.uniform() < c.ground_outage_prob && self.state.ground_down[station] == 0 {
                    let dur = 1 + rng.below(c.ground_outage_rounds);
                    self.push(round, Fault::GroundOutage { station });
                    self.push(round + dur, Fault::GroundRestore { station });
                }
            }
        }
        if c.link_noise_prob > 0.0 {
            for sat in 0..self.n_sats {
                let mut rng =
                    Rng::new(stream_seed(self.seed ^ NOISE_FAULT_SALT, round, sat as u64));
                if rng.uniform() < c.link_noise_prob && self.state.ber_nano[sat] == 0 {
                    let ber_nano = 1 + rng.below(c.link_noise_ber_nano as u64) as u32;
                    let dur = 1 + rng.below(c.link_noise_rounds);
                    self.push(round, Fault::LinkNoise { sat, ber_nano });
                    self.push(round + dur, Fault::LinkNoiseClear { sat, ber_nano });
                }
            }
        }
        if c.ps_fail_prob > 0.0 {
            for sat in 0..self.n_sats {
                let mut rng = Rng::new(stream_seed(self.seed ^ PS_FAULT_SALT, round, sat as u64));
                if rng.uniform() < c.ps_fail_prob && self.state.ps_failed[sat] == 0 {
                    let dur = 1 + rng.below(c.ps_fail_rounds);
                    self.push(round, Fault::PsFailure { sat });
                    self.push(round + dur, Fault::PsRestore { sat });
                }
            }
        }
    }

    /// Replay every fault event due by `t` into the state; returns the
    /// number of onsets applied.
    fn replay_due(&mut self, t: f64) -> usize {
        let mut injected = 0usize;
        while self.queue.peek_time().is_some_and(|due| due <= t) {
            let ev = self.queue.pop().expect("peeked event vanished");
            let Event::Fault { fault } = ev.event else {
                unreachable!("scenario queue held a non-fault event");
            };
            if fault.is_onset() {
                injected += 1;
            }
            self.state
                .apply(fault)
                .expect("paired fault schedule produced an unmatched restore");
        }
        injected
    }

    /// Re-draw the transient per-round outages for one boundary (the
    /// legacy mobility coin flip, re-seeded onto a stateless stream);
    /// returns the number of outages drawn.
    fn refresh_transients(&mut self, round: u64) -> usize {
        let mut injected = 0usize;
        self.transient.iter_mut().for_each(|t| *t = false);
        if self.outage_prob > 0.0 {
            for (sat, out) in self.transient.iter_mut().enumerate() {
                let mut rng = Rng::new(stream_seed(self.seed ^ TRANSIENT_SALT, round, sat as u64));
                if rng.uniform() < self.outage_prob {
                    *out = true;
                    injected += 1;
                }
            }
        }
        injected
    }

    /// Update the eclipse latch from the sampled positions; returns the
    /// number of fresh shadow entries.
    fn refresh_eclipse(&mut self, positions: &[Vec3]) -> usize {
        if !self.cfg.eclipse {
            return 0;
        }
        debug_assert_eq!(positions.len(), self.n_sats);
        let mut injected = 0usize;
        for (sat, p) in positions.iter().enumerate() {
            let shadowed = in_earth_shadow(*p);
            if shadowed && !self.in_eclipse[sat] {
                injected += 1;
            }
            self.in_eclipse[sat] = shadowed;
        }
        injected
    }

    /// Schedule a typed fault at an exact continuous event time. Faults
    /// drawn by the engine itself land on integer round boundaries; this
    /// entry point exists for callers (and tests) that inject at
    /// fractional times under the buffered/async plane.
    pub fn push_at(&mut self, at: f64, fault: Fault) {
        self.queue.push(at, Event::Fault { fault });
    }

    fn push(&mut self, round: u64, fault: Fault) {
        self.push_at(round as f64, fault);
    }
}

/// Whether an ECI position sits inside Earth's shadow cylinder, with the
/// sun fixed along +X: behind the terminator plane and within one Earth
/// radius of the shadow axis. A fixed sun is a deliberate simplification —
/// it keeps the power-save process a pure function of the orbital state,
/// which is all the scenario plane needs.
pub fn in_earth_shadow(p: Vec3) -> bool {
    p.x < 0.0 && p.y * p.y + p.z * p.z < EARTH_RADIUS * EARTH_RADIUS
}

#[cfg(test)]
mod tests {
    use super::*;

    fn positions(n: usize) -> Vec<Vec3> {
        (0..n)
            .map(|i| Vec3::new(7.0e6 * (i as f64 + 1.0), 0.0, 0.0))
            .collect()
    }

    #[test]
    fn kind_parse_roundtrips() {
        for kind in ScenarioKind::ALL {
            assert_eq!(ScenarioKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScenarioKind::parse("meteor-storm"), None);
    }

    #[test]
    fn presets_validate() {
        for kind in ScenarioKind::ALL {
            ScenarioConfig::preset(kind).validate().unwrap();
        }
    }

    #[test]
    fn bad_knobs_are_usage_errors() {
        let mut c = ScenarioConfig::preset(ScenarioKind::Churn);
        c.sat_fail_prob = 1.5;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::preset(ScenarioKind::FlakyGround);
        c.link_degrade_milli = 1000;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::preset(ScenarioKind::Stragglers);
        c.straggler_milli = 900;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::preset(ScenarioKind::NoisyLinks);
        c.link_noise_ber_nano = 1_000_000_000;
        assert!(c.validate().is_err());
        let mut c = ScenarioConfig::preset(ScenarioKind::PsCrash);
        c.ps_fail_rounds = 0;
        assert!(c.validate().is_err());
        assert!(ScenarioEngine::new(ScenarioConfig::default(), 1.0, 1, 4, 1).is_err());
    }

    #[test]
    fn nominal_with_zero_outage_is_quiet() {
        let mut e = ScenarioEngine::new(ScenarioConfig::default(), 0.0, 42, 8, 2).unwrap();
        for round in 1..=20u64 {
            let a = e.advance_round(round, &positions(8));
            assert_eq!(a.faults_injected, 0);
            assert!(a.unreachable.iter().all(|&u| !u));
            assert!(a.link_factor.iter().all(|&f| f == 1.0));
            assert!(a.compute_slowdown.iter().all(|&f| f == 1.0));
            assert!(a.ground_down.iter().all(|&d| !d));
            assert!(a.ber.iter().all(|&b| b == 0.0));
            assert!(a.ps_failed.iter().all(|&p| !p));
        }
    }

    #[test]
    fn noisy_links_draws_bursts_within_the_ceiling() {
        let cfg = ScenarioConfig {
            link_noise_prob: 0.5,
            ..ScenarioConfig::preset(ScenarioKind::NoisyLinks)
        };
        let mut e = ScenarioEngine::new(cfg, 0.0, 13, 12, 1).unwrap();
        let ceiling = cfg.link_noise_ber_nano as f64 / 1e9;
        let mut saw_noise = false;
        for round in 1..=15u64 {
            let a = e.advance_round(round, &positions(12));
            for sat in 0..12 {
                let b = a.ber[sat];
                assert!((0.0..=ceiling).contains(&b), "burst BER {b} out of range");
                if b > 0.0 {
                    saw_noise = true;
                    // noise never takes the satellite down by itself
                    assert!(!a.unreachable[sat]);
                }
            }
        }
        assert!(saw_noise, "a 50% burst rate must fire within 15 rounds");
    }

    #[test]
    fn ps_crashes_persist_until_restore() {
        let cfg = ScenarioConfig {
            ps_fail_prob: 0.5,
            ps_fail_rounds: 3,
            ..ScenarioConfig::preset(ScenarioKind::PsCrash)
        };
        let mut e = ScenarioEngine::new(cfg, 0.0, 21, 16, 1).unwrap();
        let mut total_injected = 0usize;
        let mut crashed_rounds = 0usize;
        for round in 1..=12u64 {
            let a = e.advance_round(round, &positions(16));
            total_injected += a.faults_injected;
            crashed_rounds += a.ps_failed.iter().filter(|&&p| p).count();
            // a crashed PS process leaves the satellite itself reachable
            assert!(a.unreachable.iter().all(|&u| !u));
        }
        assert!(total_injected > 0, "a 50% crash rate must inject faults");
        assert!(
            crashed_rounds > total_injected,
            "multi-round restores must keep processes down longer than \
             one round each ({crashed_rounds} vs {total_injected})"
        );
    }

    #[test]
    fn churn_failures_persist_until_recovery() {
        let cfg = ScenarioConfig {
            sat_fail_prob: 0.5,
            sat_fail_rounds: 3,
            ..ScenarioConfig::preset(ScenarioKind::Churn)
        };
        let mut e = ScenarioEngine::new(cfg, 0.0, 7, 16, 2).unwrap();
        let mut total_injected = 0usize;
        let mut down_rounds = 0usize;
        for round in 1..=12u64 {
            let a = e.advance_round(round, &positions(16));
            total_injected += a.faults_injected;
            down_rounds += a.unreachable.iter().filter(|&&u| u).count();
        }
        assert!(total_injected > 0, "a 50% failure rate must inject faults");
        assert!(
            down_rounds > total_injected,
            "multi-round recoveries must keep satellites down longer than \
             one round each ({down_rounds} down-rounds vs {total_injected} injections)"
        );
    }

    #[test]
    fn fault_trajectory_is_replayable() {
        // two engines with the same seed fold identical availability —
        // the property the worker-count determinism test leans on
        let cfg = ScenarioConfig {
            sat_fail_prob: 0.2,
            link_degrade_prob: 0.2,
            straggler_prob: 0.2,
            ground_outage_prob: 0.3,
            link_noise_prob: 0.2,
            ps_fail_prob: 0.2,
            ..ScenarioConfig::preset(ScenarioKind::Churn)
        };
        let mut a = ScenarioEngine::new(cfg, 0.05, 99, 12, 3).unwrap();
        let mut b = ScenarioEngine::new(cfg, 0.05, 99, 12, 3).unwrap();
        for round in 1..=10u64 {
            let ra = a.advance_round(round, &positions(12));
            let rb = b.advance_round(round, &positions(12));
            assert_eq!(ra.unreachable, rb.unreachable);
            assert_eq!(ra.link_factor, rb.link_factor);
            assert_eq!(ra.compute_slowdown, rb.compute_slowdown);
            assert_eq!(ra.ground_down, rb.ground_down);
            assert_eq!(ra.ber, rb.ber);
            assert_eq!(ra.ps_failed, rb.ps_failed);
            assert_eq!(ra.faults_injected, rb.faults_injected);
        }
    }

    #[test]
    fn degradations_stay_in_range() {
        let cfg = ScenarioConfig {
            link_degrade_prob: 0.5,
            straggler_prob: 0.5,
            ..ScenarioConfig::preset(ScenarioKind::Stragglers)
        };
        let mut e = ScenarioEngine::new(cfg, 0.0, 3, 10, 1).unwrap();
        let mut saw_link = false;
        let mut saw_slow = false;
        for round in 1..=15u64 {
            let a = e.advance_round(round, &positions(10));
            for sat in 0..10 {
                let lf = a.link_factor[sat];
                assert!(lf > 0.0 && lf <= 1.0, "link factor {lf} out of range");
                if lf < 1.0 {
                    saw_link = true;
                    assert!(lf >= cfg.link_degrade_milli as f64 / 1000.0 - 1e-9);
                }
                let sf = a.compute_slowdown[sat];
                assert!(sf >= 1.0, "slowdown {sf} below nominal");
                if sf > 1.0 {
                    saw_slow = true;
                    assert!(sf <= cfg.straggler_milli as f64 / 1000.0 + 1e-9);
                }
            }
        }
        assert!(saw_link && saw_slow, "50% rates must fire within 15 rounds");
    }

    #[test]
    fn eclipse_follows_shadow_geometry() {
        let r = EARTH_RADIUS + 500_000.0;
        assert!(in_earth_shadow(Vec3::new(-r, 0.0, 0.0)));
        assert!(!in_earth_shadow(Vec3::new(r, 0.0, 0.0)), "sunlit side");
        assert!(
            !in_earth_shadow(Vec3::new(-r, EARTH_RADIUS * 1.5, 0.0)),
            "outside the shadow cylinder"
        );
        let cfg = ScenarioConfig::preset(ScenarioKind::Eclipse);
        let mut e = ScenarioEngine::new(cfg, 0.0, 1, 2, 1).unwrap();
        let pos = vec![Vec3::new(-r, 0.0, 0.0), Vec3::new(r, 0.0, 0.0)];
        let a = e.advance_round(1, &pos);
        assert_eq!(a.unreachable, vec![true, false]);
        assert_eq!(a.faults_injected, 1, "one shadow entry");
        // staying in shadow is not a new injection
        let a = e.advance_round(2, &pos);
        assert_eq!(a.unreachable, vec![true, false]);
        assert_eq!(a.faults_injected, 0);
    }

    #[test]
    fn fractional_advances_match_integer_advances_exactly() {
        // sampling the fault plane at fractional times between the round
        // boundaries changes nothing: the integer-boundary folds and the
        // total injection count are bit-identical to per-round advances
        let cfg = ScenarioConfig {
            sat_fail_prob: 0.2,
            link_degrade_prob: 0.2,
            straggler_prob: 0.2,
            ground_outage_prob: 0.3,
            ..ScenarioConfig::preset(ScenarioKind::Churn)
        };
        let mut a = ScenarioEngine::new(cfg, 0.05, 99, 12, 3).unwrap();
        let mut b = ScenarioEngine::new(cfg, 0.05, 99, 12, 3).unwrap();
        let p = positions(12);
        let (mut inj_a, mut inj_b) = (0usize, 0usize);
        for round in 1..=10u64 {
            let ra = a.advance_round(round, &p);
            inj_a += ra.faults_injected;
            inj_b += b.advance_to(round as f64 - 0.5, &p).faults_injected;
            let rb = b.advance_to(round as f64, &p);
            inj_b += rb.faults_injected;
            assert_eq!(ra.unreachable, rb.unreachable, "round {round}");
            assert_eq!(ra.link_factor, rb.link_factor, "round {round}");
            assert_eq!(ra.compute_slowdown, rb.compute_slowdown, "round {round}");
            assert_eq!(ra.ground_down, rb.ground_down, "round {round}");
        }
        assert_eq!(inj_a, inj_b, "fractional sampling changed the fault count");
        assert!(inj_a > 0, "the comparison must exercise real faults");
    }

    #[test]
    fn repeated_fractional_advances_never_double_fire() {
        let cfg = ScenarioConfig {
            sat_fail_prob: 0.5,
            ..ScenarioConfig::preset(ScenarioKind::Churn)
        };
        let mut e = ScenarioEngine::new(cfg, 0.1, 7, 16, 1).unwrap();
        let p = positions(16);
        let _ = e.advance_to(1.0, &p);
        let mut again = 0usize;
        for step in 1..=4 {
            again += e.advance_to(1.0 + 0.2 * step as f64, &p).faults_injected;
        }
        assert_eq!(again, 0, "no new integer boundary, no new draws");
    }

    #[test]
    fn pushed_faults_apply_at_their_exact_continuous_times() {
        let mut e = ScenarioEngine::new(ScenarioConfig::default(), 0.0, 1, 4, 1).unwrap();
        e.push_at(1.25, Fault::SatFail { sat: 2 });
        e.push_at(2.75, Fault::SatRecover { sat: 2 });
        let p = positions(4);
        assert!(!e.advance_to(1.0, &p).unreachable[2], "not yet due");
        let a = e.advance_to(1.25, &p);
        assert!(a.unreachable[2], "onset applies at its exact timestamp");
        assert_eq!(a.faults_injected, 1);
        assert!(e.advance_to(2.5, &p).unreachable[2], "still down");
        let a = e.advance_to(2.75, &p);
        assert!(!a.unreachable[2], "recovery applies at its exact timestamp");
        assert_eq!(a.faults_injected, 0, "a recovery is not an injection");
    }

    #[test]
    #[should_panic(expected = "scenario time went backwards")]
    fn advance_to_rejects_time_reversal() {
        let mut e = ScenarioEngine::new(ScenarioConfig::default(), 0.0, 2, 2, 1).unwrap();
        let p = positions(2);
        e.advance_to(2.0, &p);
        e.advance_to(1.0, &p);
    }

    #[test]
    fn transient_outages_match_their_probability_roughly() {
        let mut e = ScenarioEngine::new(ScenarioConfig::default(), 0.25, 11, 40, 1).unwrap();
        let mut out = 0usize;
        let rounds = 50u64;
        for round in 1..=rounds {
            out += e
                .advance_round(round, &positions(40))
                .unreachable
                .iter()
                .filter(|&&u| u)
                .count();
        }
        let rate = out as f64 / (rounds as f64 * 40.0);
        assert!((rate - 0.25).abs() < 0.05, "transient rate {rate} vs 0.25");
    }
}

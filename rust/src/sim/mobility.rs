//! Mobility → membership churn.
//!
//! As the constellation rotates, satellites drift away from the (inertial)
//! centroid positions their clusters were built around. A satellite whose
//! nearest centroid changed is a *dropout* from its original cluster
//! (paper: "satellites may dynamically join or leave a cluster"). The
//! coordinator evaluates this model once per round to compute `C^d` and
//! the dropout rate that feeds the re-clustering trigger. On top of the
//! deterministic orbital drift, satellites the scenario plane reports as
//! unreachable (hard failure, eclipse power-save, transient outage — see
//! [`crate::sim::scenario`]) also count as dropouts: availability is
//! **event-sourced**, not sampled here, so the churn report is a pure
//! function of the orbital state and the fault trajectory.

use crate::clustering::recluster::DropoutStats;
use crate::orbit::index::{assign_nearest_brute, SphereGrid};
use crate::orbit::propagate::Constellation;
use anyhow::{bail, Result};

/// Churn model parameters.
#[derive(Clone, Copy, Debug)]
pub struct MobilityModel {
    /// Probability an otherwise-healthy member is unreachable in a given
    /// round (radiation upset, power save, link outage). The scenario
    /// engine samples this as its transient-outage process; the churn fold
    /// itself only consumes the resulting availability.
    pub outage_prob: f64,
}

impl Default for MobilityModel {
    fn default() -> Self {
        MobilityModel { outage_prob: 0.02 }
    }
}

/// Per-round membership report.
#[derive(Clone, Debug)]
pub struct ChurnReport {
    /// Per-cluster dropout statistics (C^k, C^d).
    pub stats: Vec<DropoutStats>,
    /// The "natural" assignment at time `t` (nearest current centroid).
    pub natural_assignment: Vec<usize>,
    /// Satellites unreachable this round (excluded from training).
    pub outages: Vec<usize>,
}

impl MobilityModel {
    /// Build a model, rejecting out-of-range rates as usage errors (the
    /// CLI/config error-handling style — no panics on bad input).
    pub fn new(outage_prob: f64) -> Result<Self> {
        if !(0.0..1.0).contains(&outage_prob) {
            bail!("outage probability must be in [0, 1), got {outage_prob}");
        }
        Ok(MobilityModel { outage_prob })
    }

    /// Evaluate churn at simulated time `t` against the clustering that was
    /// computed at `centroids_km` (the centroids frozen at cluster-build
    /// time) with member assignment `assignment`. `unavailable[i]` marks
    /// satellites the scenario plane has taken out this round; they count
    /// toward `C^d` exactly like drift dropouts.
    pub fn churn(
        &self,
        constellation: &Constellation,
        assignment: &[usize],
        centroids_km: &[[f64; 3]],
        t: f64,
        unavailable: &[bool],
    ) -> ChurnReport {
        self.churn_with(constellation, assignment, centroids_km, t, unavailable, None)
    }

    /// [`MobilityModel::churn`] with the nearest-centroid fold optionally
    /// served by the constellation plane's sphere grid (built from the
    /// same epoch `t`). The pruned fold is bit-identical to the exhaustive
    /// scan — see [`crate::orbit::index`] — so the report is the same
    /// either way; the index only makes it sub-linear in K per satellite.
    pub fn churn_with(
        &self,
        constellation: &Constellation,
        assignment: &[usize],
        centroids_km: &[[f64; 3]],
        t: f64,
        unavailable: &[bool],
        grid: Option<&SphereGrid>,
    ) -> ChurnReport {
        assert_eq!(
            assignment.len(),
            unavailable.len(),
            "availability mask does not cover the constellation"
        );
        let k = centroids_km.len();
        let natural = match grid {
            Some(g) => {
                assert_eq!(
                    g.len(),
                    assignment.len(),
                    "spatial index does not cover the constellation"
                );
                // O(1) epoch guard: the first satellite's indexed features
                // must be bit-identical to its features at `t` (any epoch
                // drift moves them) — a stale grid must not silently yield
                // churn for the wrong time
                if let (Some(f), Some(e)) = (g.feats().first(), constellation.elements.first()) {
                    let p = e.position_eci(t);
                    assert_eq!(
                        f,
                        &[p.x / 1e3, p.y / 1e3, p.z / 1e3],
                        "spatial index was built for a different epoch than t={t}"
                    );
                }
                let mut out = Vec::new();
                g.assign_nearest(centroids_km, &mut out);
                out
            }
            None => {
                let feats = constellation.snapshot(t).features_km();
                let mut natural = Vec::new();
                assign_nearest_brute(&feats, centroids_km, &mut natural);
                natural
            }
        };
        let mut stats = vec![DropoutStats::default(); k];
        let mut outages = Vec::new();
        for (i, &home) in assignment.iter().enumerate() {
            stats[home].members += 1;
            let moved = natural[i] != home;
            if unavailable[i] {
                outages.push(i);
            }
            if moved || unavailable[i] {
                stats[home].dropped += 1;
            }
        }
        ChurnReport {
            stats,
            natural_assignment: natural,
            outages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::kmeans::KMeans;
    use crate::orbit::elements::OrbitalElements;
    use crate::orbit::walker::WalkerConstellation;
    use crate::util::Rng;

    fn setup() -> (Constellation, Vec<usize>, Vec<[f64; 3]>) {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(4, 8));
        let feats = c.snapshot(0.0).features_km();
        let mut rng = Rng::new(1);
        let res = KMeans::new(4).run(&feats, &mut rng).unwrap();
        (c, res.assignment, res.centroids)
    }

    #[test]
    fn indexed_churn_is_bit_identical() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::default();
        let none = vec![false; asg.len()];
        for t in [0.0, 500.0, 2000.0] {
            let mut ix = crate::orbit::index::ConstellationIndex::new(0);
            ix.refresh(&c, t);
            let brute = m.churn(&c, &asg, &cents, t, &none);
            let indexed = m.churn_with(&c, &asg, &cents, t, &none, Some(ix.grid()));
            assert_eq!(brute.natural_assignment, indexed.natural_assignment, "t={t}");
            for (a, b) in brute.stats.iter().zip(&indexed.stats) {
                assert_eq!(a.members, b.members, "t={t}");
                assert_eq!(a.dropped, b.dropped, "t={t}");
            }
            assert_eq!(brute.outages, indexed.outages, "t={t}");
        }
    }

    #[test]
    fn rejects_out_of_range_rates() {
        assert!(MobilityModel::new(-0.1).is_err());
        assert!(MobilityModel::new(1.0).is_err());
        assert!(MobilityModel::new(f64::NAN).is_err());
        assert!(MobilityModel::new(0.0).is_ok());
        assert!(MobilityModel::new(0.999).is_ok());
    }

    #[test]
    fn no_drift_at_build_time_when_all_available() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::new(0.0).unwrap();
        let rep = m.churn(&c, &asg, &cents, 0.0, &vec![false; asg.len()]);
        let dropped: usize = rep.stats.iter().map(|s| s.dropped).sum();
        assert_eq!(dropped, 0, "churn at t=0 should be zero");
        assert_eq!(rep.natural_assignment, asg);
        assert!(rep.outages.is_empty());
    }

    #[test]
    fn drift_grows_with_time() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::default();
        let none = vec![false; asg.len()];
        let period = c.min_period();
        let d_small: usize = m
            .churn(&c, &asg, &cents, 0.01 * period, &none)
            .stats
            .iter()
            .map(|s| s.dropped)
            .sum();
        let d_large: usize = m
            .churn(&c, &asg, &cents, 0.25 * period, &none)
            .stats
            .iter()
            .map(|s| s.dropped)
            .sum();
        assert!(
            d_large > d_small,
            "quarter-orbit churn {d_large} <= early churn {d_small}"
        );
        assert!(d_large > 0);
    }

    #[test]
    fn members_partition_is_preserved() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::default();
        let rep = m.churn(&c, &asg, &cents, 500.0, &vec![false; asg.len()]);
        let members: usize = rep.stats.iter().map(|s| s.members).sum();
        assert_eq!(members, asg.len());
        for s in &rep.stats {
            assert!(s.dropped <= s.members);
        }
    }

    #[test]
    fn all_unavailable_drops_everyone() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::default();
        let rep = m.churn(&c, &asg, &cents, 0.0, &vec![true; asg.len()]);
        let dropped: usize = rep.stats.iter().map(|s| s.dropped).sum();
        assert_eq!(dropped, asg.len());
        assert_eq!(rep.outages.len(), asg.len());
    }

    /// Hand-built two-cluster constellation: three satellites leading at
    /// orbital phases 0°/10°/20° (cluster 0) and three trailing at
    /// 189°/190°/191° (cluster 1), same circular equatorial orbit. With
    /// centroids frozen at t=0, the equal-distance boundaries sit near
    /// phases 100° and 280° (shifted ~0.3° by the chord-mean centroid
    /// radii). Advancing the constellation 86° of phase puts exactly one
    /// satellite — the cluster-0 leader, 20°→106° — across a boundary;
    /// every other satellite stays inside its home region (cluster 1's
    /// leader reaches 277°, short of 280°).
    #[test]
    fn drift_across_boundary_reports_single_dropout() {
        let deg = std::f64::consts::PI / 180.0;
        let phases: [f64; 6] = [0.0, 10.0, 20.0, 189.0, 190.0, 191.0];
        let elements = phases
            .iter()
            .map(|&p| OrbitalElements::circular(1_300_000.0, 0.0, 0.0, p * deg))
            .collect();
        let c = Constellation::new(elements);
        let assignment = vec![0, 0, 0, 1, 1, 1];

        // frozen centroids: per-cluster mean of the t=0 feature positions
        let feats0 = c.snapshot(0.0).features_km();
        let mut centroids = vec![[0.0f64; 3]; 2];
        for (f, &a) in feats0.iter().zip(&assignment) {
            for d in 0..3 {
                centroids[a][d] += f[d] / 3.0;
            }
        }

        let t = c.min_period() * (86.0 / 360.0);
        let m = MobilityModel::new(0.0).unwrap();
        let rep = m.churn(&c, &assignment, &centroids, t, &[false; 6]);

        assert_eq!(rep.natural_assignment, vec![0, 0, 1, 1, 1, 1]);
        assert_eq!(rep.stats[0].members, 3);
        assert_eq!(rep.stats[0].dropped, 1, "exactly the boundary satellite");
        assert_eq!(rep.stats[1].members, 3);
        assert_eq!(rep.stats[1].dropped, 0, "trailing cluster stays intact");
        assert!(rep.outages.is_empty());

        // natural_assignment consistency: it is the nearest frozen
        // centroid for every satellite, recomputed independently here
        let feats_t = c.snapshot(t).features_km();
        for (i, f) in feats_t.iter().enumerate() {
            let nearest = (0..2)
                .min_by(|&a, &b| {
                    let da: f64 = (0..3).map(|d| (f[d] - centroids[a][d]).powi(2)).sum();
                    let db: f64 = (0..3).map(|d| (f[d] - centroids[b][d]).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            assert_eq!(rep.natural_assignment[i], nearest, "satellite {i}");
        }
    }
}

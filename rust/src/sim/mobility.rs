//! Mobility → membership churn.
//!
//! As the constellation rotates, satellites drift away from the (inertial)
//! centroid positions their clusters were built around. A satellite whose
//! nearest centroid changed is a *dropout* from its original cluster
//! (paper: "satellites may dynamically join or leave a cluster"). The
//! coordinator samples this model once per round to compute `C^d` and the
//! dropout rate that feeds the re-clustering trigger. On top of the
//! deterministic orbital drift, a small random outage probability models
//! link loss / eclipse power constraints.

use crate::clustering::recluster::DropoutStats;
use crate::orbit::propagate::Constellation;
use crate::util::Rng;

/// Churn model parameters.
#[derive(Clone, Copy, Debug)]
pub struct MobilityModel {
    /// Probability an otherwise-healthy member is unreachable this round
    /// (radiation upset, power save, link outage).
    pub outage_prob: f64,
}

impl Default for MobilityModel {
    fn default() -> Self {
        MobilityModel { outage_prob: 0.02 }
    }
}

/// Per-round membership report.
#[derive(Clone, Debug)]
pub struct ChurnReport {
    /// Per-cluster dropout statistics (C^k, C^d).
    pub stats: Vec<DropoutStats>,
    /// The "natural" assignment at time `t` (nearest current centroid).
    pub natural_assignment: Vec<usize>,
    /// Satellites unreachable this round (outage, excluded from training).
    pub outages: Vec<usize>,
}

impl MobilityModel {
    pub fn new(outage_prob: f64) -> Self {
        assert!((0.0..1.0).contains(&outage_prob));
        MobilityModel { outage_prob }
    }

    /// Evaluate churn at simulated time `t` against the clustering that was
    /// computed at `centroids_km` (the centroids frozen at cluster-build
    /// time) with member assignment `assignment`.
    pub fn churn(
        &self,
        constellation: &Constellation,
        assignment: &[usize],
        centroids_km: &[[f64; 3]],
        t: f64,
        rng: &mut Rng,
    ) -> ChurnReport {
        let k = centroids_km.len();
        let snap = constellation.snapshot(t);
        let feats = snap.features_km();
        let mut natural = Vec::with_capacity(feats.len());
        for f in &feats {
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids_km.iter().enumerate() {
                let dx = f[0] - cent[0];
                let dy = f[1] - cent[1];
                let dz = f[2] - cent[2];
                let d = dx * dx + dy * dy + dz * dz;
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            natural.push(best);
        }
        let mut stats = vec![DropoutStats::default(); k];
        let mut outages = Vec::new();
        for (i, &home) in assignment.iter().enumerate() {
            stats[home].members += 1;
            let moved = natural[i] != home;
            let outage = rng.uniform() < self.outage_prob;
            if outage {
                outages.push(i);
            }
            if moved || outage {
                stats[home].dropped += 1;
            }
        }
        ChurnReport {
            stats,
            natural_assignment: natural,
            outages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::kmeans::KMeans;
    use crate::orbit::walker::WalkerConstellation;

    fn setup() -> (Constellation, Vec<usize>, Vec<[f64; 3]>) {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(4, 8));
        let feats = c.snapshot(0.0).features_km();
        let mut rng = Rng::new(1);
        let res = KMeans::new(4).run(&feats, &mut rng);
        (c, res.assignment, res.centroids)
    }

    #[test]
    fn no_drift_at_build_time_without_outage() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::new(1e-12);
        let mut rng = Rng::new(2);
        let rep = m.churn(&c, &asg, &cents, 0.0, &mut rng);
        let dropped: usize = rep.stats.iter().map(|s| s.dropped).sum();
        assert_eq!(dropped, 0, "churn at t=0 should be zero");
        assert_eq!(rep.natural_assignment, asg);
    }

    #[test]
    fn drift_grows_with_time() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::new(1e-12);
        let mut rng = Rng::new(3);
        let period = c.min_period();
        let d_small: usize = m
            .churn(&c, &asg, &cents, 0.01 * period, &mut rng)
            .stats
            .iter()
            .map(|s| s.dropped)
            .sum();
        let d_large: usize = m
            .churn(&c, &asg, &cents, 0.25 * period, &mut rng)
            .stats
            .iter()
            .map(|s| s.dropped)
            .sum();
        assert!(
            d_large > d_small,
            "quarter-orbit churn {d_large} <= early churn {d_small}"
        );
        assert!(d_large > 0);
    }

    #[test]
    fn members_partition_is_preserved() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::default();
        let mut rng = Rng::new(4);
        let rep = m.churn(&c, &asg, &cents, 500.0, &mut rng);
        let members: usize = rep.stats.iter().map(|s| s.members).sum();
        assert_eq!(members, asg.len());
        for s in &rep.stats {
            assert!(s.dropped <= s.members);
        }
    }

    #[test]
    fn outage_prob_one_drops_everyone() {
        let (c, asg, cents) = setup();
        let m = MobilityModel::new(0.999999);
        let mut rng = Rng::new(5);
        let rep = m.churn(&c, &asg, &cents, 0.0, &mut rng);
        let dropped: usize = rep.stats.iter().map(|s| s.dropped).sum();
        assert_eq!(dropped, asg.len());
        assert_eq!(rep.outages.len(), asg.len());
    }
}

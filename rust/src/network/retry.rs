//! Recovery plane: unreliable transfers with detect/retry/backoff.
//!
//! A transfer over a noisy link corrupts with probability
//! `1 - (1 - BER)^bits` (independent bit errors at the payload size the
//! wire plane bills). The receiver detects corruption via a payload
//! [`checksum`] and the sender retransmits after an exponential-backoff
//! wait, up to `--max-retries` retransmissions; every attempt is billed
//! through `LinkModel`/`Payload` into the Eq. 6/7 time and energy folds.
//! Retries exhausted ⇒ the contribution is dropped and the member takes
//! the existing stale path — graceful degradation, liveness preserved.
//!
//! Determinism: the corruption draws come from the same stateless
//! `stream_seed(seed ^ SALT, round, sender)` streams as the fault plane
//! (salts in `sim::scenario`), so a transfer's attempt count is a pure
//! function of `(seed, round, sender)` — bit-identical for any
//! `--workers` count. When the effective BER is zero the coordinator
//! skips this module entirely (no RNG construction, no float ops), which
//! keeps nominal runs bit-identical to the pre-recovery goldens.
//!
//! The simulator never materialises corrupted payloads: corruption is a
//! draw against the analytic probability, and checksum verification is
//! billed at zero cost (a few hundred cycles against multi-second
//! transfer times). [`checksum`] exists so the detection mechanism is
//! real and testable, not hand-waved.

use crate::util::Rng;

/// Retry knobs for one run (from `--max-retries` / `--retry-backoff`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Retransmissions allowed after the first attempt (so a transfer
    /// makes at most `max_retries + 1` attempts).
    pub max_retries: u32,
    /// Backoff growth factor: the wait before retransmission `k` is
    /// `t_com · backoff^(k-1)` — the first retry waits one transfer
    /// time, and each further retry waits `backoff` times longer.
    pub backoff: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 3, backoff: 2.0 }
    }
}

impl RetryPolicy {
    /// Offset (seconds) at which attempt `i` (0-based) of a transfer
    /// starts, relative to the transfer's own start: attempt 0 starts
    /// immediately; attempt `i` starts after `i` full `t_com` sends plus
    /// the geometric backoff waits before retries `1..=i`. Used by the
    /// telemetry plane to place per-retry instants inside an upload span
    /// without re-running the corruption draws.
    pub fn attempt_offset(&self, i: u32, t_com: f64) -> f64 {
        let mut off = 0.0;
        for k in 0..i {
            off += t_com + t_com * self.backoff.powi(k as i32);
        }
        off
    }
}

/// What one (possibly retried) transfer did on the wire.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TransferOutcome {
    /// Send attempts made (first try + retransmissions), at least 1.
    pub attempts: u32,
    /// Total backoff wait, seconds (on top of the per-attempt `t_com`).
    pub wait_s: f64,
    /// Whether the final attempt arrived uncorrupted.
    pub delivered: bool,
}

impl TransferOutcome {
    /// Retransmissions billed to the ledger.
    pub fn retransmits(&self) -> usize {
        (self.attempts - 1) as usize
    }

    /// Corrupted (checksum-rejected) arrivals: every attempt but the
    /// last on a delivered transfer, every attempt on a dropped one.
    pub fn corrupted(&self) -> usize {
        if self.delivered {
            self.retransmits()
        } else {
            self.attempts as usize
        }
    }

    /// Wall-clock time of the whole exchange given one attempt's
    /// transfer time: every attempt retransmits the full payload, plus
    /// the backoff waits between attempts.
    pub fn total_time(&self, t_com: f64) -> f64 {
        self.attempts as f64 * t_com + self.wait_s
    }
}

/// Probability that a `bits`-sized payload corrupts at bit-error rate
/// `ber`, assuming independent bit errors: `1 - (1 - ber)^bits`. Stacked
/// noise bursts can push the additive BER past 1.0; it is clamped so the
/// probability saturates at certain corruption instead of going NaN.
///
/// Computed as `-expm1(bits · ln1p(-ber))`: the naive
/// `1 - (1 - ber).powf(bits)` form loses every significant digit once
/// `ber` drops below ~1e-16 (the subtraction `1 - ber` rounds to exactly
/// 1.0 and the whole probability collapses to 0), whereas `ln_1p`/`exp_m1`
/// keep full precision at tiny BER × huge payloads. At BER = 1 the
/// `ln_1p(-1) = -∞` chain still saturates to exactly 1.0.
pub fn corrupt_prob(ber: f64, bits: f64) -> f64 {
    debug_assert!(ber >= 0.0 && ber.is_finite(), "bad BER {ber}");
    debug_assert!(bits >= 0.0 && bits.is_finite(), "bad payload bits {bits}");
    if bits == 0.0 {
        return 0.0;
    }
    (-(bits * f64::ln_1p(-ber.min(1.0))).exp_m1()).max(0.0)
}

/// Run one transfer through the detect/retry/backoff loop. `ber` is the
/// sender's effective bit-error rate (global `--ber` floor plus any
/// active noise burst), `bits` the billed payload size, `t_com` one
/// attempt's transfer time (sets the backoff base), and `rng` the
/// transfer's own stateless stream — draws are sequential per attempt,
/// so the outcome replays exactly from `(seed, round, sender)`.
///
/// Callers must skip this entirely when the effective BER is zero: the
/// zero-noise path has to stay free of RNG constructions and float ops
/// to remain bit-identical to the pre-recovery accounting.
pub fn transfer_with_retries(
    policy: &RetryPolicy,
    ber: f64,
    bits: f64,
    t_com: f64,
    rng: &mut Rng,
) -> TransferOutcome {
    debug_assert!(ber > 0.0, "zero-BER transfers must bypass the recovery plane");
    let p = corrupt_prob(ber, bits);
    let mut wait_s = 0.0;
    let mut attempts = 1u32;
    loop {
        if rng.uniform() >= p {
            return TransferOutcome { attempts, wait_s, delivered: true };
        }
        if attempts > policy.max_retries {
            return TransferOutcome { attempts, wait_s, delivered: false };
        }
        wait_s += t_com * policy.backoff.powi(attempts as i32 - 1);
        attempts += 1;
    }
}

/// FNV-1a payload checksum over the exact f32 bit patterns — the
/// receiver-side corruption detector. Any single-bit flip in the payload
/// changes the digest (pinned by the tests below), which is all the
/// retry loop needs; this is an integrity check against channel noise,
/// not a cryptographic MAC.
pub fn checksum(params: &[f32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &v in params {
        for b in v.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::stream_seed;

    #[test]
    fn corrupt_prob_behaves_like_independent_bit_errors() {
        assert_eq!(corrupt_prob(0.5, 0.0), 0.0, "empty payload never corrupts");
        assert_eq!(corrupt_prob(1.0, 1.0), 1.0, "certain errors always corrupt");
        // monotone in both the BER and the payload size
        assert!(corrupt_prob(1e-7, 2e6) > corrupt_prob(1e-7, 1e6));
        assert!(corrupt_prob(2e-7, 1e6) > corrupt_prob(1e-7, 1e6));
        // a realistic upload: ~1.4 Mbit at BER 5e-7 corrupts about half
        // the time — the regime the noisy-links preset exercises
        let p = corrupt_prob(5e-7, 1.4e6);
        assert!((0.3..0.7).contains(&p), "p = {p}");
        // stacked bursts past BER 1.0 saturate instead of going NaN
        let p = corrupt_prob(1.7, 1e6);
        assert_eq!(p, 1.0);
    }

    #[test]
    fn corrupt_prob_survives_tiny_ber_times_huge_payload() {
        // the naive `1 - (1 - ber)^bits` collapses to exactly 0 once
        // `1 - ber` rounds to 1.0 — the expm1/ln1p form keeps the
        // first-order probability `ber·bits` instead
        let p = corrupt_prob(1e-18, 1e9);
        let expected = 1e-18 * 1e9; // ≈ 1e-9, far below one ulp of 1.0
        assert!(
            (p / expected - 1.0).abs() < 1e-6,
            "p = {p:e}, expected ≈ {expected:e}"
        );
        let naive = 1.0 - (1.0 - 1e-18f64).powf(1e9);
        assert_eq!(naive, 0.0, "the naive form should collapse here");
    }

    #[test]
    fn corrupt_prob_matches_naive_form_at_benign_magnitudes() {
        // where the naive formula is still well-conditioned the two forms
        // must agree to ~1e-9 relative (measured worst case is ~9e-11
        // over this whole regime) — the rewrite is a precision fix, not a
        // model change
        crate::util::quickprop::property("corrupt_prob ≈ naive", 256, |g| {
            // log-uniform BER in [1e-6, 1e-2], payload in [1, 1e5] bits
            let ber = 10f64.powf(g.f64_in(-6.0, -2.0));
            let bits = 10f64.powf(g.f64_in(0.0, 5.0)).floor();
            let p = corrupt_prob(ber, bits);
            let naive = 1.0 - (1.0 - ber).powf(bits);
            assert!((0.0..=1.0).contains(&p), "p = {p} out of range");
            let denom = naive.max(1e-300);
            assert!(
                ((p - naive) / denom).abs() < 1e-9,
                "ber={ber:e} bits={bits}: p={p:e} naive={naive:e}"
            );
        });
    }

    #[test]
    fn exhausted_retries_drop_with_full_backoff_bill() {
        // BER 1.0 corrupts every attempt: the loop must exhaust exactly
        // max_retries retransmissions and bill the geometric backoff
        let policy = RetryPolicy { max_retries: 3, backoff: 2.0 };
        let mut rng = Rng::new(7);
        let out = transfer_with_retries(&policy, 1.0, 1e6, 10.0, &mut rng);
        assert!(!out.delivered);
        assert_eq!(out.attempts, 4);
        assert_eq!(out.retransmits(), 3);
        assert_eq!(out.corrupted(), 4, "every arrival was checksum-rejected");
        // waits: 10·2⁰ + 10·2¹ + 10·2² = 70 s
        assert_eq!(out.wait_s, 70.0);
        assert_eq!(out.total_time(10.0), 4.0 * 10.0 + 70.0);
    }

    #[test]
    fn attempt_offsets_tile_the_retry_timeline() {
        let policy = RetryPolicy { max_retries: 3, backoff: 2.0 };
        assert_eq!(policy.attempt_offset(0, 10.0), 0.0);
        // attempt 1 starts after one send (10) + first backoff (10·2⁰)
        assert_eq!(policy.attempt_offset(1, 10.0), 20.0);
        // attempt 2 after a second send + 10·2¹ wait
        assert_eq!(policy.attempt_offset(2, 10.0), 50.0);
        assert_eq!(policy.attempt_offset(3, 10.0), 100.0);
        // the final attempt's end reproduces the outcome's total time
        let mut rng = Rng::new(7);
        let out = transfer_with_retries(&policy, 1.0, 1e6, 10.0, &mut rng);
        assert_eq!(
            policy.attempt_offset(out.attempts - 1, 10.0) + 10.0,
            out.total_time(10.0)
        );
    }

    #[test]
    fn negligible_noise_delivers_first_try() {
        let policy = RetryPolicy::default();
        let mut rng = Rng::new(11);
        let out = transfer_with_retries(&policy, 1e-15, 1e6, 10.0, &mut rng);
        assert!(out.delivered);
        assert_eq!(out.attempts, 1);
        assert_eq!(out.wait_s, 0.0);
        assert_eq!(out.retransmits(), 0);
        assert_eq!(out.corrupted(), 0);
        assert_eq!(out.total_time(10.0), 10.0);
    }

    #[test]
    fn outcomes_replay_from_the_stream_seed() {
        let policy = RetryPolicy::default();
        for sat in 0..20u64 {
            let mut a = Rng::new(stream_seed(42, 3, sat));
            let mut b = Rng::new(stream_seed(42, 3, sat));
            let oa = transfer_with_retries(&policy, 5e-7, 1.4e6, 8.0, &mut a);
            let ob = transfer_with_retries(&policy, 5e-7, 1.4e6, 8.0, &mut b);
            assert_eq!(oa, ob);
        }
    }

    #[test]
    fn moderate_noise_retries_then_delivers() {
        // at p ≈ 0.5 per attempt, 20 senders must show both first-try
        // deliveries and retried deliveries, and most must get through
        let policy = RetryPolicy::default();
        let (mut delivered, mut retried) = (0, 0);
        for sat in 0..20u64 {
            let mut rng = Rng::new(stream_seed(9, 1, sat));
            let out = transfer_with_retries(&policy, 5e-7, 1.4e6, 8.0, &mut rng);
            delivered += out.delivered as usize;
            retried += (out.retransmits() > 0) as usize;
            if out.retransmits() > 0 {
                assert!(out.wait_s > 0.0, "retries must bill backoff waits");
            }
        }
        assert!(delivered >= 15, "only {delivered}/20 delivered");
        assert!(retried >= 3, "only {retried}/20 retried");
    }

    #[test]
    fn checksum_detects_any_single_bit_flip() {
        let params: Vec<f32> = (0..64).map(|i| (i as f32).sin()).collect();
        let clean = checksum(&params);
        assert_eq!(clean, checksum(&params), "digest must be deterministic");
        for word in [0usize, 17, 63] {
            for bit in 0..32 {
                let mut flipped = params.clone();
                flipped[word] = f32::from_bits(flipped[word].to_bits() ^ (1 << bit));
                assert_ne!(
                    checksum(&flipped),
                    clean,
                    "flip of bit {bit} in word {word} went undetected"
                );
            }
        }
        // the digest distinguishes payloads from their truncations too
        assert_ne!(checksum(&params[..63]), clean);
    }
}

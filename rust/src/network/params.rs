//! Physical-layer and compute constants.

/// All constants of the paper's §II-C models in SI units.
#[derive(Clone, Copy, Debug)]
pub struct NetworkParams {
    /// Transmission power P0, watts.
    pub tx_power_w: f64,
    /// Background noise power N0, watts.
    pub noise_w: f64,
    /// Per-client transmission bandwidth B_i, Hz.
    pub bandwidth_hz: f64,
    /// Carrier frequency for the path-loss model, Hz (Ka-band default).
    pub carrier_hz: f64,
    /// Antenna gain product Gt*Gr (linear).
    pub antenna_gain: f64,
    /// Upload payload ζ per round, bits (model weights).
    pub upload_bits: f64,
    /// CPU cycles per trained sample, Q.
    pub cycles_per_sample: f64,
    /// Client CPU frequency f_i, Hz (baseline; heterogeneity multiplies it).
    pub cpu_hz: f64,
    /// Effective switched capacitance ε0 (energy = ε0 · f² · t · f = ε0 f² cycles).
    pub epsilon0: f64,
    /// Ground-station downlink rate multiplier (GS antennas are larger).
    pub ground_rate_gain: f64,
}

impl Default for NetworkParams {
    fn default() -> Self {
        // Values in the ranges used by [14], [15]: P0 ≈ 1–10 W, B ≈ 10–50 MHz,
        // Ka-band carrier, N0 ≈ 1e-13 W, directional satcom antennas
        // (~30 dBi each side → 60 dB product), Q ≈ 1e6 cycles/sample for
        // LeNet fwd+bwd, f ≈ 0.5–2 GHz edge CPUs, ε0 ≈ 1e-28.
        // At these values a 1000 km ISL carries ~60 Mb/s and a cross-shell
        // 5000 km link ~15 Mb/s — realistic LEO link budgets.
        NetworkParams {
            tx_power_w: 2.0,
            noise_w: 1e-13,
            bandwidth_hz: 20e6,
            carrier_hz: 20e9,
            antenna_gain: 1e6,
            upload_bits: 1.0, // set from the model size at runtime
            cycles_per_sample: 1e6,
            cpu_hz: 1e9,
            epsilon0: 1e-28,
            ground_rate_gain: 4.0,
        }
    }
}

impl NetworkParams {
    /// Configure the upload payload from a parameter count (f32 weights).
    pub fn with_model_params(mut self, param_count: usize) -> Self {
        self.upload_bits = param_count as f64 * 32.0;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_physical() {
        let p = NetworkParams::default();
        assert!(p.tx_power_w > 0.0);
        assert!(p.noise_w > 0.0 && p.noise_w < p.tx_power_w);
        assert!(p.bandwidth_hz > 1e6);
        assert!(p.cpu_hz >= 1e8);
    }

    #[test]
    fn model_size_sets_payload() {
        let p = NetworkParams::default().with_model_params(61_706);
        assert_eq!(p.upload_bits, 61_706.0 * 32.0);
    }
}

//! Physical-layer and compute constants, plus the wire-plane accounting
//! seam: every upload in the system is described by a [`Payload`]
//! (values + indices + header on the wire) and billed through
//! [`Payload::bits`]/[`LinkModel::upload_bytes`], so the dense and
//! compressed paths share one bytes-on-the-wire formula instead of
//! scattering `4·P` byte math around the codebase.

/// Exact on-the-wire size of one upload: `values` coefficients at
/// `value_bits` each, `indices` coordinates at `index_bits` each (top-k
/// sparsification), plus a fixed header. A dense f32 model is
/// `Payload::dense(P)` = `32·P` bits with no header, which keeps the
/// wire-plane refactor bit-identical to the historical `4·P` byte math.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Payload {
    /// Coefficients on the wire.
    pub values: usize,
    /// Bits per coefficient (32 dense/top-k, 8 int8-quantised).
    pub value_bits: u32,
    /// Coordinate count (top-k sends one per kept coefficient).
    pub indices: usize,
    /// Bits per coordinate (`ceil(log2(P))`, bit-packed).
    pub index_bits: u32,
    /// Fixed header bytes (length/scale framing).
    pub header_bytes: usize,
}

impl Payload {
    /// A dense f32 parameter upload (the uncompressed wire format).
    pub fn dense(param_count: usize) -> Payload {
        Payload {
            values: param_count,
            value_bits: 32,
            indices: 0,
            index_bits: 0,
            header_bytes: 0,
        }
    }

    /// Total size on the wire, bits — the Eq. 6/7 `ζ` this payload bills.
    pub fn bits(&self) -> f64 {
        self.values as f64 * self.value_bits as f64
            + self.indices as f64 * self.index_bits as f64
            + self.header_bytes as f64 * 8.0
    }

    /// Total size on the wire, bytes.
    pub fn bytes(&self) -> f64 {
        self.bits() / 8.0
    }
}

/// Billed wire sizes of one model exchange: the uplink payload (member →
/// PS, or PS → GS — the direction compression shrinks) and the downlink
/// payload (the dense broadcast back). With `--compress none` both equal
/// the historical `32·P`, keeping every time/energy fold bit-identical to
/// the pre-wire-plane accounting.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct WireBits {
    /// Uplink payload, bits.
    pub up: f64,
    /// Downlink (broadcast) payload, bits.
    pub down: f64,
}

impl WireBits {
    /// Dense f32 model in both directions.
    pub fn dense(param_count: usize) -> WireBits {
        WireBits::symmetric(Payload::dense(param_count).bits())
    }

    /// The same raw bit count in both directions (tests and callers that
    /// predate compression).
    pub fn symmetric(bits: f64) -> WireBits {
        WireBits { up: bits, down: bits }
    }
}

/// All constants of the paper's §II-C models in SI units.
#[derive(Clone, Copy, Debug)]
pub struct NetworkParams {
    /// Transmission power P0, watts.
    pub tx_power_w: f64,
    /// Background noise power N0, watts.
    pub noise_w: f64,
    /// Per-client transmission bandwidth B_i, Hz.
    pub bandwidth_hz: f64,
    /// Carrier frequency for the path-loss model, Hz (Ka-band default).
    pub carrier_hz: f64,
    /// Antenna gain product Gt*Gr (linear).
    pub antenna_gain: f64,
    /// Upload payload ζ per round, bits (model weights).
    pub upload_bits: f64,
    /// CPU cycles per trained sample, Q.
    pub cycles_per_sample: f64,
    /// Client CPU frequency f_i, Hz (baseline; heterogeneity multiplies it).
    pub cpu_hz: f64,
    /// Effective switched capacitance ε0 (energy = ε0 · f² · t · f = ε0 f² cycles).
    pub epsilon0: f64,
    /// Ground-station downlink rate multiplier (GS antennas are larger).
    pub ground_rate_gain: f64,
}

impl Default for NetworkParams {
    fn default() -> Self {
        // Values in the ranges used by [14], [15]: P0 ≈ 1–10 W, B ≈ 10–50 MHz,
        // Ka-band carrier, N0 ≈ 1e-13 W, directional satcom antennas
        // (~30 dBi each side → 60 dB product), Q ≈ 1e6 cycles/sample for
        // LeNet fwd+bwd, f ≈ 0.5–2 GHz edge CPUs, ε0 ≈ 1e-28.
        // At these values a 1000 km ISL carries ~60 Mb/s and a cross-shell
        // 5000 km link ~15 Mb/s — realistic LEO link budgets.
        NetworkParams {
            tx_power_w: 2.0,
            noise_w: 1e-13,
            bandwidth_hz: 20e6,
            carrier_hz: 20e9,
            antenna_gain: 1e6,
            upload_bits: 1.0, // set from the model size at runtime
            cycles_per_sample: 1e6,
            cpu_hz: 1e9,
            epsilon0: 1e-28,
            ground_rate_gain: 4.0,
        }
    }
}

impl NetworkParams {
    /// Configure the upload payload from a parameter count (dense f32
    /// weights, via the [`Payload`] seam).
    pub fn with_model_params(self, param_count: usize) -> Self {
        self.with_payload(&Payload::dense(param_count))
    }

    /// Configure the upload payload from an exact wire format.
    pub fn with_payload(mut self, payload: &Payload) -> Self {
        self.upload_bits = payload.bits();
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_physical() {
        let p = NetworkParams::default();
        assert!(p.tx_power_w > 0.0);
        assert!(p.noise_w > 0.0 && p.noise_w < p.tx_power_w);
        assert!(p.bandwidth_hz > 1e6);
        assert!(p.cpu_hz >= 1e8);
    }

    #[test]
    fn model_size_sets_payload() {
        let p = NetworkParams::default().with_model_params(61_706);
        assert_eq!(p.upload_bits, 61_706.0 * 32.0);
    }

    #[test]
    fn dense_payload_matches_historical_byte_math() {
        // the seam's golden-stability contract: a dense payload bills
        // exactly the pre-wire-plane 32·P bits, bitwise
        for n in [1usize, 2442, 50_890, 61_706] {
            let p = Payload::dense(n);
            assert_eq!(p.bits().to_bits(), (n as f64 * 32.0).to_bits());
            assert_eq!(p.bytes(), n as f64 * 4.0);
        }
        let w = WireBits::dense(2442);
        assert_eq!(w.up, 2442.0 * 32.0);
        assert_eq!(w.up.to_bits(), w.down.to_bits());
    }

    #[test]
    fn payload_bits_count_values_indices_and_header() {
        let p = Payload {
            values: 10,
            value_bits: 32,
            indices: 10,
            index_bits: 12,
            header_bytes: 8,
        };
        assert_eq!(p.bits(), 10.0 * 32.0 + 10.0 * 12.0 + 64.0);
        assert_eq!(p.bytes(), p.bits() / 8.0);
        let q = Payload {
            values: 100,
            value_bits: 8,
            indices: 0,
            index_bits: 0,
            header_bytes: 12,
        };
        assert_eq!(q.bits(), 896.0);
    }
}

//! Intra-cluster ISL routing plane: multi-hop store-and-forward trees.
//!
//! The baseline aggregation stage teleports every member model to the
//! cluster PS in one hop, however far away the member is. Real LEO
//! constellations route over inter-satellite links (ISLs) with a bounded
//! range and Earth-occluded line of sight, so a member on the far side of
//! a large cluster reaches its PS through relays. This module provides
//! the deterministic routing substrate the coordinator composes into
//! Eq. 6/7 accounting:
//!
//! * [`build_route_tree`] — a shortest-path (by hop count) spanning tree
//!   of one cluster's ISL graph rooted at the PS, built from
//!   [`SphereGrid::los_neighbors`] (or the brute oracle). Ties break to
//!   the lowest-indexed candidate parent so the tree is a pure function
//!   of `(nodes, positions, range)`; degraded relays attach as leaves and
//!   never forward; nodes with no ISL path fall back to the direct
//!   one-hop link (today's behaviour) so no member is ever stranded.
//! * [`routed_round`] — time/energy of one synchronous routed round:
//!   children-first store-and-forward with **partial aggregation at
//!   relays** (each relay merges everything below it into one pooled
//!   upload, so every tree edge carries exactly one uplink payload), plus
//!   the PS broadcast flooding back down the same edges.
//! * [`ring_round`] — ring all-reduce alternative (`--routing isl:ring`):
//!   `2(k−1)` steps of `1/k`-sized chunks around the member ring.
//!
//! Both folds optionally take per-edge [`TransferOutcome`]s from the
//! recovery plane, so a noisy hop retransmits and stretches exactly like
//! a noisy direct upload does.

use super::energy::EnergyModel;
use super::link::LinkModel;
use super::params::WireBits;
use super::retry::TransferOutcome;
use crate::orbit::index::{los_neighbors_brute, SphereGrid};
use crate::orbit::Vec3;

/// `parent` marker for the tree root.
pub const NO_PARENT: usize = usize::MAX;

/// A spanning tree over one cluster's nodes, rooted at the PS. All
/// indices are *local* (positions into the `nodes` slice the tree was
/// built from); the mapping back to constellation ids is the caller's
/// `nodes[local]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RouteTree {
    /// Local parent of each node; [`NO_PARENT`] at the root.
    pub parent: Vec<usize>,
    /// Hop distance to the root: 0 at the root, 1 for direct children
    /// *and* for out-of-range nodes that fell back to the direct link.
    pub hops: Vec<usize>,
    /// Local index of the root (the PS).
    pub root: usize,
    /// Every local index ordered children-before-parents (descending
    /// hops, ascending index within a level; the root comes last) — the
    /// deterministic schedule for the upward store-and-forward fold.
    pub order: Vec<usize>,
}

impl RouteTree {
    /// Number of nodes spanned (members plus the PS).
    pub fn len(&self) -> usize {
        self.parent.len()
    }

    pub fn is_empty(&self) -> bool {
        self.parent.is_empty()
    }

    /// Deepest hop count in the tree. `<= 1` means every member talks to
    /// the PS directly — the flat tree the one-hop baseline assumes.
    pub fn max_hops(&self) -> usize {
        self.hops.iter().copied().max().unwrap_or(0)
    }

    /// The transmitters on `i`'s upload path, in order: `i` itself, then
    /// each relay up to (but excluding) the root. Every listed node sends
    /// once to its parent to move `i`'s contribution to the PS.
    pub fn path_senders(&self, i: usize, out: &mut Vec<usize>) {
        out.clear();
        let mut u = i;
        while u != self.root {
            out.push(u);
            u = self.parent[u];
        }
    }

    /// Iterator form of [`RouteTree::path_senders`]: yields `i`, then
    /// each relay up to (but excluding) the root, with no scratch buffer.
    /// The telemetry plane walks this to emit one `relay_hop` instant per
    /// uplink transmission without allocating when tracing is disabled.
    pub fn path_iter(&self, i: usize) -> impl Iterator<Item = usize> + '_ {
        std::iter::successors(Some(i), move |&u| {
            (u != self.root).then(|| self.parent[u])
        })
        .take_while(move |&u| u != self.root)
    }
}

/// Build the shortest-path routing tree for one cluster.
///
/// * `nodes` — the cluster's constellation indices, strictly ascending
///   (members plus the PS).
/// * `root` — *local* index of the PS within `nodes`.
/// * `positions` — ECI meter positions of the **whole** constellation at
///   this epoch (neighbor queries are global; results are filtered back
///   to the cluster).
/// * `grid` — the epoch's [`SphereGrid`] for pruned neighbor queries, or
///   `None` for the brute-force oracle (bit-identical results).
/// * `relay_blocked` — scenario-plane predicate over *constellation*
///   ids: a blocked node (e.g. a degraded link) still uploads its own
///   model but never forwards for others, so routes bend around it. The
///   root always forwards.
/// * `scratch` — neighbor-list scratch buffer, reused across calls.
///
/// Determinism: BFS expands each hop level in ascending node order and
/// neighbor lists arrive sorted, so every node's parent is the
/// lowest-indexed neighbor among those closest to the root. Nodes the
/// BFS never reaches (out of ISL range or occluded from the whole
/// component) fall back to `parent = root, hops = 1` — the direct PS
/// link today's accounting bills.
pub fn build_route_tree(
    nodes: &[usize],
    root: usize,
    max_range_m: f64,
    positions: &[Vec3],
    grid: Option<&SphereGrid>,
    relay_blocked: &dyn Fn(usize) -> bool,
    scratch: &mut Vec<usize>,
) -> RouteTree {
    let n = nodes.len();
    debug_assert!(root < n, "root {root} outside cluster of {n}");
    debug_assert!(
        nodes.windows(2).all(|w| w[0] < w[1]),
        "cluster nodes must be strictly ascending"
    );
    let mut parent = vec![NO_PARENT; n];
    let mut hops = vec![usize::MAX; n];
    hops[root] = 0;
    let mut frontier = vec![root];
    let mut next: Vec<usize> = Vec::new();
    while !frontier.is_empty() {
        for &u in &frontier {
            if u != root && relay_blocked(nodes[u]) {
                continue; // degraded: a leaf that never forwards
            }
            match grid {
                Some(g) => g.los_neighbors(nodes[u], max_range_m, positions, scratch),
                None => los_neighbors_brute(nodes[u], max_range_m, positions, scratch),
            }
            for &id in scratch.iter() {
                if let Ok(v) = nodes.binary_search(&id) {
                    if hops[v] == usize::MAX {
                        hops[v] = hops[u] + 1;
                        parent[v] = u;
                        next.push(v);
                    }
                }
            }
        }
        frontier.clear();
        std::mem::swap(&mut frontier, &mut next);
        // neighbor lists of distinct expansions interleave; restore the
        // ascending order the tie-break rule is defined over
        frontier.sort_unstable();
    }
    for v in 0..n {
        if hops[v] == usize::MAX {
            parent[v] = root;
            hops[v] = 1;
        }
    }
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_unstable_by(|&a, &b| hops[b].cmp(&hops[a]).then(a.cmp(&b)));
    RouteTree {
        parent,
        hops,
        root,
        order,
    }
}

/// One node's inputs to a routed (or ring) billing fold.
#[derive(Clone, Copy, Debug)]
pub struct HopNode {
    /// Local training time (0 for a node that trained nothing, e.g. a PS
    /// that only aggregates).
    pub t_cmp: f64,
    /// Eq. 9 compute energy matching `t_cmp`.
    pub e_cmp: f64,
    /// Scenario-plane ISL rate multiplier on this node's *uplink* edge
    /// (1.0 = nominal, exactly — see [`crate::coordinator::MemberWork`]).
    pub link_factor: f64,
    /// Tree: meters to the parent (0 at the root). Ring: meters to the
    /// ring successor.
    pub d_up: f64,
}

impl HopNode {
    /// A node that forwards but trained nothing this round.
    pub fn relay_only(d_up: f64) -> HopNode {
        HopNode {
            t_cmp: 0.0,
            e_cmp: 0.0,
            link_factor: 1.0,
            d_up,
        }
    }
}

/// Time + energy of one synchronous routed cluster round (the multi-hop
/// generalisation of [`crate::coordinator::cluster_round`]).
///
/// Upward pass (children first, per [`RouteTree::order`]): a node is
/// ready when its own compute **and** every child's pooled upload have
/// arrived; it then merges and forwards one uplink payload (`wire.up`)
/// to its parent — partial aggregation means each tree edge carries
/// exactly one upload no matter how large the subtree. With `outcomes`,
/// edge `i`'s transfer stretches to `outcomes[i].total_time(t_hop)` and
/// bills `attempts` retransmissions, exactly like a noisy direct upload.
///
/// Downward pass: the PS broadcast floods the dense model (`wire.down`)
/// back along the same edges; the stage ends when it reaches the node
/// with the slowest cumulative path.
///
/// Energy (Eq. 8/9, folded in schedule order): every non-root node bills
/// one uplink transmit per attempt plus its compute plus its parent's
/// one broadcast transmit down the shared edge; the root bills only its
/// compute. Every node bills whether or not its payload ultimately
/// survives the recovery plane — the synchronous barrier waits and the
/// radios spend regardless, mirroring the direct path's accounting.
pub fn routed_round(
    link: &LinkModel,
    energy: &EnergyModel,
    tree: &RouteTree,
    nodes: &[HopNode],
    outcomes: Option<&[TransferOutcome]>,
    wire: WireBits,
) -> (f64, f64) {
    let n = nodes.len();
    assert_eq!(n, tree.len(), "hop nodes do not cover the tree");
    if let Some(o) = outcomes {
        assert_eq!(n, o.len(), "outcomes do not cover the tree");
    }
    // ready[i]: earliest time node i can transmit (own compute done and
    // all child payloads merged). order is children-before-parents.
    let mut ready = vec![0.0f64; n];
    let mut e_total = 0.0f64;
    for &i in &tree.order {
        let h = &nodes[i];
        ready[i] = ready[i].max(h.t_cmp);
        if i == tree.root {
            e_total += h.e_cmp;
            continue;
        }
        let t_hop = link.comm_time_scaled(wire.up, h.d_up, h.link_factor);
        let (t_edge, attempts) = match outcomes {
            Some(o) => (o[i].total_time(t_hop), o[i].attempts as f64),
            None => (t_hop, 1.0),
        };
        e_total += energy.tx_energy(wire.up, h.d_up) * attempts
            + h.e_cmp
            + energy.tx_energy(wire.down, h.d_up);
        let p = tree.parent[i];
        let arrive = ready[i] + t_edge;
        ready[p] = ready[p].max(arrive);
    }
    let t_up = ready[tree.root];
    // downward broadcast: parents-first (order reversed), reusing the
    // buffer — each slot is overwritten with the node's cumulative
    // downlink path time before any child reads it
    let mut t_down = 0.0f64;
    for &i in tree.order.iter().rev() {
        if i == tree.root {
            ready[i] = 0.0;
            continue;
        }
        let d = ready[tree.parent[i]] + link.comm_time(wire.down, nodes[i].d_up);
        ready[i] = d;
        t_down = t_down.max(d);
    }
    (t_up + t_down, e_total)
}

/// Time + energy of one ring all-reduce round (`--routing isl:ring`).
///
/// The `k` members form a ring in ascending index order (`nodes[i].d_up`
/// is the distance to `i`'s successor); reduce-scatter then all-gather
/// moves `1/k` of the uplink payload `2(k−1)` times around the ring.
/// Steps are synchronous: every step lasts as long as the slowest edge,
/// and with `outcomes` edge `i` replays its retry outcome on every step
/// it transmits. There is no separate PS broadcast — after the
/// all-gather every member already holds the aggregate (`wire.down`
/// never travels). A ring of one reduces to local compute.
pub fn ring_round(
    link: &LinkModel,
    energy: &EnergyModel,
    nodes: &[HopNode],
    outcomes: Option<&[TransferOutcome]>,
    wire: WireBits,
) -> (f64, f64) {
    let k = nodes.len();
    if k == 0 {
        return (0.0, 0.0);
    }
    if let Some(o) = outcomes {
        assert_eq!(k, o.len(), "outcomes do not cover the ring");
    }
    let mut t_cmp = 0.0f64;
    let mut e_total = 0.0f64;
    for h in nodes {
        t_cmp = t_cmp.max(h.t_cmp);
        e_total += h.e_cmp;
    }
    if k == 1 {
        return (t_cmp, e_total);
    }
    let chunk = wire.up / k as f64;
    let steps = (2 * (k - 1)) as f64;
    let mut t_step = 0.0f64;
    for (i, h) in nodes.iter().enumerate() {
        let t_edge = link.comm_time_scaled(chunk, h.d_up, h.link_factor);
        let (t_eff, attempts) = match outcomes {
            Some(o) => (o[i].total_time(t_edge), o[i].attempts as f64),
            None => (t_edge, 1.0),
        };
        t_step = t_step.max(t_eff);
        e_total += energy.tx_energy(chunk, h.d_up) * steps * attempts;
    }
    (t_cmp + steps * t_step, e_total)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round::{cluster_round, MemberWork};
    use crate::network::params::NetworkParams;
    use crate::orbit::propagate::Constellation;
    use crate::orbit::walker::WalkerConstellation;

    fn models() -> (LinkModel, EnergyModel) {
        let l = LinkModel::new(NetworkParams::default().with_model_params(44_426));
        (l, EnergyModel::new(l))
    }

    /// `n` satellites on a 7000 km circular arc with adjacent-neighbor
    /// chord `sep_m` — high enough that short chords clear the Earth.
    fn arc(n: usize, sep_m: f64) -> Vec<Vec3> {
        let r = 7.0e6;
        let dth = 2.0 * ((sep_m / 2.0) / r).asin();
        (0..n)
            .map(|i| {
                let th = i as f64 * dth;
                Vec3::new(r * th.cos(), r * th.sin(), 0.0)
            })
            .collect()
    }

    fn unblocked() -> impl Fn(usize) -> bool {
        |_| false
    }

    fn tree(
        nodes: &[usize],
        root: usize,
        range: f64,
        pos: &[Vec3],
        blocked: &dyn Fn(usize) -> bool,
    ) -> RouteTree {
        let mut scratch = Vec::new();
        build_route_tree(nodes, root, range, pos, None, blocked, &mut scratch)
    }

    #[test]
    fn chain_routes_hop_by_hop() {
        // 0—1—2—3 at 800 km spacing, 1000 km range: only adjacent links
        let pos = arc(4, 800e3);
        let t = tree(&[0, 1, 2, 3], 0, 1000e3, &pos, &unblocked());
        assert_eq!(t.parent, vec![NO_PARENT, 0, 1, 2]);
        assert_eq!(t.hops, vec![0, 1, 2, 3]);
        assert_eq!(t.max_hops(), 3);
        assert_eq!(t.order, vec![3, 2, 1, 0]);
        let mut path = Vec::new();
        t.path_senders(3, &mut path);
        assert_eq!(path, vec![3, 2, 1]);
        t.path_senders(0, &mut path);
        assert!(path.is_empty(), "the root uploads to nobody");
        assert_eq!(t.path_iter(3).collect::<Vec<_>>(), vec![3, 2, 1]);
        assert_eq!(t.path_iter(0).count(), 0, "path_iter matches path_senders at the root");
    }

    #[test]
    fn isolated_nodes_fall_back_to_the_direct_link() {
        // node 4 on the far side of the orbit: no LoS to the chain
        let mut pos = arc(4, 800e3);
        pos.push(Vec3::new(-7.0e6, 0.0, 0.0));
        let t = tree(&[0, 1, 2, 3, 4], 0, 1000e3, &pos, &unblocked());
        assert_eq!(t.parent[4], 0, "unreachable nodes route direct to the PS");
        assert_eq!(t.hops[4], 1);
    }

    #[test]
    fn dense_clusters_build_flat_trees() {
        // every node within range of the root: the one-hop baseline shape
        let pos = arc(4, 800e3);
        let t = tree(&[0, 1, 2, 3], 0, 3000e3, &pos, &unblocked());
        assert_eq!(t.parent, vec![NO_PARENT, 0, 0, 0]);
        assert_eq!(t.max_hops(), 1);
    }

    /// Diamond: 1 and 2 both see the root and both see 3; the root sees
    /// neither 1→2 shortcut nor 3. Node 2 sits slightly out of the orbit
    /// plane so all pairwise ranges stay in the intended regime.
    fn diamond() -> Vec<Vec3> {
        let r = 7.0e6;
        let dth = 2.0 * ((400e3) / r).asin(); // 800 km adjacent chords
        let th1 = dth;
        let th3 = 2.0 * dth;
        let tilt = 0.01; // ~70 km out-of-plane: within range of 1's slots
        vec![
            Vec3::new(r, 0.0, 0.0),
            Vec3::new(r * th1.cos(), r * th1.sin(), 0.0),
            Vec3::new(r * th1.cos(), r * th1.sin() * tilt.cos(), r * th1.sin() * tilt.sin()),
            Vec3::new(r * th3.cos(), r * th3.sin(), 0.0),
        ]
    }

    #[test]
    fn ties_break_to_the_lowest_indexed_parent() {
        let pos = diamond();
        let t = tree(&[0, 1, 2, 3], 0, 1000e3, &pos, &unblocked());
        assert_eq!(t.hops, vec![0, 1, 1, 2]);
        assert_eq!(t.parent[3], 1, "equal-hop parents tie-break low");
    }

    #[test]
    fn blocked_relays_are_leaves_and_routes_bend_around_them() {
        let pos = diamond();
        let blocked = |id: usize| id == 1;
        let t = tree(&[0, 1, 2, 3], 0, 1000e3, &pos, &blocked);
        assert_eq!(t.hops[1], 1, "a blocked node still uploads its own model");
        assert_eq!(t.parent[3], 2, "the route bends around the blocked relay");
        assert_eq!(t.hops[3], 2);
        // blocking every relay degenerates to the direct fallback
        let all = |id: usize| id != 0;
        let t = tree(&[0, 1, 2, 3], 0, 1000e3, &pos, &all);
        assert_eq!(t.parent, vec![NO_PARENT, 0, 0, 0]);
        assert_eq!(t.max_hops(), 1);
    }

    #[test]
    fn grid_and_brute_trees_are_bit_identical() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
        let snap = c.snapshot(137.0);
        let feats = snap.features_km();
        // an arbitrary ascending subset standing in for one cluster
        let nodes: Vec<usize> = (0..feats.len()).filter(|i| i % 3 != 1).collect();
        let mut scratch = Vec::new();
        for bands in [1usize, 4, 16] {
            let g = SphereGrid::build(&feats, bands);
            for range in [4500e3, 7000e3] {
                let brute = build_route_tree(
                    &nodes,
                    0,
                    range,
                    &snap.positions,
                    None,
                    &unblocked(),
                    &mut scratch,
                );
                let gridded = build_route_tree(
                    &nodes,
                    0,
                    range,
                    &snap.positions,
                    Some(&g),
                    &unblocked(),
                    &mut scratch,
                );
                assert_eq!(brute, gridded, "bands={bands} range={range}");
                assert!(
                    brute.max_hops() >= 1,
                    "shell must be routable at range {range}"
                );
            }
        }
    }

    /// Two-hop geometry in the high-SNR regime (hops ≤ 2000 km, where
    /// `2/rate(d/2) ≥ 1/rate(d)`): PS—relay—member on an arc with 800 km
    /// edges, the member 1600 km from the PS end to end.
    fn two_hop() -> (Vec<Vec3>, RouteTree) {
        let pos = arc(3, 800e3);
        let t = tree(&[0, 1, 2], 0, 1000e3, &pos, &unblocked());
        assert_eq!(t.hops, vec![0, 1, 2]);
        (pos, t)
    }

    #[test]
    fn billing_a_pure_relay_hop_costs_more_than_the_teleport() {
        // a member forced through an idle relay pays for both radios —
        // in-regime, strictly more time and energy than the one-hop
        // teleport the baseline bills (every hop is on the books)
        let (l, e) = models();
        let (pos, t) = two_hop();
        let wire = WireBits::dense(44_426);
        let m = MemberWork::nominal(640, 1e9, pos[2]);
        let (t_direct, e_direct) = cluster_round(&l, &e, &[m], pos[0], wire);
        let hops = [
            HopNode::relay_only(0.0),
            HopNode::relay_only(pos[1].dist(pos[0])),
            HopNode {
                t_cmp: l.compute_time(m.samples, m.cpu_hz),
                e_cmp: e.compute_energy(m.samples, m.cpu_hz),
                link_factor: 1.0,
                d_up: pos[2].dist(pos[1]),
            },
        ];
        let (t_routed, e_routed) = routed_round(&l, &e, &t, &hops, None, wire);
        assert!(t_routed > t_direct, "{t_routed} vs {t_direct}");
        assert!(e_routed > e_direct, "{e_routed} vs {e_direct}");
    }

    #[test]
    fn relay_merging_undercuts_two_direct_uploads() {
        // when the relay is itself a member, its own model rides the one
        // pooled forward — cheaper than it and the far member both
        // radioing the PS directly (the in-route aggregation payoff)
        let (l, e) = models();
        let (pos, t) = two_hop();
        let wire = WireBits::dense(44_426);
        let relay = MemberWork::nominal(640, 1e9, pos[1]);
        let member = MemberWork::nominal(640, 1e9, pos[2]);
        let (_, e_direct) = cluster_round(&l, &e, &[relay, member], pos[0], wire);
        let hop = |m: &MemberWork, d: f64| HopNode {
            t_cmp: l.compute_time(m.samples, m.cpu_hz),
            e_cmp: e.compute_energy(m.samples, m.cpu_hz),
            link_factor: 1.0,
            d_up: d,
        };
        let hops = [
            HopNode::relay_only(0.0),
            hop(&relay, pos[1].dist(pos[0])),
            hop(&member, pos[2].dist(pos[1])),
        ];
        let (_, e_routed) = routed_round(&l, &e, &t, &hops, None, wire);
        assert!(e_routed < e_direct, "{e_routed} vs {e_direct}");
    }

    #[test]
    fn retries_stretch_the_round_and_bill_every_attempt() {
        let (l, e) = models();
        let (pos, t) = two_hop();
        let wire = WireBits::dense(44_426);
        let hops = [
            HopNode::relay_only(0.0),
            HopNode::relay_only(pos[1].dist(pos[0])),
            HopNode {
                t_cmp: 1.0,
                e_cmp: 0.5,
                link_factor: 1.0,
                d_up: pos[2].dist(pos[1]),
            },
        ];
        let clean = TransferOutcome {
            attempts: 1,
            wait_s: 0.0,
            delivered: true,
        };
        let noisy = TransferOutcome {
            attempts: 2,
            wait_s: 0.25,
            delivered: true,
        };
        let base = routed_round(&l, &e, &t, &hops, None, wire);
        let same = routed_round(&l, &e, &t, &hops, Some(&[clean, clean, clean]), wire);
        assert_eq!(base, same, "clean outcomes are the nominal path, bitwise");
        let (t_n, e_n) = routed_round(&l, &e, &t, &hops, Some(&[clean, clean, noisy]), wire);
        let t_hop = l.comm_time_scaled(wire.up, hops[2].d_up, 1.0);
        assert!((t_n - (base.0 + t_hop + 0.25)).abs() < 1e-9);
        let extra = e.tx_energy(wire.up, hops[2].d_up);
        assert!((e_n - (base.1 + extra)).abs() < 1e-9);
    }

    #[test]
    fn ring_of_one_is_compute_only() {
        let (l, e) = models();
        let hops = [HopNode {
            t_cmp: 2.0,
            e_cmp: 3.0,
            link_factor: 1.0,
            d_up: 0.0,
        }];
        assert_eq!(
            ring_round(&l, &e, &hops, None, WireBits::dense(44_426)),
            (2.0, 3.0)
        );
        assert_eq!(ring_round(&l, &e, &[], None, WireBits::dense(44_426)), (0.0, 0.0));
    }

    #[test]
    fn ring_steps_and_chunks_match_the_hand_fold() {
        let (l, e) = models();
        let pos = arc(3, 800e3);
        let wire = WireBits::dense(44_426);
        let ds = [
            pos[0].dist(pos[1]),
            pos[1].dist(pos[2]),
            pos[2].dist(pos[0]),
        ];
        let hops: Vec<HopNode> = ds
            .iter()
            .enumerate()
            .map(|(i, &d)| HopNode {
                t_cmp: 1.0 + i as f64,
                e_cmp: 0.5,
                link_factor: 1.0,
                d_up: d,
            })
            .collect();
        let (t, en) = ring_round(&l, &e, &hops, None, wire);
        let chunk = wire.up / 3.0;
        let steps = 4.0; // 2(k-1)
        let t_step = ds
            .iter()
            .map(|&d| l.comm_time(chunk, d))
            .fold(0.0f64, f64::max);
        assert!((t - (3.0 + steps * t_step)).abs() < 1e-9);
        let e_tx: f64 = ds.iter().map(|&d| e.tx_energy(chunk, d) * steps).sum();
        assert!((en - (1.5 + e_tx)).abs() < 1e-9);
        // a degraded edge stretches every step it transmits
        let mut slow = hops.clone();
        slow[1].link_factor = 0.25;
        let (t_slow, e_slow) = ring_round(&l, &e, &slow, None, wire);
        assert!(t_slow > t, "degraded ring edge slows the all-reduce");
        assert_eq!(e_slow, en, "Eq. 8 energy depends on payload, not rate");
    }
}

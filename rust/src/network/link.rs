//! Link model: achievable rate (paper Eq. 6) with a free-space path-loss
//! channel gain, plus computation time `t_cmp = D·Q/f`.
//!
//! **Unit convention.** The paper's Eq. 6 uses a natural logarithm, so
//! [`LinkModel::rate`] is nats/s, not bits/s; the whole reproduction
//! (payloads in bits, `t_com = ζ/r`) is calibrated against that form and
//! treats it as the paper's "rate". [`LinkModel::rate_bits`] provides the
//! Shannon `B·log2(1+SNR)` bit rate (= `rate / ln 2`) for callers that
//! need physical units.

use super::params::{NetworkParams, Payload};
use crate::orbit::SPEED_OF_LIGHT;

/// Minimum link distance in meters: every rate/time/energy formula clamps
/// its distance to at least this, so degenerate co-located geometry prices
/// like a 1 m link instead of tripping a division by zero.
pub const MIN_LINK_DIST_M: f64 = 1.0;

/// Achievable-rate link model. The paper writes
/// `r_i = B_i ln(1 + P0 h_i / N0)` (nats/s with ln; we keep the paper's
/// form — see the module docs and [`LinkModel::rate_bits`]). Channel gain
/// `h_i` follows free-space path loss at the carrier:
/// `h = G (c / (4π d f_c))²`.
#[derive(Clone, Copy, Debug)]
pub struct LinkModel {
    pub params: NetworkParams,
}

impl LinkModel {
    pub fn new(params: NetworkParams) -> Self {
        LinkModel { params }
    }

    /// Free-space channel gain at distance `d` meters (linear). Distances
    /// under [`MIN_LINK_DIST_M`] are clamped up — a co-located pair (e.g.
    /// a satellite "uploading" to itself during a failover re-collection)
    /// prices like a 1 m link instead of dividing by zero. The clamp used
    /// to be scattered at call sites as `.max(1.0)`; centralising it here
    /// keeps every clamped value bit-identical (the `max` is an IEEE
    /// no-op for the d ≥ 1 m geometry every preset produces).
    pub fn channel_gain(&self, d: f64) -> f64 {
        assert!(d >= 0.0 && d.is_finite(), "bad link distance {d}");
        let d = d.max(MIN_LINK_DIST_M);
        let lambda = SPEED_OF_LIGHT / self.params.carrier_hz;
        let fspl = lambda / (4.0 * std::f64::consts::PI * d);
        self.params.antenna_gain * fspl * fspl
    }

    /// Eq. 6 achievable rate over distance `d`, **as the paper writes it**:
    /// `r = B·ln(1 + SNR)` with a natural logarithm, which is nats/s — not
    /// bits/s (Shannon capacity uses `log2`). Every reproduced time/energy
    /// number is calibrated against this form, so it stays the unit the
    /// simulator folds with; use [`LinkModel::rate_bits`] when an actual
    /// bit rate is required. The two differ by a fixed factor of
    /// `ln 2 ≈ 0.693`.
    pub fn rate(&self, d: f64) -> f64 {
        let p = &self.params;
        let snr = p.tx_power_w * self.channel_gain(d) / p.noise_w;
        p.bandwidth_hz * (1.0 + snr).ln()
    }

    /// Shannon-form achievable rate in bits/s: `B·log2(1 + SNR)`. This is
    /// [`LinkModel::rate`] (the paper's nats/s form) divided by `ln 2`.
    pub fn rate_bits(&self, d: f64) -> f64 {
        self.rate(d) / std::f64::consts::LN_2
    }

    /// Ground-link rate: same model scaled by the GS antenna advantage.
    pub fn ground_rate(&self, d: f64) -> f64 {
        self.rate(d) * self.params.ground_rate_gain
    }

    /// Communication time to upload `bits` over distance `d`:
    /// `t_com = ζ / r_i` (paper §II-C) plus propagation delay.
    pub fn comm_time(&self, bits: f64, d: f64) -> f64 {
        let d = d.max(MIN_LINK_DIST_M);
        bits / self.rate(d) + d / SPEED_OF_LIGHT
    }

    /// [`LinkModel::comm_time`] under a scenario-plane rate degradation:
    /// the achievable rate is multiplied by `factor` (1.0 = nominal);
    /// propagation delay is unaffected. Multiplying by exactly 1.0 is an
    /// IEEE identity, so an undegraded link is bit-identical to
    /// [`LinkModel::comm_time`] — the property the nominal-scenario golden
    /// trajectories pin.
    pub fn comm_time_scaled(&self, bits: f64, d: f64, factor: f64) -> f64 {
        debug_assert!(factor > 0.0 && factor <= 1.0, "bad rate factor {factor}");
        let d = d.max(MIN_LINK_DIST_M);
        bits / (self.rate(d) * factor) + d / SPEED_OF_LIGHT
    }

    /// Communication time on a ground link.
    pub fn ground_comm_time(&self, bits: f64, d: f64) -> f64 {
        let d = d.max(MIN_LINK_DIST_M);
        bits / self.ground_rate(d) + d / SPEED_OF_LIGHT
    }

    /// Computation time for `samples` local samples on a CPU running at
    /// `cpu_hz`: `t_cmp = D·Q/f`.
    pub fn compute_time(&self, samples: usize, cpu_hz: f64) -> f64 {
        samples as f64 * self.params.cycles_per_sample / cpu_hz
    }

    /// The wire-plane accounting seam: exact billed bytes of one upload.
    /// Every byte count a bench or ledger reports derives from a
    /// [`Payload`] through here, so dense and compressed paths cannot
    /// drift apart in their bytes-on-the-wire formula.
    pub fn upload_bytes(&self, payload: &Payload) -> f64 {
        payload.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn link() -> LinkModel {
        LinkModel::new(NetworkParams::default())
    }

    #[test]
    fn rate_decreases_with_distance() {
        let l = link();
        let r1 = l.rate(500e3);
        let r2 = l.rate(1000e3);
        let r3 = l.rate(2500e3);
        assert!(r1 > r2 && r2 > r3, "{r1} {r2} {r3}");
        assert!(r3 > 0.0);
    }

    #[test]
    fn rate_is_plausible_for_leo() {
        // a LEO Ka-band link at 1300 km with these defaults should land in
        // the kb/s–Gb/s envelope (the paper never states absolute rates)
        let r = link().rate(1300e3);
        assert!(r > 1e3 && r < 1e10, "rate {r}");
    }

    #[test]
    fn rate_is_nats_and_rate_bits_is_shannon() {
        // pin both conventions: `rate` is the paper's B·ln(1+SNR) nats/s,
        // `rate_bits` is the Shannon B·log2(1+SNR) — exactly ln2 apart
        let l = link();
        for &d in &[500e3, 1300e3, 2500e3] {
            let p = &l.params;
            let snr = p.tx_power_w * l.channel_gain(d) / p.noise_w;
            assert_eq!(l.rate(d), p.bandwidth_hz * (1.0 + snr).ln(), "d={d}");
            assert_eq!(l.rate_bits(d), l.rate(d) / std::f64::consts::LN_2, "d={d}");
            let log2_form = p.bandwidth_hz * (1.0 + snr).log2();
            assert!(
                (l.rate_bits(d) / log2_form - 1.0).abs() < 1e-12,
                "rate_bits is not B·log2(1+SNR) at d={d}"
            );
            // bits/s is the larger number (1 nat ≈ 1.44 bits)
            assert!(l.rate_bits(d) > l.rate(d));
        }
    }

    #[test]
    fn channel_gain_inverse_square() {
        let l = link();
        let g1 = l.channel_gain(1e6);
        let g2 = l.channel_gain(2e6);
        assert!((g1 / g2 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn scaled_comm_time_at_unit_factor_is_bit_identical() {
        let l = link();
        for &d in &[500e3, 1300e3, 2500e3] {
            assert_eq!(l.comm_time_scaled(1e6, d, 1.0), l.comm_time(1e6, d));
        }
        // a degraded link is strictly slower, and only in the payload term
        let t = l.comm_time(1e6, 1300e3);
        let t_deg = l.comm_time_scaled(1e6, 1300e3, 0.5);
        let prop = 1300e3 / SPEED_OF_LIGHT;
        assert!(t_deg > t);
        assert!(((t_deg - prop) / (t - prop) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn comm_time_scales_with_payload() {
        let l = link();
        let t1 = l.comm_time(1e6, 1300e3);
        let t2 = l.comm_time(2e6, 1300e3);
        // subtract propagation delay before comparing
        let prop = 1300e3 / SPEED_OF_LIGHT;
        assert!(((t2 - prop) / (t1 - prop) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn ground_rate_faster() {
        let l = link();
        assert!(l.ground_rate(1300e3) > l.rate(1300e3));
        assert!(l.ground_comm_time(1e6, 1300e3) < l.comm_time(1e6, 1300e3));
    }

    #[test]
    fn compute_time_formula() {
        let l = link();
        // t = D*Q/f
        let t = l.compute_time(640, 1e9);
        assert!((t - 640.0 * 1e6 / 1e9).abs() < 1e-9);
        // faster CPU → shorter time
        assert!(l.compute_time(640, 2e9) < t);
    }

    #[test]
    fn propagation_delay_included() {
        let l = link();
        let t = l.comm_time(0.0, 3000e3);
        assert!((t - 3000e3 / SPEED_OF_LIGHT).abs() < 1e-12);
    }

    #[test]
    fn sub_meter_distances_clamp_to_the_one_meter_link() {
        // co-located pairs price like a 1 m link everywhere — the clamp
        // that used to live at call sites as `d.max(1.0)`, bit for bit
        let l = link();
        for &d in &[0.0, 1e-9, 0.3, 1.0] {
            assert_eq!(l.channel_gain(d), l.channel_gain(1.0), "gain at d={d}");
            assert_eq!(l.rate(d), l.rate(1.0), "rate at d={d}");
            assert_eq!(l.comm_time(1e6, d), l.comm_time(1e6, 1.0), "t_com at d={d}");
            assert_eq!(
                l.comm_time_scaled(1e6, d, 0.5),
                l.comm_time_scaled(1e6, 1.0, 0.5),
                "scaled t_com at d={d}"
            );
            assert_eq!(
                l.ground_comm_time(1e6, d),
                l.ground_comm_time(1e6, 1.0),
                "ground t_com at d={d}"
            );
        }
        // at and above the clamp the distance passes through untouched
        assert!(l.rate(1.0) > l.rate(2.0));
    }
}

//! Energy model (paper Eq. 8–10).
//!
//! * Transmission energy (Eq. 8): `E_tr = Σ_i P0 · |w_i| / r_i` — transmit
//!   power times upload duration.
//! * Aggregation/compute energy (Eq. 9): `E_agg = Σ_i ε0 · f_i · t_cmp`
//!   with the conventional dynamic-power reading `P = ε0 f³`, giving
//!   `E = ε0 f_i² · (cycles)` — we implement `ε0 · f_i² · f_i · t_cmp`
//!   scaled so defaults land in the paper's reported joule range.

use super::link::LinkModel;

/// Per-event energy accounting helpers.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    pub link: LinkModel,
}

impl EnergyModel {
    pub fn new(link: LinkModel) -> Self {
        EnergyModel { link }
    }

    /// Eq. 8 for one client: transmit `bits` over distance `d`.
    pub fn tx_energy(&self, bits: f64, d: f64) -> f64 {
        self.link.params.tx_power_w * (bits / self.link.rate(d))
    }

    /// Eq. 8 on a ground link.
    pub fn ground_tx_energy(&self, bits: f64, d: f64) -> f64 {
        self.link.params.tx_power_w * (bits / self.link.ground_rate(d))
    }

    /// Eq. 9 for one client: CPU energy for `samples` at `cpu_hz`.
    /// E = ε0 · f² · cycles  (cycles = samples · Q).
    pub fn compute_energy(&self, samples: usize, cpu_hz: f64) -> f64 {
        let cycles = samples as f64 * self.link.params.cycles_per_sample;
        self.link.params.epsilon0 * cpu_hz * cpu_hz * cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::params::NetworkParams;

    fn model() -> EnergyModel {
        EnergyModel::new(LinkModel::new(NetworkParams::default()))
    }

    #[test]
    fn tx_energy_is_power_times_time() {
        let m = model();
        let bits = 2e6;
        let d = 1300e3;
        let e = m.tx_energy(bits, d);
        let t = bits / m.link.rate(d);
        assert!((e - m.link.params.tx_power_w * t).abs() < 1e-12);
    }

    #[test]
    fn tx_energy_grows_with_distance() {
        let m = model();
        assert!(m.tx_energy(1e6, 2000e3) > m.tx_energy(1e6, 800e3));
    }

    #[test]
    fn compute_energy_scales_with_samples_and_freq() {
        let m = model();
        let e1 = m.compute_energy(100, 1e9);
        let e2 = m.compute_energy(200, 1e9);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
        // quadratic in frequency for fixed cycles
        let e4 = m.compute_energy(100, 2e9);
        assert!((e4 / e1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn energies_in_sane_joule_range() {
        // one LeNet upload (~2 Mb) and one 600-sample epoch should each be
        // fractions of a joule to tens of joules — the paper's totals are
        // thousands of joules over hundreds of rounds × many clients.
        let m = model();
        let e_tx = m.tx_energy(61_706.0 * 32.0, 1300e3);
        let e_cmp = m.compute_energy(600, 1e9);
        assert!(e_tx > 1e-4 && e_tx < 100.0, "tx {e_tx}");
        assert!(e_cmp > 1e-4 && e_cmp < 100.0, "cmp {e_cmp}");
    }

    #[test]
    fn ground_tx_cheaper() {
        let m = model();
        assert!(m.ground_tx_energy(1e6, 1300e3) < m.tx_energy(1e6, 1300e3));
    }
}

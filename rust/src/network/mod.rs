//! Satellite communication and energy substrate (paper §II-C).
//!
//! Implements the paper's link model (Eq. 6: Shannon-style achievable rate
//! with free-space path-loss channel gain), the computation-time model
//! (`t_cmp = D·Q/f`), the transmission-energy model (Eq. 8), and the
//! aggregation/computation energy model (Eq. 9). Constants default to the
//! ranges of the papers FedHC cites for its parameters ([14] Zhu & Jiang
//! JSAC'23, [15] Zhang et al. IoT-J'23) and are fully configurable.

pub mod energy;
pub mod link;
pub mod params;

pub use energy::EnergyModel;
pub use link::LinkModel;
pub use params::NetworkParams;

//! Satellite communication and energy substrate (paper §II-C).
//!
//! Implements the paper's link model (Eq. 6: Shannon-style achievable rate
//! with free-space path-loss channel gain), the computation-time model
//! (`t_cmp = D·Q/f`), the transmission-energy model (Eq. 8), and the
//! aggregation/computation energy model (Eq. 9). Constants default to the
//! ranges of the papers FedHC cites for its parameters (Zhu & Jiang
//! JSAC'23, Zhang et al. IoT-J'23) and are fully configurable.
//!
//! ```
//! use fedhc::network::{LinkModel, NetworkParams};
//!
//! let link = LinkModel::new(NetworkParams::default());
//! // the achievable rate falls with slant range (Eq. 6)
//! assert!(link.rate(500e3) > link.rate(2_000e3));
//! // and a farther hop costs more upload time (ζ / r + propagation)
//! assert!(link.comm_time(1e6, 2_000e3) > link.comm_time(1e6, 500e3));
//! ```

pub mod energy;
pub mod link;
pub mod params;
pub mod retry;
pub mod routing;

pub use energy::EnergyModel;
pub use link::LinkModel;
pub use params::{NetworkParams, Payload, WireBits};
pub use retry::{RetryPolicy, TransferOutcome};
pub use routing::{build_route_tree, ring_round, routed_round, HopNode, RouteTree};

//! `fedhc` — leader binary.
//!
//! Subcommands:
//!   run       one method on one configuration
//!   table1    regenerate Table I (all methods × K × dataset)
//!   fig3      regenerate Fig. 3 (accuracy vs rounds)
//!   inspect   print manifest / constellation / artifact info
//!
//! Examples:
//!   fedhc run --preset tiny --method fedhc
//!   fedhc run --dataset mnist --method fedce --k 4 --rounds 50
//!   fedhc table1 --preset tiny --rounds 30
//!   fedhc inspect

use anyhow::{anyhow, bail, Result};
use fedhc::baselines::run_cfedavg;
use fedhc::config::parse::merge_file_into_args;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::metrics::recorder;
use fedhc::metrics::report::{format_fig3, format_hotspots, format_table1, TimeEnergy};
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::cli::Args;
use fedhc::util::profile;
use std::path::Path;

const FLAGS: &[&str] = &[
    "no-target",
    "verbose",
    "help",
    "no-index",
    "pooled-params",
    "resident-params",
    "strict-float",
    "profile",
    "record-extended",
];

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> Result<()> {
    let mut args = Args::from_env(FLAGS);
    if args.flag("help") || args.subcommand.is_none() {
        print_help();
        return Ok(());
    }
    if args.flag("verbose") {
        fedhc::util::logging::set_level(fedhc::util::logging::Level::Debug);
    }
    if let Some(path) = args.get("config").map(str::to_string) {
        let text = std::fs::read_to_string(&path)?;
        merge_file_into_args(&mut args, &text).map_err(|e| anyhow::anyhow!(e))?;
    }
    match args.subcommand.as_deref().unwrap() {
        "run" => cmd_run(&args),
        "table1" => cmd_table1(&args),
        "fig3" => cmd_fig3(&args),
        "inspect" => cmd_inspect(),
        other => bail!("unknown subcommand '{other}' (try --help)"),
    }
}

fn print_help() {
    println!(
        "fedhc — hierarchical clustered federated learning for satellite networks

USAGE: fedhc <subcommand> [options]

SUBCOMMANDS
  run       one method on one configuration
  table1    regenerate Table I (time/energy to target accuracy)
  fig3      regenerate Fig. 3 (accuracy vs training round)
  inspect   show artifacts, variants and constellation info

COMMON OPTIONS
  --preset tiny|mnist|cifar10|mega-sparse|mega-dense
                                 base configuration (default mnist); the
                                 mega presets run a Starlink-class 40×125
                                 shell (1k / 5k clients) on the tiny model
  --method fedhc|cfedavg|hbase|fedce|fedhc-nomaml   (run only)
  --dataset mnist|cifar10|tiny   switch dataset family
  --k N --clients N --rounds N --epochs N --lr F --seed N
  --target F | --no-target       convergence target accuracy
  --ground-every N --z F --alpha F --beta F
  --planes N --sats-per-plane N --altitude-km F --inclination F
                                 Walker shell geometry
  --no-index                     disable the sphere-grid spatial index
                                 (constellation plane); results are
                                 bit-identical, only slower at scale
  --index-bands N                grid latitude bands (0 = auto)
  --pooled-params | --resident-params
                                 bounded-memory pooled member models vs a
                                 resident parameter vector per client
                                 (identical metrics; mega presets pool)
  --timeline analytic|event      clock semantics: closed-form Eq. 7 folds, or
                                 the discrete-event timeline with PS↔GS
                                 exchanges gated by visibility windows
                                 (paper presets default to event; tiny pins
                                 analytic)
  --scenario nominal|churn|flaky-ground|stragglers|eclipse|noisy-links|ps-crash
                                 fault-injection preset (deterministic,
                                 event-sourced; see sim::scenario). Knobs:
                                 --scenario-sat-fail P --scenario-fail-rounds N
                                 --scenario-ground-outage P --scenario-ground-rounds N
                                 --scenario-link-degrade P --scenario-link-factor F
                                 --scenario-link-rounds N --scenario-straggler P
                                 --scenario-slowdown F --scenario-straggler-rounds N
                                 --scenario-eclipse 0|1
                                 --scenario-link-noise P --scenario-noise-ber F
                                 --scenario-noise-rounds N
                                 --scenario-ps-fail P --scenario-ps-rounds N
  --outage P                     transient per-round outage probability
                                 (runs under every scenario preset)
  --ber F                        recovery plane: global bit-error-rate floor
                                 on every model/data upload. Corrupted
                                 transfers are checksum-detected and
                                 retransmitted with exponential backoff:
                                 --max-retries N      retransmissions before
                                                      the contribution is
                                                      dropped (default 3)
                                 --retry-backoff F    backoff growth factor
                                                      ≥ 1 (default 2.0)
                                 Every attempt bills Eq. 6/7 time and Eq. 8
                                 energy; a crashed PS process (ps-crash)
                                 fails over to the next-ranked member
  --aggregation sync|buffered|async
                                 intra-cluster aggregation plane: the round
                                 barrier (default), FedBuff-style buffered
                                 merges when the PS buffer hits its goal
                                 count, or per-arrival async folds. Knobs:
                                 --staleness-beta F   staleness discount
                                                      exponent 1/(1+τ)^β
                                                      (default 0.5)
                                 --buffer-size N      merge goal count
                                                      (0 = auto: the
                                                      cluster member count)
  --max-ground-wait S            event timeline: seconds a PS may wait for a
                                 window before going stale (default 7000)
  --window-step S                event timeline: window-search sampling step
  --compress none|topk:<frac>|int8
                                 wire plane: compress member→PS and PS→GS
                                 uploads (error-feedback top-k or int8),
                                 billing the actual payload bytes into
                                 Eq. 6/7 time and energy. 'none' (default)
                                 is byte-identical to the historical runs
  --routing direct|isl|isl:ring
                                 routing plane: how member uploads reach the
                                 cluster PS. 'direct' (default) keeps the
                                 one-hop teleport, byte-identical to the
                                 historical runs; 'isl' store-and-forwards
                                 over the LoS ISL graph (BFS shortest paths,
                                 lowest-index tie-breaks) with partial
                                 aggregation at relays, billing every hop;
                                 'isl:ring' swaps in a ring all-reduce over
                                 wire.up/k chunks (2(k−1) steps). Knob:
                                 --isl-range-km F     max ISL reach in km
                                                      (default 2000, LoS-
                                                      limited either way)
  --strict-float                 pin the scalar (pre-SIMD) compute kernels;
                                 pure speed knob — both paths are
                                 bit-identical (see runtime::host_model)
  --workers N                    round-engine worker threads (0 = all cores;
                                 any value gives identical metrics)
  --trace FILE                   telemetry plane (run only): record the
                                 sim-time event trace — round/stage/upload
                                 spans, retry/relay-hop/merge/failover/
                                 window instants — as JSON-lines to FILE
                                 plus Chrome trace_event JSON to
                                 FILE.chrome.json (open in Perfetto).
                                 Byte-identical across --workers values;
                                 off = zero-cost, results unchanged
  --metrics FILE                 telemetry plane (run only): dump the
                                 per-entity registry (per-sat/per-cluster
                                 counters + fixed-bucket histograms) to
                                 FILE and print the hotspot table
  --hotspots N                   rows in the hotspot table (default 5)
  --profile                      print a wall-clock phase profile after the
                                 run (host ns only; the simulated
                                 trajectory is unaffected)
  --record-extended              add per-round wire-byte / retransmit /
                                 route-hop deltas to the JSON series
  --config FILE                  key=value config file (CLI wins)
  --out DIR                      write CSV/JSON series (default results/)

BACKENDS
  With AOT artifacts present (artifacts/manifest.json, from
  python/compile/aot.py) models execute through PJRT; without them the
  built-in pure-Rust host backend runs the same entry points.
"
    );
}

fn config_from(args: &Args) -> Result<ExperimentConfig> {
    let preset = args.get_or("preset", "mnist");
    ExperimentConfig::preset(preset)
        .ok_or_else(|| {
            anyhow!(
                "unknown preset '{preset}' \
                 (expected tiny|mnist|cifar10|mega-sparse|mega-dense)"
            )
        })?
        .with_args(args)
}

fn load_runtime(cfg: &ExperimentConfig) -> Result<(Manifest, ModelRuntime)> {
    // AOT artifacts when present, pure-Rust host backend otherwise
    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    let rt = ModelRuntime::load(&manifest, cfg.variant())?;
    Ok((manifest, rt))
}

fn run_method(cfg: &ExperimentConfig, manifest: &Manifest, rt: &ModelRuntime, method: &str) -> Result<RunResult> {
    let mut trial = Trial::new(cfg.clone(), manifest, rt)?;
    match method {
        "fedhc" => run_clustered(&mut trial, Strategy::fedhc()),
        "fedhc-nomaml" => run_clustered(&mut trial, Strategy::fedhc_no_maml()),
        "hbase" | "h-base" => run_clustered(&mut trial, Strategy::hbase()),
        "fedce" => run_clustered(&mut trial, Strategy::fedce()),
        "cfedavg" | "c-fedavg" => run_cfedavg(&mut trial),
        other => bail!("unknown method '{other}'"),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from(args)?;
    let method = args.get_or("method", "fedhc");
    let (manifest, rt) = load_runtime(&cfg)?;
    eprintln!(
        "running {method} on {} (K={}, clients={}, rounds≤{}, timeline={}, scenario={}, \
         aggregation={}, routing={}, platform={})",
        cfg.dataset.name(),
        cfg.clusters,
        cfg.clients,
        cfg.rounds,
        cfg.timeline.name(),
        cfg.scenario.kind.name(),
        cfg.aggregation.name(),
        cfg.routing.name(),
        rt.platform()
    );
    // telemetry plane: the run owns its Trial so the trace and registry
    // survive the run and can be dumped afterwards
    let trace_path = args.get("trace").map(str::to_string);
    let metrics_path = args.get("metrics").map(str::to_string);
    if args.flag("profile") {
        profile::enable();
        profile::reset();
    }
    let mut trial = Trial::new(cfg.clone(), &manifest, &rt)?;
    if trace_path.is_some() {
        trial.trace.enable();
    }
    if metrics_path.is_some() {
        trial.registry.enable(cfg.clients, cfg.clusters);
    }
    let res = match method {
        "fedhc" => run_clustered(&mut trial, Strategy::fedhc())?,
        "fedhc-nomaml" => run_clustered(&mut trial, Strategy::fedhc_no_maml())?,
        "hbase" | "h-base" => run_clustered(&mut trial, Strategy::hbase())?,
        "fedce" => run_clustered(&mut trial, Strategy::fedce())?,
        "cfedavg" | "c-fedavg" => run_cfedavg(&mut trial)?,
        other => bail!("unknown method '{other}'"),
    };
    print_result(&res);
    let hotspots = format_hotspots(&trial.registry, args.get_usize("hotspots", 5)?);
    if !hotspots.is_empty() {
        print!("{hotspots}");
    }
    if args.flag("profile") {
        print!("{}", profile::format_summary());
    }
    if let Some(path) = &trace_path {
        std::fs::write(path, trial.trace.to_jsonl())?;
        let chrome = format!("{path}.chrome.json");
        std::fs::write(&chrome, trial.trace.to_chrome().to_pretty())?;
        eprintln!(
            "trace written to {path} ({} events; {chrome} opens in Perfetto)",
            trial.trace.len()
        );
    }
    if let Some(path) = &metrics_path {
        std::fs::write(path, trial.registry.to_json().to_pretty())?;
        eprintln!("metrics registry written to {path}");
    }
    let out = Path::new(args.get_or("out", "results"));
    let stem = format!("{}_{}_k{}", res.name.to_lowercase(), cfg.dataset.name(), cfg.clusters);
    if args.flag("record-extended") {
        recorder::write_series_extended(&res.ledger, out, &stem)?;
    } else {
        recorder::write_series(&res.ledger, out, &stem)?;
    }
    eprintln!("series written to {}/{stem}.{{csv,json}}", out.display());
    Ok(())
}

fn print_result(res: &RunResult) {
    println!("== {} ==", res.name);
    println!("  best accuracy : {:.2}%", res.final_accuracy * 100.0);
    println!("  total time    : {:.0} s (simulated, Eq. 7)", res.ledger.time_s);
    println!("  total energy  : {:.0} J (Eq. 10)", res.ledger.energy_j);
    println!("  reclusters    : {}", res.ledger.reclusters);
    println!("  maml adapts   : {}", res.ledger.maml_adaptations);
    println!("  wire traffic  : {:.0} bytes uploaded (Eq. 6 payloads)", res.ledger.wire_bytes);
    if res.ledger.ground_wait_s > 0.0 || res.ledger.stale_passes > 0 {
        println!(
            "  ground waits  : {:.0} s over visibility windows, {} stale pass(es)",
            res.ledger.ground_wait_s, res.ledger.stale_passes
        );
    }
    if res.ledger.faults_injected > 0 {
        println!("  faults        : {} injected (scenario plane)", res.ledger.faults_injected);
    }
    if res.ledger.straggler_wait_s > 0.0 {
        println!("  straggler wait: {:.0} s of slowed compute", res.ledger.straggler_wait_s);
    }
    if res.ledger.retransmits > 0 || res.ledger.corrupted_uploads > 0 {
        println!(
            "  recovery      : {} corrupted upload(s), {} retransmit(s), {:.0} s of backoff",
            res.ledger.corrupted_uploads, res.ledger.retransmits, res.ledger.retry_wait_s
        );
    }
    if res.ledger.failovers > 0 {
        println!("  ps failovers  : {} backup promotion(s)", res.ledger.failovers);
    }
    if res.ledger.route_hops > 0 || res.ledger.relay_merges > 0 {
        println!(
            "  routing       : {} ISL hop(s) traversed, {} in-route partial merge(s)",
            res.ledger.route_hops, res.ledger.relay_merges
        );
    }
    if res.ledger.buffered_merges > 0 {
        println!(
            "  buffered aggr : {} staleness-weighted merge(s), idle {:.0} s, stale {:.0} s",
            res.ledger.buffered_merges, res.ledger.idle_s, res.ledger.stale_s
        );
        let h = &res.ledger.staleness_hist;
        println!(
            "  staleness hist: τ=0:{} 1:{} 2:{} 3:{} ≥4:{}",
            h[0], h[1], h[2], h[3], h[4]
        );
    }
    match res.converged_at {
        Some((round, t, e)) => {
            println!("  converged     : round {round} (t={t:.0} s, e={e:.0} J)")
        }
        None => println!("  converged     : no (budget exhausted)"),
    }
}

const TABLE1_METHODS: &[&str] = &["cfedavg", "hbase", "fedce", "fedhc"];
const TABLE1_NAMES: &[&str] = &["C-FedAvg", "H-BASE", "FedCE", "FedHC"];

fn cmd_table1(args: &Args) -> Result<()> {
    let base = config_from(args)?;
    let mut ks: Vec<usize> = Vec::new();
    for s in args.get_or("ks", "3,4,5").split(',') {
        ks.push(
            s.trim()
                .parse()
                .map_err(|_| anyhow!("--ks expects comma-separated integers, got '{s}'"))?,
        );
    }
    let target = base.target_accuracy.unwrap_or(0.8);
    let (manifest, rt) = load_runtime(&base)?;

    let mut rows: Vec<(&str, Vec<TimeEnergy>)> = Vec::new();
    for (mi, method) in TABLE1_METHODS.iter().enumerate() {
        let mut cells = Vec::new();
        for &k in &ks {
            let mut cfg = base.clone();
            cfg.clusters = k;
            eprintln!("table1: {method} K={k} ...");
            let res = run_method(&cfg, &manifest, &rt, method)?;
            let (t, e, conv) = match res.converged_at {
                Some((_, t, e)) => (t, e, true),
                None => (res.ledger.time_s, res.ledger.energy_j, false),
            };
            cells.push(TimeEnergy {
                time_s: t,
                energy_j: e,
                converged: conv,
            });
        }
        rows.push((TABLE1_NAMES[mi], cells));
    }
    println!(
        "{}",
        format_table1(base.dataset.name(), target, &ks, &rows)
    );
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let mut base = config_from(args)?;
    base.target_accuracy = None; // fig3 runs a fixed round budget
    let k = base.clusters;
    let (manifest, rt) = load_runtime(&base)?;
    let mut ledgers = Vec::new();
    for method in TABLE1_METHODS {
        eprintln!("fig3: {method} ...");
        let res = run_method(&base, &manifest, &rt, method)?;
        ledgers.push((res.name, res.ledger));
    }
    let series: Vec<(&str, &fedhc::metrics::Ledger)> =
        ledgers.iter().map(|(n, l)| (*n, l)).collect();
    let every = args.get_usize("sample-every", (base.rounds / 10).max(1))?;
    println!("{}", format_fig3(base.dataset.name(), k, &series, every));
    let out = Path::new(args.get_or("out", "results"));
    for (name, ledger) in &ledgers {
        let stem = format!("fig3_{}_{}_k{}", name.to_lowercase(), base.dataset.name(), k);
        recorder::write_series(ledger, out, &stem)?;
    }
    Ok(())
}

fn cmd_inspect() -> Result<()> {
    let manifest = Manifest::load_or_host(&Manifest::default_dir())?;
    println!("artifacts: {}", manifest.dir.display());
    for (name, v) in &manifest.variants {
        println!(
            "  {name}: P={} batch={} chunk={} agg_slots={} input={:?}",
            v.param_count, v.batch, v.chunk_steps, v.agg_slots, v.input_chw
        );
        if v.entries.is_empty() {
            println!("    (no lowered entries — pure-Rust host backend)");
        }
        for (e, spec) in &v.entries {
            println!("    {e:<12} {}", spec.file);
        }
    }
    let rt = ModelRuntime::load(&manifest, "tiny_mlp")?;
    println!("backend platform: {}", rt.platform());
    Ok(())
}

//! Runtime bridge: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) and executes them on the PJRT CPU client from the Rust
//! hot path. Python never runs here — this module is the only consumer of
//! what `make artifacts` produced.
//!
//! * [`artifacts`] — manifest parsing + initial-parameter loading.
//! * [`executor`] — one compiled executable per entry point, with typed
//!   wrappers (`train_step`, `train_chunk`, `eval_step`, `maml_step`,
//!   `aggregate`).
//! * [`host`] — pure-Rust fallbacks for variable-size aggregation and for
//!   tests that must run without artifacts.

pub mod artifacts;
pub mod executor;
pub mod host;

pub use artifacts::{Manifest, VariantSpec};
pub use executor::ModelRuntime;

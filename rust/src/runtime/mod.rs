//! Runtime bridge: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) when present and executes them on the PJRT client, or
//! falls back to a pure-Rust host implementation of the same entry points
//! so the stack runs on images with neither artifacts nor XLA. Python
//! never runs here — this module is the only consumer of what
//! `make artifacts` produced.
//!
//! * [`artifacts`] — manifest parsing + initial-parameter loading, plus
//!   the built-in host manifest ([`Manifest::host`]).
//! * [`executor`] — one runtime per variant with typed entry points,
//!   dispatching to PJRT or the host model. The hot path is the in-place
//!   family (`train_step_into`, `train_chunk_into`, `maml_step_into`,
//!   `eval_step_with`, `aggregate_into`) operating against a caller-owned
//!   [`HostScratch`]; the allocating wrappers (`train_step`, …) remain for
//!   convenience and tests.
//! * [`host_model`] — the pure-Rust MLP backend: cache-blocked in-place
//!   kernels plus the seed's scalar kernels retained in
//!   [`host_model::reference`] as the bit-exactness oracle.
//! * [`host`] — shared pure-Rust vector ops (weighted aggregation, norms)
//!   used by the dispatcher, the baselines, and tests.

pub mod artifacts;
pub mod executor;
pub mod host;
pub mod host_model;

pub use artifacts::{Manifest, VariantSpec};
pub use executor::ModelRuntime;
pub use host_model::{HostModel, HostScratch};

//! Runtime bridge: loads the AOT artifacts (`artifacts/*.hlo.txt` +
//! `manifest.json`) when present and executes them on the PJRT client, or
//! falls back to a pure-Rust host implementation of the same entry points
//! so the stack runs on images with neither artifacts nor XLA. Python
//! never runs here — this module is the only consumer of what
//! `make artifacts` produced.
//!
//! * [`artifacts`] — manifest parsing + initial-parameter loading, plus
//!   the built-in host manifest ([`Manifest::host`]).
//! * [`executor`] — one runtime per variant with typed wrappers
//!   (`train_step`, `train_chunk`, `eval_step`, `maml_step`,
//!   `aggregate`), dispatching to PJRT or the host model.
//! * [`host_model`] — the pure-Rust MLP backend.
//! * [`host`] — shared pure-Rust vector ops (weighted aggregation, norms)
//!   used by the dispatcher, the baselines, and tests.

pub mod artifacts;
pub mod executor;
pub mod host;
pub mod host_model;

pub use artifacts::{Manifest, VariantSpec};
pub use executor::ModelRuntime;
pub use host_model::HostModel;

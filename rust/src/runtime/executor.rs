//! Execution of the model entry points behind one `ModelRuntime` facade.
//!
//! Two backends:
//!
//! * **PJRT** — compiles each AOT `*.hlo.txt` once (HLO text →
//!   `HloModuleProto` → `XlaComputation` → loaded executable) and executes
//!   through the `xla` crate. Selected when the manifest variant carries
//!   lowered entry points.
//! * **Host** — the pure-Rust implementation in
//!   [`crate::runtime::host_model`]. Selected for variants with no
//!   artifacts, notably [`Manifest::host`], so the whole stack runs on
//!   images without an XLA toolchain.
//!
//! All tensors cross as flat `f32` slices — the manifest's shapes are only
//! used for validation and reshaping. `ModelRuntime` is `Sync` (the host
//! backend is pure math and the call counter is atomic), which lets the
//! parallel round engine ([`crate::sim::engine`]) share one runtime across
//! worker threads.

use super::artifacts::{EntrySpec, Manifest, VariantSpec};
use super::host_model::{HostModel, HostScratch};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};

enum Backend {
    Pjrt {
        client: xla::PjRtClient,
        exes: BTreeMap<String, xla::PjRtLoadedExecutable>,
    },
    Host(HostModel),
}

/// Compiled executables (or the host model) for one model variant.
pub struct ModelRuntime {
    pub spec: VariantSpec,
    backend: Backend,
    /// Entry-point call counter (perf diagnostics).
    calls: AtomicU64,
}

fn literal(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
    let expect: usize = shape.iter().product();
    if data.len() != expect {
        bail!("input has {} elements, shape {shape:?} wants {expect}", data.len());
    }
    let lit = xla::Literal::vec1(data);
    if shape.len() == 1 {
        Ok(lit)
    } else {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(lit.reshape(&dims)?)
    }
}

impl ModelRuntime {
    /// Load `variant` from the manifest: compile every lowered entry point
    /// through PJRT, or build the host model when the variant carries no
    /// artifacts.
    pub fn load(manifest: &Manifest, variant: &str) -> Result<ModelRuntime> {
        let spec = manifest.variant(variant)?.clone();
        let backend = if spec.entries.is_empty() {
            Backend::Host(HostModel::from_spec(&spec)?)
        } else {
            let client = xla::PjRtClient::cpu()?;
            let mut exes = BTreeMap::new();
            for (name, entry) in &spec.entries {
                let path = manifest.dir.join(&entry.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .with_context(|| format!("parsing {path:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compiling {name}"))?;
                exes.insert(name.clone(), exe);
            }
            Backend::Pjrt { client, exes }
        };
        Ok(ModelRuntime {
            spec,
            backend,
            calls: AtomicU64::new(0),
        })
    }

    fn pjrt_entry(&self, name: &str) -> Result<(&xla::PjRtLoadedExecutable, &EntrySpec)> {
        let Backend::Pjrt { exes, .. } = &self.backend else {
            bail!("host backend has no PJRT entry '{name}'");
        };
        let exe = exes
            .get(name)
            .with_context(|| format!("no entry '{name}'"))?;
        Ok((exe, &self.spec.entries[name]))
    }

    /// Execute PJRT entry `name` with flat inputs; returns the decomposed
    /// tuple of flat f32 outputs.
    fn run(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let (exe, spec) = self.pjrt_entry(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, {} expected",
                inputs.len(),
                spec.inputs.len()
            );
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(data, shape)| literal(data, shape))
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        // jax lowers with return_tuple=True: decompose and flatten
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs returned, {} expected",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(Into::into))
            .collect()
    }

    fn count(&self) {
        self.calls.fetch_add(1, Ordering::Relaxed);
    }

    /// Copy a PJRT output back into the caller's buffer (the in-place
    /// entry points never hand ownership of a fresh vector to the caller).
    fn write_back(name: &str, params: &mut [f32], new: &[f32]) -> Result<()> {
        if new.len() != params.len() {
            bail!(
                "{name}: runtime returned {} params, caller holds {}",
                new.len(),
                params.len()
            );
        }
        params.copy_from_slice(new);
        Ok(())
    }

    /// One SGD step (Eq. 3–4) updating `params` in place against the
    /// caller-owned `scratch`; returns the pre-update loss. The host
    /// backend performs zero allocations once the scratch is warm.
    pub fn train_step_into(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        self.count();
        match &self.backend {
            Backend::Host(m) => m.train_step_into(params, x, y, lr, scratch),
            Backend::Pjrt { .. } => {
                let out = self.run("train_step", &[&*params, x, y, &[lr]])?;
                let loss = out[1][0];
                Self::write_back("train_step", params, &out[0])?;
                Ok(loss)
            }
        }
    }

    /// `chunk_steps` consecutive SGD steps in one call (xs is `[S*B*D]`,
    /// ys `[S*B]`), updating `params` in place; returns the mean loss.
    pub fn train_chunk_into(
        &self,
        params: &mut [f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        self.count();
        match &self.backend {
            Backend::Host(m) => m.train_chunk_into(params, xs, ys, lr, scratch),
            Backend::Pjrt { .. } => {
                let out = self.run("train_chunk", &[&*params, xs, ys, &[lr]])?;
                let loss = out[1][0];
                Self::write_back("train_chunk", params, &out[0])?;
                Ok(loss)
            }
        }
    }

    /// Evaluate one batch against caller-owned scratch: returns
    /// (mean_loss, correct_count).
    pub fn eval_step_with(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        scratch: &mut HostScratch,
    ) -> Result<(f32, f32)> {
        self.count();
        match &self.backend {
            Backend::Host(m) => m.eval_step_into(params, x, y, scratch),
            Backend::Pjrt { .. } => {
                let out = self.run("eval_step", &[params, x, y])?;
                Ok((out[0][0], out[1][0]))
            }
        }
    }

    /// FOMAML warm-start (Eq. 16–17) updating `params` in place; returns
    /// the query loss at the adapted parameters.
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step_into(
        &self,
        params: &mut [f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        self.count();
        match &self.backend {
            Backend::Host(m) => m.maml_step_into(params, sx, sy, qx, qy, alpha, beta, scratch),
            Backend::Pjrt { .. } => {
                let out =
                    self.run("maml_step", &[&*params, sx, sy, qx, qy, &[alpha], &[beta]])?;
                let loss = out[1][0];
                Self::write_back("maml_step", params, &out[0])?;
                Ok(loss)
            }
        }
    }

    /// One SGD step (Eq. 3–4): returns (new_params, loss). Allocating
    /// wrapper over [`ModelRuntime::train_step_into`].
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let loss = self.train_step_into(&mut p, x, y, lr, &mut scratch)?;
        Ok((p, loss))
    }

    /// `chunk_steps` consecutive SGD steps in one call:
    /// xs is `[S*B*D]`, ys `[S*B]`. Returns (new_params, mean_loss).
    /// Allocating wrapper over [`ModelRuntime::train_chunk_into`].
    pub fn train_chunk(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let loss = self.train_chunk_into(&mut p, xs, ys, lr, &mut scratch)?;
        Ok((p, loss))
    }

    /// Evaluate one batch: returns (mean_loss, correct_count). Allocating
    /// wrapper over [`ModelRuntime::eval_step_with`].
    pub fn eval_step(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        let mut scratch = HostScratch::new();
        self.eval_step_with(params, x, y, &mut scratch)
    }

    /// FOMAML warm-start (Eq. 16–17): returns (new_params, query_loss).
    /// Allocating wrapper over [`ModelRuntime::maml_step_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step(
        &self,
        params: &[f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let qloss = self.maml_step_into(&mut p, sx, sy, qx, qy, alpha, beta, &mut scratch)?;
        Ok((p, qloss))
    }

    /// Weighted aggregation (Eq. 5 / Eq. 12) into the caller's `out`
    /// buffer, reusing its allocation. On the host backend this is the
    /// weighted sum computed directly into `out`, allocation-free. On the
    /// PJRT backend it is the Pallas kernel with a fixed slot count
    /// (`stack` rows are zero-padded up to it — exact, see kernel docs);
    /// that branch still allocates its `slots × P` staging copy per call,
    /// an inherent cost of the padded kernel ABI that PJRT dispatch
    /// overhead dwarfs.
    pub fn aggregate_into(
        &self,
        stack: &[&[f32]],
        weights: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let slots = self.spec.agg_slots;
        let p = self.spec.param_count;
        let n = stack.len();
        if n == 0 || n > slots {
            bail!("aggregate: {n} rows, kernel supports 1..={slots}");
        }
        if weights.len() != n {
            bail!("aggregate: {n} rows vs {} weights", weights.len());
        }
        for (i, row) in stack.iter().enumerate() {
            if row.len() != p {
                bail!("aggregate: row {i} has {} params, want {p}", row.len());
            }
        }
        self.count();
        match &self.backend {
            Backend::Host(_) => {
                out.resize(p, 0.0);
                super::host::aggregate_host_into(stack, weights, out);
            }
            Backend::Pjrt { .. } => {
                let mut flat = vec![0.0f32; slots * p];
                for (i, row) in stack.iter().enumerate() {
                    flat[i * p..(i + 1) * p].copy_from_slice(row);
                }
                let mut w = vec![0.0f32; slots];
                w[..n].copy_from_slice(weights);
                let res = self.run("aggregate", &[&flat, &w])?;
                out.clear();
                out.extend_from_slice(&res[0]);
            }
        }
        Ok(())
    }

    /// Weighted aggregation returning a fresh vector. Allocating wrapper
    /// over [`ModelRuntime::aggregate_into`].
    pub fn aggregate(&self, stack: &[&[f32]], weights: &[f32]) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.aggregate_into(stack, weights, &mut out)?;
        Ok(out)
    }

    /// Number of entry-point executions so far (perf counter).
    pub fn call_count(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Backend platform: the PJRT platform name, or `"host"`.
    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Host(_) => "host".to_string(),
            Backend::Pjrt { client, .. } => client.platform_name(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<ModelRuntime> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        let m = Manifest::load(&dir).unwrap();
        Some(ModelRuntime::load(&m, "tiny_mlp").unwrap())
    }

    fn toy_batch(spec: &VariantSpec, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = crate::util::Rng::new(seed);
        let b = spec.batch;
        let d = spec.input_dim();
        let mut x = vec![0.0f32; b * d];
        let mut y = vec![0.0f32; b];
        for i in 0..b {
            let c = rng.below_usize(10);
            y[i] = c as f32;
            for j in 0..d {
                x[i * d + j] = 0.1 * rng.normal() as f32;
            }
            x[i * d + c] += 2.0;
        }
        (x, y)
    }

    #[test]
    fn host_runtime_loads_and_is_sync() {
        fn assert_sync<T: Sync>(_: &T) {}
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        assert_sync(&rt);
        assert_eq!(rt.platform(), "host");
        assert_eq!(rt.call_count(), 0);
    }

    #[test]
    fn host_runtime_trains_and_counts_calls() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut params = m.init_params(&rt.spec).unwrap();
        let (x, y) = toy_batch(&rt.spec, 1);
        let mut first = None;
        for _ in 0..60 {
            let (p, loss) = rt.train_step(&params, &x, &y, 0.5).unwrap();
            params = p;
            first.get_or_insert(loss);
        }
        assert_eq!(rt.call_count(), 60);
        let (last, correct) = rt.eval_step(&params, &x, &y).unwrap();
        assert!(
            last < 0.6 * first.unwrap(),
            "loss did not drop: {first:?} -> {last}"
        );
        assert!(last.is_finite());
        assert!((0.0..=rt.spec.batch as f32).contains(&correct));
    }

    #[test]
    fn host_aggregate_matches_host_helper() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let p = rt.spec.param_count;
        let mut rng = crate::util::Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let w = [0.1, 0.3, 0.2, 0.25, 0.15];
        let got = rt.aggregate(&refs, &w).unwrap();
        let want = crate::runtime::host::aggregate_host(&refs, &w);
        assert_eq!(got, want);
        // slot-count validation still applies on the host backend
        let too_many: Vec<&[f32]> = (0..rt.spec.agg_slots + 1).map(|_| refs[0]).collect();
        let w_bad = vec![1.0f32; too_many.len()];
        assert!(rt.aggregate(&too_many, &w_bad).is_err());
    }

    #[test]
    fn train_step_reduces_loss() {
        let Some(rt) = runtime() else { return };
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let mut params = m.init_params(&rt.spec).unwrap();
        let (x, y) = toy_batch(&rt.spec, 1);
        let mut first = None;
        let mut last = 0.0;
        for _ in 0..25 {
            let (p, loss) = rt.train_step(&params, &x, &y, 0.5).unwrap();
            params = p;
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(last < 0.5 * first.unwrap(), "{first:?} -> {last}");
    }

    #[test]
    fn eval_step_counts() {
        let Some(rt) = runtime() else { return };
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let params = m.init_params(&rt.spec).unwrap();
        let (x, y) = toy_batch(&rt.spec, 2);
        let (loss, correct) = rt.eval_step(&params, &x, &y).unwrap();
        assert!(loss > 0.0);
        assert!((0.0..=rt.spec.batch as f32).contains(&correct));
    }

    #[test]
    fn chunk_matches_stepwise() {
        let Some(rt) = runtime() else { return };
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let params0 = m.init_params(&rt.spec).unwrap();
        let s = rt.spec.chunk_steps;
        let b = rt.spec.batch;
        let d = rt.spec.input_dim();
        let mut xs = Vec::with_capacity(s * b * d);
        let mut ys = Vec::with_capacity(s * b);
        let mut batches = Vec::new();
        for step in 0..s {
            let (x, y) = toy_batch(&rt.spec, 10 + step as u64);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
            batches.push((x, y));
        }
        let (pc, _) = rt.train_chunk(&params0, &xs, &ys, 0.1).unwrap();
        let mut ps = params0;
        for (x, y) in &batches {
            let (p, _) = rt.train_step(&ps, x, y, 0.1).unwrap();
            ps = p;
        }
        let max_diff = pc
            .iter()
            .zip(&ps)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "chunk vs stepwise diff {max_diff}");
    }

    #[test]
    fn aggregate_matches_host() {
        let Some(rt) = runtime() else { return };
        let p = rt.spec.param_count;
        let mut rng = crate::util::Rng::new(3);
        let rows: Vec<Vec<f32>> = (0..5)
            .map(|_| (0..p).map(|_| rng.normal() as f32).collect())
            .collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let w = [0.1, 0.3, 0.2, 0.25, 0.15];
        let got = rt.aggregate(&refs, &w).unwrap();
        let want = crate::runtime::host::aggregate_host(&refs, &w);
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-4, "kernel vs host diff {max_diff}");
    }

    #[test]
    fn maml_step_runs_and_identity_at_zero_rates() {
        let Some(rt) = runtime() else { return };
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let params = m.init_params(&rt.spec).unwrap();
        let (sx, sy) = toy_batch(&rt.spec, 4);
        let (qx, qy) = toy_batch(&rt.spec, 5);
        let (p1, qloss) = rt.maml_step(&params, &sx, &sy, &qx, &qy, 0.0, 0.0).unwrap();
        assert!(qloss > 0.0);
        let max_diff = p1
            .iter()
            .zip(&params)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 1e-6, "zero-rate maml changed params by {max_diff}");
    }

    #[test]
    fn shape_validation_rejects_bad_inputs() {
        let Some(rt) = runtime() else { return };
        let bad = vec![0.0f32; 3];
        let (x, y) = toy_batch(&rt.spec, 6);
        assert!(rt.train_step(&bad, &x, &y, 0.1).is_err());
    }
}

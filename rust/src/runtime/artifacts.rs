//! Artifact manifest: what `python/compile/aot.py` emitted, type-checked.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

/// One lowered entry point.
#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// One model variant (tiny_mlp / mnist_lenet / cifar_lenet).
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub param_count: usize,
    pub batch: usize,
    pub chunk_steps: usize,
    pub agg_slots: usize,
    pub input_chw: (usize, usize, usize),
    pub classes: usize,
    pub init_file: String,
    pub entries: BTreeMap<String, EntrySpec>,
}

impl VariantSpec {
    pub fn input_dim(&self) -> usize {
        self.input_chw.0 * self.input_chw.1 * self.input_chw.2
    }
}

/// Parsed manifest + its directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantSpec>,
}

#[derive(Debug)]
pub struct ManifestError(pub String);

impl std::fmt::Display for ManifestError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "manifest error: {}", self.0)
    }
}

impl std::error::Error for ManifestError {}

fn shape_list(j: &Json, what: &str) -> Result<Vec<Vec<usize>>, ManifestError> {
    j.as_arr()
        .ok_or_else(|| ManifestError(format!("{what} not an array")))?
        .iter()
        .map(|s| {
            s.as_arr()
                .ok_or_else(|| ManifestError(format!("{what} entry not an array")))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| ManifestError(format!("{what} dim not usize")))
                })
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, ManifestError> {
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .map_err(|e| ManifestError(format!("cannot read {path:?}: {e} — run `make artifacts`")))?;
        let j = Json::parse(&text).map_err(|e| ManifestError(e.to_string()))?;
        if j.get("format").as_usize() != Some(1) {
            return Err(ManifestError("unsupported manifest format".into()));
        }
        let mut variants = BTreeMap::new();
        let vs = j
            .get("variants")
            .as_obj()
            .ok_or_else(|| ManifestError("missing variants".into()))?;
        for (name, v) in vs {
            let chw = v
                .get("input_chw")
                .as_arr()
                .and_then(|a| {
                    if a.len() == 3 {
                        Some((a[0].as_usize()?, a[1].as_usize()?, a[2].as_usize()?))
                    } else {
                        None
                    }
                })
                .ok_or_else(|| ManifestError(format!("{name}: bad input_chw")))?;
            let mut entries = BTreeMap::new();
            let es = v
                .get("entries")
                .as_obj()
                .ok_or_else(|| ManifestError(format!("{name}: missing entries")))?;
            for (ename, e) in es {
                entries.insert(
                    ename.clone(),
                    EntrySpec {
                        file: e
                            .get("file")
                            .as_str()
                            .ok_or_else(|| ManifestError(format!("{name}.{ename}: no file")))?
                            .to_string(),
                        inputs: shape_list(e.get("inputs"), "inputs")?,
                        outputs: shape_list(e.get("outputs"), "outputs")?,
                    },
                );
            }
            let spec = VariantSpec {
                name: name.clone(),
                param_count: v
                    .get("param_count")
                    .as_usize()
                    .ok_or_else(|| ManifestError(format!("{name}: no param_count")))?,
                batch: v
                    .get("batch")
                    .as_usize()
                    .ok_or_else(|| ManifestError(format!("{name}: no batch")))?,
                chunk_steps: v.get("chunk_steps").as_usize().unwrap_or(4),
                agg_slots: v.get("agg_slots").as_usize().unwrap_or(16),
                input_chw: chw,
                classes: v.get("classes").as_usize().unwrap_or(10),
                init_file: v
                    .get("init_file")
                    .as_str()
                    .ok_or_else(|| ManifestError(format!("{name}: no init_file")))?
                    .to_string(),
                entries,
            };
            variants.insert(name.clone(), spec);
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            variants,
        })
    }

    /// Default artifact directory: `$FEDHC_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("FEDHC_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Built-in manifest for the pure-Rust host backend: the same variant
    /// names the AOT pipeline emits, but with no lowered entries (which is
    /// what routes `ModelRuntime::load` to the host model) and
    /// deterministic in-memory initial parameters. This is what makes the
    /// binary, examples and benches runnable on images that carry neither
    /// artifacts nor an XLA runtime.
    pub fn host() -> Manifest {
        fn host_variant(name: &str, chw: (usize, usize, usize), hidden: usize) -> VariantSpec {
            let d = chw.0 * chw.1 * chw.2;
            let classes = 10;
            VariantSpec {
                name: name.to_string(),
                param_count: d * hidden + hidden + hidden * classes + classes,
                batch: 16,
                chunk_steps: 4,
                agg_slots: 16,
                input_chw: chw,
                classes,
                init_file: String::new(),
                entries: BTreeMap::new(),
            }
        }
        let mut variants = BTreeMap::new();
        for v in [
            host_variant("tiny_mlp", (1, 8, 8), 32),
            host_variant("mnist_lenet", (1, 28, 28), 64),
            host_variant("cifar_lenet", (3, 32, 32), 64),
        ] {
            variants.insert(v.name.clone(), v);
        }
        Manifest {
            dir: PathBuf::from("(built-in host backend)"),
            variants,
        }
    }

    /// Load `<dir>/manifest.json` when present, otherwise fall back to the
    /// built-in host manifest ([`Manifest::host`]).
    pub fn load_or_host(dir: &Path) -> Result<Manifest, ManifestError> {
        if dir.join("manifest.json").exists() {
            Manifest::load(dir)
        } else {
            Ok(Manifest::host())
        }
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec, ManifestError> {
        self.variants
            .get(name)
            .ok_or_else(|| ManifestError(format!("unknown variant '{name}'")))
    }

    /// Load the initial flat parameter vector for a variant. Host variants
    /// (no `init_file`) generate a deterministic initialisation instead of
    /// reading one from disk.
    pub fn init_params(&self, spec: &VariantSpec) -> Result<Vec<f32>, ManifestError> {
        if spec.init_file.is_empty() {
            let model = crate::runtime::host_model::HostModel::from_spec(spec)
                .map_err(|e| ManifestError(e.to_string()))?;
            // stable per-variant seed: FNV-1a over the variant name
            let mut seed = 0xcbf2_9ce4_8422_2325u64;
            for b in spec.name.bytes() {
                seed = (seed ^ b as u64).wrapping_mul(0x1000_0000_01b3);
            }
            return Ok(model.init_params(seed));
        }
        let path = self.dir.join(&spec.init_file);
        let bytes = fs::read(&path)
            .map_err(|e| ManifestError(format!("cannot read {path:?}: {e}")))?;
        if bytes.len() != 4 * spec.param_count {
            return Err(ManifestError(format!(
                "{path:?}: {} bytes, want {}",
                bytes.len(),
                4 * spec.param_count
            )));
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest_when_present() {
        if !have_artifacts() {
            eprintln!("skipping: no artifacts (run `make artifacts`)");
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        let v = m.variant("tiny_mlp").unwrap();
        assert_eq!(v.param_count, 64 * 32 + 32 + 32 * 10 + 10);
        assert_eq!(v.input_dim(), 64);
        for e in ["train_step", "train_chunk", "eval_step", "maml_step", "aggregate"] {
            assert!(v.entries.contains_key(e), "missing entry {e}");
        }
        let init = m.init_params(v).unwrap();
        assert_eq!(init.len(), v.param_count);
        assert!(init.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn entry_shapes_match_param_count() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&Manifest::default_dir()).unwrap();
        for v in m.variants.values() {
            let ts = &v.entries["train_step"];
            assert_eq!(ts.inputs[0], vec![v.param_count]);
            assert_eq!(ts.inputs[1], vec![v.batch, v.input_dim()]);
            let ag = &v.entries["aggregate"];
            assert_eq!(ag.inputs[0], vec![v.agg_slots, v.param_count]);
        }
    }

    #[test]
    fn missing_dir_is_graceful() {
        let err = Manifest::load(Path::new("/nonexistent_dir_xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn host_manifest_has_consistent_variants() {
        let m = Manifest::host();
        for name in ["tiny_mlp", "mnist_lenet", "cifar_lenet"] {
            let v = m.variant(name).unwrap();
            assert!(v.entries.is_empty(), "{name}: host variant has entries");
            assert!(v.init_file.is_empty());
            assert_eq!(v.classes, 10);
            let init = m.init_params(v).unwrap();
            assert_eq!(init.len(), v.param_count);
            assert!(init.iter().all(|x| x.is_finite()));
            // deterministic
            assert_eq!(init, m.init_params(v).unwrap());
        }
        // tiny host variant matches the AOT tiny_mlp geometry
        let tiny = m.variant("tiny_mlp").unwrap();
        assert_eq!(tiny.param_count, 64 * 32 + 32 + 32 * 10 + 10);
        assert_eq!(tiny.input_dim(), 64);
    }

    #[test]
    fn load_or_host_falls_back() {
        let m = Manifest::load_or_host(Path::new("/nonexistent_dir_xyz")).unwrap();
        assert!(m.variants.contains_key("tiny_mlp"));
    }
}

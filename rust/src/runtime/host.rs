//! Pure-Rust host ops.
//!
//! The Pallas aggregation kernel has a fixed slot count baked at AOT time;
//! clusters larger than that (and all baseline variants that never touch
//! PJRT) aggregate here. The hot loop is written as chunked
//! multiply-accumulate over the flat vectors — see benches/bench_aggregation.

/// Weighted sum of parameter rows: `out = Σ_i w[i] * stack[i]`.
pub fn aggregate_host(stack: &[&[f32]], weights: &[f32]) -> Vec<f32> {
    assert_eq!(stack.len(), weights.len());
    assert!(!stack.is_empty(), "empty aggregation");
    let p = stack[0].len();
    let mut out = vec![0.0f32; p];
    aggregate_host_into(stack, weights, &mut out);
    out
}

/// Allocation-free variant for the hot path.
pub fn aggregate_host_into(stack: &[&[f32]], weights: &[f32], out: &mut [f32]) {
    assert_eq!(stack.len(), weights.len());
    let p = out.len();
    out.fill(0.0);
    for (row, &w) in stack.iter().zip(weights.iter()) {
        assert_eq!(row.len(), p, "ragged parameter stack");
        // simple indexed loop lets LLVM autovectorise the FMA
        for i in 0..p {
            out[i] += w * row[i];
        }
    }
}

/// In-place axpy: `y += a * x` (used by momentum-free updates and tests).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for i in 0..x.len() {
        y[i] += a * x[i];
    }
}

/// L2 distance between two parameter vectors.
pub fn l2_distance(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(&x, &y)| {
            let d = (x - y) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt()
}

/// L2 norm.
pub fn l2_norm(a: &[f32]) -> f64 {
    a.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{property, Gen};

    #[test]
    fn aggregate_identity_on_single_row() {
        let row = [1.0f32, -2.0, 3.5];
        let out = aggregate_host(&[&row], &[1.0]);
        assert_eq!(out, row.to_vec());
    }

    #[test]
    fn aggregate_weighted_mean() {
        let a = [2.0f32, 0.0];
        let b = [0.0f32, 4.0];
        let out = aggregate_host(&[&a, &b], &[0.5, 0.5]);
        assert_eq!(out, vec![1.0, 2.0]);
    }

    #[test]
    fn convex_combination_of_identical_rows_is_identity() {
        property("convex combo identity", 64, |g: &mut Gen| {
            let p = g.usize_in(1, 200);
            let n = g.usize_in(1, 8);
            let row = g.f32_vec(p, -5.0, 5.0);
            let mut w: Vec<f32> = g.f32_vec(n, 0.01, 1.0);
            let s: f32 = w.iter().sum();
            for x in w.iter_mut() {
                *x /= s;
            }
            let rows: Vec<&[f32]> = (0..n).map(|_| row.as_slice()).collect();
            let out = aggregate_host(&rows, &w);
            for (o, r) in out.iter().zip(&row) {
                assert!((o - r).abs() < 1e-4, "{o} vs {r}");
            }
        });
    }

    #[test]
    fn aggregate_linear_in_weights() {
        property("aggregation linearity", 32, |g: &mut Gen| {
            let p = g.usize_in(1, 64);
            let a = g.f32_vec(p, -1.0, 1.0);
            let b = g.f32_vec(p, -1.0, 1.0);
            let w1 = g.f64_in(0.0, 2.0) as f32;
            let w2 = g.f64_in(0.0, 2.0) as f32;
            let out = aggregate_host(&[&a, &b], &[w1, w2]);
            for i in 0..p {
                let want = w1 * a[i] + w2 * b[i];
                assert!((out[i] - want).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn l2_helpers() {
        assert_eq!(l2_distance(&[0.0, 3.0], &[4.0, 0.0]), 5.0);
        assert_eq!(l2_norm(&[3.0, 4.0]), 5.0);
        let mut y = vec![1.0f32, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
    }
}

//! Pure-Rust host backend: the model entry points (`train_step`,
//! `train_chunk`, `eval_step`, `maml_step`) for a one-hidden-layer tanh
//! MLP with softmax cross-entropy, operating on flat `f32` parameter
//! vectors laid out as `[W1 | b1 | W2 | b2]` (`W1` is `[d][h]` row-major
//! by input, `W2` is `[h][c]` row-major by hidden unit).
//!
//! This backend keeps the whole system — binary, examples, benches, the
//! parallel round engine and its determinism tests — runnable on images
//! that carry neither the AOT artifacts nor an XLA runtime. It is
//! selected automatically for manifest variants with no lowered entries
//! (see [`super::artifacts::Manifest::host`]).
//!
//! # The compute plane
//!
//! The hot path is the in-place kernel family ([`train_step_into`],
//! [`train_chunk_into`], [`maml_step_into`], [`eval_step_into`]): they
//! update `params: &mut [f32]` directly against a caller-owned
//! [`HostScratch`], so a steady-state SGD step performs **zero heap
//! allocations**, and the `W1` forward/backward loops are interchanged to
//! k-outer/j-inner so every weight access streams a contiguous row (the
//! seed's j-outer order walked `W1` with stride `h`, defeating both the
//! cache and the autovectoriser).
//!
//! The loop interchange is **bit-exact**: every accumulator (`a1[j]`,
//! `logits[o]`, `da1[j]`, each `gw1[k*h+j]`) still receives its partial
//! sums in the seed's order, only the interleaving *across independent
//! accumulators* changes — which floating-point addition cannot observe.
//! The seed's scalar kernels are retained verbatim in [`reference`] and
//! the property tests in this module pin bit-identical `(params, loss)`
//! across random geometries. Results therefore remain deterministic on
//! any worker thread — the property the engine's guarantee rests on.
//!
//! # The SIMD plane (DESIGN note: float reassociation)
//!
//! On top of the blocked schedule, the default ("fast") path vectorises
//! the dominant `W1` forward/backward loops and the parameter-sized
//! update loops with explicit 8-lane `f32` blocks (the vendored [`wide`]
//! crate), dispatched at runtime: an `#[target_feature(enable = "avx2")]`
//! specialisation when the CPU has AVX2, the same portable lane code
//! otherwise. The vectorisation introduces **zero reassociation**: lanes
//! are laid across *independent* accumulators (eight consecutive `a1[j]`
//! or `gw1[k*h+j]` cells), each of which still receives its partial sums
//! in the seed's `k`-ascending order — the forward kernel unrolls four
//! `k`-rows per pass purely to hold the `a1` tile in registers, adding
//! the four terms in the same order four scalar iterations would. There
//! is no FMA contraction (Rust never fuses `a + b * c` implicitly, and
//! [`wide`] lowers mul and add separately) and the transcendentals
//! (`tanh`, `exp`, `ln`) stay scalar libm calls. The fast path is
//! therefore **bit-identical** to the blocked path and to [`reference`] —
//! pinned by the max-ulp property test in this module, which asserts a
//! drift of exactly zero ulp across random geometries.
//!
//! [`float_mode`] (the `--strict-float` config/CLI knob) pins every
//! kernel to the scalar blocked path anyway, as the paranoid oracle
//! setting: `--strict-float` runs are byte-identical to default runs by
//! the argument above, and the golden-trajectory suite holds under
//! either setting.
//!
//! [`train_step_into`]: HostModel::train_step_into
//! [`train_chunk_into`]: HostModel::train_chunk_into
//! [`maml_step_into`]: HostModel::maml_step_into
//! [`eval_step_into`]: HostModel::eval_step_into

use super::artifacts::VariantSpec;
use anyhow::{bail, Result};
use wide::f32x8;

/// Process-wide float-path selector for the host kernels — the
/// `--strict-float` knob. `strict` pins the scalar cache-blocked kernels;
/// the default "fast" mode runs the 8-lane SIMD schedule. Both paths are
/// bit-identical (see the module docs), so the selector is a pure
/// performance switch: flipping it mid-run cannot change any result,
/// which is also why a relaxed global is sound under the parallel round
/// engine.
pub mod float_mode {
    use std::sync::atomic::{AtomicBool, Ordering};

    static STRICT: AtomicBool = AtomicBool::new(false);

    /// Pin every host kernel to the scalar cache-blocked path
    /// (`--strict-float`).
    pub fn set_strict(on: bool) {
        STRICT.store(on, Ordering::Relaxed);
    }

    /// Whether the scalar path is pinned.
    pub fn strict() -> bool {
        STRICT.load(Ordering::Relaxed)
    }
}

/// `acc[j] += x * w[j]` over one row, eight lanes at a time. Per-cell
/// arithmetic is exactly the scalar statement (one mul, one add, no FMA),
/// so the vectorisation only changes how many independent cells advance
/// per instruction.
#[inline(always)]
fn axpy_row(acc: &mut [f32], w: &[f32], x: f32) {
    let n = acc.len();
    let s = f32x8::splat(x);
    let mut j = 0;
    while j + 8 <= n {
        let a = f32x8::from_slice(&acc[j..]) + f32x8::from_slice(&w[j..]) * s;
        a.write_to_slice(&mut acc[j..]);
        j += 8;
    }
    while j < n {
        acc[j] += x * w[j];
        j += 1;
    }
}

/// Four consecutive `axpy_row`s (`w4` holds four rows of length `h`)
/// with the `acc` tile held in registers across the four rows: each cell
/// receives `x[0]·w0[j]`, `x[1]·w1[j]`, `x[2]·w2[j]`, `x[3]·w3[j]` in
/// that order — the same partial-sum order as four scalar `k`-iterations
/// — while loading and storing `acc` once instead of four times.
#[inline(always)]
fn axpy_rows4(acc: &mut [f32], w4: &[f32], h: usize, x: [f32; 4]) {
    let (w0, rest) = w4.split_at(h);
    let (w1, rest) = rest.split_at(h);
    let (w2, w3) = rest.split_at(h);
    let s0 = f32x8::splat(x[0]);
    let s1 = f32x8::splat(x[1]);
    let s2 = f32x8::splat(x[2]);
    let s3 = f32x8::splat(x[3]);
    let mut j = 0;
    while j + 8 <= h {
        let mut a = f32x8::from_slice(&acc[j..]);
        a = a + f32x8::from_slice(&w0[j..]) * s0;
        a = a + f32x8::from_slice(&w1[j..]) * s1;
        a = a + f32x8::from_slice(&w2[j..]) * s2;
        a = a + f32x8::from_slice(&w3[j..]) * s3;
        a.write_to_slice(&mut acc[j..]);
        j += 8;
    }
    while j < h {
        let mut a = acc[j];
        a += x[0] * w0[j];
        a += x[1] * w1[j];
        a += x[2] * w2[j];
        a += x[3] * w3[j];
        acc[j] = a;
        j += 1;
    }
}

/// `p[i] -= lr * g[i]`, eight lanes at a time (same per-cell arithmetic
/// as the scalar statement).
#[inline(always)]
fn sgd_step_lanes(p: &mut [f32], g: &[f32], lr: f32) {
    let n = p.len();
    let s = f32x8::splat(lr);
    let mut i = 0;
    while i + 8 <= n {
        let v = f32x8::from_slice(&p[i..]) - f32x8::from_slice(&g[i..]) * s;
        v.write_to_slice(&mut p[i..]);
        i += 8;
    }
    while i < n {
        p[i] -= lr * g[i];
        i += 1;
    }
}

/// `out[i] = p[i] - rate * g[i]`, eight lanes at a time (the MAML
/// adapted-parameter build).
#[inline(always)]
fn scaled_sub_lanes(out: &mut [f32], p: &[f32], g: &[f32], rate: f32) {
    let n = out.len();
    let s = f32x8::splat(rate);
    let mut i = 0;
    while i + 8 <= n {
        let v = f32x8::from_slice(&p[i..]) - f32x8::from_slice(&g[i..]) * s;
        v.write_to_slice(&mut out[i..]);
        i += 8;
    }
    while i < n {
        out[i] = p[i] - rate * g[i];
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sgd_step_avx2(p: &mut [f32], g: &[f32], lr: f32) {
    sgd_step_lanes(p, g, lr);
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn scaled_sub_avx2(out: &mut [f32], p: &[f32], g: &[f32], rate: f32) {
    scaled_sub_lanes(out, p, g, rate);
}

/// Dispatched SGD update `p -= lr·g`: scalar under
/// [`float_mode::strict`], AVX2-specialised lanes when the CPU has them,
/// portable lanes otherwise. All three produce identical bits.
fn sgd_step(p: &mut [f32], g: &[f32], lr: f32) {
    if float_mode::strict() {
        for (pi, &gi) in p.iter_mut().zip(g.iter()) {
            *pi -= lr * gi;
        }
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if wide::have_avx2() {
        // SAFETY: AVX2 support was verified at runtime just above.
        unsafe { sgd_step_avx2(p, g, lr) };
        return;
    }
    sgd_step_lanes(p, g, lr);
}

/// Dispatched `out = p - rate·g` (see [`sgd_step`] for the dispatch).
fn scaled_sub(out: &mut [f32], p: &[f32], g: &[f32], rate: f32) {
    if float_mode::strict() {
        for ((o, &pi), &gi) in out.iter_mut().zip(p.iter()).zip(g.iter()) {
            *o = pi - rate * gi;
        }
        return;
    }
    #[cfg(target_arch = "x86_64")]
    if wide::have_avx2() {
        // SAFETY: AVX2 support was verified at runtime just above.
        unsafe { scaled_sub_avx2(out, p, g, rate) };
        return;
    }
    scaled_sub_lanes(out, p, g, rate);
}

/// One-hidden-layer MLP geometry recovered from a variant spec.
#[derive(Clone, Copy, Debug)]
pub struct HostModel {
    /// Input dimension d.
    pub input: usize,
    /// Hidden width h.
    pub hidden: usize,
    /// Output classes c.
    pub classes: usize,
    /// Batch size B the spec was built for.
    pub batch: usize,
    /// SGD steps per `train_chunk` call.
    pub chunk_steps: usize,
}

/// Per-sample activation workspace (hidden/class sized).
#[derive(Default)]
struct ActBufs {
    a1: Vec<f32>,
    logits: Vec<f32>,
    probs: Vec<f32>,
    da1: Vec<f32>,
    dl: Vec<f32>,
}

impl ActBufs {
    fn ensure(&mut self, h: usize, c: usize) {
        self.a1.resize(h, 0.0);
        self.logits.resize(c, 0.0);
        self.probs.resize(c, 0.0);
        self.da1.resize(h, 0.0);
        self.dl.resize(c, 0.0);
    }
}

/// Caller-owned scratch for the in-place kernels: the per-sample
/// activation workspace plus the gradient and adapted-parameter vectors
/// (the two parameter-sized buffers the seed kernels allocated per step).
/// Buffers grow lazily to the geometry in use — and only to what the call
/// needs (evaluation never materialises the gradient) — so one scratch can
/// be recycled across kernels, rounds, and even model variants.
#[derive(Default)]
pub struct HostScratch {
    act: ActBufs,
    grad: Vec<f32>,
    adapted: Vec<f32>,
}

impl HostScratch {
    pub fn new() -> HostScratch {
        HostScratch::default()
    }
}

impl HostModel {
    /// Recover the MLP geometry from a variant spec
    /// (`P = d·h + h + h·c + c` must hold exactly).
    pub fn from_spec(spec: &VariantSpec) -> Result<HostModel> {
        let d = spec.input_dim();
        let c = spec.classes;
        let denom = d + c + 1;
        let h = spec.param_count.saturating_sub(c) / denom;
        if h == 0 || h * denom + c != spec.param_count {
            bail!(
                "variant '{}' (P={}, d={d}, c={c}) does not match the host MLP layout",
                spec.name,
                spec.param_count
            );
        }
        Ok(HostModel {
            input: d,
            hidden: h,
            classes: c,
            batch: spec.batch,
            chunk_steps: spec.chunk_steps,
        })
    }

    /// Total parameter count for this geometry.
    pub fn param_count(&self) -> usize {
        self.input * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    /// Deterministic Glorot-uniform initial parameters (biases zero).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let (d, h, c) = (self.input, self.hidden, self.classes);
        let mut rng = crate::util::Rng::new(seed);
        let mut out = vec![0.0f32; self.param_count()];
        let lim1 = (6.0 / (d + h) as f64).sqrt();
        for v in &mut out[..d * h] {
            *v = rng.uniform_in(-lim1, lim1) as f32;
        }
        let w2 = d * h + h;
        let lim2 = (6.0 / (h + c) as f64).sqrt();
        for v in &mut out[w2..w2 + h * c] {
            *v = rng.uniform_in(-lim2, lim2) as f32;
        }
        out
    }

    fn check(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<()> {
        if params.len() != self.param_count() {
            bail!(
                "params has {} elements, host model wants {}",
                params.len(),
                self.param_count()
            );
        }
        if y.is_empty() || x.len() != y.len() * self.input {
            bail!(
                "batch shape mismatch: {} inputs vs {} labels × d={}",
                x.len(),
                y.len(),
                self.input
            );
        }
        let c = self.classes as f32;
        if y.iter().any(|&v| !(0.0..c).contains(&v) || v.fract() != 0.0) {
            bail!("labels must be integers in [0, {})", self.classes);
        }
        Ok(())
    }

    /// Forward (+ optional backward) pass over the batch; returns
    /// `(mean_loss, correct_count)`. When `grad` is provided (zeroed,
    /// `param_count` long), accumulates d(mean_loss)/d(params) into it.
    /// Dispatches between the bit-identical schedules: the scalar
    /// cache-blocked kernel under [`float_mode::strict`], the
    /// AVX2-specialised 8-lane kernel when the CPU has AVX2, and the
    /// portable 8-lane kernel otherwise.
    fn pass(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        grad: Option<&mut [f32]>,
        act: &mut ActBufs,
    ) -> (f32, f32) {
        if float_mode::strict() {
            return self.pass_blocked(params, x, y, grad, act);
        }
        #[cfg(target_arch = "x86_64")]
        if wide::have_avx2() {
            // SAFETY: AVX2 support was verified at runtime just above.
            return unsafe { self.pass_avx2(params, x, y, grad, act) };
        }
        self.pass_lanes(params, x, y, grad, act)
    }

    #[cfg(target_arch = "x86_64")]
    #[target_feature(enable = "avx2")]
    unsafe fn pass_avx2(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        grad: Option<&mut [f32]>,
        act: &mut ActBufs,
    ) -> (f32, f32) {
        self.pass_lanes(params, x, y, grad, act)
    }

    /// The 8-lane pass: identical to [`HostModel::pass_blocked`] except
    /// that the dominant `W1` forward/backward loops run through the
    /// [`axpy_rows4`]/[`axpy_row`] lane kernels. Bit-identical to the
    /// blocked schedule — lanes span independent accumulators, each cell
    /// keeps its `k`-ascending partial-sum order, and no FMA is emitted
    /// (see the module docs). `#[inline(always)]` so the AVX2 wrapper
    /// specialises the whole body under its target features.
    #[inline(always)]
    fn pass_lanes(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        mut grad: Option<&mut [f32]>,
        act: &mut ActBufs,
    ) -> (f32, f32) {
        let d = self.input;
        let h = self.hidden;
        let c = self.classes;
        let bsz = y.len();
        let (w1, rest) = params.split_at(d * h);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(h * c);
        let ActBufs {
            a1,
            logits,
            probs,
            da1,
            dl,
        } = act;
        let inv_b = 1.0f32 / bsz as f32;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for i in 0..bsz {
            let xi = &x[i * d..(i + 1) * d];
            let label = y[i] as usize;

            // forward: a1 = tanh(W1ᵀx + b1); four k-rows per pass with the
            // a1 tile in registers, then the leftover rows one at a time
            a1.copy_from_slice(b1);
            let mut k = 0;
            while k + 4 <= d {
                axpy_rows4(
                    a1,
                    &w1[k * h..(k + 4) * h],
                    h,
                    [xi[k], xi[k + 1], xi[k + 2], xi[k + 3]],
                );
                k += 4;
            }
            while k < d {
                axpy_row(a1, &w1[k * h..(k + 1) * h], xi[k]);
                k += 1;
            }
            for aj in a1.iter_mut() {
                *aj = aj.tanh();
            }
            // logits = W2ᵀa1 + b2: c is small (≤ 10), stays scalar
            logits.copy_from_slice(b2);
            for j in 0..h {
                let aj = a1[j];
                for (lo, &w) in logits.iter_mut().zip(&w2[j * c..(j + 1) * c]) {
                    *lo += aj * w;
                }
            }

            // softmax cross-entropy (max-shifted for stability)
            let mut maxl = logits[0];
            for &l in &logits[1..] {
                if l > maxl {
                    maxl = l;
                }
            }
            let mut sum = 0.0f32;
            for (p, &l) in probs.iter_mut().zip(logits.iter()) {
                *p = (l - maxl).exp();
                sum += *p;
            }
            for p in probs.iter_mut() {
                *p /= sum;
            }
            loss_sum += -(probs[label].max(1e-12) as f64).ln();
            let mut best = 0;
            for o in 1..c {
                if logits[o] > logits[best] {
                    best = o;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_deref_mut() {
                let (gw1, grest) = g.split_at_mut(d * h);
                let (gb1, grest) = grest.split_at_mut(h);
                let (gw2, gb2) = grest.split_at_mut(h * c);
                // d(mean loss)/d(logit_o) = (p_o − 1{o=y}) / B
                for o in 0..c {
                    let dlo = (probs[o] - if o == label { 1.0 } else { 0.0 }) * inv_b;
                    dl[o] = dlo;
                    gb2[o] += dlo;
                }
                // W2 backward, j-outer: c is small, stays scalar
                for j in 0..h {
                    let aj = a1[j];
                    let w2row = &w2[j * c..(j + 1) * c];
                    let gw2row = &mut gw2[j * c..(j + 1) * c];
                    let mut acc = 0.0f32;
                    for o in 0..c {
                        gw2row[o] += aj * dl[o];
                        acc += w2row[o] * dl[o];
                    }
                    da1[j] = acc;
                }
                // tanh' = 1 − a1²; then W1 backward, one lane kernel per
                // contiguous gw1 row
                for j in 0..h {
                    da1[j] *= 1.0 - a1[j] * a1[j];
                    gb1[j] += da1[j];
                }
                for k in 0..d {
                    axpy_row(&mut gw1[k * h..(k + 1) * h], da1, xi[k]);
                }
            }
        }
        ((loss_sum / bsz as f64) as f32, correct as f32)
    }

    /// The scalar cache-blocked pass (the `--strict-float` path, and the
    /// pre-SIMD behaviour verbatim). Bit-identical to
    /// [`reference::batch_pass`]: the loop interchange only reorders
    /// *independent* accumulators, never the partial-sum order within one.
    fn pass_blocked(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        mut grad: Option<&mut [f32]>,
        act: &mut ActBufs,
    ) -> (f32, f32) {
        let d = self.input;
        let h = self.hidden;
        let c = self.classes;
        let bsz = y.len();
        let (w1, rest) = params.split_at(d * h);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(h * c);
        let ActBufs {
            a1,
            logits,
            probs,
            da1,
            dl,
        } = act;
        let inv_b = 1.0f32 / bsz as f32;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for i in 0..bsz {
            let xi = &x[i * d..(i + 1) * d];
            let label = y[i] as usize;

            // forward: a1 = tanh(W1ᵀx + b1), k-outer/j-inner so each W1
            // row w1[k*h..] streams contiguously; a1[j] still sums its
            // terms in k-ascending order
            a1.copy_from_slice(b1);
            for k in 0..d {
                let xk = xi[k];
                for (aj, &w) in a1.iter_mut().zip(&w1[k * h..(k + 1) * h]) {
                    *aj += xk * w;
                }
            }
            for aj in a1.iter_mut() {
                *aj = aj.tanh();
            }
            // logits = W2ᵀa1 + b2, j-outer so W2 rows stream contiguously
            logits.copy_from_slice(b2);
            for j in 0..h {
                let aj = a1[j];
                for (lo, &w) in logits.iter_mut().zip(&w2[j * c..(j + 1) * c]) {
                    *lo += aj * w;
                }
            }

            // softmax cross-entropy (max-shifted for stability)
            let mut maxl = logits[0];
            for &l in &logits[1..] {
                if l > maxl {
                    maxl = l;
                }
            }
            let mut sum = 0.0f32;
            for (p, &l) in probs.iter_mut().zip(logits.iter()) {
                *p = (l - maxl).exp();
                sum += *p;
            }
            for p in probs.iter_mut() {
                *p /= sum;
            }
            loss_sum += -(probs[label].max(1e-12) as f64).ln();
            let mut best = 0;
            for o in 1..c {
                if logits[o] > logits[best] {
                    best = o;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_deref_mut() {
                let (gw1, grest) = g.split_at_mut(d * h);
                let (gb1, grest) = grest.split_at_mut(h);
                let (gw2, gb2) = grest.split_at_mut(h * c);
                // d(mean loss)/d(logit_o) = (p_o − 1{o=y}) / B
                for o in 0..c {
                    let dlo = (probs[o] - if o == label { 1.0 } else { 0.0 }) * inv_b;
                    dl[o] = dlo;
                    gb2[o] += dlo;
                }
                // W2 backward, j-outer: gw2 rows stream contiguously and
                // each da1[j] keeps the o-ascending summation order
                for j in 0..h {
                    let aj = a1[j];
                    let w2row = &w2[j * c..(j + 1) * c];
                    let gw2row = &mut gw2[j * c..(j + 1) * c];
                    let mut acc = 0.0f32;
                    for o in 0..c {
                        gw2row[o] += aj * dl[o];
                        acc += w2row[o] * dl[o];
                    }
                    da1[j] = acc;
                }
                // tanh' = 1 − a1²; then W1 backward k-outer over
                // contiguous gw1 rows
                for j in 0..h {
                    da1[j] *= 1.0 - a1[j] * a1[j];
                    gb1[j] += da1[j];
                }
                for k in 0..d {
                    let xk = xi[k];
                    for (gw, &dz) in gw1[k * h..(k + 1) * h].iter_mut().zip(da1.iter()) {
                        *gw += xk * dz;
                    }
                }
            }
        }
        ((loss_sum / bsz as f64) as f32, correct as f32)
    }

    /// One SGD step updating `params` in place; returns the pre-update
    /// mean loss. Allocation-free given a warmed-up `scratch`.
    pub fn train_step_into(
        &self,
        params: &mut [f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        self.check(params, x, y)?;
        scratch.act.ensure(self.hidden, self.classes);
        scratch.grad.resize(self.param_count(), 0.0);
        let HostScratch { act, grad, .. } = scratch;
        grad.fill(0.0);
        let (loss, _) = self.pass(params, x, y, Some(grad.as_mut_slice()), act);
        sgd_step(params, grad, lr);
        Ok(loss)
    }

    /// `chunk_steps` consecutive in-place SGD steps; returns the mean loss.
    pub fn train_chunk_into(
        &self,
        params: &mut [f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        let s = self.chunk_steps;
        let bd = self.batch * self.input;
        if xs.len() != s * bd || ys.len() != s * self.batch {
            bail!(
                "chunk shape mismatch: {}×{} inputs / {} labels for S={s} B={}",
                xs.len(),
                self.input,
                ys.len(),
                self.batch
            );
        }
        let mut loss_sum = 0.0f64;
        for step in 0..s {
            let x = &xs[step * bd..(step + 1) * bd];
            let y = &ys[step * self.batch..(step + 1) * self.batch];
            let loss = self.train_step_into(params, x, y, lr, scratch)?;
            loss_sum += loss as f64;
        }
        Ok((loss_sum / s as f64) as f32)
    }

    /// Evaluate one batch against caller-owned scratch; returns
    /// `(mean_loss, correct_count)`. Never touches the gradient buffer, so
    /// an evaluation-only scratch stays activation-sized.
    pub fn eval_step_into(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        scratch: &mut HostScratch,
    ) -> Result<(f32, f32)> {
        self.check(params, x, y)?;
        scratch.act.ensure(self.hidden, self.classes);
        Ok(self.pass(params, x, y, None, &mut scratch.act))
    }

    /// First-order MAML step (Eq. 16–17) updating `params` in place: inner
    /// step on the support batch, outer step from the query gradient at
    /// the adapted parameters. Returns the query loss at the adapted
    /// parameters.
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step_into(
        &self,
        params: &mut [f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
        scratch: &mut HostScratch,
    ) -> Result<f32> {
        self.check(params, sx, sy)?;
        self.check(params, qx, qy)?;
        scratch.act.ensure(self.hidden, self.classes);
        scratch.grad.resize(self.param_count(), 0.0);
        scratch.adapted.resize(self.param_count(), 0.0);
        let HostScratch { act, grad, adapted } = scratch;
        grad.fill(0.0);
        let _ = self.pass(params, sx, sy, Some(grad.as_mut_slice()), act);
        scaled_sub(adapted, params, grad, alpha);
        grad.fill(0.0);
        let (qloss, _) = self.pass(adapted.as_slice(), qx, qy, Some(grad.as_mut_slice()), act);
        sgd_step(params, grad, beta);
        Ok(qloss)
    }

    /// One SGD step; returns `(new_params, pre-update mean loss)`.
    /// Allocating convenience wrapper over [`HostModel::train_step_into`]
    /// — hot paths thread a caller-owned [`HostScratch`] instead.
    pub fn train_step(
        &self,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let loss = self.train_step_into(&mut p, x, y, lr, &mut scratch)?;
        Ok((p, loss))
    }

    /// `chunk_steps` consecutive SGD steps; returns `(params, mean loss)`.
    /// Allocating wrapper over [`HostModel::train_chunk_into`].
    pub fn train_chunk(
        &self,
        params: &[f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let loss = self.train_chunk_into(&mut p, xs, ys, lr, &mut scratch)?;
        Ok((p, loss))
    }

    /// Evaluate one batch; returns `(mean_loss, correct_count)`.
    /// Allocating wrapper over [`HostModel::eval_step_into`].
    pub fn eval_step(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        let mut scratch = HostScratch::new();
        self.eval_step_into(params, x, y, &mut scratch)
    }

    /// First-order MAML step (Eq. 16–17); returns `(new_params, query
    /// loss)`. Allocating wrapper over [`HostModel::maml_step_into`].
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step(
        &self,
        params: &[f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let mut p = params.to_vec();
        let mut scratch = HostScratch::new();
        let qloss = self.maml_step_into(&mut p, sx, sy, qx, qy, alpha, beta, &mut scratch)?;
        Ok((p, qloss))
    }
}

/// The seed's scalar kernels, retained verbatim as the bit-exactness
/// oracle for the blocked in-place kernels: the property tests in this
/// module pin bit-identical `(params, loss)` across random geometries,
/// and `bench_runtime` measures the before/after ns/step gap against
/// these to track the perf trajectory (`BENCH_runtime.json`).
pub mod reference {
    use super::HostModel;
    use anyhow::Result;

    /// Scalar forward/backward pass over the batch (the seed's
    /// `batch_pass`): j-outer loops, stride-`h` `W1` access, one serial
    /// accumulator per output. Returns `(mean_loss, correct_count)` and,
    /// when `grad` is provided (zeroed, `param_count` long), accumulates
    /// d(mean_loss)/d(params) into it.
    pub fn batch_pass(
        m: &HostModel,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        mut grad: Option<&mut [f32]>,
    ) -> (f32, f32) {
        let d = m.input;
        let h = m.hidden;
        let c = m.classes;
        let bsz = y.len();
        let (w1, rest) = params.split_at(d * h);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(h * c);

        let mut a1 = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut probs = vec![0.0f32; c];
        let mut da1 = vec![0.0f32; h];
        let inv_b = 1.0f32 / bsz as f32;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for i in 0..bsz {
            let xi = &x[i * d..(i + 1) * d];
            let label = y[i] as usize;

            // forward: a1 = tanh(W1ᵀx + b1), logits = W2ᵀa1 + b2
            for j in 0..h {
                let mut z = b1[j];
                for k in 0..d {
                    z += xi[k] * w1[k * h + j];
                }
                a1[j] = z.tanh();
            }
            for o in 0..c {
                let mut z = b2[o];
                for j in 0..h {
                    z += a1[j] * w2[j * c + o];
                }
                logits[o] = z;
            }

            // softmax cross-entropy (max-shifted for stability)
            let mut maxl = logits[0];
            for &l in &logits[1..] {
                if l > maxl {
                    maxl = l;
                }
            }
            let mut sum = 0.0f32;
            for o in 0..c {
                probs[o] = (logits[o] - maxl).exp();
                sum += probs[o];
            }
            for o in 0..c {
                probs[o] /= sum;
            }
            loss_sum += -(probs[label].max(1e-12) as f64).ln();
            let mut best = 0;
            for o in 1..c {
                if logits[o] > logits[best] {
                    best = o;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_deref_mut() {
                let (gw1, grest) = g.split_at_mut(d * h);
                let (gb1, grest) = grest.split_at_mut(h);
                let (gw2, gb2) = grest.split_at_mut(h * c);
                for v in da1.iter_mut() {
                    *v = 0.0;
                }
                // d(mean loss)/d(logit_o) = (p_o − 1{o=y}) / B
                for o in 0..c {
                    let dl = (probs[o] - if o == label { 1.0 } else { 0.0 }) * inv_b;
                    gb2[o] += dl;
                    for j in 0..h {
                        gw2[j * c + o] += a1[j] * dl;
                        da1[j] += w2[j * c + o] * dl;
                    }
                }
                // tanh' = 1 − a1²
                for j in 0..h {
                    let dz = da1[j] * (1.0 - a1[j] * a1[j]);
                    gb1[j] += dz;
                    for k in 0..d {
                        gw1[k * h + j] += xi[k] * dz;
                    }
                }
            }
        }
        ((loss_sum / bsz as f64) as f32, correct as f32)
    }

    /// Scalar one-step SGD (the seed's `train_step`); returns
    /// `(new_params, pre-update mean loss)`.
    pub fn train_step(
        m: &HostModel,
        params: &[f32],
        x: &[f32],
        y: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        m.check(params, x, y)?;
        let mut grad = vec![0.0f32; params.len()];
        let (loss, _) = batch_pass(m, params, x, y, Some(&mut grad));
        let new = params.iter().zip(&grad).map(|(p, g)| p - lr * g).collect();
        Ok((new, loss))
    }

    /// Scalar `chunk_steps`-step SGD (the seed's `train_chunk`); returns
    /// `(params, mean loss)`.
    pub fn train_chunk(
        m: &HostModel,
        params: &[f32],
        xs: &[f32],
        ys: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let s = m.chunk_steps;
        let bd = m.batch * m.input;
        if xs.len() != s * bd || ys.len() != s * m.batch {
            anyhow::bail!(
                "chunk shape mismatch: {}×{} inputs / {} labels for S={s} B={}",
                xs.len(),
                m.input,
                ys.len(),
                m.batch
            );
        }
        let mut p = params.to_vec();
        let mut loss_sum = 0.0f64;
        for step in 0..s {
            let x = &xs[step * bd..(step + 1) * bd];
            let y = &ys[step * m.batch..(step + 1) * m.batch];
            let (np, loss) = train_step(m, &p, x, y, lr)?;
            p = np;
            loss_sum += loss as f64;
        }
        Ok((p, (loss_sum / s as f64) as f32))
    }

    /// Scalar evaluation (the seed's `eval_step`); returns
    /// `(mean_loss, correct_count)`.
    pub fn eval_step(m: &HostModel, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        m.check(params, x, y)?;
        Ok(batch_pass(m, params, x, y, None))
    }

    /// Scalar first-order MAML step (the seed's `maml_step`); returns
    /// `(new_params, query loss at the adapted parameters)`.
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step(
        m: &HostModel,
        params: &[f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        m.check(params, sx, sy)?;
        m.check(params, qx, qy)?;
        let mut gs = vec![0.0f32; params.len()];
        let _ = batch_pass(m, params, sx, sy, Some(&mut gs));
        let adapted: Vec<f32> = params.iter().zip(&gs).map(|(p, g)| p - alpha * g).collect();
        let mut gq = vec![0.0f32; params.len()];
        let (qloss, _) = batch_pass(m, &adapted, qx, qy, Some(&mut gq));
        let new = params.iter().zip(&gq).map(|(p, g)| p - beta * g).collect();
        Ok((new, qloss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{property, Gen};
    use crate::util::Rng;

    fn toy_model() -> HostModel {
        HostModel {
            input: 4,
            hidden: 3,
            classes: 5,
            batch: 2,
            chunk_steps: 2,
        }
    }

    fn toy_batch(m: &HostModel, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * m.input];
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let c = rng.below_usize(m.classes);
            y[i] = c as f32;
            for k in 0..m.input {
                x[i * m.input + k] = 0.3 * rng.normal() as f32;
            }
            x[i * m.input + c % m.input] += 1.5;
        }
        (x, y)
    }

    #[test]
    fn geometry_roundtrips_through_spec() {
        let manifest = crate::runtime::Manifest::host();
        for spec in manifest.variants.values() {
            let m = HostModel::from_spec(spec).unwrap();
            assert_eq!(m.param_count(), spec.param_count, "{}", spec.name);
            assert_eq!(m.batch, spec.batch);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = toy_model();
        let mut rng = Rng::new(9);
        let params: Vec<f32> = (0..m.param_count())
            .map(|_| 0.4 * rng.normal() as f32)
            .collect();
        let (x, y) = toy_batch(&m, 3, 10);
        let mut grad = vec![0.0f32; params.len()];
        let mut act = ActBufs::default();
        act.ensure(m.hidden, m.classes);
        let (_, _) = m.pass(&params, &x, &y, Some(&mut grad), &mut act);
        let eps = 1e-3f32;
        for i in 0..params.len() {
            let mut plus = params.clone();
            plus[i] += eps;
            let mut minus = params.clone();
            minus[i] -= eps;
            let lp = m.pass(&plus, &x, &y, None, &mut act).0;
            let lm = m.pass(&minus, &x, &y, None, &mut act).0;
            let fd = (lp - lm) / (2.0 * eps);
            let diff = (fd - grad[i]).abs();
            assert!(
                diff < 5e-3 + 0.05 * grad[i].abs(),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn train_step_overfits_one_batch() {
        let m = toy_model();
        let mut params = m.init_params(1);
        let (x, y) = toy_batch(&m, 4, 2);
        let first = m.eval_step(&params, &x, &y).unwrap().0;
        let mut scratch = HostScratch::new();
        for _ in 0..150 {
            m.train_step_into(&mut params, &x, &y, 0.5, &mut scratch).unwrap();
        }
        let last = m.eval_step(&params, &x, &y).unwrap().0;
        assert!(last < 0.6 * first, "loss {first} -> {last}");
    }

    #[test]
    fn chunk_equals_stepwise_exactly() {
        let m = toy_model();
        let params = m.init_params(3);
        let bd = m.batch * m.input;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut batches = Vec::new();
        for step in 0..m.chunk_steps {
            let (x, y) = toy_batch(&m, m.batch, 20 + step as u64);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
            batches.push((x, y));
        }
        assert_eq!(xs.len(), m.chunk_steps * bd);
        let (pc, _) = m.train_chunk(&params, &xs, &ys, 0.1).unwrap();
        let mut ps = params;
        for (x, y) in &batches {
            let (p, _) = m.train_step(&ps, x, y, 0.1).unwrap();
            ps = p;
        }
        assert_eq!(pc, ps, "chunk path diverged from stepwise path");
    }

    #[test]
    fn maml_identity_at_zero_rates() {
        let m = toy_model();
        let params = m.init_params(4);
        let (sx, sy) = toy_batch(&m, 2, 5);
        let (qx, qy) = toy_batch(&m, 2, 6);
        let (p1, qloss) = m.maml_step(&params, &sx, &sy, &qx, &qy, 0.0, 0.0).unwrap();
        assert!(qloss > 0.0);
        for (a, b) in p1.iter().zip(&params) {
            assert!((a - b).abs() == 0.0, "zero-rate maml moved params");
        }
    }

    #[test]
    fn shape_and_label_validation() {
        let m = toy_model();
        let params = m.init_params(7);
        let (x, y) = toy_batch(&m, 2, 8);
        assert!(m.train_step(&params[..5], &x, &y, 0.1).is_err());
        assert!(m.train_step(&params, &x[..3], &y, 0.1).is_err());
        let bad_y = vec![99.0f32; y.len()];
        assert!(m.eval_step(&params, &x, &bad_y).is_err());
        assert!(m.eval_step(&params, &x, &y).is_ok());
    }

    #[test]
    fn eval_counts_in_range() {
        let m = toy_model();
        let params = m.init_params(11);
        let (x, y) = toy_batch(&m, 8, 12);
        let (loss, correct) = m.eval_step(&params, &x, &y).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        assert!((0.0..=8.0).contains(&correct));
    }

    /// A random small geometry plus matching random parameters.
    fn random_geometry(g: &mut Gen) -> (HostModel, Vec<f32>) {
        let m = HostModel {
            input: g.usize_in(1, 24),
            hidden: g.usize_in(1, 16),
            classes: g.usize_in(2, 8),
            batch: g.usize_in(1, 5),
            chunk_steps: g.usize_in(1, 3),
        };
        let mut rng = Rng::new(g.u64());
        let params = (0..m.param_count()).map(|_| 0.5 * rng.normal() as f32).collect();
        (m, params)
    }

    #[test]
    fn train_and_eval_bit_identical_to_reference() {
        property("blocked train/eval == scalar reference", 48, |g: &mut Gen| {
            let (m, params) = random_geometry(g);
            let (x, y) = toy_batch(&m, m.batch, g.u64());
            let lr = 0.1f32;
            let mut scratch = HostScratch::new();

            let (p_ref, l_ref) = reference::train_step(&m, &params, &x, &y, lr).unwrap();
            let mut p_new = params.clone();
            let l_new = m.train_step_into(&mut p_new, &x, &y, lr, &mut scratch).unwrap();
            assert_eq!(p_ref, p_new, "train_step params diverged (d={} h={})", m.input, m.hidden);
            assert_eq!(l_ref.to_bits(), l_new.to_bits(), "train_step loss diverged");

            let (el_ref, ec_ref) = reference::eval_step(&m, &params, &x, &y).unwrap();
            let (el_new, ec_new) = m.eval_step_into(&params, &x, &y, &mut scratch).unwrap();
            assert_eq!(el_ref.to_bits(), el_new.to_bits(), "eval loss diverged");
            assert_eq!(ec_ref, ec_new, "eval correct-count diverged");
        });
    }

    #[test]
    fn chunk_and_maml_bit_identical_to_reference() {
        property("blocked chunk/maml == scalar reference", 32, |g: &mut Gen| {
            let (m, params) = random_geometry(g);
            let bd = m.batch * m.input;
            let mut xs = vec![0.0f32; m.chunk_steps * bd];
            let mut ys = vec![0.0f32; m.chunk_steps * m.batch];
            for step in 0..m.chunk_steps {
                let (x, y) = toy_batch(&m, m.batch, g.u64());
                xs[step * bd..(step + 1) * bd].copy_from_slice(&x);
                ys[step * m.batch..(step + 1) * m.batch].copy_from_slice(&y);
            }
            let mut scratch = HostScratch::new();

            let (p_ref, l_ref) = reference::train_chunk(&m, &params, &xs, &ys, 0.05).unwrap();
            let mut p_new = params.clone();
            let l_new = m.train_chunk_into(&mut p_new, &xs, &ys, 0.05, &mut scratch).unwrap();
            assert_eq!(p_ref, p_new, "train_chunk params diverged");
            assert_eq!(l_ref.to_bits(), l_new.to_bits(), "train_chunk loss diverged");

            let (sx, sy) = toy_batch(&m, m.batch, g.u64());
            let (qx, qy) = toy_batch(&m, m.batch, g.u64());
            let (a, b) = (0.03f32, 0.07f32);
            let (p_ref, q_ref) =
                reference::maml_step(&m, &params, &sx, &sy, &qx, &qy, a, b).unwrap();
            let mut p_new = params.clone();
            let q_new = m
                .maml_step_into(&mut p_new, &sx, &sy, &qx, &qy, a, b, &mut scratch)
                .unwrap();
            assert_eq!(p_ref, p_new, "maml_step params diverged");
            assert_eq!(q_ref.to_bits(), q_new.to_bits(), "maml query loss diverged");
        });
    }

    /// Distance in units-in-the-last-place between two f32s: map the sign-
    /// magnitude bit patterns onto a monotone integer line, then count the
    /// representable values between them (0 for equal values).
    fn ulp_diff(a: f32, b: f32) -> u64 {
        fn index(v: f32) -> i64 {
            let k = v.to_bits();
            if k & 0x8000_0000 != 0 {
                -((k & 0x7fff_ffff) as i64)
            } else {
                k as i64
            }
        }
        index(a).abs_diff(index(b))
    }

    /// The SIMD plane's contract: the fast (lane) path drifts **zero ulp**
    /// from the strict scalar path — the vectorisation reassociates
    /// nothing (module docs), so the property pins exact bit-identity of
    /// parameters and losses across random geometries, for every kernel
    /// entry point.
    #[test]
    fn simd_path_drifts_zero_ulp_from_strict() {
        property("fast kernels == strict kernels, 0 ulp", 48, |g: &mut Gen| {
            let (m, params) = random_geometry(g);
            let (x, y) = toy_batch(&m, m.batch, g.u64());
            let (sx, sy) = toy_batch(&m, m.batch, g.u64());
            let lr = 0.15f32;
            let mut scratch = HostScratch::new();

            float_mode::set_strict(true);
            let mut p_strict = params.clone();
            let l_strict = m.train_step_into(&mut p_strict, &x, &y, lr, &mut scratch).unwrap();
            let mut q_strict = p_strict.clone();
            let ml_strict = m
                .maml_step_into(&mut q_strict, &sx, &sy, &x, &y, 0.03, 0.07, &mut scratch)
                .unwrap();
            let (el_strict, ec_strict) = m.eval_step_into(&q_strict, &x, &y, &mut scratch).unwrap();

            float_mode::set_strict(false);
            let mut p_fast = params.clone();
            let l_fast = m.train_step_into(&mut p_fast, &x, &y, lr, &mut scratch).unwrap();
            let mut q_fast = p_fast.clone();
            let ml_fast = m
                .maml_step_into(&mut q_fast, &sx, &sy, &x, &y, 0.03, 0.07, &mut scratch)
                .unwrap();
            let (el_fast, ec_fast) = m.eval_step_into(&q_fast, &x, &y, &mut scratch).unwrap();

            let max_ulp = p_strict
                .iter()
                .zip(&p_fast)
                .chain(q_strict.iter().zip(&q_fast))
                .map(|(&a, &b)| ulp_diff(a, b))
                .max()
                .unwrap();
            assert_eq!(
                max_ulp, 0,
                "fast path drifted {max_ulp} ulp from strict (d={} h={})",
                m.input, m.hidden
            );
            for (a, b) in p_strict.iter().zip(&p_fast).chain(q_strict.iter().zip(&q_fast)) {
                assert_eq!(a.to_bits(), b.to_bits(), "params drifted bitwise");
            }
            assert_eq!(l_strict.to_bits(), l_fast.to_bits(), "train loss drifted");
            assert_eq!(ml_strict.to_bits(), ml_fast.to_bits(), "maml loss drifted");
            assert_eq!(el_strict.to_bits(), el_fast.to_bits(), "eval loss drifted");
            assert_eq!(ec_strict, ec_fast, "eval correct-count drifted");
        });
    }

    #[test]
    fn strict_flag_toggles_and_reads_back() {
        float_mode::set_strict(true);
        assert!(float_mode::strict());
        float_mode::set_strict(false);
        assert!(!float_mode::strict());
    }

    #[test]
    fn lane_kernels_match_scalar_statements_bitwise() {
        let mut rng = Rng::new(77);
        for n in [1usize, 7, 8, 9, 16, 23, 64, 100] {
            let w: Vec<f32> = (0..4 * n).map(|_| rng.normal() as f32).collect();
            let base: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let xs = [0.3f32, -1.7, 0.0, 2.5e-3];
            // axpy_rows4 == four scalar k-iterations in order
            let mut fast = base.clone();
            axpy_rows4(&mut fast, &w, n, xs);
            let mut slow = base.clone();
            for (k, &xk) in xs.iter().enumerate() {
                for j in 0..n {
                    slow[j] += xk * w[k * n + j];
                }
            }
            assert_eq!(fast, slow, "axpy_rows4 diverged at n={n}");
            // axpy_row == one scalar k-iteration
            let mut fast = base.clone();
            axpy_row(&mut fast, &w[..n], 0.9);
            let mut slow = base.clone();
            for j in 0..n {
                slow[j] += 0.9 * w[j];
            }
            assert_eq!(fast, slow, "axpy_row diverged at n={n}");
            // lane updates == scalar updates
            let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let mut fast = base.clone();
            sgd_step_lanes(&mut fast, &g, 0.05);
            let mut slow = base.clone();
            for (p, &gi) in slow.iter_mut().zip(&g) {
                *p -= 0.05 * gi;
            }
            assert_eq!(fast, slow, "sgd_step_lanes diverged at n={n}");
            let mut fast = vec![0.0f32; n];
            scaled_sub_lanes(&mut fast, &base, &g, 0.05);
            let slow: Vec<f32> = base.iter().zip(&g).map(|(p, gi)| p - 0.05 * gi).collect();
            assert_eq!(fast, slow, "scaled_sub_lanes diverged at n={n}");
        }
    }

    #[test]
    fn scratch_recycles_across_geometries() {
        // one scratch serving two different geometries back to back must
        // match fresh-scratch results bitwise (the lazy resize path)
        let big = HostModel {
            input: 12,
            hidden: 9,
            classes: 6,
            batch: 3,
            chunk_steps: 1,
        };
        let small = toy_model();
        let mut shared = HostScratch::new();
        for m in [&big, &small, &big] {
            let params = m.init_params(21);
            let (x, y) = toy_batch(m, m.batch, 22);
            let mut p_shared = params.clone();
            let l_shared = m.train_step_into(&mut p_shared, &x, &y, 0.2, &mut shared).unwrap();
            let mut p_fresh = params.clone();
            let l_fresh = m
                .train_step_into(&mut p_fresh, &x, &y, 0.2, &mut HostScratch::new())
                .unwrap();
            assert_eq!(p_shared, p_fresh, "recycled scratch perturbed results");
            assert_eq!(l_shared.to_bits(), l_fresh.to_bits());
        }
    }
}

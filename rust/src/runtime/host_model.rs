//! Pure-Rust host backend: the model entry points (`train_step`,
//! `train_chunk`, `eval_step`, `maml_step`) for a one-hidden-layer tanh
//! MLP with softmax cross-entropy, operating on flat `f32` parameter
//! vectors laid out as `[W1 | b1 | W2 | b2]` (`W1` is `[d][h]` row-major
//! by input, `W2` is `[h][c]` row-major by hidden unit).
//!
//! This backend keeps the whole system — binary, examples, benches, the
//! parallel round engine and its determinism tests — runnable on images
//! that carry neither the AOT artifacts nor an XLA runtime. It is
//! selected automatically for manifest variants with no lowered entries
//! (see [`super::artifacts::Manifest::host`]).
//!
//! Every op is a sequential scalar loop over fixed index order, so a
//! given `(params, batch)` pair produces bit-identical results on any
//! worker thread — the property the engine's determinism guarantee
//! rests on.

use super::artifacts::VariantSpec;
use anyhow::{bail, Result};

/// One-hidden-layer MLP geometry recovered from a variant spec.
#[derive(Clone, Copy, Debug)]
pub struct HostModel {
    /// Input dimension d.
    pub input: usize,
    /// Hidden width h.
    pub hidden: usize,
    /// Output classes c.
    pub classes: usize,
    /// Batch size B the spec was built for.
    pub batch: usize,
    /// SGD steps per `train_chunk` call.
    pub chunk_steps: usize,
}

impl HostModel {
    /// Recover the MLP geometry from a variant spec
    /// (`P = d·h + h + h·c + c` must hold exactly).
    pub fn from_spec(spec: &VariantSpec) -> Result<HostModel> {
        let d = spec.input_dim();
        let c = spec.classes;
        let denom = d + c + 1;
        let h = spec.param_count.saturating_sub(c) / denom;
        if h == 0 || h * denom + c != spec.param_count {
            bail!(
                "variant '{}' (P={}, d={d}, c={c}) does not match the host MLP layout",
                spec.name,
                spec.param_count
            );
        }
        Ok(HostModel {
            input: d,
            hidden: h,
            classes: c,
            batch: spec.batch,
            chunk_steps: spec.chunk_steps,
        })
    }

    /// Total parameter count for this geometry.
    pub fn param_count(&self) -> usize {
        self.input * self.hidden + self.hidden + self.hidden * self.classes + self.classes
    }

    /// Deterministic Glorot-uniform initial parameters (biases zero).
    pub fn init_params(&self, seed: u64) -> Vec<f32> {
        let (d, h, c) = (self.input, self.hidden, self.classes);
        let mut rng = crate::util::Rng::new(seed);
        let mut out = vec![0.0f32; self.param_count()];
        let lim1 = (6.0 / (d + h) as f64).sqrt();
        for v in &mut out[..d * h] {
            *v = rng.uniform_in(-lim1, lim1) as f32;
        }
        let w2 = d * h + h;
        let lim2 = (6.0 / (h + c) as f64).sqrt();
        for v in &mut out[w2..w2 + h * c] {
            *v = rng.uniform_in(-lim2, lim2) as f32;
        }
        out
    }

    fn check(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<()> {
        if params.len() != self.param_count() {
            bail!(
                "params has {} elements, host model wants {}",
                params.len(),
                self.param_count()
            );
        }
        if y.is_empty() || x.len() != y.len() * self.input {
            bail!(
                "batch shape mismatch: {} inputs vs {} labels × d={}",
                x.len(),
                y.len(),
                self.input
            );
        }
        let c = self.classes as f32;
        if y.iter().any(|&v| !(0.0..c).contains(&v) || v.fract() != 0.0) {
            bail!("labels must be integers in [0, {})", self.classes);
        }
        Ok(())
    }

    /// Forward pass over the batch; returns `(mean_loss, correct_count)`.
    /// When `grad` is provided (zeroed, `param_count` long), accumulates
    /// d(mean_loss)/d(params) into it.
    fn batch_pass(&self, params: &[f32], x: &[f32], y: &[f32], mut grad: Option<&mut [f32]>) -> (f32, f32) {
        let d = self.input;
        let h = self.hidden;
        let c = self.classes;
        let bsz = y.len();
        let (w1, rest) = params.split_at(d * h);
        let (b1, rest) = rest.split_at(h);
        let (w2, b2) = rest.split_at(h * c);

        let mut a1 = vec![0.0f32; h];
        let mut logits = vec![0.0f32; c];
        let mut probs = vec![0.0f32; c];
        let mut da1 = vec![0.0f32; h];
        let inv_b = 1.0f32 / bsz as f32;
        let mut loss_sum = 0.0f64;
        let mut correct = 0usize;

        for i in 0..bsz {
            let xi = &x[i * d..(i + 1) * d];
            let label = y[i] as usize;

            // forward: a1 = tanh(W1ᵀx + b1), logits = W2ᵀa1 + b2
            for j in 0..h {
                let mut z = b1[j];
                for k in 0..d {
                    z += xi[k] * w1[k * h + j];
                }
                a1[j] = z.tanh();
            }
            for o in 0..c {
                let mut z = b2[o];
                for j in 0..h {
                    z += a1[j] * w2[j * c + o];
                }
                logits[o] = z;
            }

            // softmax cross-entropy (max-shifted for stability)
            let mut maxl = logits[0];
            for &l in &logits[1..] {
                if l > maxl {
                    maxl = l;
                }
            }
            let mut sum = 0.0f32;
            for o in 0..c {
                probs[o] = (logits[o] - maxl).exp();
                sum += probs[o];
            }
            for o in 0..c {
                probs[o] /= sum;
            }
            loss_sum += -(probs[label].max(1e-12) as f64).ln();
            let mut best = 0;
            for o in 1..c {
                if logits[o] > logits[best] {
                    best = o;
                }
            }
            if best == label {
                correct += 1;
            }

            if let Some(g) = grad.as_deref_mut() {
                let (gw1, grest) = g.split_at_mut(d * h);
                let (gb1, grest) = grest.split_at_mut(h);
                let (gw2, gb2) = grest.split_at_mut(h * c);
                for v in da1.iter_mut() {
                    *v = 0.0;
                }
                // d(mean loss)/d(logit_o) = (p_o − 1{o=y}) / B
                for o in 0..c {
                    let dl = (probs[o] - if o == label { 1.0 } else { 0.0 }) * inv_b;
                    gb2[o] += dl;
                    for j in 0..h {
                        gw2[j * c + o] += a1[j] * dl;
                        da1[j] += w2[j * c + o] * dl;
                    }
                }
                // tanh' = 1 − a1²
                for j in 0..h {
                    let dz = da1[j] * (1.0 - a1[j] * a1[j]);
                    gb1[j] += dz;
                    for k in 0..d {
                        gw1[k * h + j] += xi[k] * dz;
                    }
                }
            }
        }
        ((loss_sum / bsz as f64) as f32, correct as f32)
    }

    /// One SGD step; returns `(new_params, pre-update mean loss)`.
    pub fn train_step(&self, params: &[f32], x: &[f32], y: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        self.check(params, x, y)?;
        let mut grad = vec![0.0f32; params.len()];
        let (loss, _) = self.batch_pass(params, x, y, Some(&mut grad));
        let new = params.iter().zip(&grad).map(|(p, g)| p - lr * g).collect();
        Ok((new, loss))
    }

    /// `chunk_steps` consecutive SGD steps; returns `(params, mean loss)`.
    pub fn train_chunk(&self, params: &[f32], xs: &[f32], ys: &[f32], lr: f32) -> Result<(Vec<f32>, f32)> {
        let s = self.chunk_steps;
        let bd = self.batch * self.input;
        if xs.len() != s * bd || ys.len() != s * self.batch {
            bail!(
                "chunk shape mismatch: {}×{} inputs / {} labels for S={s} B={}",
                xs.len(),
                self.input,
                ys.len(),
                self.batch
            );
        }
        let mut p = params.to_vec();
        let mut loss_sum = 0.0f64;
        for step in 0..s {
            let x = &xs[step * bd..(step + 1) * bd];
            let y = &ys[step * self.batch..(step + 1) * self.batch];
            let (np, loss) = self.train_step(&p, x, y, lr)?;
            p = np;
            loss_sum += loss as f64;
        }
        Ok((p, (loss_sum / s as f64) as f32))
    }

    /// Evaluate one batch; returns `(mean_loss, correct_count)`.
    pub fn eval_step(&self, params: &[f32], x: &[f32], y: &[f32]) -> Result<(f32, f32)> {
        self.check(params, x, y)?;
        Ok(self.batch_pass(params, x, y, None))
    }

    /// First-order MAML step (Eq. 16–17): inner step on the support batch,
    /// outer step from the query gradient at the adapted parameters.
    /// Returns `(new_params, query loss at the adapted parameters)`.
    #[allow(clippy::too_many_arguments)]
    pub fn maml_step(
        &self,
        params: &[f32],
        sx: &[f32],
        sy: &[f32],
        qx: &[f32],
        qy: &[f32],
        alpha: f32,
        beta: f32,
    ) -> Result<(Vec<f32>, f32)> {
        self.check(params, sx, sy)?;
        self.check(params, qx, qy)?;
        let mut gs = vec![0.0f32; params.len()];
        let _ = self.batch_pass(params, sx, sy, Some(&mut gs));
        let adapted: Vec<f32> = params.iter().zip(&gs).map(|(p, g)| p - alpha * g).collect();
        let mut gq = vec![0.0f32; params.len()];
        let (qloss, _) = self.batch_pass(&adapted, qx, qy, Some(&mut gq));
        let new = params.iter().zip(&gq).map(|(p, g)| p - beta * g).collect();
        Ok((new, qloss))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn toy_model() -> HostModel {
        HostModel {
            input: 4,
            hidden: 3,
            classes: 5,
            batch: 2,
            chunk_steps: 2,
        }
    }

    fn toy_batch(m: &HostModel, n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0f32; n * m.input];
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let c = rng.below_usize(m.classes);
            y[i] = c as f32;
            for k in 0..m.input {
                x[i * m.input + k] = 0.3 * rng.normal() as f32;
            }
            x[i * m.input + c % m.input] += 1.5;
        }
        (x, y)
    }

    #[test]
    fn geometry_roundtrips_through_spec() {
        let manifest = crate::runtime::Manifest::host();
        for spec in manifest.variants.values() {
            let m = HostModel::from_spec(spec).unwrap();
            assert_eq!(m.param_count(), spec.param_count, "{}", spec.name);
            assert_eq!(m.batch, spec.batch);
        }
    }

    #[test]
    fn gradient_matches_finite_differences() {
        let m = toy_model();
        let mut rng = Rng::new(9);
        let params: Vec<f32> = (0..m.param_count())
            .map(|_| 0.4 * rng.normal() as f32)
            .collect();
        let (x, y) = toy_batch(&m, 3, 10);
        let mut grad = vec![0.0f32; params.len()];
        let (_, _) = m.batch_pass(&params, &x, &y, Some(&mut grad));
        let eps = 1e-3f32;
        for i in 0..params.len() {
            let mut plus = params.clone();
            plus[i] += eps;
            let mut minus = params.clone();
            minus[i] -= eps;
            let lp = m.batch_pass(&plus, &x, &y, None).0;
            let lm = m.batch_pass(&minus, &x, &y, None).0;
            let fd = (lp - lm) / (2.0 * eps);
            let diff = (fd - grad[i]).abs();
            assert!(
                diff < 5e-3 + 0.05 * grad[i].abs(),
                "param {i}: analytic {} vs fd {fd}",
                grad[i]
            );
        }
    }

    #[test]
    fn train_step_overfits_one_batch() {
        let m = toy_model();
        let mut params = m.init_params(1);
        let (x, y) = toy_batch(&m, 4, 2);
        let first = m.eval_step(&params, &x, &y).unwrap().0;
        for _ in 0..150 {
            let (p, _) = m.train_step(&params, &x, &y, 0.5).unwrap();
            params = p;
        }
        let last = m.eval_step(&params, &x, &y).unwrap().0;
        assert!(last < 0.6 * first, "loss {first} -> {last}");
    }

    #[test]
    fn chunk_equals_stepwise_exactly() {
        let m = toy_model();
        let params = m.init_params(3);
        let bd = m.batch * m.input;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut batches = Vec::new();
        for step in 0..m.chunk_steps {
            let (x, y) = toy_batch(&m, m.batch, 20 + step as u64);
            xs.extend_from_slice(&x);
            ys.extend_from_slice(&y);
            batches.push((x, y));
        }
        assert_eq!(xs.len(), m.chunk_steps * bd);
        let (pc, _) = m.train_chunk(&params, &xs, &ys, 0.1).unwrap();
        let mut ps = params;
        for (x, y) in &batches {
            let (p, _) = m.train_step(&ps, x, y, 0.1).unwrap();
            ps = p;
        }
        assert_eq!(pc, ps, "chunk path diverged from stepwise path");
    }

    #[test]
    fn maml_identity_at_zero_rates() {
        let m = toy_model();
        let params = m.init_params(4);
        let (sx, sy) = toy_batch(&m, 2, 5);
        let (qx, qy) = toy_batch(&m, 2, 6);
        let (p1, qloss) = m.maml_step(&params, &sx, &sy, &qx, &qy, 0.0, 0.0).unwrap();
        assert!(qloss > 0.0);
        for (a, b) in p1.iter().zip(&params) {
            assert!((a - b).abs() == 0.0, "zero-rate maml moved params");
        }
    }

    #[test]
    fn shape_and_label_validation() {
        let m = toy_model();
        let params = m.init_params(7);
        let (x, y) = toy_batch(&m, 2, 8);
        assert!(m.train_step(&params[..5], &x, &y, 0.1).is_err());
        assert!(m.train_step(&params, &x[..3], &y, 0.1).is_err());
        let bad_y = vec![99.0f32; y.len()];
        assert!(m.eval_step(&params, &x, &bad_y).is_err());
        assert!(m.eval_step(&params, &x, &y).is_ok());
    }

    #[test]
    fn eval_counts_in_range() {
        let m = toy_model();
        let params = m.init_params(11);
        let (x, y) = toy_batch(&m, 8, 12);
        let (loss, correct) = m.eval_step(&params, &x, &y).unwrap();
        assert!(loss > 0.0 && loss.is_finite());
        assert!((0.0..=8.0).contains(&correct));
    }
}

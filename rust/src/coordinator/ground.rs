//! Ground-station aggregation stage (paper §III-A step 4).
//!
//! The designated ground station (the one seeing the most cluster PSes at
//! the current time) collects the models of its visible clusters,
//! aggregates them with data-size weights (Eq. 5 over clusters), and
//! broadcasts the global model back to those clusters. Invisible clusters
//! keep training on their own model until a later pass — the paper's
//! assumption is only that *at least one* cluster is reachable.

use crate::orbit::{GroundStation, Vec3};

/// Which ground station leads this pass and which clusters participate.
#[derive(Clone, Debug)]
pub struct GroundPlan {
    pub station: usize,
    /// Participating cluster ids (their PS is visible).
    pub clusters: Vec<usize>,
}

/// Like [`plan`] but enforcing the paper's connectivity assumption ("the
/// ground station can connect at least one satellite cluster throughout the
/// FL process"): when no PS is geometrically visible, the nearest PS/GS
/// pair is scheduled anyway (the pass is deferred within the round until
/// the next contact window; the link budget uses the actual distance).
pub fn plan_with_fallback(stations: &[GroundStation], ps_pos: &[Vec3], t: f64) -> GroundPlan {
    if let Some(p) = plan(stations, ps_pos, t) {
        return p;
    }
    let (gs, k) = stations
        .iter()
        .flat_map(|g| {
            let gp = g.eci(t);
            ps_pos
                .iter()
                .enumerate()
                .map(move |(k, &p)| (g.id, k, p.dist(gp)))
        })
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap())
        .map(|(g, k, _)| (g, k))
        .expect("no stations or no clusters");
    GroundPlan {
        station: gs,
        clusters: vec![k],
    }
}

/// Choose the station seeing the most PSes. `ps_pos[k]` is cluster k's PS
/// position at time `t`. Returns None when nobody sees anything.
pub fn plan(stations: &[GroundStation], ps_pos: &[Vec3], t: f64) -> Option<GroundPlan> {
    let mut best: Option<GroundPlan> = None;
    for gs in stations {
        let visible: Vec<usize> = ps_pos
            .iter()
            .enumerate()
            .filter(|(_, &p)| gs.sees(p, t))
            .map(|(k, _)| k)
            .collect();
        if !visible.is_empty()
            && best
                .as_ref()
                .map(|b| visible.len() > b.clusters.len())
                .unwrap_or(true)
        {
            best = Some(GroundPlan {
                station: gs.id,
                clusters: visible,
            });
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::geo::default_ground_segment;
    use crate::orbit::EARTH_RADIUS;

    #[test]
    fn picks_station_with_most_visible() {
        let stations = default_ground_segment();
        // one PS directly over station 0 (wuhan ~30.6N 114.3E at t=0),
        // three over nobody (deep space on the far side is still "visible"
        // if above horizon — use antipodal points)
        let wuhan = stations[0].eci(0.0);
        let above = wuhan.scale((EARTH_RADIUS + 1.3e6) / wuhan.norm());
        let anti = above.scale(-1.0);
        let plan = plan(&stations, &[above, anti], 0.0).unwrap();
        assert_eq!(plan.station, 0);
        assert_eq!(plan.clusters, vec![0]);
    }

    #[test]
    fn none_when_nothing_visible() {
        let stations = vec![GroundStation::new(0, "eq", 0.0, 0.0, 10.0)];
        let anti = Vec3::new(-(EARTH_RADIUS + 1.3e6), 0.0, 0.0);
        assert!(plan(&stations, &[anti], 0.0).is_none());
    }

    #[test]
    fn fallback_always_schedules_someone() {
        let stations = vec![GroundStation::new(0, "eq", 0.0, 0.0, 10.0)];
        let anti = Vec3::new(-(EARTH_RADIUS + 1.3e6), 0.0, 0.0);
        // 90° away: still below the horizon but much closer than the antipode
        let near_anti = Vec3::new(0.0, EARTH_RADIUS + 1.3e6, 0.0);
        let p = plan_with_fallback(&stations, &[anti, near_anti], 0.0);
        assert_eq!(p.clusters.len(), 1);
        assert_eq!(p.clusters[0], 1, "nearest PS should be picked");
    }

    #[test]
    fn ties_broken_deterministically() {
        let stations = default_ground_segment();
        let p0 = stations[0].eci(0.0);
        let above0 = p0.scale((EARTH_RADIUS + 1.3e6) / p0.norm());
        let p1 = stations[1].eci(0.0);
        let above1 = p1.scale((EARTH_RADIUS + 1.3e6) / p1.norm());
        // one PS over each of two stations: each sees one → first wins ties
        let a = plan(&stations, &[above0, above1], 0.0).unwrap();
        let b = plan(&stations, &[above0, above1], 0.0).unwrap();
        assert_eq!(a.station, b.station);
    }
}

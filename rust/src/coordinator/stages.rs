//! Stage decomposition of the round loop.
//!
//! The coordinator drives three stages that every clustered method (FedHC,
//! H-BASE, FedCE) shares and that C-FedAvg reuses for its central step:
//!
//! 1. [`LocalTrainStage`] — scatter local training across the parallel
//!    round engine and gather [`MemberOutcome`]s in job order.
//! 2. [`ClusterAggregateStage`] — weight and merge member models at each
//!    cluster PS (Eq. 12 quality weights or Eq. 5 data-size weights).
//! 3. [`GroundExchangeStage`] — the PS↔GS pass. Two implementations give
//!    the two timelines: [`AnalyticGroundExchange`] keeps the legacy
//!    closed-form Eq. 7 sum over whichever PSes the plan finds visible,
//!    while [`EventGroundExchange`] runs a discrete-event schedule in
//!    which **every** cluster attempts the pass, gated by
//!    `orbit::visibility` windows — a PS whose window has not opened
//!    waits for it (the wait is real simulated time) and a PS with no
//!    window inside the staleness bound skips the pass with a stale model.
//!
//! All event times are **offsets from the stage start** and are computed
//! with the same floating-point operation order as the analytic folds, so
//! when every window is open at the stage start the two timelines produce
//! bit-identical ledgers (pinned by `tests/timeline_equivalence.rs`).

use super::ground;
use super::round::{ground_exchange, member_times, MemberWork};
use crate::config::{ExperimentConfig, RoutingMode, Timeline};
use crate::coordinator::fedhc::{Strategy, WeightPolicy};
use crate::fl::aggregate::{aggregate, fedavg_weights, quality_weights, stale_composed_weights};
use crate::fl::client::SatClient;
use crate::fl::local::{train_params, TrainScratch};
use crate::network::{EnergyModel, LinkModel, WireBits};
use crate::orbit::propagate::Constellation;
use crate::orbit::visibility::next_window_open;
use crate::orbit::GroundStation;
use crate::runtime::host::aggregate_host_into;
use crate::runtime::ModelRuntime;
use crate::sim::engine::Engine;
use crate::sim::events::{Event, EventQueue};
use crate::sim::param_pool::{ParamPool, ScratchPool};
use crate::util::rng::stream_seed;
use crate::util::Rng;
use anyhow::Result;

/// Gathered result of one member's scattered local-training job.
pub struct MemberOutcome {
    /// Client index.
    pub member: usize,
    /// Cluster the member trained for.
    pub cluster: usize,
    /// Updated parameters — a pooled buffer the coordinator checks back
    /// into the run's [`RoundPools`] after the gather.
    pub params: Vec<f32>,
    /// Mean training loss over the round (drives Eq. 12 weights).
    pub mean_loss: f32,
    /// Distinct samples processed (drives the Eq. 7/9 time & energy
    /// models).
    pub samples: usize,
}

/// Per-run recycled buffers threaded through the local-training stage:
/// parameter vectors for member models (taken in the scatter, checked back
/// in after the gather) and per-worker training scratch (which must
/// outlive the engine's short-lived workers to keep steady-state rounds
/// free of parameter-sized allocations).
pub struct RoundPools {
    /// Recycled `param_count`-sized member/model buffers.
    pub params: ParamPool,
    /// Recycled per-worker training scratch.
    pub scratch: ScratchPool<TrainScratch>,
}

impl RoundPools {
    pub fn new(rt: &ModelRuntime) -> RoundPools {
        RoundPools {
            params: ParamPool::new(rt.spec.param_count),
            scratch: ScratchPool::new(),
        }
    }
}

/// Local-training stage: run every `(member, cluster)` job from the
/// matching cluster model and return outcomes in job order. Member
/// parameter buffers come from `pools` and must be returned to it by the
/// caller once gathered.
pub trait LocalTrainStage {
    #[allow(clippy::too_many_arguments)]
    fn train(
        &self,
        engine: &Engine,
        rt: &ModelRuntime,
        cfg: &ExperimentConfig,
        clients: &[SatClient],
        models: &[Vec<f32>],
        jobs: &[(usize, usize)],
        round: u64,
        pools: &RoundPools,
    ) -> Result<Vec<MemberOutcome>>;
}

/// Default local-training stage: the deterministic parallel round engine.
/// Each job's RNG stream derives statelessly from `(seed, round, sat_id)`,
/// so results are byte-identical for any worker count; each job trains a
/// pooled buffer overwritten from the cluster model (never a fresh clone),
/// which cannot perturb the numerics because the buffer is fully
/// overwritten before use.
pub struct EngineLocalTrain;

impl LocalTrainStage for EngineLocalTrain {
    #[allow(clippy::too_many_arguments)]
    fn train(
        &self,
        engine: &Engine,
        rt: &ModelRuntime,
        cfg: &ExperimentConfig,
        clients: &[SatClient],
        models: &[Vec<f32>],
        jobs: &[(usize, usize)],
        round: u64,
        pools: &RoundPools,
    ) -> Result<Vec<MemberOutcome>> {
        let scattered: Vec<Result<MemberOutcome>> = engine.run_with(
            jobs,
            || pools.scratch.take_or(|| TrainScratch::new(rt)),
            |scratch, _i, &(m, c)| {
                let client = &clients[m];
                let mut rng = Rng::new(stream_seed(cfg.seed, round, client.sat as u64));
                let (params, out) = train_params(
                    rt,
                    &client.shard,
                    pools.params.take_copy(&models[c]),
                    cfg.local_epochs,
                    cfg.lr,
                    &mut **scratch,
                    &mut rng,
                )?;
                Ok(MemberOutcome {
                    member: m,
                    cluster: c,
                    params,
                    mean_loss: out.mean_loss,
                    samples: out.samples,
                })
            },
        );
        let mut results = Vec::with_capacity(scattered.len());
        for r in scattered {
            results.push(r?);
        }
        Ok(results)
    }
}

/// Intra-cluster aggregation at the PS.
pub trait ClusterAggregateStage {
    /// Member weights for the PS merge (Eq. 12 or Eq. 5).
    fn member_weights(&self, losses: &[f32], sizes: &[usize]) -> Vec<f32>;

    /// FedBuff-style weights for a buffered merge: the stage's own
    /// weighting composed with each contribution's staleness discount
    /// `1/(1+τ)^β` and renormalised. When every contribution is fresh
    /// (τ = 0 across the buffer) this returns [`Self::member_weights`]
    /// **bitwise unchanged** — the hinge of the sync-degeneracy
    /// differential test.
    fn member_weights_stale(
        &self,
        losses: &[f32],
        sizes: &[usize],
        staleness: &[f64],
        beta: f64,
    ) -> Vec<f32> {
        stale_composed_weights(&self.member_weights(losses, sizes), staleness, beta)
    }

    /// Weighted model merge (kernel-backed when the cluster fits the AOT
    /// slot count — see [`aggregate`]).
    fn merge(
        &self,
        rt: &ModelRuntime,
        rows: &[&[f32]],
        weights: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        aggregate(rt, rows, weights, out)
    }
}

/// The strategy-selected weighting: Eq. 12 inverse-loss quality weights
/// (FedHC) or Eq. 5 data-size FedAvg weights (baselines).
pub struct WeightedClusterAggregate {
    pub policy: WeightPolicy,
}

impl ClusterAggregateStage for WeightedClusterAggregate {
    fn member_weights(&self, losses: &[f32], sizes: &[usize]) -> Vec<f32> {
        match self.policy {
            WeightPolicy::Quality => quality_weights(losses),
            WeightPolicy::FedAvg => fedavg_weights(sizes),
        }
    }
}

/// Ring all-reduce aggregation (`--routing isl:ring`): the same
/// strategy-selected weighting as [`WeightedClusterAggregate`], but the
/// merge is pinned to the strict sequential left fold a ring
/// reduce-scatter physically performs — every chunk accumulates member by
/// member in ring order, so the merged bits never depend on the AOT
/// kernel's slot count. [`crate::network::ring_round`] bills the matching
/// `2(k−1)`-step timeline.
pub struct RingClusterAggregate {
    pub policy: WeightPolicy,
}

impl ClusterAggregateStage for RingClusterAggregate {
    fn member_weights(&self, losses: &[f32], sizes: &[usize]) -> Vec<f32> {
        match self.policy {
            WeightPolicy::Quality => quality_weights(losses),
            WeightPolicy::FedAvg => fedavg_weights(sizes),
        }
    }

    fn merge(
        &self,
        rt: &ModelRuntime,
        rows: &[&[f32]],
        weights: &[f32],
        out: &mut Vec<f32>,
    ) -> Result<()> {
        out.resize(rt.spec.param_count, 0.0);
        aggregate_host_into(rows, weights, out);
        Ok(())
    }
}

/// Borrowed context for a ground pass.
pub struct GroundCtx<'a> {
    pub link: &'a LinkModel,
    pub energy: &'a EnergyModel,
    pub stations: &'a [GroundStation],
    /// Client satellites (cluster PS indices point into its elements).
    pub constellation: &'a Constellation,
}

/// Outcome of one ground-station pass.
pub struct GroundOutcome {
    /// Station that led the pass.
    pub station: usize,
    /// Clusters whose PS exchanged with the station, in completion order.
    pub exchanged: Vec<usize>,
    /// Clusters whose PS missed the pass (no window within the staleness
    /// bound, or the antenna stayed busy past their window).
    pub stale: Vec<usize>,
    /// Simulated duration of the pass (window waits + transfers), seconds.
    pub duration_s: f64,
    /// Satellite-side transmit energy of the pass, joules.
    pub energy_j: f64,
    /// Total time PSes spent waiting for their window to open, seconds.
    pub wait_s: f64,
    /// Telemetry plane: per served cluster, in antenna-service order,
    /// `(ps-slice index, window-open offset, service-completion offset)`
    /// — both offsets from the pass start, seconds. The analytic stage
    /// has no window machinery and leaves this empty (`Vec::new()`
    /// allocates nothing, so the nominal path stays allocation-free).
    pub windows: Vec<(usize, f64, f64)>,
}

/// Ground-station exchange stage: PS models up (billed at the possibly
/// compressed uplink payload), global model back down (dense).
pub trait GroundExchangeStage {
    /// Run one pass for the clusters whose PS client indices are `ps`,
    /// starting at absolute sim time `now`.
    fn exchange(&self, ctx: &GroundCtx, ps: &[usize], now: f64, wire: WireBits) -> GroundOutcome;
}

/// Legacy Eq. 7 semantics: the plan's station serves exactly the PSes it
/// currently sees (nearest pair as a fallback), the stage time is the sum
/// over those links, and invisible clusters skip the pass for free.
pub struct AnalyticGroundExchange;

impl GroundExchangeStage for AnalyticGroundExchange {
    fn exchange(&self, ctx: &GroundCtx, ps: &[usize], now: f64, wire: WireBits) -> GroundOutcome {
        let ps_pos: Vec<_> = ps
            .iter()
            .map(|&p| ctx.constellation.elements[p].position_eci(now))
            .collect();
        let plan = ground::plan_with_fallback(ctx.stations, &ps_pos, now);
        let gs_pos = ctx.stations[plan.station].eci(now);
        let mut duration = 0.0f64;
        let mut energy = 0.0f64;
        for &c in &plan.clusters {
            let (t_x, e_x) = ground_exchange(ctx.link, ctx.energy, ps_pos[c], gs_pos, wire);
            duration += t_x;
            energy += e_x;
        }
        GroundOutcome {
            station: plan.station,
            exchanged: plan.clusters,
            stale: Vec::new(),
            duration_s: duration,
            energy_j: energy,
            wait_s: 0.0,
            windows: Vec::new(),
        }
    }
}

/// Event-timeline pass: every cluster attempts the exchange with the
/// plan's station. Each PS's next visibility window (searched up to
/// `max_wait_s` ahead) enters the queue as a `WindowOpen` plus — for
/// windows that genuinely close inside the horizon — a `WindowClose`
/// marking the interval end on the timeline (the stale decision itself
/// reads the close offset when the `WindowOpen` pops, since that is when
/// the antenna commits). The single antenna serves transfers in
/// window-open order, one at a time. A PS with no window inside the bound
/// — or whose bounded window closes before the antenna frees up — goes
/// stale and keeps its model. Zero-wait transfers use the link budget
/// frozen at the pass start, which makes a fully-visible pass
/// bit-identical to [`AnalyticGroundExchange`]; waited transfers are
/// billed at their window-open geometry.
pub struct EventGroundExchange {
    pub max_wait_s: f64,
    pub window_step_s: f64,
}

impl GroundExchangeStage for EventGroundExchange {
    fn exchange(&self, ctx: &GroundCtx, ps: &[usize], now: f64, wire: WireBits) -> GroundOutcome {
        let ps_pos: Vec<_> = ps
            .iter()
            .map(|&p| ctx.constellation.elements[p].position_eci(now))
            .collect();
        let station = ground::plan_with_fallback(ctx.stations, &ps_pos, now).station;
        let gs = &ctx.stations[station];
        let gs_pos = gs.eci(now);

        // schedule each PS's next window as offsets from the pass start
        let k = ps.len();
        let mut queue = EventQueue::new();
        let mut open_off = vec![0.0f64; k];
        let mut close_off = vec![0.0f64; k];
        let mut stale = Vec::new();
        for (c, &sat) in ps.iter().enumerate() {
            let elem = &ctx.constellation.elements[sat];
            match next_window_open(gs, elem, now, self.max_wait_s, self.window_step_s) {
                Some((open, close)) => {
                    open_off[c] = open - now;
                    // a close at the search cap means the window outlives
                    // the horizon — treat it as unbounded so an
                    // always-visible PS can never be busy-staled, however
                    // long the antenna queue grows
                    close_off[c] = if close >= open + self.max_wait_s {
                        f64::INFINITY
                    } else {
                        close - now
                    };
                    queue.push(open_off[c], Event::WindowOpen { cluster: c });
                    if close_off[c].is_finite() {
                        queue.push(close_off[c], Event::WindowClose { cluster: c });
                    }
                }
                None => stale.push(c),
            }
        }

        // drain: the antenna serves one transfer at a time in window order
        let mut exchanged = Vec::new();
        let mut windows: Vec<(usize, f64, f64)> = Vec::new();
        let mut free_off = 0.0f64;
        let mut end_off = 0.0f64;
        let mut wait_s = 0.0f64;
        let mut energy = 0.0f64;
        while let Some(ev) = queue.pop() {
            match ev.event {
                Event::WindowOpen { cluster } => {
                    let start = ev.at.max(free_off);
                    if start > close_off[cluster] {
                        // the antenna stayed busy past this window
                        stale.push(cluster);
                        continue;
                    }
                    // link budget: frozen at the pass start for zero-wait
                    // transfers (bit-identity with the analytic stage), but
                    // evaluated at the window-open instant for transfers
                    // that waited — a waited PS is billed for its in-window
                    // slant range, not the occluded geometry it had when
                    // the pass began
                    let (sat_pos, station_pos) = if open_off[cluster] > 0.0 {
                        let t_open = now + open_off[cluster];
                        (
                            ctx.constellation.elements[ps[cluster]].position_eci(t_open),
                            gs.eci(t_open),
                        )
                    } else {
                        (ps_pos[cluster], gs_pos)
                    };
                    let (t_x, e_x) =
                        ground_exchange(ctx.link, ctx.energy, sat_pos, station_pos, wire);
                    wait_s += open_off[cluster];
                    energy += e_x;
                    free_off = start + t_x;
                    windows.push((cluster, open_off[cluster], free_off));
                    queue.push(
                        free_off,
                        Event::TxDone {
                            member: ps[cluster],
                            cluster,
                        },
                    );
                }
                Event::TxDone { cluster, .. } => {
                    exchanged.push(cluster);
                    end_off = end_off.max(ev.at);
                }
                Event::WindowClose { .. } => {}
                Event::ComputeDone { .. }
                | Event::UploadReady { .. }
                | Event::MergeDue { .. }
                | Event::EvalDue { .. }
                | Event::Fault { .. } => {
                    unreachable!("ground pass scheduled a non-ground event")
                }
            }
        }

        GroundOutcome {
            station,
            exchanged,
            stale,
            duration_s: end_off,
            energy_j: energy,
            wait_s,
            windows,
        }
    }
}

/// Queue-driven replay of one cluster's intra-cluster round: every member
/// gets a `ComputeDone` at `t_cmp` and a `TxDone` at `t_cmp + t_com`
/// (offsets from the stage start); the PS broadcast to the farthest member
/// closes the round. Bit-identical to [`super::round::cluster_round`] by
/// construction — the same durations enter the same folds, the queue only
/// orders them.
pub fn cluster_round_events(
    queue: &mut EventQueue,
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[MemberWork],
    cluster: usize,
    ps_pos: crate::orbit::Vec3,
    wire: WireBits,
) -> (f64, f64) {
    debug_assert!(queue.is_empty(), "cluster round expects a drained queue");
    let mut uplink = Vec::with_capacity(members.len());
    let mut e_total = 0.0f64;
    let mut far: Option<f64> = None;
    for (i, m) in members.iter().enumerate() {
        let (t_cmp, t_com, d) = member_times(link, m, ps_pos, wire.up);
        queue.push(t_cmp, Event::ComputeDone { member: i, cluster });
        uplink.push(t_com);
        e_total += energy.tx_energy(wire.up, d)
            + energy.compute_energy(m.samples, m.cpu_hz)
            + energy.tx_energy(wire.down, d);
        far = Some(far.map_or(d, |a: f64| a.max(d)));
    }
    let mut t_max = 0.0f64;
    while let Some(ev) = queue.pop() {
        match ev.event {
            Event::ComputeDone { member, cluster: c } => {
                queue.push(ev.at + uplink[member], Event::TxDone { member, cluster: c });
            }
            Event::TxDone { .. } => t_max = t_max.max(ev.at),
            _ => unreachable!("cluster round scheduled a non-cluster event"),
        }
    }
    if let Some(d) = far {
        t_max += link.comm_time(wire.down, d);
    }
    (t_max, e_total)
}

/// The stage set one run drives, assembled from the configuration's
/// timeline and the strategy's policies.
pub struct Stages {
    pub local: Box<dyn LocalTrainStage>,
    pub cluster: Box<dyn ClusterAggregateStage>,
    pub ground: Box<dyn GroundExchangeStage>,
}

impl Stages {
    pub fn for_run(cfg: &ExperimentConfig, strategy: &Strategy) -> Stages {
        let ground: Box<dyn GroundExchangeStage> = match cfg.timeline {
            Timeline::Analytic => Box::new(AnalyticGroundExchange),
            Timeline::Event => Box::new(EventGroundExchange {
                max_wait_s: cfg.max_ground_wait_s,
                window_step_s: cfg.window_step_s,
            }),
        };
        let cluster: Box<dyn ClusterAggregateStage> = if cfg.routing == RoutingMode::Ring {
            Box::new(RingClusterAggregate {
                policy: strategy.weights,
            })
        } else {
            Box::new(WeightedClusterAggregate {
                policy: strategy.weights,
            })
        };
        Stages {
            local: Box::new(EngineLocalTrain),
            cluster,
            ground,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::round::cluster_round;
    use crate::network::NetworkParams;
    use crate::orbit::elements::OrbitalElements;
    use crate::orbit::Vec3;

    fn models() -> (LinkModel, EnergyModel) {
        let l = LinkModel::new(NetworkParams::default().with_model_params(44_426));
        (l, EnergyModel::new(l))
    }

    #[test]
    fn event_cluster_round_matches_analytic_bitwise() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let wire = WireBits::symmetric(44_426.0 * 32.0);
        let members: Vec<MemberWork> = (0..17)
            .map(|i| {
                MemberWork::nominal(
                    320 + 16 * i,
                    0.5e9 + 3.3e7 * i as f64,
                    Vec3::new(1.0e5 + 4.0e4 * i as f64, -2.0e4 * i as f64, 7.0e6),
                )
            })
            .collect();
        let analytic = cluster_round(&l, &e, &members, ps, wire);
        let mut queue = EventQueue::new();
        let event = cluster_round_events(&mut queue, &l, &e, &members, 0, ps, wire);
        assert_eq!(analytic, event, "timelines disagree on the cluster round");
        assert!(queue.is_empty());
        // an asymmetric (compressed-uplink) wire keeps the identity too
        let thin = WireBits {
            up: wire.up / 8.0,
            down: wire.down,
        };
        let mut queue = EventQueue::new();
        assert_eq!(
            cluster_round(&l, &e, &members, ps, thin),
            cluster_round_events(&mut queue, &l, &e, &members, 0, ps, thin)
        );
        // and for the empty cluster
        let mut queue = EventQueue::new();
        assert_eq!(
            cluster_round(&l, &e, &[], ps, wire),
            cluster_round_events(&mut queue, &l, &e, &[], 0, ps, wire)
        );
    }

    #[test]
    fn ring_merge_is_the_sequential_fold_bitwise() {
        let cfg = ExperimentConfig::tiny();
        let manifest = crate::runtime::Manifest::host();
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let p = rt.spec.param_count;
        let rows_owned: Vec<Vec<f32>> = (0..3)
            .map(|r| (0..p).map(|i| ((i + 7 * r) % 13) as f32 * 0.1 - 0.5).collect())
            .collect();
        let rows: Vec<&[f32]> = rows_owned.iter().map(|r| r.as_slice()).collect();
        let weights = [0.25f32, 0.35, 0.4];
        let stage = RingClusterAggregate {
            policy: WeightPolicy::Quality,
        };
        let mut out = Vec::new();
        stage.merge(&rt, &rows, &weights, &mut out).unwrap();
        let mut expect = vec![0.0f32; p];
        for (row, &w) in rows.iter().zip(&weights) {
            for (o, &x) in expect.iter_mut().zip(row.iter()) {
                *o += w * x;
            }
        }
        assert_eq!(out.len(), p);
        for (a, b) in out.iter().zip(&expect) {
            assert_eq!(a.to_bits(), b.to_bits(), "ring merge must fold in order");
        }
        // the weighting itself is the strategy's, unchanged
        let losses = [0.9f32, 0.4, 1.7];
        let sizes = [64usize, 48, 80];
        for policy in [WeightPolicy::Quality, WeightPolicy::FedAvg] {
            let ring = RingClusterAggregate { policy };
            let flat = WeightedClusterAggregate { policy };
            assert_eq!(
                ring.member_weights(&losses, &sizes),
                flat.member_weights(&losses, &sizes)
            );
        }
    }

    #[test]
    fn fresh_stale_weights_are_bitwise_the_sync_weights() {
        let losses = [0.9f32, 0.4, 1.7, 0.6];
        let sizes = [64usize, 48, 80, 64];
        for policy in [WeightPolicy::Quality, WeightPolicy::FedAvg] {
            let stage = WeightedClusterAggregate { policy };
            let sync = stage.member_weights(&losses, &sizes);
            let fresh = stage.member_weights_stale(&losses, &sizes, &[0.0; 4], 0.5);
            for (a, b) in sync.iter().zip(&fresh) {
                assert_eq!(a.to_bits(), b.to_bits(), "fresh buffer must merge like sync");
            }
            // a genuinely stale member loses weight relative to sync
            let stale = stage.member_weights_stale(&losses, &sizes, &[0.0, 0.0, 0.0, 2.0], 1.0);
            assert!(stale[3] < sync[3], "staleness must discount member 3");
            assert!((stale.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        }
    }

    /// Two equatorial satellites (one overhead at t=0, one antipodal) and
    /// the context both ground stages consume.
    fn two_sat_setup() -> (LinkModel, EnergyModel, Constellation) {
        let (l, e) = models();
        let c = Constellation::new(vec![
            OrbitalElements::circular(500_000.0, 0.0, 0.0, 0.0),
            OrbitalElements::circular(500_000.0, 0.0, 0.0, std::f64::consts::PI),
        ]);
        (l, e, c)
    }

    #[test]
    fn ground_stages_agree_when_always_visible() {
        let (l, e, c) = two_sat_setup();
        // -91° is below the geometric elevation minimum of -90°, so even a
        // perfectly antipodal satellite counts as visible
        let stations = vec![GroundStation::new(0, "everywhere", 0.0, 0.0, -91.0)];
        let ctx = GroundCtx {
            link: &l,
            energy: &e,
            stations: &stations,
            constellation: &c,
        };
        let wire = WireBits::symmetric(1e6);
        let analytic = AnalyticGroundExchange.exchange(&ctx, &[0, 1], 0.0, wire);
        let event = EventGroundExchange {
            max_wait_s: 7000.0,
            window_step_s: 30.0,
        }
        .exchange(&ctx, &[0, 1], 0.0, wire);
        assert_eq!(analytic.exchanged, vec![0, 1]);
        assert_eq!(event.exchanged, vec![0, 1]);
        assert_eq!(analytic.duration_s, event.duration_s, "durations diverged");
        assert_eq!(analytic.energy_j, event.energy_j, "energies diverged");
        assert_eq!(event.wait_s, 0.0);
        assert!(event.stale.is_empty() && analytic.stale.is_empty());
    }

    #[test]
    fn event_ground_waits_for_the_window() {
        let (l, e, c) = two_sat_setup();
        // a 10° mask: sat 0 is overhead (visible now), sat 1 is antipodal
        // and must wait roughly half a synodic period for its pass
        let stations = vec![GroundStation::new(0, "eq", 0.0, 0.0, 10.0)];
        let ctx = GroundCtx {
            link: &l,
            energy: &e,
            stations: &stations,
            constellation: &c,
        };
        let wire = WireBits::symmetric(1e6);
        let out = EventGroundExchange {
            max_wait_s: 7000.0,
            window_step_s: 30.0,
        }
        .exchange(&ctx, &[0, 1], 0.0, wire);
        assert_eq!(out.exchanged, vec![0, 1], "both should eventually exchange");
        assert!(out.wait_s > 1000.0, "antipodal PS should wait: {}", out.wait_s);
        assert!(out.duration_s > out.wait_s * 0.5, "waits must be simulated time");
        assert!(out.stale.is_empty());
        // the analytic stage charges nothing for the invisible PS
        let analytic = AnalyticGroundExchange.exchange(&ctx, &[0, 1], 0.0, wire);
        assert_eq!(analytic.exchanged, vec![0]);
        assert!(out.duration_s > analytic.duration_s);
    }

    #[test]
    fn event_ground_marks_unreachable_ps_stale() {
        let (l, e, c) = two_sat_setup();
        // an equatorial orbit never rises above 10° for a polar station:
        // with no window inside the bound every PS goes stale
        let stations = vec![GroundStation::new(0, "polar", 85.0, 0.0, 10.0)];
        let ctx = GroundCtx {
            link: &l,
            energy: &e,
            stations: &stations,
            constellation: &c,
        };
        let out = EventGroundExchange {
            max_wait_s: 2000.0,
            window_step_s: 30.0,
        }
        .exchange(&ctx, &[0, 1], 0.0, WireBits::symmetric(1e6));
        assert!(out.exchanged.is_empty());
        assert_eq!(out.stale, vec![0, 1]);
        assert_eq!(out.duration_s, 0.0);
        assert_eq!(out.energy_j, 0.0);
    }
}

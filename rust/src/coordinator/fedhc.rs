//! Algorithm 1: the hierarchical clustered FL driver.
//!
//! The driver is strategy-parameterised so FedHC and the two clustered
//! baselines (H-BASE, FedCE) share every mechanism except the three the
//! paper varies — how clusters form, how the PS is chosen, and what
//! happens after a re-clustering event:
//!
//! | method | clustering            | PS choice          | weights  | re-cluster adaptation |
//! |--------|-----------------------|--------------------|----------|-----------------------|
//! | FedHC  | geo k-means (Eq13-15) | centroid+comm      | Eq. 12   | MAML warm start       |
//! | H-BASE | uniform random        | random member      | Eq. 5    | reset to cluster model|
//! | FedCE  | label-histogram k-means| data-centroid      | Eq. 5   | reset to cluster model|
//!
//! C-FedAvg is structurally different (raw-data upload + centralised
//! training) and lives in `baselines::cfedavg`.

use super::round::{cluster_round_with, member_times, throttle_cpu, MemberWork};
use super::stages::{cluster_round_events, ClusterAggregateStage, GroundCtx, RoundPools, Stages};
use super::trial::Trial;
use crate::clustering::kmeans::KMeans;
use crate::clustering::ps_select::{rank_cluster_ps, select_parameter_servers};
use crate::clustering::quality::kmeans_nd;
use crate::clustering::recluster::{align_labels, changed_members, ReclusterPolicy};
use crate::config::{AggregationMode, RoutingMode, Timeline};
use crate::fl::aggregate::{aggregate, fedavg_weights, fold_stale, staleness_weight};
use crate::fl::compress::{encode_upload, CompressScratch};
use crate::fl::evaluate::evaluate_with;
use crate::info;
use crate::metrics::{Entity, MetricsRegistry, Tracer};
use crate::network::retry::{transfer_with_retries, TransferOutcome};
use crate::network::routing::{
    build_route_tree, ring_round, routed_round, HopNode, RouteTree, NO_PARENT,
};
use crate::network::Payload;
use crate::orbit::index::{ConstellationIndex, SphereGrid};
use crate::orbit::GroundStation;
use crate::runtime::HostScratch;
use crate::sim::engine::Engine;
use crate::sim::events::{Event, EventQueue};
use crate::sim::scenario::{Availability, CORRUPT_SALT, RELAY_CORRUPT_SALT};
use crate::util::profile::{Phase, Scope};
use crate::util::rng::stream_seed;
use crate::util::Rng;
use anyhow::Result;

/// Clustering policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// Paper §III-B: k-means on satellite positions.
    GeoKMeans,
    /// H-BASE: uniform random assignment.
    Random,
    /// FedCE: k-means on client label histograms.
    DataDistribution,
}

/// Parameter-server choice within a cluster.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PsPolicy {
    /// Paper: nearest-to-centroid with communication tie-break.
    CentroidComm,
    /// H-BASE: random member.
    Random,
    /// FedCE: member nearest the cluster's *data* centroid (geometry-blind).
    DataCentroid,
}

/// Intra-cluster aggregation weights.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WeightPolicy {
    /// Eq. 12 inverse-loss quality weights (FedHC).
    Quality,
    /// Eq. 5 data-size FedAvg weights.
    FedAvg,
}

/// A complete method description.
#[derive(Clone, Copy, Debug)]
pub struct Strategy {
    pub name: &'static str,
    pub cluster: ClusterPolicy,
    pub ps: PsPolicy,
    pub weights: WeightPolicy,
    /// MAML warm start for re-assigned members (paper §III-C).
    pub maml_warmstart: bool,
}

impl Strategy {
    pub fn fedhc() -> Strategy {
        Strategy {
            name: "FedHC",
            cluster: ClusterPolicy::GeoKMeans,
            ps: PsPolicy::CentroidComm,
            weights: WeightPolicy::Quality,
            maml_warmstart: true,
        }
    }

    /// FedHC without MAML — the ablation the paper implies when it credits
    /// meta-learning for the convergence speedup.
    pub fn fedhc_no_maml() -> Strategy {
        Strategy {
            name: "FedHC-noMAML",
            maml_warmstart: false,
            ..Strategy::fedhc()
        }
    }

    pub fn hbase() -> Strategy {
        Strategy {
            name: "H-BASE",
            cluster: ClusterPolicy::Random,
            ps: PsPolicy::Random,
            weights: WeightPolicy::FedAvg,
            maml_warmstart: false,
        }
    }

    pub fn fedce() -> Strategy {
        Strategy {
            name: "FedCE",
            cluster: ClusterPolicy::DataDistribution,
            ps: PsPolicy::DataCentroid,
            weights: WeightPolicy::FedAvg,
            maml_warmstart: false,
        }
    }
}

/// Cluster topology: per-client assignment + frozen centroids + PS per
/// cluster + the cluster models.
pub struct Topology {
    pub assignment: Vec<usize>,
    pub centroids_km: Vec<[f64; 3]>,
    /// Client index acting as PS for each cluster.
    pub ps: Vec<usize>,
    pub models: Vec<Vec<f32>>,
}

impl Topology {
    pub fn clusters(&self, k: usize) -> Vec<Vec<usize>> {
        let mut out = vec![Vec::new(); k];
        for (i, &c) in self.assignment.iter().enumerate() {
            out[c].push(i);
        }
        out
    }
}

/// Outcome of a full run.
pub struct RunResult {
    pub name: &'static str,
    pub ledger: crate::metrics::Ledger,
    /// (round, time, energy) at target-accuracy crossing, if reached.
    pub converged_at: Option<(usize, f64, f64)>,
    pub final_accuracy: f64,
}

/// Build a topology under the strategy's clustering/PS policy. `grid` is
/// the constellation plane's sphere grid for the current epoch (when the
/// index is enabled): the geo k-means assignment step runs index-pruned
/// but bit-identical, and the clustering features are read straight off
/// the index instead of re-propagating the snapshot.
pub fn build_topology(
    trial: &mut Trial,
    strategy: &Strategy,
    global: &[f32],
    grid: Option<&SphereGrid>,
) -> Result<Topology> {
    let k = trial.cfg.clusters;
    let feats_owned;
    let feats: &[[f64; 3]] = match grid {
        Some(g) => g.feats(),
        None => {
            feats_owned = trial.features_km();
            &feats_owned
        }
    };
    let (assignment, centroids_km) = match strategy.cluster {
        ClusterPolicy::GeoKMeans => {
            let res = KMeans::new(k).run_indexed(feats, &mut trial.rng, grid)?;
            (res.assignment, res.centroids)
        }
        ClusterPolicy::Random => {
            // uniform random, each cluster non-empty
            let n = trial.clients.len();
            let mut assignment = vec![0usize; n];
            for (i, a) in assignment.iter_mut().enumerate() {
                *a = if i < k { i } else { trial.rng.below_usize(k) };
            }
            // centroids = mean member position (for churn accounting)
            (assignment.clone(), centroids_of(feats, &assignment, k))
        }
        ClusterPolicy::DataDistribution => {
            let hists: Vec<Vec<f64>> = trial
                .clients
                .iter()
                .map(|c| c.shard.label_histogram())
                .collect();
            let (assignment, _) = kmeans_nd(&hists, k, 25, &mut trial.rng);
            (fix_empty(assignment, k, &mut trial.rng), Vec::new())
        }
    };
    let centroids_km = if centroids_km.is_empty() {
        centroids_of(feats, &assignment, k)
    } else {
        centroids_km
    };

    let positions = trial.positions();
    let ps = match strategy.ps {
        PsPolicy::CentroidComm => {
            let res = crate::clustering::kmeans::KMeansResult {
                centroids: centroids_km.clone(),
                assignment: assignment.clone(),
                iterations: 0,
                inertia: 0.0,
            };
            select_parameter_servers(&res, &positions, &trial.link)
                .into_iter()
                .map(|c| c.ps)
                .collect()
        }
        PsPolicy::Random => {
            let mut ps = Vec::with_capacity(k);
            for members in group(&assignment, k) {
                ps.push(members[trial.rng.below_usize(members.len())]);
            }
            ps
        }
        PsPolicy::DataCentroid => {
            // member whose label histogram is nearest the cluster's mean
            let hists: Vec<Vec<f64>> = trial
                .clients
                .iter()
                .map(|c| c.shard.label_histogram())
                .collect();
            let mut ps = Vec::with_capacity(k);
            for members in group(&assignment, k) {
                let dim = hists[0].len();
                let mut mean = vec![0.0f64; dim];
                for &m in &members {
                    for d in 0..dim {
                        mean[d] += hists[m][d];
                    }
                }
                for v in mean.iter_mut() {
                    *v /= members.len() as f64;
                }
                let best = members
                    .iter()
                    .copied()
                    .min_by(|&a, &b| {
                        let da: f64 = hists[a]
                            .iter()
                            .zip(&mean)
                            .map(|(x, m)| (x - m) * (x - m))
                            .sum();
                        let db: f64 = hists[b]
                            .iter()
                            .zip(&mean)
                            .map(|(x, m)| (x - m) * (x - m))
                            .sum();
                        da.total_cmp(&db)
                    })
                    .unwrap();
                ps.push(best);
            }
            ps
        }
    };

    Ok(Topology {
        assignment,
        centroids_km,
        ps,
        models: vec![global.to_vec(); k],
    })
}

/// Billed bits of one MAML warm-start support batch: raw f32 features on
/// the wire, through the [`Payload`] accounting seam (never compressed —
/// data transfers are outside the `--compress` parameter plane).
fn maml_batch_bits(rt: &crate::runtime::ModelRuntime) -> f64 {
    Payload {
        values: rt.spec.batch * rt.spec.input_dim(),
        value_bits: 32,
        indices: 0,
        index_bits: 0,
        header_bytes: 0,
    }
    .bits()
}

/// Largest cluster in a topology — the pooled round path's peak concurrent
/// parameter-buffer demand.
fn max_cluster_size(topo: &Topology, k: usize) -> usize {
    let mut counts = vec![0usize; k];
    for &a in &topo.assignment {
        counts[a] += 1;
    }
    counts.into_iter().max().unwrap_or(0)
}

/// Recovery plane: deterministic mid-round PS failover. A
/// `Fault::PsFailure` crashes the *server process* on the PS satellite —
/// the satellite itself keeps training as an ordinary member — so before
/// the ground pass plan forms, every affected cluster promotes the next
/// candidate from its [`rank_cluster_ps`] ranking (rank 0 is the original
/// selection) that is neither crashed nor unreachable. The crashed
/// process loses its working buffer, not the last *published* cluster
/// model (that broadcast already reached the members), so the backup
/// re-collects exactly the cached member updates `migrates` names — no
/// training is redone; the bill is one Eq. 6 upload time (clusters fail
/// over in parallel, members within one re-collection in parallel) plus
/// Eq. 8 transmit energy per salvaged update, on the wire at the full
/// uplink payload. A cluster with no live candidate keeps its crashed PS
/// and takes the ordinary stale-pass path until a later round. Returns
/// the wall-clock cost of the slowest re-collection.
#[allow(clippy::too_many_arguments)]
fn fail_over_ps(
    trial: &mut Trial,
    topo: &mut Topology,
    members_of: &[Vec<usize>],
    avail: &Availability,
    positions: &[crate::orbit::Vec3],
    up_bytes: f64,
    up_bits: f64,
    migrates: &dyn Fn(usize) -> bool,
) -> f64 {
    let mut failover_time = 0.0f64;
    let now = trial.clock.now();
    for c in 0..topo.ps.len() {
        if !avail.ps_failed[topo.ps[c]] {
            continue;
        }
        let rank = rank_cluster_ps(&members_of[c], &topo.centroids_km[c], positions, &trial.link);
        let Some(backup) = rank
            .into_iter()
            .find(|&s| !avail.ps_failed[s] && !avail.unreachable[s])
        else {
            continue;
        };
        let mut t_re = 0.0f64;
        let mut n_re = 0usize;
        for &m in &members_of[c] {
            if m == backup || avail.unreachable[m] || !migrates(m) {
                continue;
            }
            let d = positions[m].dist(positions[backup]);
            t_re = t_re.max(trial.link.comm_time(up_bits, d));
            trial.ledger.add_energy(trial.energy.tx_energy(up_bits, d));
            n_re += 1;
        }
        trial.ledger.add_wire_bytes(up_bytes * n_re as f64);
        trial.ledger.add_failover();
        trial.trace.instant(now, "failover", Entity::Cluster(c));
        trial.registry.record_failover(c);
        failover_time = failover_time.max(t_re);
        topo.ps[c] = backup;
    }
    failover_time
}

fn centroids_of(feats: &[[f64; 3]], assignment: &[usize], k: usize) -> Vec<[f64; 3]> {
    let mut sums = vec![[0.0f64; 3]; k];
    let mut counts = vec![0usize; k];
    for (f, &a) in feats.iter().zip(assignment) {
        for d in 0..3 {
            sums[a][d] += f[d];
        }
        counts[a] += 1;
    }
    for c in 0..k {
        let n = counts[c].max(1) as f64;
        for d in 0..3 {
            sums[c][d] /= n;
        }
    }
    sums
}

fn fix_empty(mut assignment: Vec<usize>, k: usize, rng: &mut crate::util::Rng) -> Vec<usize> {
    loop {
        let mut counts = vec![0usize; k];
        for &a in &assignment {
            counts[a] += 1;
        }
        let Some(empty) = counts.iter().position(|&c| c == 0) else {
            return assignment;
        };
        // move a random member of the largest cluster
        let largest = (0..k).max_by_key(|&c| counts[c]).unwrap();
        let members: Vec<usize> = assignment
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == largest)
            .map(|(i, _)| i)
            .collect();
        assignment[members[rng.below_usize(members.len())]] = empty;
    }
}

fn group(assignment: &[usize], k: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); k];
    for (i, &a) in assignment.iter().enumerate() {
        out[a].push(i);
    }
    out
}

/// Run the clustered FL algorithm (FedHC / H-BASE / FedCE) to completion
/// with the stage set derived from the configuration's timeline and the
/// strategy's policies (see [`Stages::for_run`]).
pub fn run_clustered(trial: &mut Trial, strategy: Strategy) -> Result<RunResult> {
    let stages = Stages::for_run(&trial.cfg, &strategy);
    run_staged(trial, strategy, &stages)
}

/// Algorithm 1 driven through the stage traits in
/// [`crate::coordinator::stages`]: a [`super::stages::LocalTrainStage`]
/// scatter (the deterministic parallel round engine — metrics are
/// byte-identical for any worker count), a
/// [`super::stages::ClusterAggregateStage`] gather/merge **in member
/// order**, and a [`super::stages::GroundExchangeStage`] pass every
/// `ground_every` rounds. Under `--timeline event` the cluster and ground
/// stages run on the `sim::events` queue and ground exchanges are gated by
/// visibility windows; under `--timeline analytic` the legacy Eq. 7
/// closed-form folds apply.
pub fn run_staged(trial: &mut Trial, strategy: Strategy, stages: &Stages) -> Result<RunResult> {
    // the buffered/async aggregation plane replaces the intra-cluster
    // barrier with an event-driven merge schedule; the sync path below is
    // byte-for-byte the pre-aggregation-axis behaviour
    if trial.cfg.aggregation != AggregationMode::Sync {
        return run_staged_buffered(trial, strategy, stages);
    }
    let cfg = trial.cfg.clone();
    let rt = trial.rt;
    let k = cfg.clusters;
    // wire plane: bits billed per model exchange (compressed uplink, dense
    // downlink) and the exact bytes of one uplink payload; with `--compress
    // none` the WireBits are symmetric and every fold below is bit-identical
    // to the historical single-`model_bits` accounting
    let wire = cfg.compress.wire(rt.spec.param_count);
    let up_bytes = trial.link.upload_bytes(&cfg.compress.payload(rt.spec.param_count));
    let compressing = !cfg.compress.is_none();
    let retry = cfg.retry_policy();
    let mut wire_scratch = CompressScratch::new();
    // error-feedback residuals, pooled lazily on first encode: one per
    // member (member → PS uploads) and one per cluster slot (PS → GS)
    let mut residuals: Vec<Option<Vec<f32>>> = if compressing {
        (0..trial.clients.len()).map(|_| None).collect()
    } else {
        Vec::new()
    };
    let mut ground_residuals: Vec<Option<Vec<f32>>> = if compressing {
        (0..k).map(|_| None).collect()
    } else {
        Vec::new()
    };
    // routing plane: a relay that re-encodes a pooled partial aggregate
    // before forwarding keeps its own error-feedback residual, one per
    // satellite ever acting as a relay (lazily pooled like the above)
    let mut relay_residuals: Vec<Option<Vec<f32>>> = if compressing
        && cfg.routing == RoutingMode::Isl
    {
        (0..trial.clients.len()).map(|_| None).collect()
    } else {
        Vec::new()
    };
    let resident = cfg.resident_params;
    let policy = ReclusterPolicy::new(cfg.recluster_threshold)?;
    let engine = Engine::new(cfg.workers);
    let pools = RoundPools::new(rt);
    let mut queue = EventQueue::new(); // event-timeline scratch
    let mut agg_buf: Vec<f32> = Vec::new(); // recycled cluster-merge output
    let mut eval_scratch = HostScratch::new();

    // constellation plane: one sphere grid per epoch, rebuilt in place at
    // round starts and on re-cluster events (`--no-index` disables it;
    // results are bit-identical either way — the index only prunes)
    let mut geo: Option<ConstellationIndex> = if cfg.spatial_index {
        Some(ConstellationIndex::new(cfg.index_bands))
    } else {
        None
    };

    // Algorithm 1 line 1: satellite-clustered PS selection
    let global0 = trial.init.clone();
    if let Some(g) = geo.as_mut() {
        g.refresh(&trial.constellation, trial.clock.now());
    }
    let mut topo = build_topology(trial, &strategy, &global0, geo.as_ref().map(|g| g.grid()))?;
    // warm the pool up to the largest cluster once, so steady-state rounds
    // never allocate parameter-sized buffers however availability moves
    pools.params.ensure_free(max_cluster_size(&topo, k));
    let mut global = global0;
    let mut converged_at = None;
    let mut batch_buf = BatchBuf::new(rt);
    let mut jobs: Vec<(usize, usize)> = Vec::new(); // (member, cluster)
    // routing plane scratch: the routed cluster's node set (ascending
    // constellation ids) and the BFS neighbour buffer
    let mut node_ids: Vec<usize> = Vec::new();
    let mut neigh_scratch: Vec<usize> = Vec::new();

    for round in 1..=cfg.rounds {
        let positions = trial.positions();
        let round_t0 = trial.clock.now();
        // scenario plane: fold this round's fault events into availability
        // (hard failures, eclipse power-save, transient outages, link and
        // compute degradations, dark ground stations)
        let avail = trial.scenario.advance_round(round as u64, &positions);
        trial.ledger.add_faults(avail.faults_injected);
        // membership churn at the current epoch (drives line 15's d_r);
        // unreachable satellites count as dropouts alongside orbital
        // drift. The index refresh reuses the positions this round just
        // propagated — no second Kepler pass.
        if let Some(g) = geo.as_mut() {
            g.refresh_positions(&positions, trial.clock.now());
        }
        let churn = trial.mobility.churn_with(
            &trial.constellation,
            &topo.assignment,
            &topo.centroids_km,
            trial.clock.now(),
            &avail.unreachable,
            geo.as_ref().map(|g| g.grid()),
        );
        let outage: std::collections::BTreeSet<usize> = churn.outages.iter().copied().collect();
        // recovery plane: when any sender sees a nonzero effective BER
        // (the `--ber` floor plus active noise bursts), member uploads run
        // the detect/retry/backoff loop; otherwise the whole plane is
        // skipped — no RNG streams, no float ops — keeping nominal rounds
        // bit-identical to the pre-recovery accounting
        let noisy = cfg.ber > 0.0 || avail.ber.iter().any(|&b| b > 0.0);

        // ---- local training + cluster aggregation (lines 6–13) ----
        // Sharded per cluster: each cluster scatters its active members
        // across the engine, gathers in member order, merges at the PS and
        // recycles its buffers before the next cluster starts. Peak pooled
        // demand is therefore the largest *cluster*, not the whole
        // constellation — the bounded-memory round path mega presets rely
        // on — and the outcome is bit-identical to an all-at-once scatter
        // (member results derive from stateless `(seed, round, sat)`
        // streams and are reduced in the same member order either way).
        let clusters = topo.clusters(k);
        let mut stage_time = 0.0f64;
        for (c, members) in clusters.iter().enumerate() {
            jobs.clear();
            for &m in members {
                if !outage.contains(&m) {
                    jobs.push((m, c));
                }
            }
            if jobs.is_empty() {
                continue;
            }
            let mut batch = {
                let _p = Scope::new(Phase::LocalTrain);
                stages.local.train(
                    &engine,
                    rt,
                    &cfg,
                    &trial.clients,
                    &topo.models,
                    &jobs,
                    round as u64,
                    &pools,
                )?
            };
            let mut work = Vec::with_capacity(batch.len());
            let mut losses = Vec::with_capacity(batch.len());
            let mut sizes = Vec::with_capacity(batch.len());
            for r in batch.iter() {
                let m = r.member;
                debug_assert_eq!(r.cluster, c, "gather out of cluster order");
                trial.clients[m].last_loss = r.mean_loss;
                trial.clients[m].rounds_trained += 1;
                // scenario degradations: a straggler's effective CPU rate
                // shrinks (stretching t_cmp through the ordinary Eq. 7
                // fold) and a degraded ISL scales the uplink rate; at the
                // nominal factors both divisions/multiplications are IEEE
                // identities, so undisturbed rounds stay bit-identical
                let cpu_hz = throttle_cpu(
                    &trial.link,
                    &mut trial.ledger,
                    r.samples,
                    trial.clients[m].cpu_hz,
                    avail.compute_slowdown[m],
                );
                work.push(MemberWork {
                    samples: r.samples,
                    cpu_hz,
                    pos: positions[m],
                    link_factor: avail.link_factor[m],
                });
                losses.push(r.mean_loss);
                sizes.push(trial.clients[m].data_size());
            }
            // routing plane: the intra-cluster route tree for this epoch —
            // BFS over the LoS ISL graph rooted at the PS, hop-count
            // shortest paths with lowest-index tie-breaks, degraded relays
            // demoted to leaves (routes bend around them), out-of-range
            // members falling back to the direct link. A *flat* tree
            // (every member one hop from the PS) takes the direct machinery
            // below verbatim, so `--routing isl` on dense clusters is
            // bit-identical to `--routing direct` by construction.
            let tree: Option<RouteTree> = {
                let _p = Scope::new(Phase::Routing);
                (cfg.routing == RoutingMode::Isl).then(|| {
                    node_ids.clear();
                    node_ids.extend(jobs.iter().map(|&(m, _)| m));
                    if node_ids.binary_search(&topo.ps[c]).is_err() {
                        node_ids.push(topo.ps[c]);
                        node_ids.sort_unstable();
                    }
                    let root = node_ids
                        .binary_search(&topo.ps[c])
                        .expect("PS present in its own route tree");
                    build_route_tree(
                        &node_ids,
                        root,
                        cfg.isl_range_km * 1e3,
                        &positions,
                        geo.as_ref().map(|g| g.grid()),
                        &|g| avail.link_factor[g] < 1.0,
                        &mut neigh_scratch,
                    )
                })
            };
            let _p_agg = Scope::new(Phase::ClusterAgg);
            let multi_hop = tree.as_ref().is_some_and(|t| t.max_hops() > 1);
            if cfg.routing == RoutingMode::Ring || multi_hop {
                let (t, e) = if cfg.routing == RoutingMode::Ring {
                    // ring all-reduce (`--routing isl:ring`): the active
                    // members form a ring in ascending id order and exchange
                    // `wire.up / k`-bit chunks for 2(k−1) steps (reduce-
                    // scatter, then all-gather). Every member ends holding
                    // the full fold, so the PS "merge" below is the ring's
                    // own sequential accumulation — the RingClusterAggregate
                    // stage pins exactly that order.
                    let kr = batch.len();
                    let steps = 2 * kr.saturating_sub(1);
                    let ps_pos = positions[topo.ps[c]];
                    let mut hop_nodes: Vec<HopNode> = Vec::with_capacity(kr);
                    for (i, w) in work.iter().enumerate() {
                        let succ = batch[(i + 1) % kr].member;
                        let (t_cmp, _, _) = member_times(&trial.link, w, ps_pos, wire.up);
                        hop_nodes.push(HopNode {
                            t_cmp,
                            e_cmp: trial.energy.compute_energy(w.samples, w.cpu_hz),
                            link_factor: w.link_factor,
                            d_up: positions[batch[i].member].dist(positions[succ]),
                        });
                    }
                    // recovery plane: one outcome per member's ring edge,
                    // replayed by each step; keyed off the dedicated relay
                    // stream so the direct path's draws stay untouched
                    let mut outcomes: Vec<TransferOutcome> = Vec::new();
                    if noisy && kr > 1 {
                        outcomes.reserve(kr);
                        let chunk = wire.up / kr as f64;
                        for (i, h) in hop_nodes.iter().enumerate() {
                            let m = batch[i].member;
                            let eff_ber = cfg.ber + avail.ber[m];
                            let out = if eff_ber > 0.0 {
                                let t_edge =
                                    trial.link.comm_time_scaled(chunk, h.d_up, h.link_factor);
                                let mut rng = Rng::new(stream_seed(
                                    cfg.seed ^ RELAY_CORRUPT_SALT,
                                    round as u64,
                                    m as u64,
                                ));
                                transfer_with_retries(&retry, eff_ber, chunk, t_edge, &mut rng)
                            } else {
                                TransferOutcome { attempts: 1, wait_s: 0.0, delivered: true }
                            };
                            trial.ledger.add_retransmits(out.retransmits() * steps);
                            trial.ledger.add_corrupted_uploads(out.corrupted() * steps);
                            trial.ledger.add_retry_wait(out.wait_s * steps as f64);
                            outcomes.push(out);
                        }
                    }
                    // wire plane: encode survivors in member order (a member
                    // whose chunk exchange died keeps its residual)
                    if compressing {
                        for (i, r) in batch.iter_mut().enumerate() {
                            if !outcomes.is_empty() && !outcomes[i].delivered {
                                continue;
                            }
                            let res = residuals[r.member]
                                .get_or_insert_with(|| pools.params.take_zeroed());
                            encode_upload(
                                cfg.compress,
                                &mut r.params,
                                &topo.models[c],
                                res,
                                &mut wire_scratch,
                            );
                        }
                    }
                    // every step moves k chunks — one model's worth of bits
                    // per step — and each chunk bills once per attempt
                    if kr > 1 {
                        let chunk_bytes = up_bytes / kr as f64;
                        if outcomes.is_empty() {
                            trial.ledger.add_wire_bytes(chunk_bytes * (kr * steps) as f64);
                        } else {
                            let attempts: u32 = outcomes.iter().map(|o| o.attempts).sum();
                            trial
                                .ledger
                                .add_wire_bytes(chunk_bytes * steps as f64 * attempts as f64);
                        }
                        trial.ledger.add_route_hops(steps);
                        trial.ledger.add_relay_merges(kr - 1);
                    }
                    let weights;
                    let rows: Vec<&[f32]>;
                    if !outcomes.is_empty() && outcomes.iter().any(|o| !o.delivered) {
                        let mut kept_losses = Vec::with_capacity(batch.len());
                        let mut kept_sizes = Vec::with_capacity(batch.len());
                        let mut kept_rows: Vec<&[f32]> = Vec::with_capacity(batch.len());
                        for (i, r) in batch.iter().enumerate() {
                            if outcomes[i].delivered {
                                kept_losses.push(losses[i]);
                                kept_sizes.push(sizes[i]);
                                kept_rows.push(r.params.as_slice());
                            }
                        }
                        weights = stages.cluster.member_weights(&kept_losses, &kept_sizes);
                        rows = kept_rows;
                    } else {
                        weights = stages.cluster.member_weights(&losses, &sizes);
                        rows = batch.iter().map(|r| r.params.as_slice()).collect();
                    }
                    if !rows.is_empty() {
                        stages.cluster.merge(rt, &rows, &weights, &mut agg_buf)?;
                        std::mem::swap(&mut topo.models[c], &mut agg_buf);
                    }
                    let out = ring_round(
                        &trial.link,
                        &trial.energy,
                        &hop_nodes,
                        (!outcomes.is_empty()).then_some(outcomes.as_slice()),
                        wire,
                    );
                    // telemetry plane: the all-reduce is collective, so
                    // every member's upload span covers the whole exchange
                    if trial.trace.is_enabled() || trial.registry.is_enabled() {
                        let chunk_bytes = up_bytes / kr.max(1) as f64;
                        for (i, r) in batch.iter().enumerate() {
                            let (retx, att) = if outcomes.is_empty() {
                                (0usize, 1u32)
                            } else {
                                (outcomes[i].retransmits() * steps, outcomes[i].attempts)
                            };
                            trial.trace.span(round_t0, out.0, "upload", Entity::Sat(r.member));
                            trial.registry.record_upload(
                                r.member,
                                out.0,
                                chunk_bytes * steps as f64 * att as f64,
                                retx,
                                steps,
                            );
                        }
                    }
                    out
                } else {
                    // multi-hop store-and-forward (`--routing isl`): every
                    // member's upload walks its BFS path toward the PS, and
                    // a relay holding more than one in-flight payload
                    // partially aggregates before forwarding — each hop then
                    // carries exactly one model payload. Weights ride along
                    // as the forwarded weight-sum, so the fold the PS ends
                    // with is the same weighted average over the same
                    // members, just associated along the tree.
                    let tree = tree.as_ref().expect("multi-hop implies a tree");
                    let n = node_ids.len();
                    let ps_pos = positions[topo.ps[c]];
                    // map tree-local nodes ↔ batch rows (the PS is the only
                    // node that may have trained nothing — it relays only)
                    let mut local_of: Vec<usize> = Vec::with_capacity(batch.len());
                    let mut batch_of: Vec<Option<usize>> = vec![None; n];
                    for (j, r) in batch.iter().enumerate() {
                        let local = node_ids
                            .binary_search(&r.member)
                            .expect("trained member missing from its route tree");
                        local_of.push(local);
                        batch_of[local] = Some(j);
                    }
                    let mut hop_nodes: Vec<HopNode> = Vec::with_capacity(n);
                    for local in 0..n {
                        let d_up = if tree.parent[local] == NO_PARENT {
                            0.0
                        } else {
                            positions[node_ids[local]]
                                .dist(positions[node_ids[tree.parent[local]]])
                        };
                        hop_nodes.push(match batch_of[local] {
                            Some(j) => {
                                let w = &work[j];
                                let (t_cmp, _, _) =
                                    member_times(&trial.link, w, ps_pos, wire.up);
                                HopNode {
                                    t_cmp,
                                    e_cmp: trial.energy.compute_energy(w.samples, w.cpu_hz),
                                    link_factor: w.link_factor,
                                    d_up,
                                }
                            }
                            None => HopNode::relay_only(d_up),
                        });
                    }
                    // recovery plane: one retry outcome per tree edge, each
                    // a pure function of (seed, round, sender) through the
                    // dedicated relay stream — worker-count invariant and
                    // disjoint from the direct path's draws
                    let mut outcomes: Vec<TransferOutcome> = Vec::new();
                    if noisy {
                        outcomes.reserve(n);
                        for (local, h) in hop_nodes.iter().enumerate() {
                            if tree.parent[local] == NO_PARENT {
                                // placeholder keeps edge/node indices aligned
                                outcomes.push(TransferOutcome {
                                    attempts: 1,
                                    wait_s: 0.0,
                                    delivered: true,
                                });
                                continue;
                            }
                            let g = node_ids[local];
                            let eff_ber = cfg.ber + avail.ber[g];
                            let out = if eff_ber > 0.0 {
                                let t_hop =
                                    trial.link.comm_time_scaled(wire.up, h.d_up, h.link_factor);
                                let mut rng = Rng::new(stream_seed(
                                    cfg.seed ^ RELAY_CORRUPT_SALT,
                                    round as u64,
                                    g as u64,
                                ));
                                transfer_with_retries(&retry, eff_ber, wire.up, t_hop, &mut rng)
                            } else {
                                TransferOutcome { attempts: 1, wait_s: 0.0, delivered: true }
                            };
                            trial.ledger.add_retransmits(out.retransmits());
                            trial.ledger.add_corrupted_uploads(out.corrupted());
                            trial.ledger.add_retry_wait(out.wait_s);
                            outcomes.push(out);
                        }
                    }
                    // a contribution reaches the PS only if *every* edge on
                    // its path delivered; parents resolve before children in
                    // reverse merge order (store-and-forward: a payload lost
                    // on a later hop was still transmitted on earlier ones)
                    let mut path_ok = vec![true; n];
                    if noisy {
                        for &local in tree.order.iter().rev() {
                            let p = tree.parent[local];
                            if p != NO_PARENT {
                                path_ok[local] = outcomes[local].delivered && path_ok[p];
                            }
                        }
                    }
                    // wire plane: encode in member order against the model
                    // the member trained from. A first hop that never
                    // delivered leaves its sender's residual untouched;
                    // payloads lost deeper already left their sender — its
                    // residual updates as usual.
                    if compressing {
                        for (j, r) in batch.iter_mut().enumerate() {
                            if noisy && !outcomes[local_of[j]].delivered {
                                continue;
                            }
                            let res = residuals[r.member]
                                .get_or_insert_with(|| pools.params.take_zeroed());
                            encode_upload(
                                cfg.compress,
                                &mut r.params,
                                &topo.models[c],
                                res,
                                &mut wire_scratch,
                            );
                        }
                    }
                    // every tree edge carries one full payload per attempt —
                    // the in-route aggregation is what keeps it to *one*
                    if noisy {
                        let attempts: u32 = (0..n)
                            .filter(|&l| tree.parent[l] != NO_PARENT)
                            .map(|l| outcomes[l].attempts)
                            .sum();
                        trial.ledger.add_wire_bytes(up_bytes * attempts as f64);
                    } else {
                        trial.ledger.add_wire_bytes(up_bytes * (n - 1) as f64);
                    }
                    trial.ledger.add_route_hops(n - 1);
                    // the delivered set's strategy weights (Eq. 12 / Eq. 5),
                    // normalised once over the survivors and carried through
                    // the tree as absolute weights
                    let mut kept_losses = Vec::with_capacity(batch.len());
                    let mut kept_sizes = Vec::with_capacity(batch.len());
                    for j in 0..batch.len() {
                        if path_ok[local_of[j]] {
                            kept_losses.push(losses[j]);
                            kept_sizes.push(sizes[j]);
                        }
                    }
                    let kept_w = stages.cluster.member_weights(&kept_losses, &kept_sizes);
                    let mut w_abs = vec![0.0f32; batch.len()];
                    let mut wi = 0;
                    for j in 0..batch.len() {
                        if path_ok[local_of[j]] {
                            w_abs[j] = kept_w[wi];
                            wi += 1;
                        }
                    }
                    // the upward fold, children before parents: each node
                    // pools what its subtree delivered (own row first, then
                    // child payloads in schedule order), partially
                    // aggregates when holding more than one, and forwards a
                    // single payload tagged with the pooled weight-sum
                    enum Upload<'a> {
                        Own(&'a [f32]),
                        Pooled(Vec<f32>),
                    }
                    impl Upload<'_> {
                        fn row(&self) -> &[f32] {
                            match self {
                                Upload::Own(r) => r,
                                Upload::Pooled(b) => b.as_slice(),
                            }
                        }
                    }
                    let mut inbox: Vec<Vec<(Upload<'_>, f32)>> =
                        (0..n).map(|_| Vec::new()).collect();
                    for &local in &tree.order {
                        let mut items = std::mem::take(&mut inbox[local]);
                        if path_ok[local] {
                            if let Some(j) = batch_of[local] {
                                items.insert(0, (Upload::Own(&batch[j].params), w_abs[j]));
                            }
                        }
                        let p = tree.parent[local];
                        if p == NO_PARENT {
                            // the PS folds whatever survived into the model
                            if !items.is_empty() {
                                let sw: f32 = items.iter().map(|it| it.1).sum();
                                let rows: Vec<&[f32]> =
                                    items.iter().map(|it| it.0.row()).collect();
                                let weights: Vec<f32> =
                                    items.iter().map(|it| it.1 / sw).collect();
                                stages.cluster.merge(rt, &rows, &weights, &mut agg_buf)?;
                                drop(rows);
                                std::mem::swap(&mut topo.models[c], &mut agg_buf);
                                for (up, _) in items {
                                    if let Upload::Pooled(buf) = up {
                                        pools.params.put(buf);
                                    }
                                }
                            }
                            continue;
                        }
                        if items.is_empty() {
                            continue; // nothing survived below this node
                        }
                        if items.len() == 1 {
                            // a lone payload forwards as-is — no merge
                            inbox[p].push(items.pop().expect("len checked"));
                            continue;
                        }
                        // in-route partial aggregation: locally normalised
                        // merge; the forwarded weight-sum keeps the final
                        // fold unchanged
                        let sw: f32 = items.iter().map(|it| it.1).sum();
                        let rows: Vec<&[f32]> = items.iter().map(|it| it.0.row()).collect();
                        let weights: Vec<f32> = items.iter().map(|it| it.1 / sw).collect();
                        let mut pooled = pools.params.take_zeroed();
                        stages.cluster.merge(rt, &rows, &weights, &mut pooled)?;
                        drop(rows);
                        trial.ledger.add_relay_merges(1);
                        for (up, _) in items {
                            if let Upload::Pooled(buf) = up {
                                pools.params.put(buf);
                            }
                        }
                        // wire plane: the forwarding relay re-encodes the
                        // pooled payload through its own residual
                        if compressing {
                            let res = relay_residuals[node_ids[local]]
                                .get_or_insert_with(|| pools.params.take_zeroed());
                            encode_upload(
                                cfg.compress,
                                &mut pooled,
                                &topo.models[c],
                                res,
                                &mut wire_scratch,
                            );
                        }
                        inbox[p].push((Upload::Pooled(pooled), sw));
                    }
                    let out = routed_round(
                        &trial.link,
                        &trial.energy,
                        tree,
                        &hop_nodes,
                        noisy.then_some(outcomes.as_slice()),
                        wire,
                    );
                    // telemetry plane: one relay_hop instant per tree edge
                    // (mirroring the ledger's route-hop count), one upload
                    // span per trained member at its path depth
                    if trial.trace.is_enabled() || trial.registry.is_enabled() {
                        for local in 0..n {
                            if tree.parent[local] == NO_PARENT {
                                continue;
                            }
                            trial
                                .trace
                                .instant(round_t0, "relay_hop", Entity::Sat(node_ids[local]));
                            if noisy && outcomes[local].retransmits() > 0 {
                                trial
                                    .trace
                                    .instant(round_t0, "retry", Entity::Sat(node_ids[local]));
                            }
                        }
                        for (j, r) in batch.iter().enumerate() {
                            let local = local_of[j];
                            let (retx, att) = if noisy {
                                (outcomes[local].retransmits(), outcomes[local].attempts)
                            } else {
                                (0usize, 1u32)
                            };
                            trial.trace.span(round_t0, out.0, "upload", Entity::Sat(r.member));
                            trial.registry.record_upload(
                                r.member,
                                out.0,
                                up_bytes * att as f64,
                                retx,
                                tree.hops[local],
                            );
                        }
                    }
                    out
                };
                // recycle the trained buffers exactly as the direct path
                // does below — pool bookkeeping only, no numeric effect
                for r in batch.iter_mut() {
                    let buf = std::mem::take(&mut r.params);
                    if resident {
                        let old = std::mem::replace(&mut trial.clients[r.member].params, buf);
                        pools.params.put(old);
                    } else {
                        pools.params.put(buf);
                    }
                }
                trial.trace.span(round_t0, t, "cluster_round", Entity::Cluster(c));
                trial.trace.instant(round_t0 + t, "merge", Entity::Cluster(c));
                trial.registry.record_merge(c);
                stage_time = stage_time.max(t); // clusters run in parallel
                trial.ledger.add_energy(e);
                continue;
            }
            // recovery plane: draw each member upload's retry outcome
            // before the wire encodes anything — a dropped contribution
            // must not consume its sender's error-feedback residual. Each
            // outcome is a pure function of `(seed, round, member)` through
            // its own `CORRUPT_SALT` stream, so it is worker-count
            // invariant and leaves every other draw stream untouched.
            let mut outcomes: Vec<TransferOutcome> = Vec::new();
            if noisy {
                outcomes.reserve(batch.len());
                let ps_pos = positions[topo.ps[c]];
                for (r, w) in batch.iter().zip(&work) {
                    let (_, t_com, _) = member_times(&trial.link, w, ps_pos, wire.up);
                    let eff_ber = cfg.ber + avail.ber[r.member];
                    let out = if eff_ber > 0.0 {
                        let mut rng = Rng::new(stream_seed(
                            cfg.seed ^ CORRUPT_SALT,
                            round as u64,
                            r.member as u64,
                        ));
                        transfer_with_retries(&retry, eff_ber, wire.up, t_com, &mut rng)
                    } else {
                        TransferOutcome { attempts: 1, wait_s: 0.0, delivered: true }
                    };
                    trial.ledger.add_retransmits(out.retransmits());
                    trial.ledger.add_corrupted_uploads(out.corrupted());
                    trial.ledger.add_retry_wait(out.wait_s);
                    outcomes.push(out);
                }
            }
            // wire plane: encode each member → PS upload in member order on
            // the coordinator thread (worker-count invariant), against the
            // cluster model the member trained from; what the encoder drops
            // folds into the member's persistent residual. The merge below
            // then sees exactly what the wire delivered.
            if compressing {
                for (i, r) in batch.iter_mut().enumerate() {
                    if noisy && !outcomes[i].delivered {
                        continue;
                    }
                    let res = residuals[r.member]
                        .get_or_insert_with(|| pools.params.take_zeroed());
                    encode_upload(
                        cfg.compress,
                        &mut r.params,
                        &topo.models[c],
                        res,
                        &mut wire_scratch,
                    );
                }
            }
            // every attempt retransmits the full payload and is billed on
            // the wire; the nominal round is exactly one attempt per member
            if noisy {
                let attempts: u32 = outcomes.iter().map(|o| o.attempts).sum();
                trial.ledger.add_wire_bytes(up_bytes * attempts as f64);
            } else {
                trial.ledger.add_wire_bytes(up_bytes * batch.len() as f64);
            }
            // line 13: aggregate at the PS under the strategy's weighting,
            // merging straight from the trained pooled buffers into the
            // recycled output, then swap it in: the displaced model vector
            // becomes the next merge's output
            let weights;
            let rows: Vec<&[f32]>;
            if noisy && outcomes.iter().any(|o| !o.delivered) {
                // graceful degradation: contributions whose retries
                // exhausted never reached the PS, so they are excluded
                // from the merge (their residuals untouched) and their
                // members keep the published cluster model — the ordinary
                // stale path, liveness preserved
                let mut kept_losses = Vec::with_capacity(batch.len());
                let mut kept_sizes = Vec::with_capacity(batch.len());
                let mut kept_rows: Vec<&[f32]> = Vec::with_capacity(batch.len());
                for (i, r) in batch.iter().enumerate() {
                    if outcomes[i].delivered {
                        kept_losses.push(losses[i]);
                        kept_sizes.push(sizes[i]);
                        kept_rows.push(r.params.as_slice());
                    }
                }
                weights = stages.cluster.member_weights(&kept_losses, &kept_sizes);
                rows = kept_rows;
            } else {
                weights = stages.cluster.member_weights(&losses, &sizes);
                rows = batch.iter().map(|r| r.params.as_slice()).collect();
            }
            if !rows.is_empty() {
                stages.cluster.merge(rt, &rows, &weights, &mut agg_buf)?;
                std::mem::swap(&mut topo.models[c], &mut agg_buf);
            }
            // recycle the trained buffers: resident mode swaps them into
            // the clients (the displaced vector returns to the pool); the
            // pooled mode returns them directly, keeping resident
            // parameter state at O(K), not O(N)
            for r in batch.iter_mut() {
                let buf = std::mem::take(&mut r.params);
                if resident {
                    let old = std::mem::replace(&mut trial.clients[r.member].params, buf);
                    pools.params.put(old);
                } else {
                    pools.params.put(buf);
                }
            }

            // Eq. 7 inner max + Eq. 8/9 energy for this cluster: the
            // closed-form fold and the event replay are bit-identical —
            // the queue only changes *how* the durations are ordered. A
            // noisy round folds inline instead (valid for both timelines
            // precisely because their nominal folds agree bitwise): each
            // upload stretches to its attempts plus backoff waits — the PS
            // barrier waits through every retry, delivered or not — uplink
            // energy bills once per attempt, and the closing broadcast
            // still reaches the farthest member, dropped senders included.
            let (t, e) = if noisy {
                let ps_pos = positions[topo.ps[c]];
                let mut t_max = 0.0f64;
                let mut e_total = 0.0f64;
                let mut far: Option<f64> = None;
                for (w, out) in work.iter().zip(&outcomes) {
                    let (t_cmp, t_com, d) = member_times(&trial.link, w, ps_pos, wire.up);
                    t_max = t_max.max(t_cmp + out.total_time(t_com));
                    e_total += trial.energy.tx_energy(wire.up, d) * out.attempts as f64
                        + trial.energy.compute_energy(w.samples, w.cpu_hz)
                        + trial.energy.tx_energy(wire.down, d);
                    far = Some(far.map_or(d, |a: f64| a.max(d)));
                }
                if let Some(d) = far {
                    t_max += trial.link.comm_time(wire.down, d);
                }
                (t_max, e_total)
            } else {
                match cfg.timeline {
                    Timeline::Analytic => cluster_round_with(
                        &engine,
                        &trial.link,
                        &trial.energy,
                        &work,
                        positions[topo.ps[c]],
                        wire,
                    ),
                    Timeline::Event => cluster_round_events(
                        &mut queue,
                        &trial.link,
                        &trial.energy,
                        &work,
                        c,
                        positions[topo.ps[c]],
                        wire,
                    ),
                }
            };
            // telemetry plane: per-member upload spans (compute offset +
            // transfer incl. retries), retry instants on the deterministic
            // backoff timeline, and the cluster merge — re-derived from the
            // same member_times the fold used, only when a sink is enabled
            if trial.trace.is_enabled() || trial.registry.is_enabled() {
                trial.trace.span(round_t0, t, "cluster_round", Entity::Cluster(c));
                let ps_pos = positions[topo.ps[c]];
                for (i, (r, w)) in batch.iter().zip(&work).enumerate() {
                    let (t_cmp, t_com, _) = member_times(&trial.link, w, ps_pos, wire.up);
                    let (dur, retx, att) = if noisy {
                        let o = &outcomes[i];
                        (o.total_time(t_com), o.retransmits(), o.attempts)
                    } else {
                        (t_com, 0usize, 1u32)
                    };
                    trial
                        .trace
                        .span(round_t0 + t_cmp, dur, "upload", Entity::Sat(r.member));
                    for a in 1..att {
                        trial.trace.instant(
                            round_t0 + t_cmp + retry.attempt_offset(a, t_com),
                            "retry",
                            Entity::Sat(r.member),
                        );
                    }
                    trial
                        .registry
                        .record_upload(r.member, dur, up_bytes * att as f64, retx, 1);
                }
                trial.trace.instant(round_t0 + t, "merge", Entity::Cluster(c));
                trial.registry.record_merge(c);
            }
            stage_time = stage_time.max(t); // clusters run in parallel
            trial.ledger.add_energy(e);
        }
        let stage_end = trial.clock.now() + stage_time;
        trial.clock.advance_to(stage_end);
        trial.ledger.advance_to(stage_end);
        trial.trace.span(round_t0, stage_time, "cluster_stage", Entity::Run);

        // ---- re-clustering check (lines 14–18) ----
        let mut reclustered = false;
        if policy.should_recluster(&churn.stats) {
            let _p = Scope::new(Phase::Recluster);
            reclustered = true;
            trial.ledger.reclusters += 1;
            trial.trace.instant(trial.clock.now(), "recluster", Entity::Run);
            let old_assignment = topo.assignment.clone();
            let old_models = topo.models.clone();
            // topology rebuilds at the post-aggregation epoch: re-sync the
            // constellation index to it before the k-means pass
            if let Some(g) = geo.as_mut() {
                g.refresh(&trial.constellation, trial.clock.now());
            }
            let mut new_topo =
                build_topology(trial, &strategy, &global, geo.as_ref().map(|g| g.grid()))?;
            new_topo.assignment = align_labels(&old_assignment, &new_topo.assignment, k);
            // carry each cluster's model forward to its aligned successor
            new_topo.models = old_models;
            // re-derive PS for the aligned labels under the strategy
            let changed = changed_members(&old_assignment, &new_topo.assignment);
            info!(
                "round {round}: re-clustering ({} members moved, strategy {})",
                changed.len(),
                strategy.name
            );
            for &m in &changed {
                let dest = new_topo.assignment[m];
                if strategy.maml_warmstart {
                    // §III-C: inherit the new cluster head's model, adapt
                    // with one MAML step (support = head's data, query =
                    // own) — on the member's resident buffer, or on a
                    // pooled one in the bounded-memory mode
                    let head = new_topo.ps[dest];
                    batch_buf.fill_support(&trial.clients[head].shard, &mut trial.rng);
                    batch_buf.fill_query(&trial.clients[m].shard, &mut trial.rng);
                    let mut pooled: Option<Vec<f32>> = None;
                    let params: &mut Vec<f32> = if resident {
                        trial.clients[m].params.clone_from(&new_topo.models[dest]);
                        &mut trial.clients[m].params
                    } else {
                        pooled = Some(pools.params.take_copy(&new_topo.models[dest]));
                        pooled.as_mut().unwrap()
                    };
                    let _qloss = rt.maml_step_into(
                        params,
                        &batch_buf.x1, &batch_buf.y1, &batch_buf.x2, &batch_buf.y2,
                        cfg.maml_alpha,
                        cfg.maml_beta,
                        &mut batch_buf.scratch,
                    )?;
                    if let Some(buf) = pooled {
                        pools.params.put(buf);
                    }
                    trial.ledger.maml_adaptations += 1;
                    // adaptation cost: one support-batch transfer + one
                    // batch of compute at the member
                    let d = positions[m].dist(positions[head]);
                    let batch_bits = maml_batch_bits(rt);
                    trial
                        .ledger
                        .add_energy(trial.energy.tx_energy(batch_bits, d));
                    trial.ledger.add_energy(
                        trial
                            .energy
                            .compute_energy(2 * rt.spec.batch, trial.clients[m].cpu_hz),
                    );
                } else if resident {
                    // baselines: cold reset to the destination cluster
                    // model (the pooled mode has no resident member state
                    // to reset — members start every round from their
                    // cluster model regardless)
                    trial.clients[m].params.clone_from(&new_topo.models[dest]);
                }
            }
            topo = new_topo;
            // wire plane: residuals are deltas against base models the
            // re-clustering just replaced — flush them to the pool so every
            // sender restarts its error feedback from zero, exactly like
            // parked buffered contributions
            for slot in residuals
                .iter_mut()
                .chain(ground_residuals.iter_mut())
                .chain(relay_residuals.iter_mut())
            {
                if let Some(buf) = slot.take() {
                    pools.params.put(buf);
                }
            }
            // cluster sizes moved: re-warm the pool to the new maximum
            pools.params.ensure_free(max_cluster_size(&topo, k));
        }

        // ---- ground station aggregation stage (lines 21–24) ----
        if round % cfg.ground_every == 0 {
            let _p = Scope::new(Phase::Ground);
            // recovery plane: crashed PS processes fail over before the
            // pass plan forms — the round's member updates (everything a
            // non-outaged member sent this round) migrate to the promoted
            // backup, billed as one re-upload each (see [`fail_over_ps`])
            if avail.ps_failed.iter().any(|&p| p) {
                let members_of = topo.clusters(k);
                let dt = fail_over_ps(
                    trial,
                    &mut topo,
                    &members_of,
                    &avail,
                    &positions,
                    up_bytes,
                    wire.up,
                    &|m| !outage.contains(&m),
                );
                if dt > 0.0 {
                    let t_end = trial.clock.now() + dt;
                    trial.clock.advance_to(t_end);
                    trial.ledger.advance_to(t_end);
                }
            }
            // scenario plane: dark stations drop out of the pass plan and a
            // hard-failed/eclipsed PS cannot serve as its cluster's hub —
            // nor can a crashed PS process that found no live backup; all
            // of these make the affected cluster(s) keep a stale model
            // until a later pass, and a round with no live station (or no
            // live PS) skips the pass entirely
            let live: Vec<usize> = (0..topo.ps.len())
                .filter(|&c| !avail.unreachable[topo.ps[c]] && !avail.ps_failed[topo.ps[c]])
                .collect();
            trial.ledger.add_stale_passes(topo.ps.len() - live.len());
            let any_station_down = avail.ground_down.iter().any(|&d| d);
            let all_stations_down = any_station_down && avail.ground_down.iter().all(|&d| d);
            if all_stations_down || live.is_empty() {
                trial.ledger.add_stale_passes(live.len());
            } else {
                let live_stations: Vec<GroundStation>;
                let stations: &[GroundStation] = if any_station_down {
                    live_stations = trial
                        .ground
                        .iter()
                        .zip(&avail.ground_down)
                        .filter(|(_, &down)| !down)
                        .map(|(g, _)| g.clone())
                        .collect();
                    &live_stations
                } else {
                    &trial.ground
                };
                let t = trial.clock.now();
                let ctx = GroundCtx {
                    link: &trial.link,
                    energy: &trial.energy,
                    stations,
                    constellation: &trial.constellation,
                };
                // the stage sees only the live PSes; its cluster indices
                // are positions in `live_ps`, mapped back through `live`
                let live_ps: Vec<usize> = live.iter().map(|&c| topo.ps[c]).collect();
                let out = stages.ground.exchange(&ctx, &live_ps, t, wire);
                let exchanged: Vec<usize> = out.exchanged.iter().map(|&i| live[i]).collect();
                if !exchanged.is_empty() {
                    // Eq. 5 over the participating clusters, by data size
                    let members_of = topo.clusters(k);
                    let sizes: Vec<usize> = exchanged
                        .iter()
                        .map(|&c| {
                            members_of[c]
                                .iter()
                                .map(|&m| trial.clients[m].data_size())
                                .sum()
                        })
                        .collect();
                    let weights = fedavg_weights(&sizes);
                    // wire plane: each PS → GS upload is encoded against the
                    // ground segment's current global model with a per-
                    // cluster-slot residual, so the global aggregate sees
                    // exactly what the wire delivered
                    let mut uploads: Vec<Vec<f32>> = Vec::new();
                    if compressing {
                        for &c in &exchanged {
                            let mut up = pools.params.take_copy(&topo.models[c]);
                            let res = ground_residuals[c]
                                .get_or_insert_with(|| pools.params.take_zeroed());
                            encode_upload(cfg.compress, &mut up, &global, res, &mut wire_scratch);
                            uploads.push(up);
                        }
                    }
                    let rows: Vec<&[f32]> = if compressing {
                        uploads.iter().map(|u| u.as_slice()).collect()
                    } else {
                        exchanged.iter().map(|&c| topo.models[c].as_slice()).collect()
                    };
                    // aggregate straight into the persistent global buffer
                    aggregate(rt, &rows, &weights, &mut global)?;
                    drop(rows);
                    for up in uploads {
                        pools.params.put(up);
                    }
                    trial.ledger.add_wire_bytes(up_bytes * exchanged.len() as f64);
                    // broadcast back to participating clusters; stale
                    // clusters keep training on their own model until a
                    // later pass
                    for &c in &exchanged {
                        topo.models[c].clone_from(&global);
                    }
                }
                // Eq. 7 outer sum over the served PS↔GS links, plus (event
                // timeline) the window waits the pass spent blocked
                trial.ledger.add_energy(out.energy_j);
                trial.ledger.add_stale_passes(out.stale.len());
                trial.ledger.add_ground_wait(out.wait_s);
                let pass_end = t + out.duration_s;
                // telemetry plane: the pass span on the station's track,
                // window open/close instants mapped back through `live`
                if trial.trace.is_enabled() || trial.registry.is_enabled() {
                    trial
                        .trace
                        .span(t, out.duration_s, "ground_pass", Entity::Ground(out.station));
                    for &(i, open, close) in &out.windows {
                        let cg = live[i];
                        trial.trace.instant(t + open, "window_open", Entity::Cluster(cg));
                        trial.trace.instant(t + close, "window_close", Entity::Cluster(cg));
                        trial.registry.record_window(cg, close - open);
                    }
                    if !exchanged.is_empty() {
                        trial
                            .trace
                            .instant(pass_end, "global_merge", Entity::Ground(out.station));
                    }
                }
                trial.clock.advance_to(pass_end);
                trial.ledger.advance_to(pass_end);
            }
        }

        // ---- evaluation / convergence check ----
        // The evaluated model is the *logical* global: the data-size-
        // weighted aggregate of the live cluster models (what the next
        // ground pass would produce). Pure instrumentation — no ledger cost.
        trial
            .trace
            .span(round_t0, trial.clock.now() - round_t0, "round", Entity::Run);
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            let _p = Scope::new(Phase::Eval);
            let sizes: Vec<usize> = topo
                .clusters(k)
                .iter()
                .map(|ms| ms.iter().map(|&m| trial.clients[m].data_size()).sum())
                .collect();
            let weights = fedavg_weights(&sizes);
            let rows: Vec<&[f32]> = topo.models.iter().map(|m| m.as_slice()).collect();
            aggregate(rt, &rows, &weights, &mut global)?;
            let eval =
                evaluate_with(rt, &global, &trial.test, cfg.eval_batches, &mut eval_scratch)?;
            trial
                .ledger
                .record(round, eval.accuracy, eval.loss, reclustered);
            trial.trace.instant(trial.clock.now(), "eval", Entity::Run);
            if let Some(target) = cfg.target_accuracy {
                if eval.accuracy >= target && converged_at.is_none() {
                    converged_at =
                        Some((round, trial.ledger.time_s, trial.ledger.energy_j));
                    break;
                }
            }
        }
    }

    // wire plane: residual buffers return to the pool with the run
    for slot in residuals
        .iter_mut()
        .chain(ground_residuals.iter_mut())
        .chain(relay_residuals.iter_mut())
    {
        if let Some(buf) = slot.take() {
            pools.params.put(buf);
        }
    }

    let final_accuracy = trial.ledger.best_accuracy();
    Ok(RunResult {
        name: strategy.name,
        ledger: std::mem::take(&mut trial.ledger),
        converged_at,
        final_accuracy,
    })
}

/// One member contribution parked at (or in flight to) its cluster PS
/// under `--aggregation buffered|async`.
struct Contribution {
    /// Trained parameters — a pooled buffer, returned on merge or flush.
    params: Vec<f32>,
    /// Mean training loss (Eq. 12 quality weighting input).
    loss: f32,
    /// Shard size at training time (Eq. 5 FedAvg weighting input).
    size: usize,
    /// Slant range to the PS at training time (broadcast billing).
    dist: f64,
    /// Absolute sim time the upload reached the PS.
    arrival: f64,
    /// Cluster-model version the member trained from, and that version's
    /// publish timestamp — the two staleness measures (integer τ and
    /// publish-lag seconds).
    based_on_ver: u64,
    based_on_t: f64,
}

/// Merge every parked contribution of `members`' cluster at stage offset
/// `at`: staleness-composed weights, fold **in member order** (the same
/// order as the sync merge — the hinge of the degeneracy differential),
/// one PS broadcast to the farthest merged member, ledger accounting, and
/// buffer recycling. Returns the cluster-stage offset at which the new
/// version is published.
#[allow(clippy::too_many_arguments)]
fn merge_parked(
    rt: &crate::runtime::ModelRuntime,
    stage: &dyn ClusterAggregateStage,
    link: &crate::network::LinkModel,
    ledger: &mut crate::metrics::Ledger,
    tracer: &mut Tracer,
    registry: &mut MetricsRegistry,
    pools: &RoundPools,
    cluster: usize,
    members: &[usize],
    parked: &mut [Option<Contribution>],
    model: &mut Vec<f32>,
    agg_buf: &mut Vec<f32>,
    version: &mut u64,
    pub_time: &mut f64,
    beta: f64,
    down_bits: f64,
    stage_start: f64,
    at: f64,
) -> Result<f64> {
    let mut merged: Vec<usize> = Vec::new();
    let mut losses: Vec<f32> = Vec::new();
    let mut sizes: Vec<usize> = Vec::new();
    let mut staleness: Vec<f64> = Vec::new();
    let mut far: Option<f64> = None;
    for &m in members {
        let Some(ct) = parked[m].as_ref() else { continue };
        merged.push(m);
        losses.push(ct.loss);
        sizes.push(ct.size);
        staleness.push((*version - ct.based_on_ver) as f64);
        far = Some(far.map_or(ct.dist, |a: f64| a.max(ct.dist)));
    }
    debug_assert!(!merged.is_empty(), "merge of an empty buffer");
    let weights = stage.member_weights_stale(&losses, &sizes, &staleness, beta);
    let rows: Vec<&[f32]> = merged
        .iter()
        .map(|&m| parked[m].as_ref().unwrap().params.as_slice())
        .collect();
    stage.merge(rt, &rows, &weights, agg_buf)?;
    drop(rows);
    std::mem::swap(model, agg_buf);
    let end = at + link.comm_time(down_bits, far.expect("merge with no members"));
    let now = stage_start + at;
    for (i, &m) in merged.iter().enumerate() {
        let ct = parked[m].take().expect("parked contribution vanished");
        // buffer-wait idleness (arrival → merge) and model staleness
        // (publish lag of the version the member trained from); both are
        // exact zeros for a same-instant fresh contribution
        ledger.add_idle(now - ct.arrival);
        ledger.add_staleness(*pub_time - ct.based_on_t, staleness[i] as usize);
        registry.record_staleness(cluster, staleness[i]);
        pools.params.put(ct.params);
    }
    ledger.add_buffered_merge();
    tracer.instant(now, "merge", Entity::Cluster(cluster));
    registry.record_merge(cluster);
    *version += 1;
    *pub_time = stage_start + end;
    Ok(end)
}

/// Algorithm 1 under `--aggregation buffered|async`: the intra-cluster
/// barrier is replaced by an event-driven merge schedule on the
/// `sim::events` queue. Members upload the moment compute + uplink
/// finishes ([`Event::UploadReady`]); the PS merges FedBuff-style when the
/// buffer reaches its goal count ([`Event::MergeDue`], goal =
/// `--buffer-size`, 0 = the cluster's member count), weighting each
/// contribution by the strategy weights composed with the `1/(1+τ)^β`
/// staleness discount. Under-goal leftovers merge at the round barrier
/// when no goal fired (liveness); otherwise they stay parked — their
/// members skip the next training round (genuine staleness ≥ 1 plus
/// buffer-wait idleness, the FedSpace tradeoff). `async` instead folds
/// every arrival into the cluster model immediately, damped by data share
/// × staleness discount. Evaluation is mediated by [`Event::EvalDue`]
/// pops rather than the round index directly.
///
/// Determinism matches the sync path: arrivals are scheduled in member
/// order, ties pop FIFO, merges fold in member order, and with
/// always-visible geometry + the auto buffer goal the buffered schedule
/// degenerates to the sync fold bit-for-bit (every merge is all-fresh, so
/// the staleness composition returns the sync weights bitwise unchanged).
fn run_staged_buffered(trial: &mut Trial, strategy: Strategy, stages: &Stages) -> Result<RunResult> {
    let cfg = trial.cfg.clone();
    let rt = trial.rt;
    let k = cfg.clusters;
    // wire plane (see `run_staged`): compressed uplink, dense downlink,
    // error-feedback residuals per member and per cluster slot
    let wire = cfg.compress.wire(rt.spec.param_count);
    let up_bytes = trial.link.upload_bytes(&cfg.compress.payload(rt.spec.param_count));
    let compressing = !cfg.compress.is_none();
    let retry = cfg.retry_policy();
    let mut wire_scratch = CompressScratch::new();
    let mut residuals: Vec<Option<Vec<f32>>> = if compressing {
        (0..trial.clients.len()).map(|_| None).collect()
    } else {
        Vec::new()
    };
    let mut ground_residuals: Vec<Option<Vec<f32>>> = if compressing {
        (0..k).map(|_| None).collect()
    } else {
        Vec::new()
    };
    let beta = cfg.staleness_beta;
    let policy = ReclusterPolicy::new(cfg.recluster_threshold)?;
    let engine = Engine::new(cfg.workers);
    let pools = RoundPools::new(rt);
    let mut queue = EventQueue::new(); // per-cluster arrival/merge schedule
    let mut eval_queue = EventQueue::new();
    let mut agg_buf: Vec<f32> = Vec::new();
    let mut eval_scratch = HostScratch::new();

    let mut geo: Option<ConstellationIndex> = if cfg.spatial_index {
        Some(ConstellationIndex::new(cfg.index_bands))
    } else {
        None
    };

    let global0 = trial.init.clone();
    if let Some(g) = geo.as_mut() {
        g.refresh(&trial.constellation, trial.clock.now());
    }
    let mut topo = build_topology(trial, &strategy, &global0, geo.as_ref().map(|g| g.grid()))?;
    // an auto goal (and the async fold) flushes every buffer by the round
    // barrier, so pooled demand stays the largest cluster exactly as in
    // sync mode; an explicit sub-cluster goal parks contributions across
    // rounds, so the warm pool must cover the whole constellation once
    let warm = if cfg.buffer_size == 0 || cfg.aggregation == AggregationMode::Async {
        max_cluster_size(&topo, k)
    } else {
        trial.clients.len()
    };
    pools.params.ensure_free(warm);
    let mut global = global0;
    let mut converged_at = None;
    let mut batch_buf = BatchBuf::new(rt);
    let mut jobs: Vec<(usize, usize)> = Vec::new(); // (member, cluster)
    // routing plane scratch (see `run_staged`). Ring all-reduce needs the
    // sync round barrier, so under buffered/async timelines `isl:ring`
    // routes uploads over the same store-and-forward tree as `isl` (the
    // ring stage still pins the parked-merge fold order). Contributions
    // arrive at the PS individually — there is no barrier for relays to
    // pool on — so buffered routing forwards without partial aggregation,
    // and PS fail-over re-uploads stay direct (the emergency hop).
    let routing = cfg.routing != RoutingMode::Direct;
    let mut node_ids: Vec<usize> = Vec::new();
    let mut neigh_scratch: Vec<usize> = Vec::new();
    let mut path_scratch: Vec<usize> = Vec::new();

    // aggregation-plane bookkeeping: per-cluster model version + publish
    // time, per-member in-flight uploads and parked PS buffers
    let mut version = vec![0u64; k];
    let mut pub_time = vec![0.0f64; k];
    let mut in_flight: Vec<Option<Contribution>> =
        (0..trial.clients.len()).map(|_| None).collect();
    let mut parked: Vec<Option<Contribution>> =
        (0..trial.clients.len()).map(|_| None).collect();

    for round in 1..=cfg.rounds {
        let positions = trial.positions();
        let avail = trial.scenario.advance_round(round as u64, &positions);
        trial.ledger.add_faults(avail.faults_injected);
        if let Some(g) = geo.as_mut() {
            g.refresh_positions(&positions, trial.clock.now());
        }
        let churn = trial.mobility.churn_with(
            &trial.constellation,
            &topo.assignment,
            &topo.centroids_km,
            trial.clock.now(),
            &avail.unreachable,
            geo.as_ref().map(|g| g.grid()),
        );
        let outage: std::collections::BTreeSet<usize> = churn.outages.iter().copied().collect();
        // recovery plane (see `run_staged`): zero effective BER skips the
        // retry machinery entirely, keeping the nominal schedule
        // bit-identical to the pre-recovery accounting
        let noisy = cfg.ber > 0.0 || avail.ber.iter().any(|&b| b > 0.0);

        // ---- local training + event-driven staleness-weighted merges ----
        let clusters = topo.clusters(k);
        let mut stage_time = 0.0f64;
        let stage_start = trial.clock.now();
        for (c, members) in clusters.iter().enumerate() {
            // members with a contribution still parked at the PS skip
            // training this round — their update is queued, not lost
            jobs.clear();
            for &m in members {
                if !outage.contains(&m) && parked[m].is_none() {
                    jobs.push((m, c));
                }
            }
            let parked_count = members.iter().filter(|&&m| parked[m].is_some()).count();
            if jobs.is_empty() && parked_count == 0 {
                continue;
            }
            let goal = if cfg.buffer_size == 0 {
                members.len()
            } else {
                cfg.buffer_size
            };

            debug_assert!(queue.is_empty(), "arrival schedule leaked across clusters");
            let mut async_total = 0usize; // async data-share denominator
            if !jobs.is_empty() {
                let mut batch = {
                    let _p = Scope::new(Phase::LocalTrain);
                    stages.local.train(
                        &engine,
                        rt,
                        &cfg,
                        &trial.clients,
                        &topo.models,
                        &jobs,
                        round as u64,
                        &pools,
                    )?
                };
                // schedule every upload at its compute+uplink offset (in
                // member order, so ties pop in member order) and bill
                // energy with exactly the sync path's per-member terms
                let mut e_total = 0.0f64;
                let mut retransmit_count = 0usize;
                // routing plane: this epoch's upload tree over the active
                // members + PS (flat trees leave every member on the
                // direct expressions below, bit-identical to `--routing
                // direct`)
                let route_tree: Option<RouteTree> = {
                    let _p = Scope::new(Phase::Routing);
                    routing.then(|| {
                        node_ids.clear();
                        node_ids.extend(jobs.iter().map(|&(mm, _)| mm));
                        if node_ids.binary_search(&topo.ps[c]).is_err() {
                            node_ids.push(topo.ps[c]);
                            node_ids.sort_unstable();
                        }
                        let root = node_ids
                            .binary_search(&topo.ps[c])
                            .expect("PS present in its own route tree");
                        build_route_tree(
                            &node_ids,
                            root,
                            cfg.isl_range_km * 1e3,
                            &positions,
                            geo.as_ref().map(|g| g.grid()),
                            &|g| avail.link_factor[g] < 1.0,
                            &mut neigh_scratch,
                        )
                    })
                };
                for r in batch.iter_mut() {
                    let m = r.member;
                    debug_assert_eq!(r.cluster, c, "gather out of cluster order");
                    trial.clients[m].last_loss = r.mean_loss;
                    trial.clients[m].rounds_trained += 1;
                    let cpu_hz = throttle_cpu(
                        &trial.link,
                        &mut trial.ledger,
                        r.samples,
                        trial.clients[m].cpu_hz,
                        avail.compute_slowdown[m],
                    );
                    let work = MemberWork {
                        samples: r.samples,
                        cpu_hz,
                        pos: positions[m],
                        link_factor: avail.link_factor[m],
                    };
                    let (t_cmp, t_com, d) =
                        member_times(&trial.link, &work, positions[topo.ps[c]], wire.up);
                    // routing plane: a multi-hop member's upload walks its
                    // BFS path to the PS hop by hop — per-edge uplink
                    // times, retries, and billing — then parks exactly like
                    // a direct arrival. The broadcast leg keeps the direct
                    // slant range (the PS publishes downward one hop, as in
                    // the sync routed round's closing broadcast).
                    if let Some(tree) = route_tree.as_ref() {
                        let local = node_ids
                            .binary_search(&m)
                            .expect("trained member missing from its route tree");
                        if tree.hops[local] > 1 {
                            tree.path_senders(local, &mut path_scratch);
                            let eff_ber = if noisy { cfg.ber + avail.ber[m] } else { 0.0 };
                            let mut rng = (eff_ber > 0.0).then(|| {
                                Rng::new(stream_seed(
                                    cfg.seed ^ RELAY_CORRUPT_SALT,
                                    round as u64,
                                    m as u64,
                                ))
                            });
                            let mut t_path = 0.0f64;
                            let mut sends = 0usize;
                            let mut delivered = true;
                            for &s in path_scratch.iter() {
                                let sg = node_ids[s];
                                let pg = node_ids[tree.parent[s]];
                                let d_edge = positions[sg].dist(positions[pg]);
                                let t_edge = trial.link.comm_time_scaled(
                                    wire.up,
                                    d_edge,
                                    avail.link_factor[sg],
                                );
                                trial.ledger.add_route_hops(1);
                                if let Some(rng) = rng.as_mut() {
                                    let out = transfer_with_retries(
                                        &retry, eff_ber, wire.up, t_edge, rng,
                                    );
                                    trial.ledger.add_retransmits(out.retransmits());
                                    trial.ledger.add_corrupted_uploads(out.corrupted());
                                    trial.ledger.add_retry_wait(out.wait_s);
                                    sends += out.attempts as usize;
                                    e_total += trial.energy.tx_energy(wire.up, d_edge)
                                        * out.attempts as f64;
                                    t_path += out.total_time(t_edge);
                                    if !out.delivered {
                                        // a payload lost mid-route never
                                        // reaches the buffer; later edges
                                        // never transmit
                                        delivered = false;
                                        break;
                                    }
                                } else {
                                    sends += 1;
                                    e_total += trial.energy.tx_energy(wire.up, d_edge);
                                    t_path += t_edge;
                                }
                            }
                            // each edge attempt is one full payload on the
                            // wire; the shared counter already bills one
                            // per batch member
                            retransmit_count += sends - 1;
                            e_total += trial.energy.compute_energy(r.samples, cpu_hz)
                                + trial.energy.tx_energy(wire.down, d);
                            if !delivered {
                                pools.params.put(std::mem::take(&mut r.params));
                                continue;
                            }
                            let arrives = t_cmp + t_path;
                            queue.push(arrives, Event::UploadReady { member: m, cluster: c });
                            if trial.trace.is_enabled() || trial.registry.is_enabled() {
                                trial.trace.span(
                                    stage_start + t_cmp,
                                    arrives - t_cmp,
                                    "upload",
                                    Entity::Sat(m),
                                );
                                for &s in path_scratch.iter().skip(1) {
                                    trial.trace.instant(
                                        stage_start + t_cmp,
                                        "relay_hop",
                                        Entity::Sat(node_ids[s]),
                                    );
                                }
                                trial.registry.record_upload(
                                    m,
                                    arrives - t_cmp,
                                    up_bytes * sends as f64,
                                    sends - path_scratch.len(),
                                    tree.hops[local],
                                );
                            }
                            async_total += trial.clients[m].data_size();
                            if compressing {
                                let res = residuals[m]
                                    .get_or_insert_with(|| pools.params.take_zeroed());
                                encode_upload(
                                    cfg.compress,
                                    &mut r.params,
                                    &topo.models[c],
                                    res,
                                    &mut wire_scratch,
                                );
                            }
                            in_flight[m] = Some(Contribution {
                                params: std::mem::take(&mut r.params),
                                loss: r.mean_loss,
                                size: trial.clients[m].data_size(),
                                dist: d,
                                arrival: stage_start + arrives,
                                based_on_ver: version[c],
                                based_on_t: pub_time[c],
                            });
                            continue;
                        }
                    }
                    // recovery plane: a noisy upload stretches to its
                    // attempts plus backoff waits before it can arrive;
                    // one whose retries exhaust never enters the buffer —
                    // the member keeps the published cluster model (the
                    // ordinary stale path) while its compute and every
                    // attempt's uplink still bill through Eq. 8/9
                    let eff_ber = if noisy { cfg.ber + avail.ber[m] } else { 0.0 };
                    let mut m_retx = 0usize;
                    let arrives = if eff_ber > 0.0 {
                        let mut rng = Rng::new(stream_seed(
                            cfg.seed ^ CORRUPT_SALT,
                            round as u64,
                            m as u64,
                        ));
                        let out =
                            transfer_with_retries(&retry, eff_ber, wire.up, t_com, &mut rng);
                        trial.ledger.add_retransmits(out.retransmits());
                        trial.ledger.add_corrupted_uploads(out.corrupted());
                        trial.ledger.add_retry_wait(out.wait_s);
                        retransmit_count += out.retransmits();
                        m_retx = out.retransmits();
                        e_total += trial.energy.tx_energy(wire.up, d) * out.retransmits() as f64;
                        if !out.delivered {
                            e_total += trial.energy.tx_energy(wire.up, d)
                                + trial.energy.compute_energy(r.samples, cpu_hz)
                                + trial.energy.tx_energy(wire.down, d);
                            pools.params.put(std::mem::take(&mut r.params));
                            continue;
                        }
                        t_cmp + out.total_time(t_com)
                    } else {
                        t_cmp + t_com
                    };
                    queue.push(arrives, Event::UploadReady { member: m, cluster: c });
                    if trial.trace.is_enabled() || trial.registry.is_enabled() {
                        trial.trace.span(
                            stage_start + t_cmp,
                            arrives - t_cmp,
                            "upload",
                            Entity::Sat(m),
                        );
                        for a in 1..=(m_retx as u32) {
                            trial.trace.instant(
                                stage_start + t_cmp + retry.attempt_offset(a, t_com),
                                "retry",
                                Entity::Sat(m),
                            );
                        }
                        trial.registry.record_upload(
                            m,
                            arrives - t_cmp,
                            up_bytes * (1 + m_retx) as f64,
                            m_retx,
                            1,
                        );
                    }
                    e_total += trial.energy.tx_energy(wire.up, d)
                        + trial.energy.compute_energy(r.samples, cpu_hz)
                        + trial.energy.tx_energy(wire.down, d);
                    async_total += trial.clients[m].data_size();
                    // wire plane: encode at send time, against the cluster
                    // model the member trained from — the contribution
                    // parked at (or folded into) the PS is what the wire
                    // delivered, however stale it is when merged
                    if compressing {
                        let res = residuals[m].get_or_insert_with(|| pools.params.take_zeroed());
                        encode_upload(
                            cfg.compress,
                            &mut r.params,
                            &topo.models[c],
                            res,
                            &mut wire_scratch,
                        );
                    }
                    in_flight[m] = Some(Contribution {
                        params: std::mem::take(&mut r.params),
                        loss: r.mean_loss,
                        size: trial.clients[m].data_size(),
                        dist: d,
                        arrival: stage_start + arrives,
                        based_on_ver: version[c],
                        based_on_t: pub_time[c],
                    });
                }
                trial
                    .ledger
                    .add_wire_bytes(up_bytes * (batch.len() + retransmit_count) as f64);
                trial.ledger.add_energy(e_total);
            }

            let mut cluster_time = 0.0f64;
            let mut last_arrival = 0.0f64;
            let _p_agg = Scope::new(Phase::ClusterAgg);
            match cfg.aggregation {
                AggregationMode::Buffered => {
                    let mut buf_count = parked_count;
                    let mut merges_round = 0usize;
                    // a backlog can already satisfy the goal (membership
                    // shrank, goal lowered): merge before any new arrival
                    if buf_count >= goal {
                        queue.push(0.0, Event::MergeDue { cluster: c });
                    }
                    while let Some(ev) = queue.pop() {
                        // telemetry plane: one instant per event pop, named
                        // by the popped variant
                        if trial.trace.is_enabled() {
                            let ent = match ev.event {
                                Event::UploadReady { member, .. } => Entity::Sat(member),
                                _ => Entity::Cluster(c),
                            };
                            trial.trace.instant(stage_start + ev.at, ev.event.kind(), ent);
                        }
                        match ev.event {
                            Event::UploadReady { member, .. } => {
                                parked[member] = in_flight[member].take();
                                debug_assert!(parked[member].is_some());
                                buf_count += 1;
                                last_arrival = last_arrival.max(ev.at);
                                if buf_count == goal {
                                    queue.push(ev.at, Event::MergeDue { cluster: c });
                                }
                            }
                            Event::MergeDue { .. } => {
                                if buf_count == 0 {
                                    continue;
                                }
                                let end = merge_parked(
                                    rt,
                                    stages.cluster.as_ref(),
                                    &trial.link,
                                    &mut trial.ledger,
                                    &mut trial.trace,
                                    &mut trial.registry,
                                    &pools,
                                    c,
                                    members,
                                    &mut parked,
                                    &mut topo.models[c],
                                    &mut agg_buf,
                                    &mut version[c],
                                    &mut pub_time[c],
                                    beta,
                                    wire.down,
                                    stage_start,
                                    ev.at,
                                )?;
                                cluster_time = cluster_time.max(end);
                                merges_round += 1;
                                buf_count = 0;
                            }
                            _ => unreachable!("unexpected event in the buffered drain"),
                        }
                    }
                    // liveness at the round barrier: when no goal fired,
                    // the under-goal buffer merges at its last arrival —
                    // which is exactly the sync barrier's fold instant
                    if merges_round == 0 && buf_count > 0 {
                        let end = merge_parked(
                            rt,
                            stages.cluster.as_ref(),
                            &trial.link,
                            &mut trial.ledger,
                            &mut trial.trace,
                            &mut trial.registry,
                            &pools,
                            c,
                            members,
                            &mut parked,
                            &mut topo.models[c],
                            &mut agg_buf,
                            &mut version[c],
                            &mut pub_time[c],
                            beta,
                            wire.down,
                            stage_start,
                            last_arrival,
                        )?;
                        cluster_time = cluster_time.max(end);
                    }
                }
                AggregationMode::Async => {
                    // FedAsync-style: every arrival folds into the cluster
                    // model immediately, damped by data share × staleness
                    // discount; an arrival of the model itself is an exact
                    // fixed point (`fold_stale` adds a zero delta)
                    let mut far: Option<f64> = None;
                    while let Some(ev) = queue.pop() {
                        let Event::UploadReady { member, .. } = ev.event else {
                            unreachable!("unexpected event in the async drain");
                        };
                        trial.trace.instant(
                            stage_start + ev.at,
                            ev.event.kind(),
                            Entity::Sat(member),
                        );
                        let ct = in_flight[member]
                            .take()
                            .expect("async upload without a contribution");
                        let tau = version[c] - ct.based_on_ver;
                        let share = ct.size as f32 / async_total as f32;
                        let step = share * staleness_weight(tau as f64, beta);
                        fold_stale(&mut topo.models[c], &ct.params, step);
                        version[c] += 1;
                        trial.ledger.add_buffered_merge();
                        trial.ledger.add_staleness(pub_time[c] - ct.based_on_t, tau as usize);
                        trial.trace.instant(stage_start + ev.at, "merge", Entity::Cluster(c));
                        trial.registry.record_merge(c);
                        trial.registry.record_staleness(c, tau as f64);
                        pub_time[c] = stage_start + ev.at;
                        last_arrival = last_arrival.max(ev.at);
                        far = Some(far.map_or(ct.dist, |a: f64| a.max(ct.dist)));
                        pools.params.put(ct.params);
                    }
                    // the PS announces the final round state once, to the
                    // farthest contributing member
                    cluster_time = match far {
                        Some(d) => last_arrival + trial.link.comm_time(wire.down, d),
                        None => 0.0,
                    };
                }
                AggregationMode::Sync => unreachable!("sync runs the barrier path"),
            }
            trial
                .trace
                .span(stage_start, cluster_time, "cluster_round", Entity::Cluster(c));
            stage_time = stage_time.max(cluster_time); // clusters run in parallel
        }
        let stage_end = trial.clock.now() + stage_time;
        trial.clock.advance_to(stage_end);
        trial.ledger.advance_to(stage_end);
        trial.trace.span(stage_start, stage_time, "cluster_stage", Entity::Run);

        // ---- re-clustering check (lines 14–18) ----
        let mut reclustered = false;
        if policy.should_recluster(&churn.stats) {
            let _p = Scope::new(Phase::Recluster);
            reclustered = true;
            trial.ledger.reclusters += 1;
            trial.trace.instant(trial.clock.now(), "recluster", Entity::Run);
            // in-flight work addressed to the old PSes dies with the
            // topology: recycle parked contributions so moved members
            // retrain fresh against their aligned cluster model; the wire
            // plane's error-feedback residuals are likewise deltas against
            // the replaced base models, so they flush with them
            for slot in parked.iter_mut() {
                if let Some(ct) = slot.take() {
                    pools.params.put(ct.params);
                }
            }
            for slot in residuals.iter_mut().chain(ground_residuals.iter_mut()) {
                if let Some(buf) = slot.take() {
                    pools.params.put(buf);
                }
            }
            let old_assignment = topo.assignment.clone();
            let old_models = topo.models.clone();
            if let Some(g) = geo.as_mut() {
                g.refresh(&trial.constellation, trial.clock.now());
            }
            let mut new_topo =
                build_topology(trial, &strategy, &global, geo.as_ref().map(|g| g.grid()))?;
            new_topo.assignment = align_labels(&old_assignment, &new_topo.assignment, k);
            new_topo.models = old_models;
            let changed = changed_members(&old_assignment, &new_topo.assignment);
            info!(
                "round {round}: re-clustering ({} members moved, strategy {})",
                changed.len(),
                strategy.name
            );
            for &m in &changed {
                let dest = new_topo.assignment[m];
                if strategy.maml_warmstart {
                    let head = new_topo.ps[dest];
                    batch_buf.fill_support(&trial.clients[head].shard, &mut trial.rng);
                    batch_buf.fill_query(&trial.clients[m].shard, &mut trial.rng);
                    let mut pooled = pools.params.take_copy(&new_topo.models[dest]);
                    let _qloss = rt.maml_step_into(
                        &mut pooled,
                        &batch_buf.x1, &batch_buf.y1, &batch_buf.x2, &batch_buf.y2,
                        cfg.maml_alpha,
                        cfg.maml_beta,
                        &mut batch_buf.scratch,
                    )?;
                    pools.params.put(pooled);
                    trial.ledger.maml_adaptations += 1;
                    let d = positions[m].dist(positions[head]);
                    let batch_bits = maml_batch_bits(rt);
                    trial
                        .ledger
                        .add_energy(trial.energy.tx_energy(batch_bits, d));
                    trial.ledger.add_energy(
                        trial
                            .energy
                            .compute_energy(2 * rt.spec.batch, trial.clients[m].cpu_hz),
                    );
                }
            }
            topo = new_topo;
            let warm = if cfg.buffer_size == 0 || cfg.aggregation == AggregationMode::Async {
                max_cluster_size(&topo, k)
            } else {
                trial.clients.len()
            };
            pools.params.ensure_free(warm);
        }

        // ---- ground station aggregation stage (lines 21–24) ----
        if round % cfg.ground_every == 0 {
            let _p = Scope::new(Phase::Ground);
            // recovery plane: crashed PS processes fail over before the
            // pass plan forms. Merged versions were already published to
            // the members (salvaged for free); only contributions still
            // *parked* at the crashed process migrate, billed as one
            // re-upload each to the promoted backup (see [`fail_over_ps`];
            // the eventual broadcast keeps each contribution's
            // training-time slant range — a conservative simplification)
            if avail.ps_failed.iter().any(|&p| p) {
                let members_of = topo.clusters(k);
                let dt = fail_over_ps(
                    trial,
                    &mut topo,
                    &members_of,
                    &avail,
                    &positions,
                    up_bytes,
                    wire.up,
                    &|m| parked[m].is_some(),
                );
                if dt > 0.0 {
                    let t_end = trial.clock.now() + dt;
                    trial.clock.advance_to(t_end);
                    trial.ledger.advance_to(t_end);
                }
            }
            let live: Vec<usize> = (0..topo.ps.len())
                .filter(|&c| !avail.unreachable[topo.ps[c]] && !avail.ps_failed[topo.ps[c]])
                .collect();
            trial.ledger.add_stale_passes(topo.ps.len() - live.len());
            let any_station_down = avail.ground_down.iter().any(|&d| d);
            let all_stations_down = any_station_down && avail.ground_down.iter().all(|&d| d);
            if all_stations_down || live.is_empty() {
                trial.ledger.add_stale_passes(live.len());
            } else {
                let live_stations: Vec<GroundStation>;
                let stations: &[GroundStation] = if any_station_down {
                    live_stations = trial
                        .ground
                        .iter()
                        .zip(&avail.ground_down)
                        .filter(|(_, &down)| !down)
                        .map(|(g, _)| g.clone())
                        .collect();
                    &live_stations
                } else {
                    &trial.ground
                };
                let t = trial.clock.now();
                let ctx = GroundCtx {
                    link: &trial.link,
                    energy: &trial.energy,
                    stations,
                    constellation: &trial.constellation,
                };
                let live_ps: Vec<usize> = live.iter().map(|&c| topo.ps[c]).collect();
                let out = stages.ground.exchange(&ctx, &live_ps, t, wire);
                let exchanged: Vec<usize> = out.exchanged.iter().map(|&i| live[i]).collect();
                let pass_end = t + out.duration_s;
                if !exchanged.is_empty() {
                    let members_of = topo.clusters(k);
                    let sizes: Vec<usize> = exchanged
                        .iter()
                        .map(|&c| {
                            members_of[c]
                                .iter()
                                .map(|&m| trial.clients[m].data_size())
                                .sum()
                        })
                        .collect();
                    let weights = fedavg_weights(&sizes);
                    // wire plane: PS → GS uploads encode against the ground
                    // segment's current global model (see `run_staged`)
                    let mut uploads: Vec<Vec<f32>> = Vec::new();
                    if compressing {
                        for &c in &exchanged {
                            let mut up = pools.params.take_copy(&topo.models[c]);
                            let res = ground_residuals[c]
                                .get_or_insert_with(|| pools.params.take_zeroed());
                            encode_upload(cfg.compress, &mut up, &global, res, &mut wire_scratch);
                            uploads.push(up);
                        }
                    }
                    let rows: Vec<&[f32]> = if compressing {
                        uploads.iter().map(|u| u.as_slice()).collect()
                    } else {
                        exchanged.iter().map(|&c| topo.models[c].as_slice()).collect()
                    };
                    aggregate(rt, &rows, &weights, &mut global)?;
                    drop(rows);
                    for up in uploads {
                        pools.params.put(up);
                    }
                    trial.ledger.add_wire_bytes(up_bytes * exchanged.len() as f64);
                    // the broadcast publishes a *new* cluster-model version:
                    // anything still parked is now one version staler
                    for &c in &exchanged {
                        topo.models[c].clone_from(&global);
                        version[c] += 1;
                        pub_time[c] = pass_end;
                    }
                }
                trial.ledger.add_energy(out.energy_j);
                trial.ledger.add_stale_passes(out.stale.len());
                trial.ledger.add_ground_wait(out.wait_s);
                // telemetry plane (see `run_staged`): pass span, window
                // instants mapped through `live`, per-cluster window time
                if trial.trace.is_enabled() || trial.registry.is_enabled() {
                    trial
                        .trace
                        .span(t, out.duration_s, "ground_pass", Entity::Ground(out.station));
                    for &(i, open, close) in &out.windows {
                        let cg = live[i];
                        trial.trace.instant(t + open, "window_open", Entity::Cluster(cg));
                        trial.trace.instant(t + close, "window_close", Entity::Cluster(cg));
                        trial.registry.record_window(cg, close - open);
                    }
                    if !exchanged.is_empty() {
                        trial
                            .trace
                            .instant(pass_end, "global_merge", Entity::Ground(out.station));
                    }
                }
                trial.clock.advance_to(pass_end);
                trial.ledger.advance_to(pass_end);
            }
        }

        // ---- evaluation / convergence check ----
        // cadence decoupled from the round barrier: the round schedules an
        // EvalDue at its completion timestamp; evaluation runs when the
        // event pops, evaluating the same logical global as the sync path
        trial
            .trace
            .span(stage_start, trial.clock.now() - stage_start, "round", Entity::Run);
        if round % cfg.eval_every == 0 || round == cfg.rounds {
            eval_queue.push(trial.clock.now(), Event::EvalDue { round });
        }
        while eval_queue
            .peek_time()
            .is_some_and(|due| due <= trial.clock.now())
        {
            let _p = Scope::new(Phase::Eval);
            let sched = eval_queue.pop().expect("peeked event vanished");
            trial.trace.instant(sched.at, sched.event.kind(), Entity::Run);
            let Event::EvalDue { round: due_round } = sched.event else {
                unreachable!("unexpected event on the eval queue");
            };
            let sizes: Vec<usize> = topo
                .clusters(k)
                .iter()
                .map(|ms| ms.iter().map(|&m| trial.clients[m].data_size()).sum())
                .collect();
            let weights = fedavg_weights(&sizes);
            let rows: Vec<&[f32]> = topo.models.iter().map(|m| m.as_slice()).collect();
            aggregate(rt, &rows, &weights, &mut global)?;
            let eval =
                evaluate_with(rt, &global, &trial.test, cfg.eval_batches, &mut eval_scratch)?;
            trial
                .ledger
                .record(due_round, eval.accuracy, eval.loss, reclustered);
            trial.trace.instant(trial.clock.now(), "eval", Entity::Run);
            if let Some(target) = cfg.target_accuracy {
                if eval.accuracy >= target && converged_at.is_none() {
                    converged_at =
                        Some((due_round, trial.ledger.time_s, trial.ledger.energy_j));
                }
            }
        }
        if converged_at.is_some() {
            break;
        }
    }

    // un-merged leftovers at run end return to the pool, residuals with them
    for slot in parked.iter_mut() {
        if let Some(ct) = slot.take() {
            pools.params.put(ct.params);
        }
    }
    for slot in residuals.iter_mut().chain(ground_residuals.iter_mut()) {
        if let Some(buf) = slot.take() {
            pools.params.put(buf);
        }
    }

    let final_accuracy = trial.ledger.best_accuracy();
    Ok(RunResult {
        name: strategy.name,
        ledger: std::mem::take(&mut trial.ledger),
        converged_at,
        final_accuracy,
    })
}

/// Reusable batch sampling buffers (and kernel scratch) for MAML warm
/// starts.
struct BatchBuf {
    x1: Vec<f32>,
    y1: Vec<f32>,
    x2: Vec<f32>,
    y2: Vec<f32>,
    batch: usize,
    scratch: HostScratch,
}

impl BatchBuf {
    fn new(rt: &crate::runtime::ModelRuntime) -> BatchBuf {
        let b = rt.spec.batch;
        let d = rt.spec.input_dim();
        BatchBuf {
            x1: vec![0.0; b * d],
            y1: vec![0.0; b],
            x2: vec![0.0; b * d],
            y2: vec![0.0; b],
            batch: b,
            scratch: HostScratch::new(),
        }
    }

    fn fill_support(&mut self, shard: &crate::data::Dataset, rng: &mut crate::util::Rng) {
        let n_batches = shard.len().div_ceil(self.batch).max(1);
        shard.fill_batch(rng.below_usize(n_batches), self.batch, &mut self.x1, &mut self.y1);
    }

    fn fill_query(&mut self, shard: &crate::data::Dataset, rng: &mut crate::util::Rng) {
        let n_batches = shard.len().div_ceil(self.batch).max(1);
        shard.fill_batch(rng.below_usize(n_batches), self.batch, &mut self.x2, &mut self.y2);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::runtime::{Manifest, ModelRuntime};

    fn with_runtime<F: FnOnce(&Manifest, &ModelRuntime)>(f: F) {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        f(&m, &rt);
    }

    #[test]
    fn topology_is_well_formed_for_all_strategies() {
        with_runtime(|m, rt| {
            for strat in [Strategy::fedhc(), Strategy::hbase(), Strategy::fedce()] {
                let mut trial = Trial::new(ExperimentConfig::tiny(), m, rt).unwrap();
                let global = trial.init.clone();
                let topo = build_topology(&mut trial, &strat, &global, None).unwrap();
                let k = trial.cfg.clusters;
                assert_eq!(topo.assignment.len(), trial.clients.len());
                assert!(topo.assignment.iter().all(|&a| a < k));
                assert_eq!(topo.ps.len(), k);
                assert_eq!(topo.models.len(), k);
                // each PS belongs to its own cluster, clusters non-empty
                for (c, members) in topo.clusters(k).iter().enumerate() {
                    assert!(!members.is_empty(), "{}: empty cluster {c}", strat.name);
                    assert_eq!(topo.assignment[topo.ps[c]], c, "{}", strat.name);
                }
            }
        });
    }

    #[test]
    fn fedhc_short_run_improves_accuracy() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 10;
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
            assert!(!res.ledger.records.is_empty());
            let first = res.ledger.records.first().unwrap().accuracy;
            let best = res.final_accuracy;
            assert!(best > first, "accuracy {first} -> {best}");
            assert!(res.ledger.time_s > 0.0);
            assert!(res.ledger.energy_j > 0.0);
        });
    }

    #[test]
    fn ledger_monotone_and_consistent() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 6;
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_clustered(&mut trial, Strategy::hbase()).unwrap();
            let recs = &res.ledger.records;
            for w in recs.windows(2) {
                assert!(w[1].time_s >= w[0].time_s);
                assert!(w[1].energy_j >= w[0].energy_j);
                assert!(w[1].round > w[0].round);
            }
        });
    }

    #[test]
    fn target_accuracy_stops_early() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 50;
            cfg.target_accuracy = Some(0.5);
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
            if let Some((round, t, e)) = res.converged_at {
                assert!(round < 50, "should converge early");
                assert!(t > 0.0 && e > 0.0);
                let last = res.ledger.records.last().unwrap();
                assert!(last.accuracy >= 0.5);
            } else {
                panic!("tiny task should reach 50% within 50 rounds");
            }
        });
    }

    /// The constellation plane's exactness guarantee, end to end: the same
    /// run with the spatial index on (the default) and off must produce
    /// byte-identical metrics — the index only prunes, never re-scores.
    #[test]
    fn disabling_the_index_does_not_change_results() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 5;
        cfg.target_accuracy = None;
        assert!(cfg.spatial_index, "the index must default to on");
        let mut with_ix = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let a = run_clustered(&mut with_ix, Strategy::fedhc()).unwrap();
        cfg.spatial_index = false;
        let mut without = Trial::new(cfg, &m, &rt).unwrap();
        let b = run_clustered(&mut without, Strategy::fedhc()).unwrap();
        assert_eq!(a.ledger.time_s.to_bits(), b.ledger.time_s.to_bits());
        assert_eq!(a.ledger.energy_j.to_bits(), b.ledger.energy_j.to_bits());
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
        assert_eq!(a.ledger.reclusters, b.ledger.reclusters);
        assert_eq!(a.ledger.records.len(), b.ledger.records.len());
    }

    /// The bounded-memory (pooled) round path must be a pure memory
    /// optimisation: identical ledger, with no resident per-client
    /// parameter vectors afterwards.
    #[test]
    fn pooled_params_mode_matches_resident_ledger() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 6;
        cfg.target_accuracy = None;
        let mut res_trial = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let res = run_clustered(&mut res_trial, Strategy::fedhc()).unwrap();
        cfg.resident_params = false;
        let mut pool_trial = Trial::new(cfg, &m, &rt).unwrap();
        let pooled = run_clustered(&mut pool_trial, Strategy::fedhc()).unwrap();
        assert_eq!(res.ledger.time_s.to_bits(), pooled.ledger.time_s.to_bits());
        assert_eq!(res.ledger.energy_j.to_bits(), pooled.ledger.energy_j.to_bits());
        assert_eq!(res.final_accuracy.to_bits(), pooled.final_accuracy.to_bits());
        assert_eq!(res.ledger.maml_adaptations, pooled.ledger.maml_adaptations);
        assert!(
            pool_trial.clients.iter().all(|c| c.params.is_empty()),
            "pooled mode must not leave resident per-client parameters"
        );
        assert!(res_trial.clients.iter().all(|c| !c.params.is_empty()));
    }

    /// The buffered plane end to end: a sub-cluster goal forces mid-round
    /// merges and cross-round parking (populating the staleness counters),
    /// async folds every arrival. Ledgers must stay monotone throughout.
    #[test]
    fn buffered_and_async_runs_populate_staleness_counters() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 6;
        cfg.target_accuracy = None;
        cfg.aggregation = crate::config::AggregationMode::Buffered;
        cfg.buffer_size = 2;
        let mut t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let buffered = run_clustered(&mut t, Strategy::fedhc()).unwrap();
        assert!(buffered.ledger.buffered_merges > 0, "no buffered merges fired");
        let merged: usize = buffered.ledger.staleness_hist.iter().sum();
        assert!(merged > 0, "staleness histogram stayed empty");
        assert!(buffered.ledger.idle_s > 0.0, "a goal of 2 must make members wait");
        assert!(buffered.ledger.time_s > 0.0 && buffered.ledger.energy_j > 0.0);
        assert!(!buffered.ledger.records.is_empty());
        for w in buffered.ledger.records.windows(2) {
            assert!(w[1].time_s >= w[0].time_s);
            assert!(w[1].energy_j >= w[0].energy_j);
        }
        cfg.aggregation = crate::config::AggregationMode::Async;
        let mut t = Trial::new(cfg, &m, &rt).unwrap();
        let asy = run_clustered(&mut t, Strategy::fedhc()).unwrap();
        assert!(asy.ledger.buffered_merges > 0);
        assert_eq!(asy.ledger.idle_s, 0.0, "async merges at arrival — no buffer wait");
        assert!(asy.final_accuracy > 0.0);
    }

    /// The wire plane end to end: compressed uplinks bill fewer bytes,
    /// less time and less energy than dense ones, and the run still learns.
    #[test]
    fn compressed_runs_bill_fewer_bytes_time_and_energy() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 5;
        cfg.target_accuracy = None;
        let mut dense_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let dense = run_clustered(&mut dense_t, Strategy::fedhc()).unwrap();
        assert!(dense.ledger.wire_bytes > 0.0, "dense runs must still bill bytes");

        cfg.compress = crate::fl::CompressMode::TopK(0.1);
        let mut topk_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let topk = run_clustered(&mut topk_t, Strategy::fedhc()).unwrap();
        let ratio = topk.ledger.wire_bytes / dense.ledger.wire_bytes;
        assert!(ratio < 0.15, "top-k 0.1 billed {ratio} of dense bytes");
        assert!(topk.ledger.time_s < dense.ledger.time_s, "thin uplinks must be faster");
        assert!(topk.ledger.energy_j < dense.ledger.energy_j, "and cheaper");
        assert!(topk.final_accuracy > 0.0);

        cfg.compress = crate::fl::CompressMode::Int8;
        let mut int8_t = Trial::new(cfg, &m, &rt).unwrap();
        let int8 = run_clustered(&mut int8_t, Strategy::fedhc()).unwrap();
        let ratio = int8.ledger.wire_bytes / dense.ledger.wire_bytes;
        assert!(ratio < 0.3, "int8 billed {ratio} of dense bytes");
        assert!(int8.final_accuracy > 0.0);
    }

    #[test]
    fn strategies_produce_different_trajectories() {
        with_runtime(|m, rt| {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 5;
            let run = |s: Strategy| {
                let mut trial = Trial::new(cfg.clone(), m, rt).unwrap();
                run_clustered(&mut trial, s).unwrap().ledger.time_s
            };
            let t_fedhc = run(Strategy::fedhc());
            let t_hbase = run(Strategy::hbase());
            // random clusters scatter members across the shell → longer
            // links → more round time than geo clusters
            assert!(t_hbase > t_fedhc, "hbase {t_hbase} vs fedhc {t_fedhc}");
        });
    }

    /// The routing plane's identity guarantee: at the default 2000 km ISL
    /// range the tiny shell (satellites ≥ 7600 km apart) has no inter-
    /// satellite edges at all, so every route tree degenerates to direct
    /// fallbacks and `--routing isl` must be byte-identical to
    /// `--routing direct` — in the sync and the buffered timeline alike.
    #[test]
    fn sparse_isl_routing_is_bitwise_identical_to_direct() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        for aggregation in [AggregationMode::Sync, AggregationMode::Buffered] {
            let mut cfg = ExperimentConfig::tiny();
            cfg.rounds = 5;
            cfg.target_accuracy = None;
            cfg.aggregation = aggregation;
            let mut direct_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
            let direct = run_clustered(&mut direct_t, Strategy::fedhc()).unwrap();
            cfg.routing = RoutingMode::Isl;
            let mut isl_t = Trial::new(cfg, &m, &rt).unwrap();
            let isl = run_clustered(&mut isl_t, Strategy::fedhc()).unwrap();
            assert_eq!(direct.ledger.time_s.to_bits(), isl.ledger.time_s.to_bits());
            assert_eq!(direct.ledger.energy_j.to_bits(), isl.ledger.energy_j.to_bits());
            assert_eq!(direct.final_accuracy.to_bits(), isl.final_accuracy.to_bits());
            assert_eq!(
                direct.ledger.wire_bytes.to_bits(),
                isl.ledger.wire_bytes.to_bits()
            );
            assert_eq!(isl.ledger.route_hops, 0, "flat trees — no routed hops");
            assert_eq!(isl.ledger.relay_merges, 0);
        }
    }

    /// Multi-hop routing engaged: one cluster over the whole shell at
    /// 9000 km ISL range turns each orbital plane into a 6-ring, so
    /// uploads from the PS's plane store-and-forward through up to three
    /// hops with partial aggregation at the relays. The accounting must
    /// diverge from the one-hop teleport and stay worker-count invariant.
    #[test]
    fn multi_hop_routing_bills_hops_and_stays_worker_invariant() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.target_accuracy = None;
        cfg.clusters = 1;
        cfg.isl_range_km = 9000.0;
        cfg.workers = 1;
        let mut direct_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let direct = run_clustered(&mut direct_t, Strategy::fedhc()).unwrap();
        cfg.routing = RoutingMode::Isl;
        let mut isl_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let isl = run_clustered(&mut isl_t, Strategy::fedhc()).unwrap();
        assert!(isl.ledger.route_hops > 0, "the 6-rings must engage multi-hop");
        assert!(isl.ledger.relay_merges > 0, "relays must partially aggregate");
        assert_ne!(
            direct.ledger.time_s.to_bits(),
            isl.ledger.time_s.to_bits(),
            "multi-hop routing must change the round schedule"
        );
        assert_ne!(direct.ledger.energy_j.to_bits(), isl.ledger.energy_j.to_bits());
        cfg.workers = 4;
        let mut w_t = Trial::new(cfg, &m, &rt).unwrap();
        let w = run_clustered(&mut w_t, Strategy::fedhc()).unwrap();
        assert_eq!(isl.ledger.time_s.to_bits(), w.ledger.time_s.to_bits());
        assert_eq!(isl.ledger.energy_j.to_bits(), w.ledger.energy_j.to_bits());
        assert_eq!(isl.ledger.wire_bytes.to_bits(), w.ledger.wire_bytes.to_bits());
        assert_eq!(isl.ledger.route_hops, w.ledger.route_hops);
        assert_eq!(isl.ledger.relay_merges, w.ledger.relay_merges);
        assert_eq!(isl.final_accuracy.to_bits(), w.final_accuracy.to_bits());
    }

    /// The ring all-reduce alternative (`--routing isl:ring`): 2(k−1)
    /// billed steps per cluster round, a relay merge per fold step, and
    /// the sequential merge order pinned across worker counts.
    #[test]
    fn ring_allreduce_bills_steps_and_stays_worker_invariant() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.target_accuracy = None;
        cfg.routing = RoutingMode::Ring;
        cfg.workers = 1;
        let mut a_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let a = run_clustered(&mut a_t, Strategy::fedhc()).unwrap();
        assert!(a.ledger.route_hops > 0, "ring steps must bill as hops");
        assert!(a.ledger.relay_merges > 0);
        assert!(a.final_accuracy > 0.0);
        assert!(a.ledger.wire_bytes > 0.0);
        cfg.workers = 4;
        let mut b_t = Trial::new(cfg, &m, &rt).unwrap();
        let b = run_clustered(&mut b_t, Strategy::fedhc()).unwrap();
        assert_eq!(a.ledger.time_s.to_bits(), b.ledger.time_s.to_bits());
        assert_eq!(a.ledger.energy_j.to_bits(), b.ledger.energy_j.to_bits());
        assert_eq!(a.ledger.wire_bytes.to_bits(), b.ledger.wire_bytes.to_bits());
        assert_eq!(a.ledger.route_hops, b.ledger.route_hops);
        assert_eq!(a.ledger.relay_merges, b.ledger.relay_merges);
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    }

    /// Routed uploads under the buffered plane: a multi-hop member's
    /// arrival stretches over its store-and-forward path, every hop is
    /// billed, and the event schedule stays worker-count invariant.
    #[test]
    fn buffered_routed_uploads_bill_hops_and_stay_worker_invariant() {
        let m = Manifest::host();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.target_accuracy = None;
        cfg.clusters = 1;
        cfg.isl_range_km = 9000.0;
        cfg.aggregation = AggregationMode::Buffered;
        cfg.routing = RoutingMode::Isl;
        cfg.workers = 1;
        let mut a_t = Trial::new(cfg.clone(), &m, &rt).unwrap();
        let a = run_clustered(&mut a_t, Strategy::fedhc()).unwrap();
        assert!(a.ledger.route_hops > 0, "multi-hop arrivals must bill hops");
        assert_eq!(a.ledger.relay_merges, 0, "buffered relays forward, never pool");
        assert!(a.final_accuracy > 0.0);
        cfg.workers = 4;
        let mut b_t = Trial::new(cfg, &m, &rt).unwrap();
        let b = run_clustered(&mut b_t, Strategy::fedhc()).unwrap();
        assert_eq!(a.ledger.time_s.to_bits(), b.ledger.time_s.to_bits());
        assert_eq!(a.ledger.energy_j.to_bits(), b.ledger.energy_j.to_bits());
        assert_eq!(a.ledger.wire_bytes.to_bits(), b.ledger.wire_bytes.to_bits());
        assert_eq!(a.ledger.route_hops, b.ledger.route_hops);
        assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits());
    }
}

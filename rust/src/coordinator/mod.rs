//! The FedHC coordinator (paper §III): two-stage hierarchical clustered FL
//! with satellite-clustered PS selection and meta-learning-driven
//! re-clustering, plus the shared trial context and round accounting that
//! the baselines reuse for apples-to-apples comparison.
//!
//! The round loop is decomposed into stage traits ([`stages`]) shared by
//! FedHC, H-BASE, FedCE and C-FedAvg: local training, PS aggregation, and
//! the ground exchange. Two timelines drive the clock
//! (`--timeline analytic|event`, [`crate::config::Timeline`]): the
//! analytic Eq. 7 closed forms, or a discrete-event schedule
//! ([`crate::sim::events`]) in which PS↔GS exchanges are gated by
//! `orbit::visibility` windows — a PS that misses its window waits or
//! goes stale instead of teleporting parameters.
//!
//! The cluster stage runs on the parallel round engine
//! ([`crate::sim::engine::Engine`]): local training fans out across worker
//! threads and reduces deterministically, so `--workers N` changes only
//! wall-clock, never the simulated metrics.
//!
//! A full (tiny) run end to end — the built-in host backend means no AOT
//! artifacts are needed:
//!
//! ```
//! use fedhc::config::ExperimentConfig;
//! use fedhc::coordinator::{run_clustered, Strategy, Trial};
//! use fedhc::runtime::{Manifest, ModelRuntime};
//!
//! let mut cfg = ExperimentConfig::tiny();
//! cfg.rounds = 2;
//! let manifest = Manifest::host(); // pure-Rust backend, no artifacts
//! let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
//! let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
//! let result = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
//! assert_eq!(result.ledger.records.len(), 2);
//! assert!(result.ledger.time_s > 0.0);
//! ```

pub mod fedhc;
pub mod ground;
pub mod round;
pub mod stages;
pub mod trial;

pub use fedhc::{run_clustered, run_staged, RunResult, Strategy};
pub use stages::Stages;
pub use trial::{run_scenario_matrix, MatrixCell, Trial};

//! The FedHC coordinator (paper §III): two-stage hierarchical clustered FL
//! with satellite-clustered PS selection and meta-learning-driven
//! re-clustering, plus the shared trial context and round accounting that
//! the baselines reuse for apples-to-apples comparison.

pub mod fedhc;
pub mod ground;
pub mod round;
pub mod trial;

pub use fedhc::{run_clustered, RunResult, Strategy};
pub use trial::Trial;

//! Shared run context: constellation, ground segment, clients with data
//! shards, link/energy models, scenario fault engine, simulated clock and
//! ledger — plus the scenario-matrix sweep that runs every method across
//! the fault presets.

use super::fedhc::{run_clustered, RunResult, Strategy};
use crate::baselines::run_cfedavg;
use crate::config::ExperimentConfig;
use crate::data::idx::load_or_synth;
use crate::data::{partition_dirichlet, partition_iid, Dataset};
use crate::fl::SatClient;
use crate::metrics::{Ledger, MetricsRegistry, Tracer};
use crate::network::{EnergyModel, LinkModel, NetworkParams};
use crate::orbit::geo::default_ground_segment;
use crate::orbit::propagate::Constellation;
use crate::orbit::walker::WalkerConstellation;
use crate::orbit::{GroundStation, Vec3};
use crate::runtime::{Manifest, ModelRuntime};
use crate::sim::scenario::{ScenarioConfig, ScenarioEngine, ScenarioKind};
use crate::sim::{MobilityModel, SimClock};
use crate::util::Rng;
use anyhow::{bail, Result};
use std::path::Path;

/// Everything one FL run needs, independent of the method.
pub struct Trial<'rt> {
    pub cfg: ExperimentConfig,
    pub rt: &'rt ModelRuntime,
    /// Sub-constellation containing exactly the client satellites
    /// (client i ↔ element i).
    pub constellation: Constellation,
    pub ground: Vec<GroundStation>,
    pub link: LinkModel,
    pub energy: EnergyModel,
    pub mobility: MobilityModel,
    /// Per-run fault-injection engine (scenario plane): folds typed fault
    /// events into the per-round availability the coordinator consumes.
    pub scenario: ScenarioEngine,
    pub clients: Vec<SatClient>,
    /// The shared initial model every client starts from. In the default
    /// resident mode each client also holds a copy in `SatClient::params`;
    /// the bounded-memory mode (`resident_params = false`, mega presets)
    /// keeps only this one vector plus the per-cluster models, so resident
    /// parameter state is O(K), not O(N).
    pub init: Vec<f32>,
    pub test: Dataset,
    pub clock: SimClock,
    pub ledger: Ledger,
    /// Telemetry plane: sim-time tracer, disabled by default (`--trace`
    /// enables it; disabled emit calls are allocation-free no-ops).
    pub trace: Tracer,
    /// Telemetry plane: per-entity counters/histograms, disabled by
    /// default (`--metrics` enables it).
    pub registry: MetricsRegistry,
    pub rng: Rng,
    /// Whether real benchmark files were found (vs synthetic substitute).
    pub real_data: bool,
}

impl<'rt> Trial<'rt> {
    /// Build a trial: constellation, data shards, initial models.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest, rt: &'rt ModelRuntime) -> Result<Trial<'rt>> {
        cfg.validate()?;
        // --strict-float pins the scalar kernel path; a pure performance
        // switch, since both paths are bit-identical (host_model docs)
        crate::runtime::host_model::float_mode::set_strict(cfg.strict_float);
        assert_eq!(
            rt.spec.name,
            cfg.variant(),
            "runtime variant {} does not match config dataset {:?}",
            rt.spec.name,
            cfg.dataset
        );
        let mut rng = Rng::new(cfg.seed);

        // constellation: Walker shell (altitude/inclination from the
        // config — paper presets pin 1300 km / 53°, mega presets the
        // Starlink-class 550 km shell), first `clients` slots become
        // clients
        let walker = WalkerConstellation::shell(
            cfg.altitude_km * 1e3,
            cfg.inclination_deg,
            cfg.planes,
            cfg.sats_per_plane,
        );
        let all = walker.elements();
        let mut ids: Vec<usize> = (0..all.len()).collect();
        rng.shuffle(&mut ids);
        ids.truncate(cfg.clients);
        ids.sort_unstable();
        let elements = ids.iter().map(|&i| all[i]).collect();
        let constellation = Constellation::new(elements);

        // data: real files if present, synthetic otherwise
        let (train, test, real_data) = load_or_synth(
            cfg.dataset,
            Path::new("data"),
            cfg.train_samples,
            cfg.test_samples,
            cfg.seed ^ 0xDA7A,
        );
        let shards = if cfg.dirichlet_alpha.is_finite() {
            partition_dirichlet(&train, cfg.clients, cfg.dirichlet_alpha, rt.spec.batch, &mut rng)
        } else {
            partition_iid(&train, cfg.clients, &mut rng)
        };

        // clients with CPU heterogeneity
        let params = NetworkParams::default().with_model_params(rt.spec.param_count);
        let init = manifest.init_params(&rt.spec)?;
        let base_hz = params.cpu_hz;
        let clients: Vec<SatClient> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let hz = base_hz * rng.uniform_in(cfg.cpu_het.0, cfg.cpu_het.1);
                // the bounded-memory mode keeps no resident per-client
                // parameter vector — members train on pooled buffers
                let params = if cfg.resident_params {
                    init.clone()
                } else {
                    Vec::new()
                };
                SatClient::new(i, shard, params, hz)
            })
            .collect();

        let link = LinkModel::new(params);
        let ground = default_ground_segment();
        // the mobility model owns the transient-outage rate; the scenario
        // engine samples it (event-stream seeded) alongside the preset's
        // fault processes
        let mobility = MobilityModel::new(cfg.outage_prob)?;
        let scenario = ScenarioEngine::new(
            cfg.scenario,
            mobility.outage_prob,
            cfg.seed,
            cfg.clients,
            ground.len(),
        )?;
        Ok(Trial {
            cfg,
            rt,
            constellation,
            ground,
            link,
            energy: EnergyModel::new(link),
            mobility,
            scenario,
            clients,
            init,
            test,
            clock: SimClock::new(),
            ledger: Ledger::new(),
            trace: Tracer::disabled(),
            registry: MetricsRegistry::disabled(),
            rng,
            real_data,
        })
    }

    /// ECI positions of all client satellites at the current sim time.
    pub fn positions(&self) -> Vec<Vec3> {
        self.constellation.snapshot(self.clock.now()).positions
    }

    /// Clustering features (km) at the current sim time.
    pub fn features_km(&self) -> Vec<[f64; 3]> {
        self.constellation.snapshot(self.clock.now()).features_km()
    }

    /// Total data across clients.
    pub fn total_data(&self) -> usize {
        self.clients.iter().map(|c| c.data_size()).sum()
    }
}

/// One cell of the scenario × method matrix sweep.
pub struct MatrixCell {
    pub scenario: ScenarioKind,
    pub method: &'static str,
    pub result: RunResult,
}

/// Run every `method` under every scenario preset in `scenarios`, each on
/// a fresh [`Trial`] built from `base` (same seed, same data, same
/// constellation — only the fault processes differ). Methods are the CLI
/// names: `fedhc`, `fedhc-nomaml`, `hbase`, `fedce`, `cfedavg`. This is
/// the sweep behind `bench_scenarios` and its `BENCH_scenarios.json`.
pub fn run_scenario_matrix(
    base: &ExperimentConfig,
    manifest: &Manifest,
    rt: &ModelRuntime,
    scenarios: &[ScenarioKind],
    methods: &[&'static str],
) -> Result<Vec<MatrixCell>> {
    let mut out = Vec::with_capacity(scenarios.len() * methods.len());
    for &scenario in scenarios {
        for &method in methods {
            let mut cfg = base.clone();
            cfg.scenario = ScenarioConfig::preset(scenario);
            let mut trial = Trial::new(cfg, manifest, rt)?;
            let result = match method {
                "fedhc" => run_clustered(&mut trial, Strategy::fedhc())?,
                "fedhc-nomaml" => run_clustered(&mut trial, Strategy::fedhc_no_maml())?,
                "hbase" => run_clustered(&mut trial, Strategy::hbase())?,
                "fedce" => run_clustered(&mut trial, Strategy::fedce())?,
                "cfedavg" => run_cfedavg(&mut trial)?,
                other => bail!("unknown matrix method '{other}'"),
            };
            out.push(MatrixCell {
                scenario,
                method,
                result,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_runtime<F: FnOnce(&Manifest, &ModelRuntime)>(f: F) {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        f(&m, &rt);
    }

    #[test]
    fn builds_consistent_trial() {
        with_runtime(|m, rt| {
            let cfg = ExperimentConfig::tiny();
            let t = Trial::new(cfg.clone(), m, rt).unwrap();
            assert_eq!(t.clients.len(), cfg.clients);
            assert_eq!(t.constellation.len(), cfg.clients);
            assert_eq!(t.total_data(), cfg.train_samples);
            assert_eq!(t.positions().len(), cfg.clients);
            // every client got the same init
            for c in &t.clients {
                assert_eq!(c.params.len(), rt.spec.param_count);
            }
            // heterogeneous CPUs within the configured band
            let base = NetworkParams::default().cpu_hz;
            for c in &t.clients {
                assert!(c.cpu_hz >= base * cfg.cpu_het.0 && c.cpu_hz <= base * cfg.cpu_het.1);
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        with_runtime(|m, rt| {
            let a = Trial::new(ExperimentConfig::tiny(), m, rt).unwrap();
            let b = Trial::new(ExperimentConfig::tiny(), m, rt).unwrap();
            for (x, y) in a.clients.iter().zip(&b.clients) {
                assert_eq!(x.shard.labels, y.shard.labels);
                assert_eq!(x.cpu_hz, y.cpu_hz);
            }
        });
    }
}

//! Shared run context: constellation, ground segment, clients with data
//! shards, link/energy models, simulated clock and ledger.

use crate::config::ExperimentConfig;
use crate::data::idx::load_or_synth;
use crate::data::{partition_dirichlet, partition_iid, Dataset};
use crate::fl::SatClient;
use crate::metrics::Ledger;
use crate::network::{EnergyModel, LinkModel, NetworkParams};
use crate::orbit::geo::default_ground_segment;
use crate::orbit::propagate::Constellation;
use crate::orbit::walker::WalkerConstellation;
use crate::orbit::{GroundStation, Vec3};
use crate::runtime::{Manifest, ModelRuntime};
use crate::sim::{MobilityModel, SimClock};
use crate::util::Rng;
use anyhow::Result;
use std::path::Path;

/// Everything one FL run needs, independent of the method.
pub struct Trial<'rt> {
    pub cfg: ExperimentConfig,
    pub rt: &'rt ModelRuntime,
    /// Sub-constellation containing exactly the client satellites
    /// (client i ↔ element i).
    pub constellation: Constellation,
    pub ground: Vec<GroundStation>,
    pub link: LinkModel,
    pub energy: EnergyModel,
    pub mobility: MobilityModel,
    pub clients: Vec<SatClient>,
    pub test: Dataset,
    pub clock: SimClock,
    pub ledger: Ledger,
    pub rng: Rng,
    /// Whether real benchmark files were found (vs synthetic substitute).
    pub real_data: bool,
}

impl<'rt> Trial<'rt> {
    /// Build a trial: constellation, data shards, initial models.
    pub fn new(cfg: ExperimentConfig, manifest: &Manifest, rt: &'rt ModelRuntime) -> Result<Trial<'rt>> {
        cfg.validate()?;
        assert_eq!(
            rt.spec.name,
            cfg.variant(),
            "runtime variant {} does not match config dataset {:?}",
            rt.spec.name,
            cfg.dataset
        );
        let mut rng = Rng::new(cfg.seed);

        // constellation: Walker shell, first `clients` slots become clients
        let walker = WalkerConstellation::paper_shell(cfg.planes, cfg.sats_per_plane);
        let all = walker.elements();
        let mut ids: Vec<usize> = (0..all.len()).collect();
        rng.shuffle(&mut ids);
        ids.truncate(cfg.clients);
        ids.sort_unstable();
        let elements = ids.iter().map(|&i| all[i]).collect();
        let constellation = Constellation::new(elements);

        // data: real files if present, synthetic otherwise
        let (train, test, real_data) = load_or_synth(
            cfg.dataset,
            Path::new("data"),
            cfg.train_samples,
            cfg.test_samples,
            cfg.seed ^ 0xDA7A,
        );
        let shards = if cfg.dirichlet_alpha.is_finite() {
            partition_dirichlet(&train, cfg.clients, cfg.dirichlet_alpha, rt.spec.batch, &mut rng)
        } else {
            partition_iid(&train, cfg.clients, &mut rng)
        };

        // clients with CPU heterogeneity
        let params = NetworkParams::default().with_model_params(rt.spec.param_count);
        let init = manifest.init_params(&rt.spec)?;
        let base_hz = params.cpu_hz;
        let clients: Vec<SatClient> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard)| {
                let hz = base_hz * rng.uniform_in(cfg.cpu_het.0, cfg.cpu_het.1);
                SatClient::new(i, shard, init.clone(), hz)
            })
            .collect();

        let link = LinkModel::new(params);
        Ok(Trial {
            cfg,
            rt,
            constellation,
            ground: default_ground_segment(),
            link,
            energy: EnergyModel::new(link),
            mobility: MobilityModel::default(),
            clients,
            test,
            clock: SimClock::new(),
            ledger: Ledger::new(),
            rng,
            real_data,
        })
    }

    /// ECI positions of all client satellites at the current sim time.
    pub fn positions(&self) -> Vec<Vec3> {
        self.constellation.snapshot(self.clock.now()).positions
    }

    /// Clustering features (km) at the current sim time.
    pub fn features_km(&self) -> Vec<[f64; 3]> {
        self.constellation.snapshot(self.clock.now()).features_km()
    }

    /// Total data across clients.
    pub fn total_data(&self) -> usize {
        self.clients.iter().map(|c| c.data_size()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn with_runtime<F: FnOnce(&Manifest, &ModelRuntime)>(f: F) {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        f(&m, &rt);
    }

    #[test]
    fn builds_consistent_trial() {
        with_runtime(|m, rt| {
            let cfg = ExperimentConfig::tiny();
            let t = Trial::new(cfg.clone(), m, rt).unwrap();
            assert_eq!(t.clients.len(), cfg.clients);
            assert_eq!(t.constellation.len(), cfg.clients);
            assert_eq!(t.total_data(), cfg.train_samples);
            assert_eq!(t.positions().len(), cfg.clients);
            // every client got the same init
            for c in &t.clients {
                assert_eq!(c.params.len(), rt.spec.param_count);
            }
            // heterogeneous CPUs within the configured band
            let base = NetworkParams::default().cpu_hz;
            for c in &t.clients {
                assert!(c.cpu_hz >= base * cfg.cpu_het.0 && c.cpu_hz <= base * cfg.cpu_het.1);
            }
        });
    }

    #[test]
    fn deterministic_given_seed() {
        with_runtime(|m, rt| {
            let a = Trial::new(ExperimentConfig::tiny(), m, rt).unwrap();
            let b = Trial::new(ExperimentConfig::tiny(), m, rt).unwrap();
            for (x, y) in a.clients.iter().zip(&b.clients) {
                assert_eq!(x.shard.labels, y.shard.labels);
                assert_eq!(x.cpu_hz, y.cpu_hz);
            }
        });
    }
}

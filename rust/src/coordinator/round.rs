//! Per-round time and energy accounting (paper Eq. 7–10).
//!
//! * Cluster stage (Eq. 7 inner max): each member computes for
//!   `t_cmp = D·Q/f_i` and uploads its model to the PS over the live ISL;
//!   the synchronous round takes the max over members; the PS broadcast
//!   back is one transmission per member. Clusters run in parallel, so the
//!   stage advances the clock by the max over clusters.
//! * Ground stage (Eq. 7 outer sum): each participating cluster PS
//!   uploads to / downloads from its ground station; the stage time is the
//!   sum over those links, as the paper writes it.
//! * Energy (Eq. 8–10): transmission energy of every upload/broadcast plus
//!   ε0·f²·cycles computation energy of every trained sample.

use crate::metrics::Ledger;
use crate::network::{EnergyModel, LinkModel, WireBits};
use crate::orbit::Vec3;
use crate::sim::engine::Engine;

/// Per-member inputs to the cluster-stage accounting.
#[derive(Clone, Copy, Debug)]
pub struct MemberWork {
    /// Samples trained this round (λ epochs × batches × B).
    pub samples: usize,
    /// CPU frequency f_i — already divided by any scenario-plane compute
    /// slowdown, so a straggler's `t_cmp` stretches through the ordinary
    /// Eq. 7 fold.
    pub cpu_hz: f64,
    /// Member position.
    pub pos: Vec3,
    /// Scenario-plane ISL rate multiplier (1.0 = nominal; a degraded
    /// member's uplink slows by `1 / link_factor`). Exactly 1.0 leaves the
    /// comm-time float ops bit-identical to the undegraded path.
    pub link_factor: f64,
}

impl MemberWork {
    /// A member with nominal (undegraded) link and compute.
    pub fn nominal(samples: usize, cpu_hz: f64, pos: Vec3) -> MemberWork {
        MemberWork {
            samples,
            cpu_hz,
            pos,
            link_factor: 1.0,
        }
    }
}

/// Apply a scenario-plane compute slowdown to one node's CPU rate:
/// returns the throttled rate and bills the extra compute time to the
/// ledger's straggler-wait counter. Shared by the clustered gather loop
/// and the C-FedAvg central step so the two methods' counters stay
/// arithmetically comparable. Dividing by a slowdown of exactly 1.0 is an
/// IEEE identity and bills nothing.
pub fn throttle_cpu(
    link: &LinkModel,
    ledger: &mut Ledger,
    samples: usize,
    cpu_hz: f64,
    slowdown: f64,
) -> f64 {
    let cpu_eff = cpu_hz / slowdown;
    if slowdown > 1.0 {
        let extra = link.compute_time(samples, cpu_eff) - link.compute_time(samples, cpu_hz);
        ledger.add_straggler_wait(extra);
    }
    cpu_eff
}

/// One member's `(t_cmp, t_com, distance-to-PS)` split — the raw durations
/// both timelines consume. The analytic fold sums `t_cmp + t_com` per
/// member; the event timeline schedules a `ComputeDone` at `t_cmp` and a
/// `TxDone` at `t_cmp + t_com`, which keeps the floating-point operation
/// order (and thus the numbers) identical across timelines.
pub fn member_times(
    link: &LinkModel,
    m: &MemberWork,
    ps_pos: Vec3,
    up_bits: f64,
) -> (f64, f64, f64) {
    let d = m.pos.dist(ps_pos);
    (
        link.compute_time(m.samples, m.cpu_hz),
        link.comm_time_scaled(up_bits, d, m.link_factor),
        d,
    )
}

/// One member's contribution to the cluster round: `(t_cmp + t_com,
/// Eq. 8 upload + Eq. 9 compute + Eq. 8 PS broadcast back, distance to
/// the PS)`. The upload bills the (possibly compressed) uplink payload,
/// the broadcast back the dense downlink. Pure per-member math — the
/// scatter job of the engine-mapped accounting.
fn member_cost(
    link: &LinkModel,
    energy: &EnergyModel,
    m: &MemberWork,
    ps_pos: Vec3,
    wire: WireBits,
) -> (f64, f64, f64) {
    let (t_cmp, t_com, d) = member_times(link, m, ps_pos, wire.up);
    let t = t_cmp + t_com;
    let e = energy.tx_energy(wire.up, d)
        + energy.compute_energy(m.samples, m.cpu_hz)
        + energy.tx_energy(wire.down, d);
    (t, e, d)
}

/// Deterministic reduction of per-member costs, in member order: the
/// synchronous round takes the max member time plus one PS broadcast (the
/// dense downlink) to the farthest member; energy is additive.
fn reduce_costs(link: &LinkModel, costs: &[(f64, f64, f64)], down_bits: f64) -> (f64, f64) {
    let mut t_max = 0.0f64;
    let mut e_total = 0.0f64;
    let mut far: Option<f64> = None;
    for &(t, e, d) in costs {
        t_max = t_max.max(t);
        e_total += e;
        far = Some(far.map_or(d, |a: f64| a.max(d)));
    }
    // broadcast time: the PS transmit to the farthest member overlaps the
    // next round's compute only partially; count the slowest broadcast once
    if let Some(d) = far {
        t_max += link.comm_time(down_bits, d);
    }
    (t_max, e_total)
}

/// Time + energy of one cluster's intra-cluster round (Eq. 7 inner term
/// for this cluster, Eq. 8+9 contributions).
pub fn cluster_round(
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[MemberWork],
    ps_pos: Vec3,
    wire: WireBits,
) -> (f64, f64) {
    let costs: Vec<(f64, f64, f64)> = members
        .iter()
        .map(|m| member_cost(link, energy, m, ps_pos, wire))
        .collect();
    reduce_costs(link, &costs, wire.down)
}

/// Below this membership the per-member cost math (a handful of flops) is
/// folded inline: a thread spawn costs orders of magnitude more than the
/// whole map, and the engine-mapped and sequential paths are numerically
/// identical by construction (see the
/// `engine_mapped_costs_match_sequential_exactly` test).
const ENGINE_MAP_MIN_MEMBERS: usize = 1024;

/// [`cluster_round`] with the per-member map fanned out on the engine for
/// production-scale memberships (small clusters fold inline — same
/// numerics, no thread-spawn overhead in the hot round loop). Identical
/// results for any worker count: the map is pure per-member math and the
/// reduction always folds in member order.
pub fn cluster_round_with(
    engine: &Engine,
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[MemberWork],
    ps_pos: Vec3,
    wire: WireBits,
) -> (f64, f64) {
    if members.len() < ENGINE_MAP_MIN_MEMBERS {
        return cluster_round(link, energy, members, ps_pos, wire);
    }
    let costs = engine.run(members, |_, m| member_cost(link, energy, m, ps_pos, wire));
    reduce_costs(link, &costs, wire.down)
}

/// Time + energy of the ground-station stage for one PS link: the
/// (possibly compressed) cluster model up to the GS and the dense global
/// model back down (Eq. 7 `t_j^com` for both directions; Eq. 8 energy on
/// the satellite side). With a symmetric payload the `up + down` sum is
/// bit-identical to the historical `2·t_oneway` (IEEE: `x + x == 2·x`).
pub fn ground_exchange(
    link: &LinkModel,
    energy: &EnergyModel,
    ps_pos: Vec3,
    gs_pos: Vec3,
    wire: WireBits,
) -> (f64, f64) {
    let d = ps_pos.dist(gs_pos);
    let t = link.ground_comm_time(wire.up, d) + link.ground_comm_time(wire.down, d);
    // satellite transmits up once; the downlink is ground-powered
    let e = energy.ground_tx_energy(wire.up, d);
    (t, e)
}

/// One uploader's contribution to the C-FedAvg collection stage:
/// `(samples, position, link_factor)`. The scenario-plane rate factor
/// stretches the upload time; transmit energy stays the Eq. 8 function of
/// payload and distance. Public because the buffered collection plane
/// schedules each arrival individually instead of folding the max.
pub fn upload_cost(
    link: &LinkModel,
    energy: &EnergyModel,
    samples: usize,
    pos: Vec3,
    link_factor: f64,
    bits_per_sample: f64,
    central_pos: Vec3,
) -> (f64, f64) {
    let d = pos.dist(central_pos);
    let bits = samples as f64 * bits_per_sample;
    (
        link.comm_time_scaled(bits, d, link_factor),
        energy.tx_energy(bits, d),
    )
}

/// Fold per-uploader costs: stage time is the slowest upload, energy is
/// additive. Always folds in member order (deterministic).
fn reduce_upload_costs(costs: &[(f64, f64)]) -> (f64, f64) {
    let mut t_max = 0.0f64;
    let mut e = 0.0f64;
    for &(t, e_i) in costs {
        t_max = t_max.max(t);
        e += e_i;
    }
    (t_max, e)
}

/// Raw-data upload for the C-FedAvg baseline: every client ships its shard
/// to the central node once (bits = samples × bits_per_sample); each entry
/// is `(samples, position, link_factor)`.
pub fn data_upload(
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[(usize, Vec3, f64)],
    bits_per_sample: f64,
    central_pos: Vec3,
) -> (f64, f64) {
    let costs: Vec<(f64, f64)> = members
        .iter()
        .map(|&(samples, pos, factor)| {
            upload_cost(link, energy, samples, pos, factor, bits_per_sample, central_pos)
        })
        .collect();
    reduce_upload_costs(&costs)
}

/// [`data_upload`] with the per-uploader map fanned out on the engine for
/// production-scale client counts (small fleets fold inline — same
/// numerics, no thread-spawn overhead in the round loop).
pub fn data_upload_with(
    engine: &Engine,
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[(usize, Vec3, f64)],
    bits_per_sample: f64,
    central_pos: Vec3,
) -> (f64, f64) {
    if members.len() < ENGINE_MAP_MIN_MEMBERS {
        return data_upload(link, energy, members, bits_per_sample, central_pos);
    }
    let costs = engine.run(members, |_, &(samples, pos, factor)| {
        upload_cost(link, energy, samples, pos, factor, bits_per_sample, central_pos)
    });
    reduce_upload_costs(&costs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NetworkParams;

    fn models() -> (LinkModel, EnergyModel) {
        let l = LinkModel::new(NetworkParams::default().with_model_params(44_426));
        (l, EnergyModel::new(l))
    }

    fn member(samples: usize, cpu: f64, x: f64) -> MemberWork {
        MemberWork::nominal(samples, cpu, Vec3::new(x, 0.0, 7.0e6))
    }

    #[test]
    fn round_time_is_slowest_member() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let wire = WireBits::symmetric(44_426.0 * 32.0);
        let fast = member(640, 2e9, 1.0e5);
        let slow = member(640, 0.5e9, 1.0e5);
        let (t_fast, _) = cluster_round(&l, &e, &[fast], ps, wire);
        let (t_both, _) = cluster_round(&l, &e, &[fast, slow], ps, wire);
        let (t_slow, _) = cluster_round(&l, &e, &[slow], ps, wire);
        assert!(t_both >= t_slow && t_slow > t_fast);
    }

    #[test]
    fn energy_additive_in_members() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let wire = WireBits::symmetric(1e6);
        let m = member(320, 1e9, 2.0e5);
        let (_, e1) = cluster_round(&l, &e, &[m], ps, wire);
        let (_, e2) = cluster_round(&l, &e, &[m, m], ps, wire);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn farther_ps_costs_more() {
        let (l, e) = models();
        let wire = WireBits::symmetric(1e6);
        let m = member(320, 1e9, 1.0e5);
        let (t_near, e_near) = cluster_round(&l, &e, &[m], Vec3::new(2.0e5, 0.0, 7.0e6), wire);
        let (t_far, e_far) = cluster_round(&l, &e, &[m], Vec3::new(3.0e6, 0.0, 7.0e6), wire);
        assert!(t_far > t_near);
        assert!(e_far > e_near);
    }

    #[test]
    fn ground_exchange_roundtrip() {
        let (l, e) = models();
        let ps = Vec3::new(7.0e6, 0.0, 0.0);
        let gs = Vec3::new(6.371e6, 0.0, 0.0);
        let (t, en) = ground_exchange(&l, &e, ps, gs, WireBits::symmetric(1e6));
        assert!(t > 0.0 && en > 0.0);
        // a symmetric up+down takes exactly twice one-way, bitwise
        let d = ps.dist(gs);
        assert_eq!(t, 2.0 * l.ground_comm_time(1e6, d));
    }

    #[test]
    fn compressed_uplink_bills_less_than_dense() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let m = member(320, 1e9, 2.0e5);
        let dense = WireBits::dense(44_426);
        let thin = WireBits {
            up: dense.up / 10.0,
            down: dense.down,
        };
        let (t_dense, e_dense) = cluster_round(&l, &e, &[m], ps, dense);
        let (t_thin, e_thin) = cluster_round(&l, &e, &[m], ps, thin);
        assert!(t_thin < t_dense, "smaller uplink payload is faster");
        assert!(e_thin < e_dense, "and cheaper (Eq. 8)");
        // the ground hop bills the compressed up but the dense down
        let gs = Vec3::new(6.371e6, 0.0, 0.0);
        let (tg_dense, eg_dense) = ground_exchange(&l, &e, ps, gs, dense);
        let (tg_thin, eg_thin) = ground_exchange(&l, &e, ps, gs, thin);
        assert!(tg_thin < tg_dense && eg_thin < eg_dense);
        let d = ps.dist(gs);
        assert_eq!(
            tg_thin,
            l.ground_comm_time(thin.up, d) + l.ground_comm_time(dense.down, d)
        );
    }

    #[test]
    fn engine_mapped_costs_match_sequential_exactly() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let wire = WireBits::symmetric(44_426.0 * 32.0);
        // large enough to take the engine-mapped path (above the inline
        // fold threshold), so the parallel map itself is exercised
        let n = ENGINE_MAP_MIN_MEMBERS + 200;
        let members: Vec<MemberWork> = (0..n)
            .map(|i| member(320 + 16 * i, 0.5e9 + 1e7 * i as f64, 1.0e5 + 3.0e4 * i as f64))
            .collect();
        let seq = cluster_round(&l, &e, &members, ps, wire);
        for workers in [1usize, 2, 4, 8] {
            let eng = Engine::new(workers);
            let par = cluster_round_with(&eng, &l, &e, &members, ps, wire);
            assert_eq!(seq, par, "workers={workers}");
        }
        // small memberships short-circuit to the sequential fold
        let small = &members[..9];
        let eng = Engine::new(8);
        assert_eq!(
            cluster_round(&l, &e, small, ps, wire),
            cluster_round_with(&eng, &l, &e, small, ps, wire)
        );
        let uploads: Vec<(usize, Vec3, f64)> = (0..n)
            .map(|i| (100 + i, Vec3::new(1.0e5 + 1.0e4 * i as f64, 0.0, 7.0e6), 1.0))
            .collect();
        let seq_up = data_upload(&l, &e, &uploads, 6e3, ps);
        for workers in [1usize, 3, 8] {
            let eng = Engine::new(workers);
            assert_eq!(seq_up, data_upload_with(&eng, &l, &e, &uploads, 6e3, ps));
        }
    }

    #[test]
    fn throttle_cpu_bills_only_real_slowdowns() {
        let (l, _) = models();
        let mut ledger = Ledger::new();
        let hz = throttle_cpu(&l, &mut ledger, 640, 1e9, 1.0);
        assert_eq!(hz, 1e9, "nominal slowdown must be an exact identity");
        assert_eq!(ledger.straggler_wait_s, 0.0);
        let hz = throttle_cpu(&l, &mut ledger, 640, 1e9, 4.0);
        assert_eq!(hz, 0.25e9);
        let expect = l.compute_time(640, 0.25e9) - l.compute_time(640, 1e9);
        assert!((ledger.straggler_wait_s - expect).abs() < 1e-12);
        assert!(ledger.straggler_wait_s > 0.0);
    }

    #[test]
    fn data_upload_dominated_by_biggest_shard() {
        let (l, e) = models();
        let central = Vec3::new(0.0, 0.0, 7.0e6);
        let near_small = (100usize, Vec3::new(1.0e5, 0.0, 7.0e6), 1.0);
        let near_big = (10_000usize, Vec3::new(1.0e5, 0.0, 7.0e6), 1.0);
        let (t_small, e_small) = data_upload(&l, &e, &[near_small], 6e3, central);
        let (t_big, e_big) = data_upload(&l, &e, &[near_small, near_big], 6e3, central);
        assert!(t_big > 10.0 * t_small);
        assert!(e_big > e_small);
    }

    #[test]
    fn degraded_member_slows_the_round_but_not_its_energy() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let wire = WireBits::symmetric(44_426.0 * 32.0);
        let nominal = member(320, 1e9, 2.0e5);
        let degraded = MemberWork {
            link_factor: 0.25,
            ..nominal
        };
        let (t_nom, e_nom) = cluster_round(&l, &e, &[nominal], ps, wire);
        let (t_deg, e_deg) = cluster_round(&l, &e, &[degraded], ps, wire);
        assert!(t_deg > t_nom, "a degraded uplink must stretch the round");
        assert_eq!(e_nom, e_deg, "Eq. 8 energy depends on payload, not rate");
        // an explicit 1.0 factor is the nominal path, bit for bit
        let unit = MemberWork {
            link_factor: 1.0,
            ..nominal
        };
        assert_eq!(cluster_round(&l, &e, &[unit], ps, wire), (t_nom, e_nom));
    }
}

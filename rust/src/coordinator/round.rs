//! Per-round time and energy accounting (paper Eq. 7–10).
//!
//! * Cluster stage (Eq. 7 inner max): each member computes for
//!   `t_cmp = D·Q/f_i` and uploads its model to the PS over the live ISL;
//!   the synchronous round takes the max over members; the PS broadcast
//!   back is one transmission per member. Clusters run in parallel, so the
//!   stage advances the clock by the max over clusters.
//! * Ground stage (Eq. 7 outer sum): each participating cluster PS
//!   uploads to / downloads from its ground station; the stage time is the
//!   sum over those links, as the paper writes it.
//! * Energy (Eq. 8–10): transmission energy of every upload/broadcast plus
//!   ε0·f²·cycles computation energy of every trained sample.

use crate::network::{EnergyModel, LinkModel};
use crate::orbit::Vec3;

/// Per-member inputs to the cluster-stage accounting.
#[derive(Clone, Copy, Debug)]
pub struct MemberWork {
    /// Samples trained this round (λ epochs × batches × B).
    pub samples: usize,
    /// CPU frequency f_i.
    pub cpu_hz: f64,
    /// Member position.
    pub pos: Vec3,
}

/// Time + energy of one cluster's intra-cluster round (Eq. 7 inner term
/// for this cluster, Eq. 8+9 contributions).
pub fn cluster_round(
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[MemberWork],
    ps_pos: Vec3,
    model_bits: f64,
) -> (f64, f64) {
    let mut t_max = 0.0f64;
    let mut e_total = 0.0f64;
    for m in members {
        let d = m.pos.dist(ps_pos).max(1.0);
        let t_cmp = link.compute_time(m.samples, m.cpu_hz);
        let t_com = link.comm_time(model_bits, d);
        t_max = t_max.max(t_cmp + t_com);
        // Eq. 8 upload + Eq. 9 compute
        e_total += energy.tx_energy(model_bits, d);
        e_total += energy.compute_energy(m.samples, m.cpu_hz);
        // PS broadcast of the aggregated model back to this member
        e_total += energy.tx_energy(model_bits, d);
    }
    // broadcast time: the PS transmit to the farthest member overlaps the
    // next round's compute only partially; count the slowest broadcast once
    if let Some(far) = members
        .iter()
        .map(|m| m.pos.dist(ps_pos).max(1.0))
        .fold(None::<f64>, |acc, d| Some(acc.map_or(d, |a: f64| a.max(d))))
    {
        t_max += link.comm_time(model_bits, far);
    }
    (t_max, e_total)
}

/// Time + energy of the ground-station stage for one PS link: model up to
/// the GS and the global model back down (Eq. 7 `t_j^com`, doubled for the
/// return broadcast; Eq. 8 energy on the satellite side).
pub fn ground_exchange(
    link: &LinkModel,
    energy: &EnergyModel,
    ps_pos: Vec3,
    gs_pos: Vec3,
    model_bits: f64,
) -> (f64, f64) {
    let d = ps_pos.dist(gs_pos).max(1.0);
    let t = 2.0 * link.ground_comm_time(model_bits, d);
    // satellite transmits up once; the downlink is ground-powered
    let e = energy.ground_tx_energy(model_bits, d);
    (t, e)
}

/// Raw-data upload for the C-FedAvg baseline: every client ships its shard
/// to the central node once (bits = samples × bits_per_sample).
pub fn data_upload(
    link: &LinkModel,
    energy: &EnergyModel,
    members: &[(usize, Vec3)],
    bits_per_sample: f64,
    central_pos: Vec3,
) -> (f64, f64) {
    let mut t_max = 0.0f64;
    let mut e = 0.0f64;
    for &(samples, pos) in members {
        let d = pos.dist(central_pos).max(1.0);
        let bits = samples as f64 * bits_per_sample;
        t_max = t_max.max(link.comm_time(bits, d));
        e += energy.tx_energy(bits, d);
    }
    (t_max, e)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::network::NetworkParams;

    fn models() -> (LinkModel, EnergyModel) {
        let l = LinkModel::new(NetworkParams::default().with_model_params(44_426));
        (l, EnergyModel::new(l))
    }

    fn member(samples: usize, cpu: f64, x: f64) -> MemberWork {
        MemberWork {
            samples,
            cpu_hz: cpu,
            pos: Vec3::new(x, 0.0, 7.0e6),
        }
    }

    #[test]
    fn round_time_is_slowest_member() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let bits = 44_426.0 * 32.0;
        let fast = member(640, 2e9, 1.0e5);
        let slow = member(640, 0.5e9, 1.0e5);
        let (t_fast, _) = cluster_round(&l, &e, &[fast], ps, bits);
        let (t_both, _) = cluster_round(&l, &e, &[fast, slow], ps, bits);
        let (t_slow, _) = cluster_round(&l, &e, &[slow], ps, bits);
        assert!(t_both >= t_slow && t_slow > t_fast);
    }

    #[test]
    fn energy_additive_in_members() {
        let (l, e) = models();
        let ps = Vec3::new(0.0, 0.0, 7.0e6);
        let bits = 1e6;
        let m = member(320, 1e9, 2.0e5);
        let (_, e1) = cluster_round(&l, &e, &[m], ps, bits);
        let (_, e2) = cluster_round(&l, &e, &[m, m], ps, bits);
        assert!((e2 / e1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn farther_ps_costs_more() {
        let (l, e) = models();
        let bits = 1e6;
        let m = member(320, 1e9, 1.0e5);
        let (t_near, e_near) = cluster_round(&l, &e, &[m], Vec3::new(2.0e5, 0.0, 7.0e6), bits);
        let (t_far, e_far) = cluster_round(&l, &e, &[m], Vec3::new(3.0e6, 0.0, 7.0e6), bits);
        assert!(t_far > t_near);
        assert!(e_far > e_near);
    }

    #[test]
    fn ground_exchange_roundtrip() {
        let (l, e) = models();
        let ps = Vec3::new(7.0e6, 0.0, 0.0);
        let gs = Vec3::new(6.371e6, 0.0, 0.0);
        let (t, en) = ground_exchange(&l, &e, ps, gs, 1e6);
        assert!(t > 0.0 && en > 0.0);
        // up+down takes twice one-way
        let d = ps.dist(gs);
        assert!((t - 2.0 * l.ground_comm_time(1e6, d)).abs() < 1e-12);
    }

    #[test]
    fn data_upload_dominated_by_biggest_shard() {
        let (l, e) = models();
        let central = Vec3::new(0.0, 0.0, 7.0e6);
        let near_small = (100usize, Vec3::new(1.0e5, 0.0, 7.0e6));
        let near_big = (10_000usize, Vec3::new(1.0e5, 0.0, 7.0e6));
        let (t_small, e_small) = data_upload(&l, &e, &[near_small], 6e3, central);
        let (t_big, e_big) = data_upload(&l, &e, &[near_small, near_big], 6e3, central);
        assert!(t_big > 10.0 * t_small);
        assert!(e_big > e_small);
    }
}

//! # FedHC — Hierarchical Clustered Federated Learning for Satellite Networks
//!
//! Reproduction of "FedHC: A Hierarchical Clustered Federated Learning
//! Framework for Satellite Networks" (CS.DC 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordination contribution: LEO
//!   constellation simulation, satellite-clustered parameter-server
//!   selection, the two-stage (cluster → ground-station) aggregation
//!   hierarchy, meta-learning-driven re-clustering, and the time/energy
//!   accounting of the paper's evaluation. Plus every substrate the paper
//!   depends on: orbital mechanics, link models, k-means clustering,
//!   dataset synthesis/partitioning, a discrete-event simulator, the
//!   deterministic parallel round engine (`sim::engine`) that fans local
//!   training out across CPU cores, and the three comparison baselines
//!   (C-FedAvg, H-BASE, FedCE).
//! * **Layer 2 (python/compile)** — LeNet/MLP forward+backward, MAML
//!   inner/outer steps, and weighted aggregation written in JAX and
//!   AOT-lowered to HLO text once at build time (`make artifacts`).
//! * **Layer 1 (python/compile/kernels)** — Pallas kernels for the compute
//!   hot spots (fused dense layers, weighted parameter aggregation, fused
//!   SGD update), validated against pure-jnp oracles.
//!
//! Python never runs on the request path: the Rust binary loads the HLO
//! artifacts through PJRT (`runtime`) and drives everything itself. When
//! no artifacts are present the runtime transparently falls back to a
//! pure-Rust host backend (`runtime::host_model`) with the same entry
//! points, so the whole stack — binary, examples, benches, tests — runs
//! on images without an XLA toolchain.

pub mod baselines;
pub mod clustering;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fl;
pub mod metrics;
pub mod network;
pub mod orbit;
pub mod runtime;
pub mod sim;
pub mod util;

pub use config::{ExperimentConfig, Timeline};
pub use coordinator::{run_clustered, RunResult, Strategy, Trial};

//! Experiment configuration: one struct with everything a run needs,
//! presets matching the paper's setups, a flat `key = value` config-file
//! parser, and CLI overrides.

pub mod parse;

use crate::data::DatasetKind;
use crate::fl::CompressMode;
use crate::network::RetryPolicy;
use crate::sim::scenario::{ScenarioConfig, ScenarioKind};
use crate::util::cli::Args;
use anyhow::{anyhow, bail, Result};

/// Which timeline drives the simulated clock and the metrics ledger.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Timeline {
    /// Closed-form Eq. 7 folds with an always-reachable ground segment
    /// (the original reproduction semantics; parameters "teleport" to any
    /// station the plan picks).
    Analytic,
    /// Discrete-event timeline: stage durations flow through the
    /// `sim::events` queue and PS↔GS exchanges are gated by
    /// `orbit::visibility` windows — a PS that misses its window waits for
    /// the next one or goes stale. Under always-visible geometry this is
    /// bit-identical to `Analytic` (see `tests/timeline_equivalence.rs`).
    Event,
}

impl Timeline {
    /// Parse the `--timeline` flag value.
    pub fn parse(s: &str) -> Option<Timeline> {
        match s {
            "analytic" => Some(Timeline::Analytic),
            "event" => Some(Timeline::Event),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Timeline::Analytic => "analytic",
            Timeline::Event => "event",
        }
    }
}

/// When a cluster PS folds member contributions into the cluster model.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AggregationMode {
    /// Synchronous barrier: every scheduled member trains, the PS merges
    /// once per round (the original reproduction semantics).
    Sync,
    /// FedBuff-style buffered aggregation: members upload as soon as their
    /// compute + uplink finishes, the PS merges whenever `buffer_size`
    /// contributions have accumulated (and once at round end if none did),
    /// down-weighting stale contributions by `1/(1+τ)^β`. With
    /// always-visible geometry and `buffer_size` = cluster size this
    /// degenerates bit-exactly to `Sync` (see
    /// `tests/aggregation_equivalence.rs`).
    Buffered,
    /// Fully asynchronous: every arriving contribution is folded into the
    /// cluster model immediately as a staleness-damped update
    /// `m += s(τ)·(u − m)`, FedAsync-style. No buffer, no barrier.
    Async,
}

impl AggregationMode {
    /// Parse the `--aggregation` flag value.
    pub fn parse(s: &str) -> Option<AggregationMode> {
        match s {
            "sync" => Some(AggregationMode::Sync),
            "buffered" => Some(AggregationMode::Buffered),
            "async" => Some(AggregationMode::Async),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            AggregationMode::Sync => "sync",
            AggregationMode::Buffered => "buffered",
            AggregationMode::Async => "async",
        }
    }
}

/// How member uploads travel to their cluster PS (`--routing`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoutingMode {
    /// One-hop teleport: every member uploads straight to the PS at its
    /// line-of-sight distance, however far (the historical accounting;
    /// bit-identical to the committed goldens).
    Direct,
    /// Multi-hop ISL store-and-forward: uploads follow shortest-hop paths
    /// over the cluster's line-of-sight graph (edges within
    /// `isl_range_km`, lowest-index tie-breaks), relays partially
    /// aggregate incoming contributions into one pooled payload before
    /// forwarding, and every hop is billed through the
    /// `LinkModel`/`Payload` seam. See [`crate::network::routing`].
    Isl,
    /// Ring all-reduce: cluster members form a logical ring (ascending
    /// index) and reduce-scatter + all-gather the model in `2(k−1)`
    /// steps of `1/k`-sized chunks — no PS bottleneck link.
    Ring,
}

impl RoutingMode {
    /// Parse the `--routing` flag value (`isl:ring` is accepted as an
    /// alias for `ring` — the ring runs over the same ISL plane).
    pub fn parse(s: &str) -> Option<RoutingMode> {
        match s {
            "direct" => Some(RoutingMode::Direct),
            "isl" => Some(RoutingMode::Isl),
            "ring" | "isl:ring" => Some(RoutingMode::Ring),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RoutingMode::Direct => "direct",
            RoutingMode::Isl => "isl",
            RoutingMode::Ring => "ring",
        }
    }
}

/// Complete configuration of one FL experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Dataset geometry (drives the model variant too).
    pub dataset: DatasetKind,
    /// Number of satellite clients C.
    pub clients: usize,
    /// Number of clusters K.
    pub clusters: usize,
    /// Max intra-cluster FL rounds M (budget; runs may stop at target).
    pub rounds: usize,
    /// Local epochs λ per round.
    pub local_epochs: usize,
    /// SGD learning rate η (paper: 0.01).
    pub lr: f32,
    /// Ground aggregation every this many cluster rounds.
    pub ground_every: usize,
    /// Re-clustering dropout threshold Z.
    pub recluster_threshold: f64,
    /// MAML inner learning rate α (paper: 1e-3).
    pub maml_alpha: f32,
    /// MAML outer learning rate β (paper: 1e-3).
    pub maml_beta: f32,
    /// Stop when global accuracy reaches this (None = run all rounds).
    pub target_accuracy: Option<f64>,
    /// Training samples to generate/load.
    pub train_samples: usize,
    /// Test samples (sized to a batch multiple).
    pub test_samples: usize,
    /// Dirichlet α for non-IID sharding (f64::INFINITY = IID).
    pub dirichlet_alpha: f64,
    /// Walker constellation geometry.
    pub planes: usize,
    pub sats_per_plane: usize,
    /// Shell altitude, km (paper presets: 1300; mega presets: the
    /// Starlink-class 550).
    pub altitude_km: f64,
    /// Shell inclination, degrees.
    pub inclination_deg: f64,
    /// Constellation plane: serve nearest-centroid assignment and churn
    /// through the sphere-grid spatial index (`orbit::index`). Pruned
    /// searches are exactness-guaranteed, so this is purely a speed knob —
    /// `--no-index` disables it without changing any result.
    pub spatial_index: bool,
    /// Latitude bands of the sphere grid (`--index-bands`; 0 = auto-sized
    /// from the constellation).
    pub index_bands: usize,
    /// Keep a resident parameter vector per client (the historical
    /// behaviour, required only for inspecting per-client models). Mega
    /// presets disable it: members train on pooled buffers and resident
    /// parameter state stays O(K + largest cluster) instead of O(N).
    pub resident_params: bool,
    /// Per-round client outage probability (the scenario plane's
    /// transient-outage process; runs under every scenario preset).
    pub outage_prob: f64,
    /// Fault-injection scenario (`--scenario` preset + per-knob
    /// overrides): hard failures, ground outages, link degradation,
    /// stragglers, eclipse power-save. See [`crate::sim::scenario`].
    pub scenario: ScenarioConfig,
    /// Client CPU heterogeneity: f_i uniform in [cpu_hz*lo, cpu_hz*hi].
    pub cpu_het: (f64, f64),
    /// Eval batches per evaluation (0 = full test set).
    pub eval_batches: usize,
    /// Evaluate every this many cluster rounds.
    pub eval_every: usize,
    /// Worker threads for the parallel round engine (0 = all available
    /// cores). Any value produces byte-identical metrics — see
    /// [`crate::sim::engine`].
    pub workers: usize,
    /// Timeline semantics (`--timeline analytic|event`).
    pub timeline: Timeline,
    /// Aggregation semantics (`--aggregation sync|buffered|async`).
    pub aggregation: AggregationMode,
    /// Staleness decay exponent β for buffered/async merges: a
    /// contribution computed τ model versions ago is weighted by
    /// `1/(1+τ)^β` (β = 0 ignores staleness entirely).
    pub staleness_beta: f64,
    /// Buffered mode: merge once this many contributions have arrived
    /// (0 = auto, the cluster's member count — the sync-degenerate goal).
    pub buffer_size: usize,
    /// Event timeline: how long a cluster PS may wait for a ground
    /// visibility window before it goes stale and skips the pass, seconds.
    pub max_ground_wait_s: f64,
    /// Event timeline: sampling step of the visibility-window search,
    /// seconds (edges are bisection-refined; windows shorter than this can
    /// be missed).
    pub window_step_s: f64,
    /// Upload compression (`--compress none|topk:<frac>|int8`): how member
    /// → PS and PS → GS parameter uploads are coded on the wire, with
    /// error-feedback residuals. `None` is a structural no-op —
    /// byte-identical to the pre-compression trajectories. See
    /// [`crate::fl::compress`].
    pub compress: CompressMode,
    /// Pin the scalar (pre-SIMD) kernel path (`--strict-float`). A pure
    /// performance switch: the SIMD path is bit-identical to it (see
    /// `runtime::host_model`), so results never change either way.
    pub strict_float: bool,
    /// Global bit-error-rate floor on every model upload (`--ber`; the
    /// scenario plane's noise bursts add on top). 0 disables the
    /// recovery plane's corruption draws entirely — bit-identical to the
    /// pre-recovery accounting.
    pub ber: f64,
    /// Retransmissions allowed per corrupted transfer (`--max-retries`)
    /// before the contribution is dropped to the stale path.
    pub max_retries: u32,
    /// Exponential-backoff growth factor between retransmissions
    /// (`--retry-backoff`, ≥ 1.0).
    pub retry_backoff: f64,
    /// Intra-cluster routing plane (`--routing direct|isl|ring`).
    /// `Direct` (default) keeps the historical one-hop accounting
    /// bit-for-bit; `Isl` routes uploads over the line-of-sight graph
    /// with partial aggregation at relays; `Ring` replaces the PS merge
    /// with a ring all-reduce over the same graph.
    pub routing: RoutingMode,
    /// Maximum inter-satellite-link range, km (`--isl-range-km`): two
    /// satellites are graph neighbors when within this range *and* in
    /// line of sight. Only consulted when `routing != Direct`.
    pub isl_range_km: f64,
    /// Master seed.
    pub seed: u64,
}

impl ExperimentConfig {
    /// Paper's model variant name for the dataset.
    pub fn variant(&self) -> &'static str {
        match self.dataset {
            DatasetKind::Mnist => "mnist_lenet",
            DatasetKind::Cifar10 => "cifar_lenet",
            DatasetKind::Tiny => "tiny_mlp",
        }
    }

    /// Fast smoke preset (tiny model, small constellation) — used by tests
    /// and the quickstart example.
    pub fn tiny() -> Self {
        ExperimentConfig {
            dataset: DatasetKind::Tiny,
            clients: 24,
            clusters: 3,
            rounds: 20,
            local_epochs: 1,
            lr: 0.2,
            ground_every: 2,
            recluster_threshold: 0.25,
            maml_alpha: 0.05,
            maml_beta: 0.05,
            target_accuracy: None,
            train_samples: 1536,
            test_samples: 256,
            dirichlet_alpha: 0.5,
            planes: 4,
            sats_per_plane: 6,
            altitude_km: 1300.0,
            inclination_deg: 53.0,
            spatial_index: true,
            index_bands: 0,
            resident_params: true,
            outage_prob: 0.02,
            scenario: ScenarioConfig::default(),
            cpu_het: (0.5, 2.0),
            eval_batches: 0,
            eval_every: 1,
            workers: 0,
            // the smoke preset pins the analytic timeline so the fast
            // deterministic test suite keeps the legacy Eq. 7 semantics;
            // paper-scale presets default to the event timeline
            timeline: Timeline::Analytic,
            aggregation: AggregationMode::Sync,
            staleness_beta: 0.5,
            buffer_size: 0,
            max_ground_wait_s: 7000.0,
            window_step_s: 30.0,
            compress: CompressMode::None,
            strict_float: false,
            ber: 0.0,
            max_retries: 3,
            retry_backoff: 2.0,
            routing: RoutingMode::Direct,
            isl_range_km: 2000.0,
            seed: 42,
        }
    }

    /// MNIST preset following §IV-A (scaled client count; see DESIGN.md §3).
    pub fn mnist() -> Self {
        ExperimentConfig {
            dataset: DatasetKind::Mnist,
            clients: 96,
            clusters: 3,
            rounds: 300,
            local_epochs: 1,
            lr: 0.05,
            ground_every: 5,
            recluster_threshold: 0.25,
            maml_alpha: 1e-3,
            maml_beta: 1e-3,
            target_accuracy: Some(0.80),
            train_samples: 12_288,
            test_samples: 1024,
            dirichlet_alpha: 0.5,
            planes: 8,
            sats_per_plane: 12,
            altitude_km: 1300.0,
            inclination_deg: 53.0,
            spatial_index: true,
            index_bands: 0,
            resident_params: true,
            outage_prob: 0.02,
            scenario: ScenarioConfig::default(),
            cpu_het: (0.5, 2.0),
            eval_batches: 8,
            eval_every: 1,
            workers: 0,
            timeline: Timeline::Event,
            aggregation: AggregationMode::Sync,
            staleness_beta: 0.5,
            buffer_size: 0,
            // one paper-shell orbital period (≈ 6680 s) plus margin: a PS
            // that cannot reach its station within an orbit goes stale
            max_ground_wait_s: 7000.0,
            window_step_s: 30.0,
            compress: CompressMode::None,
            strict_float: false,
            ber: 0.0,
            max_retries: 3,
            retry_backoff: 2.0,
            routing: RoutingMode::Direct,
            isl_range_km: 2000.0,
            seed: 42,
        }
    }

    /// CIFAR-10 preset (§IV-A; target accuracy 40 %).
    pub fn cifar10() -> Self {
        ExperimentConfig {
            dataset: DatasetKind::Cifar10,
            rounds: 400,
            lr: 0.03,
            target_accuracy: Some(0.40),
            ..Self::mnist()
        }
    }

    /// Mega-constellation tier 1: a Starlink-class 40-plane × 125-slot
    /// shell (5 000 satellites at 550 km) with 1 000 of them enrolled as
    /// FL clients. Tiny model so the workload stays geometry-bound; the
    /// spatial index and the bounded-memory (pooled) round path carry the
    /// scale.
    pub fn mega_sparse() -> Self {
        ExperimentConfig {
            dataset: DatasetKind::Tiny,
            clients: 1000,
            clusters: 10,
            rounds: 40,
            local_epochs: 1,
            lr: 0.2,
            ground_every: 5,
            recluster_threshold: 0.25,
            maml_alpha: 0.05,
            maml_beta: 0.05,
            target_accuracy: None,
            train_samples: 16_000,
            test_samples: 256,
            dirichlet_alpha: 0.5,
            planes: 40,
            sats_per_plane: 125,
            altitude_km: 550.0,
            inclination_deg: 53.0,
            spatial_index: true,
            index_bands: 0,
            resident_params: false,
            outage_prob: 0.02,
            scenario: ScenarioConfig::default(),
            cpu_het: (0.5, 2.0),
            eval_batches: 4,
            eval_every: 5,
            workers: 0,
            timeline: Timeline::Event,
            aggregation: AggregationMode::Sync,
            staleness_beta: 0.5,
            buffer_size: 0,
            max_ground_wait_s: 7000.0,
            window_step_s: 30.0,
            compress: CompressMode::None,
            strict_float: false,
            ber: 0.0,
            max_retries: 3,
            retry_backoff: 2.0,
            routing: RoutingMode::Direct,
            isl_range_km: 2000.0,
            seed: 42,
        }
    }

    /// Mega-constellation tier 2: the full 5 000-satellite shell enrolled,
    /// K = 40 clusters. This is the `bench_mega` end-to-end configuration.
    pub fn mega_dense() -> Self {
        ExperimentConfig {
            clients: 5000,
            clusters: 40,
            train_samples: 80_000,
            ..Self::mega_sparse()
        }
    }

    /// Preset by name.
    pub fn preset(name: &str) -> Option<Self> {
        match name {
            "tiny" => Some(Self::tiny()),
            "mnist" => Some(Self::mnist()),
            "cifar10" | "cifar" => Some(Self::cifar10()),
            "mega-sparse" => Some(Self::mega_sparse()),
            "mega-dense" => Some(Self::mega_dense()),
            _ => None,
        }
    }

    /// Apply CLI overrides (`--clients 48 --k 4 --rounds 100 ...`).
    /// Malformed flags return usage errors instead of panicking.
    pub fn with_args(mut self, args: &Args) -> Result<Self> {
        if let Some(d) = args.get("dataset") {
            let kind = DatasetKind::parse(d)
                .ok_or_else(|| anyhow!("unknown dataset '{d}' (expected mnist|cifar10|tiny)"))?;
            // switch preset family when the dataset changes
            if kind != self.dataset {
                let mut base = match kind {
                    DatasetKind::Mnist => Self::mnist(),
                    DatasetKind::Cifar10 => Self::cifar10(),
                    DatasetKind::Tiny => Self::tiny(),
                };
                base.seed = self.seed;
                self = base;
            }
        }
        self.clients = args.get_usize("clients", self.clients)?;
        self.clusters = args.get_usize("k", self.clusters)?;
        self.rounds = args.get_usize("rounds", self.rounds)?;
        self.local_epochs = args.get_usize("epochs", self.local_epochs)?;
        self.lr = args.get_f64("lr", self.lr as f64)? as f32;
        self.ground_every = args.get_usize("ground-every", self.ground_every)?;
        self.recluster_threshold = args.get_f64("z", self.recluster_threshold)?;
        self.maml_alpha = args.get_f64("alpha", self.maml_alpha as f64)? as f32;
        self.maml_beta = args.get_f64("beta", self.maml_beta as f64)? as f32;
        if let Some(t) = args.get("target") {
            let parsed = t
                .parse()
                .map_err(|_| anyhow!("--target expects a number, got '{t}'"))?;
            self.target_accuracy = Some(parsed);
        }
        if args.flag("no-target") {
            self.target_accuracy = None;
        }
        self.train_samples = args.get_usize("train-samples", self.train_samples)?;
        self.test_samples = args.get_usize("test-samples", self.test_samples)?;
        self.dirichlet_alpha = args.get_f64("dirichlet", self.dirichlet_alpha)?;
        self.planes = args.get_usize("planes", self.planes)?;
        self.sats_per_plane = args.get_usize("sats-per-plane", self.sats_per_plane)?;
        self.altitude_km = args.get_f64("altitude-km", self.altitude_km)?;
        self.inclination_deg = args.get_f64("inclination", self.inclination_deg)?;
        if args.flag("no-index") {
            self.spatial_index = false;
        }
        self.index_bands = args.get_usize("index-bands", self.index_bands)?;
        match (args.flag("pooled-params"), args.flag("resident-params")) {
            (true, true) => bail!("--pooled-params and --resident-params are mutually exclusive"),
            (true, false) => self.resident_params = false,
            (false, true) => self.resident_params = true,
            (false, false) => {}
        }
        self.outage_prob = args.get_f64("outage", self.outage_prob)?;
        if let Some(s) = args.get("scenario") {
            let kind = ScenarioKind::parse(s).ok_or_else(|| {
                anyhow!(
                    "unknown scenario '{s}' (expected nominal|churn|flaky-ground\
                     |stragglers|eclipse|noisy-links|ps-crash)"
                )
            })?;
            self.scenario = ScenarioConfig::preset(kind);
        }
        let sc = &mut self.scenario;
        sc.sat_fail_prob = args.get_f64("scenario-sat-fail", sc.sat_fail_prob)?;
        sc.sat_fail_rounds = args.get_u64("scenario-fail-rounds", sc.sat_fail_rounds)?;
        sc.ground_outage_prob = args.get_f64("scenario-ground-outage", sc.ground_outage_prob)?;
        sc.ground_outage_rounds = args.get_u64("scenario-ground-rounds", sc.ground_outage_rounds)?;
        sc.link_degrade_prob = args.get_f64("scenario-link-degrade", sc.link_degrade_prob)?;
        let link_factor =
            args.get_f64("scenario-link-factor", sc.link_degrade_milli as f64 / 1000.0)?;
        sc.link_degrade_milli = (link_factor * 1000.0).round() as u32;
        sc.link_degrade_rounds = args.get_u64("scenario-link-rounds", sc.link_degrade_rounds)?;
        sc.straggler_prob = args.get_f64("scenario-straggler", sc.straggler_prob)?;
        let slowdown = args.get_f64("scenario-slowdown", sc.straggler_milli as f64 / 1000.0)?;
        sc.straggler_milli = (slowdown * 1000.0).round() as u32;
        sc.straggler_rounds = args.get_u64("scenario-straggler-rounds", sc.straggler_rounds)?;
        sc.eclipse = args.get_usize("scenario-eclipse", sc.eclipse as usize)? != 0;
        sc.link_noise_prob = args.get_f64("scenario-link-noise", sc.link_noise_prob)?;
        let noise_ber = args.get_f64("scenario-noise-ber", sc.link_noise_ber_nano as f64 / 1e9)?;
        sc.link_noise_ber_nano = (noise_ber * 1e9).round() as u32;
        sc.link_noise_rounds = args.get_u64("scenario-noise-rounds", sc.link_noise_rounds)?;
        sc.ps_fail_prob = args.get_f64("scenario-ps-fail", sc.ps_fail_prob)?;
        sc.ps_fail_rounds = args.get_u64("scenario-ps-rounds", sc.ps_fail_rounds)?;
        self.ber = args.get_f64("ber", self.ber)?;
        let retries = args.get_u64("max-retries", self.max_retries as u64)?;
        self.max_retries =
            u32::try_from(retries).map_err(|_| anyhow!("--max-retries too large: {retries}"))?;
        self.retry_backoff = args.get_f64("retry-backoff", self.retry_backoff)?;
        if let Some(r) = args.get("routing") {
            self.routing = RoutingMode::parse(r).ok_or_else(|| {
                anyhow!("--routing expects 'direct', 'isl' or 'isl:ring', got '{r}'")
            })?;
        }
        self.isl_range_km = args.get_f64("isl-range-km", self.isl_range_km)?;
        self.eval_batches = args.get_usize("eval-batches", self.eval_batches)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        self.workers = args.get_usize("workers", self.workers)?;
        if let Some(t) = args.get("timeline") {
            self.timeline = Timeline::parse(t)
                .ok_or_else(|| anyhow!("--timeline expects 'analytic' or 'event', got '{t}'"))?;
        }
        if let Some(a) = args.get("aggregation") {
            self.aggregation = AggregationMode::parse(a).ok_or_else(|| {
                anyhow!("--aggregation expects 'sync', 'buffered' or 'async', got '{a}'")
            })?;
        }
        self.staleness_beta = args.get_f64("staleness-beta", self.staleness_beta)?;
        self.buffer_size = args.get_usize("buffer-size", self.buffer_size)?;
        self.max_ground_wait_s = args.get_f64("max-ground-wait", self.max_ground_wait_s)?;
        self.window_step_s = args.get_f64("window-step", self.window_step_s)?;
        if let Some(c) = args.get("compress") {
            self.compress = CompressMode::parse(c).ok_or_else(|| {
                anyhow!("--compress expects 'none', 'topk:<frac>' or 'int8', got '{c}'")
            })?;
        }
        if args.flag("strict-float") {
            self.strict_float = true;
        }
        self.seed = args.get_u64("seed", self.seed)?;
        self.validate()?;
        Ok(self)
    }

    /// Sanity-check invariants.
    pub fn validate(&self) -> Result<()> {
        if self.clients < self.clusters {
            bail!("fewer clients than clusters");
        }
        if self.planes * self.sats_per_plane < self.clients {
            bail!("constellation smaller than client count");
        }
        if !self.altitude_km.is_finite() || self.altitude_km <= 0.0 {
            bail!("shell altitude must be positive, got {} km", self.altitude_km);
        }
        if !(0.0..=180.0).contains(&self.inclination_deg) {
            bail!(
                "shell inclination must be in [0, 180] degrees, got {}",
                self.inclination_deg
            );
        }
        // cells grow ~1.27·bands²; 512 bands (~333k cells) is already far
        // beyond useful resolution, anything more is a typo heading for OOM
        if self.index_bands > 512 {
            bail!(
                "index bands must be at most 512 (0 = auto), got {}",
                self.index_bands
            );
        }
        if self.clusters < 1 || self.rounds < 1 || self.local_epochs < 1 {
            bail!("clusters, rounds and epochs must all be at least 1");
        }
        if self.lr.is_nan() || self.lr <= 0.0 {
            bail!("learning rate must be positive");
        }
        if !(0.0..=1.0).contains(&self.recluster_threshold) {
            bail!("recluster threshold must be in [0, 1]");
        }
        if !(0.0..1.0).contains(&self.outage_prob) {
            bail!("outage probability must be in [0, 1)");
        }
        self.scenario.validate()?;
        if self.cpu_het.0 <= 0.0 || self.cpu_het.1 < self.cpu_het.0 {
            bail!("cpu heterogeneity band must be positive and ordered");
        }
        if let Some(t) = self.target_accuracy {
            if !(0.0..=1.0).contains(&t) {
                bail!("target accuracy must be in [0, 1]");
            }
        }
        if !self.staleness_beta.is_finite() || self.staleness_beta < 0.0 {
            bail!(
                "staleness beta must be finite and non-negative, got {}",
                self.staleness_beta
            );
        }
        if !self.max_ground_wait_s.is_finite() || self.max_ground_wait_s <= 0.0 {
            bail!("max ground wait must be positive and finite");
        }
        if !self.window_step_s.is_finite() || self.window_step_s <= 0.0 {
            bail!("window step must be positive and finite");
        }
        if let CompressMode::TopK(frac) = self.compress {
            if !frac.is_finite() || frac <= 0.0 || frac > 1.0 {
                bail!("top-k compress fraction must be in (0, 1], got {frac}");
            }
        }
        if !(0.0..1.0).contains(&self.ber) {
            bail!("--ber must be a bit-error rate in [0, 1), got {}", self.ber);
        }
        if !self.retry_backoff.is_finite() || self.retry_backoff < 1.0 {
            bail!("--retry-backoff must be at least 1.0, got {}", self.retry_backoff);
        }
        if !self.isl_range_km.is_finite() || self.isl_range_km <= 0.0 {
            bail!(
                "--isl-range-km must be positive and finite, got {}",
                self.isl_range_km
            );
        }
        Ok(())
    }

    /// The recovery plane's retry knobs as a [`RetryPolicy`].
    pub fn retry_policy(&self) -> RetryPolicy {
        RetryPolicy { max_retries: self.max_retries, backoff: self.retry_backoff }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_valid() {
        for name in ["tiny", "mnist", "cifar10", "mega-sparse", "mega-dense"] {
            ExperimentConfig::preset(name).unwrap().validate().unwrap();
        }
        assert!(ExperimentConfig::preset("nope").is_none());
        // mega presets: Starlink-class shell, pooled round path, index on
        let mega = ExperimentConfig::mega_dense();
        assert_eq!(mega.planes * mega.sats_per_plane, 5000);
        assert_eq!(mega.clients, 5000);
        assert_eq!(mega.altitude_km, 550.0);
        assert!(mega.spatial_index && !mega.resident_params);
        assert_eq!(ExperimentConfig::mega_sparse().clients, 1000);
        // paper presets keep the historical shell and resident params
        assert_eq!(ExperimentConfig::mnist().altitude_km, 1300.0);
        assert!(ExperimentConfig::tiny().resident_params);
        assert!(ExperimentConfig::tiny().spatial_index, "index defaults on");
        // paper-scale presets default to the event timeline; the smoke
        // preset pins analytic for the fast deterministic suite
        assert_eq!(ExperimentConfig::mnist().timeline, Timeline::Event);
        assert_eq!(ExperimentConfig::cifar10().timeline, Timeline::Event);
        assert_eq!(ExperimentConfig::tiny().timeline, Timeline::Analytic);
    }

    #[test]
    fn variant_follows_dataset() {
        assert_eq!(ExperimentConfig::mnist().variant(), "mnist_lenet");
        assert_eq!(ExperimentConfig::cifar10().variant(), "cifar_lenet");
        assert_eq!(ExperimentConfig::tiny().variant(), "tiny_mlp");
    }

    #[test]
    fn cli_overrides_apply() {
        let args = Args::parse(
            ["--k", "5", "--rounds", "7", "--lr", "0.5", "--no-target"]
                .iter()
                .map(|s| s.to_string()),
            &["no-target"],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.clusters, 5);
        assert_eq!(c.rounds, 7);
        assert!((c.lr - 0.5).abs() < 1e-6);
        assert!(c.target_accuracy.is_none());
    }

    #[test]
    fn workers_override_applies() {
        let args = Args::parse(
            ["--workers", "6"].iter().map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.workers, 6);
        assert_eq!(ExperimentConfig::tiny().workers, 0, "default is auto");
    }

    #[test]
    fn timeline_override_applies() {
        let args = Args::parse(
            ["--timeline", "event", "--max-ground-wait", "1200"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.timeline, Timeline::Event);
        assert_eq!(c.max_ground_wait_s, 1200.0);
        let bad = Args::parse(["--timeline", "wallclock"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("--timeline"), "{e}");
    }

    #[test]
    fn aggregation_override_applies() {
        // every preset defaults to the synchronous barrier
        for name in ["tiny", "mnist", "cifar10", "mega-sparse", "mega-dense"] {
            let c = ExperimentConfig::preset(name).unwrap();
            assert_eq!(c.aggregation, AggregationMode::Sync, "{name}");
            assert_eq!(c.buffer_size, 0, "{name}: buffer goal defaults to auto");
        }
        let args = Args::parse(
            ["--aggregation", "buffered", "--staleness-beta", "1.5", "--buffer-size", "4"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.aggregation, AggregationMode::Buffered);
        assert_eq!(c.staleness_beta, 1.5);
        assert_eq!(c.buffer_size, 4);
        let args = Args::parse(["--aggregation", "async"].iter().map(|s| s.to_string()), &[]);
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.aggregation, AggregationMode::Async);
        let bad = Args::parse(
            ["--aggregation", "eventual"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("--aggregation"), "{e}");
        let bad = Args::parse(
            ["--staleness-beta", "-1"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("staleness beta"), "{e}");
    }

    #[test]
    fn compress_and_strict_float_overrides_apply() {
        // every preset defaults to the uncompressed wire and fast kernels
        for name in ["tiny", "mnist", "cifar10", "mega-sparse", "mega-dense"] {
            let c = ExperimentConfig::preset(name).unwrap();
            assert_eq!(c.compress, CompressMode::None, "{name}");
            assert!(!c.strict_float, "{name}");
        }
        let args = Args::parse(
            ["--compress", "topk:0.1", "--strict-float"]
                .iter()
                .map(|s| s.to_string()),
            &["strict-float"],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.compress, CompressMode::TopK(0.1));
        assert!(c.strict_float);
        let args = Args::parse(["--compress", "int8"].iter().map(|s| s.to_string()), &[]);
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.compress, CompressMode::Int8);
        // malformed modes and out-of-range fractions are usage errors
        let bad = Args::parse(["--compress", "gzip"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("--compress"), "{e}");
        let bad = Args::parse(["--compress", "topk:0"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("top-k compress fraction"), "{e}");
        let bad = Args::parse(["--compress", "topk:1.5"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("top-k compress fraction"), "{e}");
    }

    #[test]
    fn constellation_plane_overrides_apply() {
        let args = Args::parse(
            ["--no-index", "--index-bands", "7", "--altitude-km", "600"]
                .iter()
                .map(|s| s.to_string()),
            &["no-index"],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert!(!c.spatial_index);
        assert_eq!(c.index_bands, 7);
        assert_eq!(c.altitude_km, 600.0);
        let args = Args::parse(
            ["--pooled-params"].iter().map(|s| s.to_string()),
            &["pooled-params"],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert!(!c.resident_params);
        let args = Args::parse(
            ["--resident-params"].iter().map(|s| s.to_string()),
            &["resident-params"],
        );
        let c = ExperimentConfig::mega_sparse().with_args(&args).unwrap();
        assert!(c.resident_params);
        // bad shell geometry is a usage error
        let args = Args::parse(
            ["--altitude-km", "-5"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("altitude"), "{e}");
        let args = Args::parse(
            ["--inclination", "200"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("inclination"), "{e}");
        // an absurd band count is a usage error, not an OOM
        let args = Args::parse(
            ["--index-bands", "200000"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("index bands"), "{e}");
    }

    #[test]
    fn dataset_switch_changes_family() {
        let args = Args::parse(
            ["--dataset", "cifar10"].iter().map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::mnist().with_args(&args).unwrap();
        assert_eq!(c.dataset, DatasetKind::Cifar10);
        assert_eq!(c.target_accuracy, Some(0.40));
    }

    #[test]
    fn bad_flags_are_usage_errors_not_panics() {
        let args = Args::parse(["--k", "many"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("--k expects an integer"), "{e}");
        let args = Args::parse(["--dataset", "imagenet"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("unknown dataset"), "{e}");
    }

    #[test]
    fn scenario_preset_and_knob_overrides_apply() {
        let args = Args::parse(
            ["--scenario", "churn", "--scenario-sat-fail", "0.2"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::Churn);
        assert_eq!(c.scenario.sat_fail_prob, 0.2);
        // knobs compose onto a preset the flag did not change
        let args = Args::parse(
            ["--scenario-eclipse", "1", "--scenario-slowdown", "2.5"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::Nominal);
        assert!(c.scenario.eclipse);
        assert_eq!(c.scenario.straggler_milli, 2500);
    }

    #[test]
    fn bad_scenario_values_are_usage_errors() {
        let args = Args::parse(
            ["--scenario", "meteor-storm"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("unknown scenario"), "{e}");
        let args = Args::parse(
            ["--scenario-sat-fail", "1.5"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("scenario-sat-fail"), "{e}");
        let args = Args::parse(
            ["--scenario", "stragglers", "--scenario-slowdown", "0.5"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("scenario-slowdown"), "{e}");
    }

    #[test]
    fn recovery_flag_overrides_apply() {
        // every preset defaults to a quiet recovery plane
        for name in ["tiny", "mnist", "cifar10", "mega-sparse", "mega-dense"] {
            let c = ExperimentConfig::preset(name).unwrap();
            assert_eq!(c.ber, 0.0, "{name}");
            assert_eq!(c.max_retries, 3, "{name}");
            assert_eq!(c.retry_backoff, 2.0, "{name}");
        }
        let args = Args::parse(
            ["--ber", "5e-7", "--max-retries", "5", "--retry-backoff", "1.5"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.ber, 5e-7);
        assert_eq!(c.max_retries, 5);
        assert_eq!(c.retry_backoff, 1.5);
        assert_eq!(c.retry_policy(), RetryPolicy { max_retries: 5, backoff: 1.5 });
        // the recovery presets and their knobs parse too
        let args = Args::parse(
            ["--scenario", "noisy-links", "--scenario-noise-ber", "2e-7"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::NoisyLinks);
        assert_eq!(c.scenario.link_noise_ber_nano, 200);
        let args = Args::parse(
            ["--scenario", "ps-crash", "--scenario-ps-rounds", "4"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.scenario.kind, ScenarioKind::PsCrash);
        assert_eq!(c.scenario.ps_fail_rounds, 4);
    }

    #[test]
    fn bad_recovery_values_are_usage_errors() {
        let args = Args::parse(["--ber", "1.5"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("--ber"), "{e}");
        let args = Args::parse(["--retry-backoff", "0.5"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("--retry-backoff"), "{e}");
        let args = Args::parse(
            ["--scenario", "noisy-links", "--scenario-noise-ber", "1"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&args).unwrap_err();
        assert!(e.to_string().contains("scenario-noise-ber"), "{e}");
    }

    #[test]
    fn routing_flag_overrides_apply() {
        // every preset defaults to the historical direct teleport
        for name in ["tiny", "mnist", "cifar10", "mega-sparse", "mega-dense"] {
            let c = ExperimentConfig::preset(name).unwrap();
            assert_eq!(c.routing, RoutingMode::Direct, "{name}");
            assert_eq!(c.isl_range_km, 2000.0, "{name}");
        }
        let args = Args::parse(
            ["--routing", "isl", "--isl-range-km", "3500"]
                .iter()
                .map(|s| s.to_string()),
            &[],
        );
        let c = ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(c.routing, RoutingMode::Isl);
        assert_eq!(c.isl_range_km, 3500.0);
        // both ring spellings parse to the same mode
        for spelling in ["ring", "isl:ring"] {
            let args = Args::parse(
                ["--routing", spelling].iter().map(|s| s.to_string()),
                &[],
            );
            let c = ExperimentConfig::tiny().with_args(&args).unwrap();
            assert_eq!(c.routing, RoutingMode::Ring, "{spelling}");
        }
        let bad = Args::parse(["--routing", "warp"].iter().map(|s| s.to_string()), &[]);
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("--routing"), "{e}");
        let bad = Args::parse(
            ["--isl-range-km", "-10"].iter().map(|s| s.to_string()),
            &[],
        );
        let e = ExperimentConfig::tiny().with_args(&bad).unwrap_err();
        assert!(e.to_string().contains("--isl-range-km"), "{e}");
    }

    #[test]
    fn validate_catches_bad_k() {
        let mut c = ExperimentConfig::tiny();
        c.clusters = c.clients + 1;
        let e = c.validate().unwrap_err();
        assert!(e.to_string().contains("fewer clients than clusters"), "{e}");
    }
}

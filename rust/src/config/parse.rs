//! Flat `key = value` config-file parser (TOML subset: comments with `#`,
//! bare sections `[name]` flattened to `name.key`). Files feed the same
//! override path as CLI flags, so `fedhc run --config exp.toml --k 5`
//! works with the CLI winning.

use crate::util::cli::Args;
use std::collections::BTreeMap;

/// Parse the subset grammar into a flat key→value map.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        if key.is_empty() || val.is_empty() {
            return Err(format!("line {}: empty key or value", lineno + 1));
        }
        out.insert(key, val);
    }
    Ok(out)
}

/// Merge a config file into parsed CLI args: file values become options
/// unless the CLI already set them (CLI wins). Section prefixes are
/// dropped (sections are organisational only).
pub fn merge_file_into_args(args: &mut Args, text: &str) -> Result<(), String> {
    for (k, v) in parse_kv(text)? {
        let key = k.rsplit('.').next().unwrap().to_string();
        args.options.entry(key).or_insert(v);
    }
    Ok(())
}

/// Serialise a flat key→value map back to the config grammar (one sorted
/// `key = value` line each; dotted keys stay inline rather than becoming
/// sections). Values must not contain `#` or newlines — the comment
/// stripper would eat them on re-parse. Round-trips through [`parse_kv`]:
/// used to dump an effective configuration next to recorded results.
pub fn format_kv(kv: &BTreeMap<String, String>) -> String {
    let mut out = String::new();
    for (k, v) in kv {
        out.push_str(k);
        out.push_str(" = ");
        out.push_str(v);
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let text = r#"
            # experiment
            k = 4
            lr = 0.01
            [maml]
            alpha = 0.001   # inner
        "#;
        let kv = parse_kv(text).unwrap();
        assert_eq!(kv["k"], "4");
        assert_eq!(kv["lr"], "0.01");
        assert_eq!(kv["maml.alpha"], "0.001");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_kv("novalue").is_err());
        assert!(parse_kv("x =").is_err());
        assert!(parse_kv("= 3").is_err(), "empty key must be rejected");
        assert!(parse_kv("[unclosed\nk = 1").is_err(), "bad section header");
        // errors carry the offending line number
        let err = parse_kv("k = 1\nbroken").unwrap_err();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn unknown_file_keys_leave_config_defaults_untouched() {
        // a config file with unrelated keys merges into args but does not
        // perturb any ExperimentConfig default
        let mut args = Args::parse(std::iter::empty::<String>(), &[]);
        merge_file_into_args(&mut args, "custom_note = hello").unwrap();
        let cfg = crate::config::ExperimentConfig::tiny().with_args(&args).unwrap();
        let def = crate::config::ExperimentConfig::tiny();
        assert_eq!(cfg.clusters, def.clusters);
        assert_eq!(cfg.rounds, def.rounds);
        assert_eq!(cfg.seed, def.seed);
        assert_eq!(cfg.workers, def.workers);
    }

    #[test]
    fn file_overrides_reach_the_config() {
        let mut args = Args::parse(std::iter::empty::<String>(), &[]);
        merge_file_into_args(&mut args, "k = 5\nrounds = 9\nworkers = 2").unwrap();
        let cfg = crate::config::ExperimentConfig::tiny().with_args(&args).unwrap();
        assert_eq!(cfg.clusters, 5);
        assert_eq!(cfg.rounds, 9);
        assert_eq!(cfg.workers, 2);
    }

    #[test]
    fn bad_recovery_values_in_files_are_usage_errors() {
        // recovery-plane knobs arriving through a config file go through
        // the same validation as the CLI: out-of-range values surface as
        // usage errors naming the flag, never panics
        let reject = |text: &str, needle: &str| {
            let mut args = Args::parse(std::iter::empty::<String>(), &[]);
            merge_file_into_args(&mut args, text).unwrap();
            let err = crate::config::ExperimentConfig::tiny()
                .with_args(&args)
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "'{err}' should mention '{needle}'");
        };
        reject("ber = 1.5", "--ber");
        reject("ber = -0.25", "--ber");
        reject("retry-backoff = 0.5", "--retry-backoff");
        reject("max-retries = many", "--max-retries");
        reject("scenario = noisy-links\nscenario-noise-ber = 1", "scenario-noise-ber");
        reject("scenario = ps-crash\nscenario-ps-rounds = 0", "scenario-ps-rounds");
    }

    #[test]
    fn format_parse_roundtrip() {
        let text = "alpha = 0.001\nk = 4\nlr = 0.01\nmaml.beta = 0.002\n";
        let kv = parse_kv(text).unwrap();
        let dumped = format_kv(&kv);
        let reparsed = parse_kv(&dumped).unwrap();
        assert_eq!(kv, reparsed, "format_kv did not round-trip");
        // formatting is canonical: dumping again is a fixed point
        assert_eq!(dumped, format_kv(&reparsed));
    }

    #[test]
    fn quoted_values_unquoted() {
        let kv = parse_kv("dataset = \"mnist\"").unwrap();
        assert_eq!(kv["dataset"], "mnist");
    }

    #[test]
    fn out_of_range_model_values_from_files_are_rejected() {
        // the assert-style panics in ReclusterPolicy/MobilityModel are
        // gone: a bad Z or outage rate in a config file surfaces as a
        // usage error through the same validation path as the CLI
        let reject = |text: &str, needle: &str| {
            let mut args = Args::parse(std::iter::empty::<String>(), &[]);
            merge_file_into_args(&mut args, text).unwrap();
            let e = crate::config::ExperimentConfig::tiny()
                .with_args(&args)
                .unwrap_err();
            assert!(e.to_string().contains(needle), "'{needle}' not in '{e}'");
        };
        reject("z = 1.5", "recluster threshold");
        reject("z = -0.1", "recluster threshold");
        reject("outage = 1.0", "outage probability");
        reject("outage = -0.5", "outage probability");
        reject("scenario = solar-flare", "unknown scenario");
        reject("scenario-sat-fail = 2.0", "scenario-sat-fail");
        // and the model constructors themselves reject the same values
        assert!(crate::clustering::recluster::ReclusterPolicy::new(1.5).is_err());
        assert!(crate::sim::MobilityModel::new(1.0).is_err());
    }

    #[test]
    fn oversized_cluster_count_is_rejected_before_kmeans() {
        // an aggressive --k override on a mega preset used to reach
        // KMeans::run and panic (k > points); now both layers reject it as
        // a usage error — the config at validation time, and the algorithm
        // itself if a caller bypasses the config
        let mut args = Args::parse(std::iter::empty::<String>(), &[]);
        merge_file_into_args(&mut args, "k = 5000").unwrap();
        let e = crate::config::ExperimentConfig::preset("mega-sparse")
            .unwrap()
            .with_args(&args)
            .unwrap_err();
        assert!(e.to_string().contains("fewer clients than clusters"), "{e}");

        use crate::clustering::kmeans::KMeans;
        use crate::util::Rng;
        let pts = vec![[0.0f64; 3], [1.0, 0.0, 0.0]];
        let e = KMeans::new(3).run(&pts, &mut Rng::new(1)).unwrap_err();
        assert!(e.to_string().contains("cannot form 3 clusters"), "{e}");
        assert!(KMeans::new(0).run(&pts, &mut Rng::new(1)).is_err());
    }

    #[test]
    fn cli_wins_over_file() {
        let mut args = Args::parse(
            ["--k", "9"].iter().map(|s| s.to_string()),
            &[],
        );
        merge_file_into_args(&mut args, "k = 3\nrounds = 50").unwrap();
        assert_eq!(args.get("k"), Some("9"));
        assert_eq!(args.get("rounds"), Some("50"));
    }
}

//! Flat `key = value` config-file parser (TOML subset: comments with `#`,
//! bare sections `[name]` flattened to `name.key`). Files feed the same
//! override path as CLI flags, so `fedhc run --config exp.toml --k 5`
//! works with the CLI winning.

use crate::util::cli::Args;
use std::collections::BTreeMap;

/// Parse the subset grammar into a flat key→value map.
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, String> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        let val = v.trim().trim_matches('"').to_string();
        if key.is_empty() || val.is_empty() {
            return Err(format!("line {}: empty key or value", lineno + 1));
        }
        out.insert(key, val);
    }
    Ok(out)
}

/// Merge a config file into parsed CLI args: file values become options
/// unless the CLI already set them (CLI wins). Section prefixes are
/// dropped (sections are organisational only).
pub fn merge_file_into_args(args: &mut Args, text: &str) -> Result<(), String> {
    for (k, v) in parse_kv(text)? {
        let key = k.rsplit('.').next().unwrap().to_string();
        args.options.entry(key).or_insert(v);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let text = r#"
            # experiment
            k = 4
            lr = 0.01
            [maml]
            alpha = 0.001   # inner
        "#;
        let kv = parse_kv(text).unwrap();
        assert_eq!(kv["k"], "4");
        assert_eq!(kv["lr"], "0.01");
        assert_eq!(kv["maml.alpha"], "0.001");
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse_kv("novalue").is_err());
        assert!(parse_kv("x =").is_err());
    }

    #[test]
    fn quoted_values_unquoted() {
        let kv = parse_kv("dataset = \"mnist\"").unwrap();
        assert_eq!(kv["dataset"], "mnist");
    }

    #[test]
    fn cli_wins_over_file() {
        let mut args = Args::parse(
            ["--k", "9"].iter().map(|s| s.to_string()),
            &[],
        );
        merge_file_into_args(&mut args, "k = 3\nrounds = 50").unwrap();
        assert_eq!(args.get("k"), Some("9"));
        assert_eq!(args.get("rounds"), Some("50"));
    }
}

//! Test-set evaluation through the AOT eval graph.

use crate::data::Dataset;
use crate::runtime::host_model::HostScratch;
use crate::runtime::ModelRuntime;
use anyhow::Result;

/// Accuracy/loss of `params` on (a prefix of) `test`.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub samples: usize,
}

/// Evaluate on up to `max_batches` full batches (0 = whole set). The tail
/// that doesn't fill a batch is dropped (shapes are AOT-fixed); callers
/// size their test sets to batch multiples.
pub fn evaluate(
    rt: &ModelRuntime,
    params: &[f32],
    test: &Dataset,
    max_batches: usize,
) -> Result<EvalResult> {
    let mut scratch = HostScratch::new();
    evaluate_with(rt, params, test, max_batches, &mut scratch)
}

/// [`evaluate`] against a caller-owned kernel scratch, for round loops
/// that evaluate repeatedly. Evaluation only touches the activation
/// buffers, so the scratch stays small and the per-call allocations are
/// limited to the batch-staging buffers.
pub fn evaluate_with(
    rt: &ModelRuntime,
    params: &[f32],
    test: &Dataset,
    max_batches: usize,
    scratch: &mut HostScratch,
) -> Result<EvalResult> {
    let b = rt.spec.batch;
    let d = rt.spec.input_dim();
    let n_batches = test.len() / b;
    let use_batches = if max_batches == 0 {
        n_batches
    } else {
        n_batches.min(max_batches)
    };
    assert!(use_batches > 0, "test set smaller than one batch");
    let mut xs = vec![0.0f32; b * d];
    let mut ys = vec![0.0f32; b];
    let mut loss_sum = 0.0f64;
    let mut correct = 0.0f64;
    for bi in 0..use_batches {
        test.fill_batch(bi, b, &mut xs, &mut ys);
        let (loss, corr) = rt.eval_step_with(params, &xs, &ys, scratch)?;
        loss_sum += loss as f64;
        correct += corr as f64;
    }
    let samples = use_batches * b;
    Ok(EvalResult {
        loss: loss_sum / use_batches as f64,
        accuracy: correct / samples as f64,
        samples,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_tiny;
    use crate::fl::client::SatClient;
    use crate::fl::local::{local_train, TrainScratch};
    use crate::runtime::Manifest;
    use crate::util::Rng;

    #[test]
    fn accuracy_improves_with_training() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let init = m.init_params(&rt.spec).unwrap();
        let mut rng = Rng::new(1);
        let train = synth_tiny(256, &mut rng);
        let test = synth_tiny(64, &mut rng);

        let before = evaluate(&rt, &init, &test, 0).unwrap();
        assert_eq!(before.samples, 64);
        assert!((0.0..=1.0).contains(&before.accuracy));

        let mut client = SatClient::new(0, train, init, 1e9);
        let mut scratch = TrainScratch::new(&rt);
        for _ in 0..12 {
            local_train(&rt, &mut client, 1, 0.2, &mut scratch, &mut rng).unwrap();
        }
        let after = evaluate(&rt, &client.params, &test, 0).unwrap();
        assert!(
            after.accuracy > before.accuracy + 0.2,
            "accuracy {} -> {}",
            before.accuracy,
            after.accuracy
        );
        assert!(after.loss < before.loss);
    }

    #[test]
    fn max_batches_limits_work() {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        let init = m.init_params(&rt.spec).unwrap();
        let test = synth_tiny(4 * rt.spec.batch, &mut Rng::new(2));
        let r = evaluate(&rt, &init, &test, 2).unwrap();
        assert_eq!(r.samples, 2 * rt.spec.batch);
    }
}

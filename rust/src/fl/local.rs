//! Local training loop (Algorithm 1 lines 7–10): λ epochs of minibatch SGD
//! on the client's shard, executed through the AOT train graphs.
//!
//! §Perf: the hot path is allocation-free. Batches are staged into
//! [`TrainScratch`]'s reused buffers and the in-place kernels
//! (`train_step_into` / `train_chunk_into`) update the parameter vector
//! directly against the scratch-owned gradient, so a steady-state round
//! performs **zero parameter-sized allocations** (asserted by the
//! counting allocator in `bench_runtime`; before/after ns/step numbers
//! live in `BENCH_runtime.json`). Batches can still be packed into
//! `train_chunk` calls (S SGD steps per dispatch, numerically identical —
//! see runtime tests); on the host backend both paths run the same
//! blocked kernels, so packing only matters for PJRT-backed runs where
//! per-call dispatch overhead dominates — set `TrainScratch::use_chunk`
//! (env `FEDHC_CHUNK=1`) there.

use super::client::SatClient;
use crate::runtime::host_model::HostScratch;
use crate::runtime::ModelRuntime;
use crate::util::Rng;
use anyhow::Result;

/// Outcome of one client's local round.
#[derive(Clone, Copy, Debug)]
pub struct LocalOutcome {
    /// Mean training loss over the round (drives Eq. 12 weights).
    pub mean_loss: f32,
    /// Distinct samples processed (drives the Eq. 7/9 time & energy
    /// models). Wrap-filled batch tails re-serve existing rows and are
    /// not billed.
    pub samples: usize,
    /// SGD steps taken.
    pub steps: usize,
}

/// Scratch buffers reused across clients (allocation-free hot path):
/// batch staging plus the kernel scratch (gradient + activations) the
/// in-place train path updates against.
pub struct TrainScratch {
    xs: Vec<f32>,
    ys: Vec<f32>,
    /// Pack batches into scan-based `train_chunk` calls (see module docs).
    pub use_chunk: bool,
    host: HostScratch,
}

impl TrainScratch {
    pub fn new(rt: &ModelRuntime) -> TrainScratch {
        let s = rt.spec.chunk_steps;
        let b = rt.spec.batch;
        let d = rt.spec.input_dim();
        TrainScratch {
            xs: vec![0.0; s * b * d],
            ys: vec![0.0; s * b],
            use_chunk: std::env::var("FEDHC_CHUNK").map(|v| v == "1").unwrap_or(false),
            host: HostScratch::new(),
        }
    }
}

/// Train `params` on `shard` for `epochs` local epochs at learning rate
/// `lr`, returning the updated parameters and the round outcome. `rng`
/// shuffles the batch order per epoch.
///
/// This is the pure scatter job of the parallel round engine: it touches
/// no client state, so the engine can fan it out across worker threads
/// while the coordinator applies the results in member order afterwards.
pub fn train_params(
    rt: &ModelRuntime,
    shard: &crate::data::Dataset,
    mut params: Vec<f32>,
    epochs: usize,
    lr: f32,
    scratch: &mut TrainScratch,
    rng: &mut Rng,
) -> Result<(Vec<f32>, LocalOutcome)> {
    let b = rt.spec.batch;
    let d = rt.spec.input_dim();
    let s = rt.spec.chunk_steps;
    let n_batches = shard.len().div_ceil(b).max(1);
    let mut loss_sum = 0.0f64;
    let mut loss_n = 0usize;
    let mut steps = 0usize;

    for _ in 0..epochs {
        // random batch phase each epoch approximates reshuffling without
        // regathering the shard
        let phase = rng.below_usize(n_batches);
        let mut batch_ids: Vec<usize> = (0..n_batches).map(|i| (i + phase) % n_batches).collect();
        rng.shuffle(&mut batch_ids);

        let mut i = 0;
        while i < batch_ids.len() {
            let remaining = batch_ids.len() - i;
            if scratch.use_chunk && remaining >= s {
                // pack S batches into one chunk call
                for (slot, &bi) in batch_ids[i..i + s].iter().enumerate() {
                    let (xs_part, ys_part) = (
                        &mut scratch.xs[slot * b * d..(slot + 1) * b * d],
                        &mut scratch.ys[slot * b..(slot + 1) * b],
                    );
                    shard.fill_batch(bi, b, xs_part, ys_part);
                }
                let loss = rt.train_chunk_into(
                    &mut params,
                    &scratch.xs,
                    &scratch.ys,
                    lr,
                    &mut scratch.host,
                )?;
                loss_sum += loss as f64;
                loss_n += 1;
                steps += s;
                i += s;
            } else {
                shard.fill_batch(batch_ids[i], b, &mut scratch.xs[..b * d], &mut scratch.ys[..b]);
                let loss = rt.train_step_into(
                    &mut params,
                    &scratch.xs[..b * d],
                    &scratch.ys[..b],
                    lr,
                    &mut scratch.host,
                )?;
                loss_sum += loss as f64;
                loss_n += 1;
                steps += 1;
                i += 1;
            }
        }
    }

    let mean_loss = if loss_n == 0 {
        f32::INFINITY
    } else {
        (loss_sum / loss_n as f64) as f32
    };
    Ok((
        params,
        LocalOutcome {
            mean_loss,
            // bill distinct samples: n_batches·b ≥ |shard| whenever the
            // shard is not a batch multiple, and the wrapped tail rows are
            // duplicates the Eq. 7/9 ledger must not charge for
            samples: epochs * shard.len(),
            steps,
        },
    ))
}

/// Train `client` in place for `epochs` local epochs at learning rate `lr`
/// and update its bookkeeping (`last_loss`, `rounds_trained`). Sequential
/// convenience wrapper over [`train_params`] used by the centralised
/// baseline and tests.
pub fn local_train(
    rt: &ModelRuntime,
    client: &mut SatClient,
    epochs: usize,
    lr: f32,
    scratch: &mut TrainScratch,
    rng: &mut Rng,
) -> Result<LocalOutcome> {
    let params = std::mem::take(&mut client.params);
    let (params, out) = train_params(rt, &client.shard, params, epochs, lr, scratch, rng)?;
    client.params = params;
    client.last_loss = out.mean_loss;
    client.rounds_trained += 1;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_tiny;
    use crate::runtime::Manifest;

    fn runtime() -> Option<(Manifest, ModelRuntime)> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts");
            return None;
        }
        let m = Manifest::load(&dir).unwrap();
        let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
        Some((m, rt))
    }

    #[test]
    fn local_train_reduces_loss_over_rounds() {
        let Some((m, rt)) = runtime() else { return };
        let init = m.init_params(&rt.spec).unwrap();
        let shard = synth_tiny(96, &mut Rng::new(1));
        let mut client = SatClient::new(0, shard, init, 1e9);
        let mut scratch = TrainScratch::new(&rt);
        let mut rng = Rng::new(2);
        let first = local_train(&rt, &mut client, 1, 0.1, &mut scratch, &mut rng)
            .unwrap()
            .mean_loss;
        let mut last = first;
        for _ in 0..6 {
            last = local_train(&rt, &mut client, 1, 0.1, &mut scratch, &mut rng)
                .unwrap()
                .mean_loss;
        }
        assert!(last < first, "loss {first} -> {last}");
        assert_eq!(client.rounds_trained, 7);
        assert_eq!(client.last_loss, last);
    }

    #[test]
    fn outcome_accounting() {
        let Some((m, rt)) = runtime() else { return };
        let init = m.init_params(&rt.spec).unwrap();
        // 40 samples, batch 16 → 3 batches/epoch (ceil)
        let shard = synth_tiny(40, &mut Rng::new(3));
        let mut client = SatClient::new(0, shard, init, 1e9);
        let mut scratch = TrainScratch::new(&rt);
        let out = local_train(&rt, &mut client, 2, 0.05, &mut scratch, &mut Rng::new(4)).unwrap();
        // 3 batches of 16 process 48 rows/epoch, but 8 of them are
        // wrap-filled duplicates: the ledger bills the 40 distinct samples
        assert_eq!(out.samples, 2 * 40);
        assert_eq!(out.steps, 2 * 3);
        assert!(out.mean_loss.is_finite());
    }

    #[test]
    fn chunk_packing_uses_fewer_pjrt_calls() {
        let Some((m, rt)) = runtime() else { return };
        let init = m.init_params(&rt.spec).unwrap();
        // 8 batches/epoch with chunk_steps=4 → 2 chunk calls instead of 8
        let shard = synth_tiny(8 * rt.spec.batch, &mut Rng::new(5));
        let mut client = SatClient::new(0, shard, init, 1e9);
        let mut scratch = TrainScratch::new(&rt);
        scratch.use_chunk = true;
        let before = rt.call_count();
        local_train(&rt, &mut client, 1, 0.05, &mut scratch, &mut Rng::new(6)).unwrap();
        let calls = rt.call_count() - before;
        assert_eq!(calls, 2, "expected 2 chunked calls, got {calls}");
    }
}

//! Aggregation weighting schemes and the aggregation dispatcher.

use crate::runtime::host::aggregate_host_into;
use crate::runtime::ModelRuntime;
use anyhow::Result;

/// FedAvg weights (Eq. 5): `p_i = |D_i| / |D|`.
pub fn fedavg_weights(sizes: &[usize]) -> Vec<f32> {
    let total: usize = sizes.iter().sum();
    assert!(total > 0, "no data across clients");
    sizes
        .iter()
        .map(|&s| s as f32 / total as f32)
        .collect()
}

/// Loss-quality weights (Eq. 12): `p_i = (1/L_i) / Σ_j (1/L_j)`.
/// Non-finite or non-positive losses get the weight of the worst finite
/// loss (a client that has never trained shouldn't dominate).
pub fn quality_weights(losses: &[f32]) -> Vec<f32> {
    assert!(!losses.is_empty());
    let worst = losses
        .iter()
        .copied()
        .filter(|l| l.is_finite() && *l > 0.0)
        .fold(f32::MIN_POSITIVE, f32::max);
    let inv: Vec<f32> = losses
        .iter()
        .map(|&l| {
            let l = if l.is_finite() && l > 0.0 { l } else { worst };
            1.0 / l
        })
        .collect();
    let sum: f32 = inv.iter().sum();
    inv.iter().map(|&x| x / sum).collect()
}

/// FedBuff staleness discount: a contribution computed against a model
/// `staleness` versions old is down-weighted by `1/(1+τ)^β`. Always in
/// `(0, 1]`, monotone decreasing in `τ`, and **exactly** 1.0 for a fresh
/// contribution (`pow(1,β) = 1` in IEEE 754, any β) — that identity is
/// what lets buffered mode degenerate bit-exactly to sync when every
/// contribution is fresh.
pub fn staleness_weight(staleness: f64, beta: f64) -> f32 {
    debug_assert!(staleness >= 0.0 && beta >= 0.0);
    (1.0 / (1.0 + staleness).powf(beta)) as f32
}

/// Compose per-member merge weights with their staleness discounts and
/// renormalise. When every contribution is fresh the discounts are all
/// exactly 1.0, so the input weights come back **bitwise unchanged** — the
/// degeneracy hinge for `tests/aggregation_equivalence.rs`.
pub fn stale_composed_weights(weights: &[f32], staleness: &[f64], beta: f64) -> Vec<f32> {
    assert_eq!(weights.len(), staleness.len());
    if staleness.iter().all(|&t| t == 0.0) {
        return weights.to_vec();
    }
    let u: Vec<f32> = weights
        .iter()
        .zip(staleness)
        .map(|(&w, &t)| w * staleness_weight(t, beta))
        .collect();
    let total: f32 = u.iter().sum();
    assert!(total > 0.0, "stale-composed weights vanished");
    u.iter().map(|&x| x / total).collect()
}

/// Asynchronous damped fold (FedAsync-style): `m_j += s·(u_j − m_j)`.
/// Folding a row identical to the model is an **exact** fixed point
/// (`u − m = 0` bitwise, any step size), which pins down that an async
/// merge of already-agreed parameters changes nothing.
pub fn fold_stale(model: &mut [f32], row: &[f32], step: f32) {
    assert_eq!(model.len(), row.len());
    for (m, &u) in model.iter_mut().zip(row) {
        *m += step * (u - *m);
    }
}

/// Aggregate client parameter rows with the given weights. Uses the Pallas
/// kernel through PJRT when the cluster fits the AOT slot count, otherwise
/// the host fallback (identical numerics — see runtime tests). Both
/// branches write into the caller's `out` buffer instead of replacing the
/// vector per call; the host branch is fully allocation-free, while the
/// PJRT branch still stages its zero-padded `slots × P` kernel input
/// internally (see [`ModelRuntime::aggregate_into`]) — dispatch overhead
/// dominates that path anyway.
pub fn aggregate(
    rt: &ModelRuntime,
    rows: &[&[f32]],
    weights: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    assert_eq!(rows.len(), weights.len());
    if rows.len() <= rt.spec.agg_slots {
        rt.aggregate_into(rows, weights, out)?;
    } else {
        out.resize(rt.spec.param_count, 0.0);
        aggregate_host_into(rows, weights, out);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{property, Gen};

    #[test]
    fn fedavg_weights_proportional() {
        let w = fedavg_weights(&[10, 30, 60]);
        assert!((w[0] - 0.1).abs() < 1e-6);
        assert!((w[1] - 0.3).abs() < 1e-6);
        assert!((w[2] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn quality_weights_inverse_loss() {
        // L = [1, 2] → inverse [1, 0.5] → normalised [2/3, 1/3]
        let w = quality_weights(&[1.0, 2.0]);
        assert!((w[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((w[1] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quality_weights_lower_loss_gets_more() {
        let w = quality_weights(&[0.1, 1.0, 10.0]);
        assert!(w[0] > w[1] && w[1] > w[2]);
    }

    #[test]
    fn quality_weights_handle_infinite_loss() {
        let w = quality_weights(&[f32::INFINITY, 1.0, 2.0]);
        assert!(w.iter().all(|x| x.is_finite() && *x > 0.0));
        // the infinite-loss client is treated as worst (2.0), not dominant
        assert!((w[0] - w[2]).abs() < 1e-6);
    }

    #[test]
    fn staleness_weight_is_bounded_and_monotone() {
        property("staleness weight in (0,1], monotone", 128, |g: &mut Gen| {
            let beta = g.f64_in(0.0, 4.0);
            let t1 = g.f64_in(0.0, 50.0);
            let t2 = t1 + g.f64_in(0.0, 50.0);
            let w1 = staleness_weight(t1, beta);
            let w2 = staleness_weight(t2, beta);
            assert!(w1 > 0.0 && w1 <= 1.0, "w({t1},{beta}) = {w1}");
            assert!(w2 > 0.0 && w2 <= 1.0, "w({t2},{beta}) = {w2}");
            assert!(w2 <= w1, "weight rose with staleness: {w2} > {w1}");
            // freshness is an exact identity, not an approximation
            assert_eq!(staleness_weight(0.0, beta).to_bits(), 1.0f32.to_bits());
        });
    }

    #[test]
    fn fresh_composition_is_bitwise_identity() {
        property("all-fresh staleness composition is id", 64, |g: &mut Gen| {
            let n = g.usize_in(1, 16);
            let sizes: Vec<usize> = (0..n).map(|_| g.usize_in(1, 500)).collect();
            let w = fedavg_weights(&sizes);
            let beta = g.f64_in(0.0, 4.0);
            let composed = stale_composed_weights(&w, &vec![0.0; n], beta);
            for (a, b) in w.iter().zip(&composed) {
                assert_eq!(a.to_bits(), b.to_bits(), "fresh composition moved a weight");
            }
        });
    }

    #[test]
    fn stale_composition_is_a_distribution_that_penalises_staleness() {
        let w = fedavg_weights(&[100, 100]);
        let composed = stale_composed_weights(&w, &[0.0, 3.0], 1.0);
        assert!((composed.iter().sum::<f32>() - 1.0).abs() < 1e-5);
        assert!(
            composed[0] > composed[1],
            "equal data, stale member must weigh less: {composed:?}"
        );
    }

    #[test]
    fn fold_of_identical_params_is_bit_identical() {
        property("async fold fixed point", 64, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let params = g.f32_vec(n, -2.0, 2.0);
            let mut model = params.clone();
            // any staleness mix, any β: folding the model into itself is a no-op
            for _ in 0..g.usize_in(1, 5) {
                let step = staleness_weight(g.f64_in(0.0, 20.0), g.f64_in(0.0, 3.0));
                fold_stale(&mut model, &params, step);
            }
            for (a, b) in model.iter().zip(&params) {
                assert_eq!(a.to_bits(), b.to_bits(), "fixed point drifted");
            }
        });
    }

    #[test]
    fn fold_moves_toward_the_row() {
        let mut m = vec![0.0f32, 1.0];
        fold_stale(&mut m, &[1.0, 1.0], 0.5);
        assert_eq!(m, vec![0.5, 1.0]);
        fold_stale(&mut m, &[1.0, 1.0], 1.0);
        assert_eq!(m, vec![1.0, 1.0]);
    }

    #[test]
    fn weight_vectors_are_distributions() {
        property("weights sum to 1", 64, |g: &mut Gen| {
            let n = g.usize_in(1, 20);
            let sizes: Vec<usize> = (0..n).map(|_| g.usize_in(1, 500)).collect();
            let w1 = fedavg_weights(&sizes);
            assert!((w1.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            assert!(w1.iter().all(|&x| x >= 0.0));
            let losses: Vec<f32> = (0..n).map(|_| g.f64_in(0.01, 5.0) as f32).collect();
            let w2 = quality_weights(&losses);
            assert!((w2.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            assert!(w2.iter().all(|&x| x >= 0.0));
        });
    }
}

//! Aggregation weighting schemes and the aggregation dispatcher.

use crate::runtime::host::aggregate_host_into;
use crate::runtime::ModelRuntime;
use anyhow::Result;

/// FedAvg weights (Eq. 5): `p_i = |D_i| / |D|`.
pub fn fedavg_weights(sizes: &[usize]) -> Vec<f32> {
    let total: usize = sizes.iter().sum();
    assert!(total > 0, "no data across clients");
    sizes
        .iter()
        .map(|&s| s as f32 / total as f32)
        .collect()
}

/// Loss-quality weights (Eq. 12): `p_i = (1/L_i) / Σ_j (1/L_j)`.
/// Non-finite or non-positive losses get the weight of the worst finite
/// loss (a client that has never trained shouldn't dominate).
pub fn quality_weights(losses: &[f32]) -> Vec<f32> {
    assert!(!losses.is_empty());
    let worst = losses
        .iter()
        .copied()
        .filter(|l| l.is_finite() && *l > 0.0)
        .fold(f32::MIN_POSITIVE, f32::max);
    let inv: Vec<f32> = losses
        .iter()
        .map(|&l| {
            let l = if l.is_finite() && l > 0.0 { l } else { worst };
            1.0 / l
        })
        .collect();
    let sum: f32 = inv.iter().sum();
    inv.iter().map(|&x| x / sum).collect()
}

/// Aggregate client parameter rows with the given weights. Uses the Pallas
/// kernel through PJRT when the cluster fits the AOT slot count, otherwise
/// the host fallback (identical numerics — see runtime tests). Both
/// branches write into the caller's `out` buffer instead of replacing the
/// vector per call; the host branch is fully allocation-free, while the
/// PJRT branch still stages its zero-padded `slots × P` kernel input
/// internally (see [`ModelRuntime::aggregate_into`]) — dispatch overhead
/// dominates that path anyway.
pub fn aggregate(
    rt: &ModelRuntime,
    rows: &[&[f32]],
    weights: &[f32],
    out: &mut Vec<f32>,
) -> Result<()> {
    assert_eq!(rows.len(), weights.len());
    if rows.len() <= rt.spec.agg_slots {
        rt.aggregate_into(rows, weights, out)?;
    } else {
        out.resize(rt.spec.param_count, 0.0);
        aggregate_host_into(rows, weights, out);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{property, Gen};

    #[test]
    fn fedavg_weights_proportional() {
        let w = fedavg_weights(&[10, 30, 60]);
        assert!((w[0] - 0.1).abs() < 1e-6);
        assert!((w[1] - 0.3).abs() < 1e-6);
        assert!((w[2] - 0.6).abs() < 1e-6);
    }

    #[test]
    fn quality_weights_inverse_loss() {
        // L = [1, 2] → inverse [1, 0.5] → normalised [2/3, 1/3]
        let w = quality_weights(&[1.0, 2.0]);
        assert!((w[0] - 2.0 / 3.0).abs() < 1e-6);
        assert!((w[1] - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn quality_weights_lower_loss_gets_more() {
        let w = quality_weights(&[0.1, 1.0, 10.0]);
        assert!(w[0] > w[1] && w[1] > w[2]);
    }

    #[test]
    fn quality_weights_handle_infinite_loss() {
        let w = quality_weights(&[f32::INFINITY, 1.0, 2.0]);
        assert!(w.iter().all(|x| x.is_finite() && *x > 0.0));
        // the infinite-loss client is treated as worst (2.0), not dominant
        assert!((w[0] - w[2]).abs() < 1e-6);
    }

    #[test]
    fn weight_vectors_are_distributions() {
        property("weights sum to 1", 64, |g: &mut Gen| {
            let n = g.usize_in(1, 20);
            let sizes: Vec<usize> = (0..n).map(|_| g.usize_in(1, 500)).collect();
            let w1 = fedavg_weights(&sizes);
            assert!((w1.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            assert!(w1.iter().all(|&x| x >= 0.0));
            let losses: Vec<f32> = (0..n).map(|_| g.f64_in(0.01, 5.0) as f32).collect();
            let w2 = quality_weights(&losses);
            assert!((w2.iter().sum::<f32>() - 1.0).abs() < 1e-4);
            assert!(w2.iter().all(|&x| x >= 0.0));
        });
    }
}

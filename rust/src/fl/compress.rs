//! Upload compression (`--compress none|topk:<frac>|int8`): the wire
//! plane that shrinks member → PS and PS → GS parameter uploads, billed
//! through the [`Payload`] accounting seam so Eq. 6/7 time and energy see
//! the real bytes on the wire.
//!
//! Both lossy modes carry **error feedback**: what the encoder drops or
//! rounds away this round is parked in a per-sender residual and added
//! back into the next round's delta, so quantisation error accumulates
//! into later uploads instead of being lost (Seide et al. 2014; Stich
//! et al. 2018). Residual buffers live in the coordinator's `ParamPool`
//! and are flushed when re-clustering invalidates the sender's base
//! model, exactly like parked buffered contributions.
//!
//! Determinism contract: encoding happens on the coordinator thread in
//! member order (never inside engine jobs), top-k selection uses a total
//! order (`|v|` descending, lowest index wins ties), and `--compress
//! none` is a structural no-op — byte-identical to the pre-compression
//! goldens.

use crate::network::{Payload, WireBits};

/// What an upload looks like on the wire.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressMode {
    /// Dense f32 parameters — the historical wire format, bit-identical
    /// to the pre-compression accounting and trajectories.
    None,
    /// Top-k sparsification: keep the `frac·P` largest-magnitude delta
    /// coordinates, send them as f32 values plus bit-packed
    /// `ceil(log2(P))`-bit indices; the rest feeds the residual.
    TopK(f64),
    /// Uniform int8 quantisation of the delta: one f32 scale per upload
    /// (`max|v|/127`), 8-bit codes; rounding error feeds the residual.
    Int8,
}

impl CompressMode {
    /// Parse the `--compress` flag value (`none`, `topk:<frac>`, `int8`).
    /// Range validation lives in `ExperimentConfig::validate`.
    pub fn parse(s: &str) -> Option<CompressMode> {
        match s {
            "none" => Some(CompressMode::None),
            "int8" => Some(CompressMode::Int8),
            _ => {
                let frac: f64 = s.strip_prefix("topk:")?.parse().ok()?;
                frac.is_finite().then_some(CompressMode::TopK(frac))
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            CompressMode::None => "none".into(),
            CompressMode::TopK(frac) => format!("topk:{frac}"),
            CompressMode::Int8 => "int8".into(),
        }
    }

    /// Coordinates kept by a top-k upload of a `param_count` model.
    pub fn kept(frac: f64, param_count: usize) -> usize {
        ((frac * param_count as f64).ceil() as usize).clamp(1, param_count)
    }

    /// The exact wire format of one upload under this mode.
    pub fn payload(&self, param_count: usize) -> Payload {
        match *self {
            CompressMode::None => Payload::dense(param_count),
            CompressMode::TopK(frac) => {
                let k = CompressMode::kept(frac, param_count);
                Payload {
                    values: k,
                    value_bits: 32,
                    indices: k,
                    index_bits: ceil_log2(param_count),
                    // kept-count (u32) + base-model version tag (u32)
                    header_bytes: 8,
                }
            }
            CompressMode::Int8 => Payload {
                values: param_count,
                value_bits: 8,
                indices: 0,
                index_bits: 0,
                // scale (f32) + length (u32) + base-model version (u32)
                header_bytes: 12,
            },
        }
    }

    /// Billed bits of one model exchange: compressed uplink, dense f32
    /// downlink (the broadcast back is never compressed — every receiver
    /// needs the exact new base model for the next round's delta).
    pub fn wire(&self, param_count: usize) -> WireBits {
        WireBits {
            up: self.payload(param_count).bits(),
            down: Payload::dense(param_count).bits(),
        }
    }

    /// Whether encoding is a no-op (skip residual allocation entirely).
    pub fn is_none(&self) -> bool {
        matches!(self, CompressMode::None)
    }
}

/// Bits needed to index a coordinate of an `n`-vector: `ceil(log2(n))`,
/// at least 1.
fn ceil_log2(n: usize) -> u32 {
    assert!(n > 0, "empty payload");
    (usize::BITS - (n - 1).leading_zeros()).max(1)
}

/// Reused encoder workspace (delta vector + index permutation), so the
/// per-member encode loop allocates nothing in steady state.
#[derive(Debug, Default)]
pub struct CompressScratch {
    v: Vec<f32>,
    idx: Vec<u32>,
}

impl CompressScratch {
    pub fn new() -> CompressScratch {
        CompressScratch::default()
    }
}

/// Encode one upload in place and return what it costs on the wire.
///
/// `params` holds the sender's trained model and `base` the broadcast
/// model it trained from (which the receiver also holds — deltas are
/// coded against it). The error-feedback delta is
/// `v = (params − base) + residual`; on return `params` holds the
/// **decoded** model the receiver reconstructs (`base` + transmitted
/// delta) and `residual` holds what was dropped, so that transmitted +
/// residual′ recovers `v` (bitwise exactly for top-k). `--compress none`
/// touches nothing.
pub fn encode_upload(
    mode: CompressMode,
    params: &mut [f32],
    base: &[f32],
    residual: &mut [f32],
    scratch: &mut CompressScratch,
) -> Payload {
    let n = params.len();
    assert_eq!(base.len(), n, "base/model length mismatch");
    if mode.is_none() {
        return Payload::dense(n);
    }
    assert_eq!(residual.len(), n, "residual length mismatch");
    let v = &mut scratch.v;
    v.clear();
    v.extend((0..n).map(|i| (params[i] - base[i]) + residual[i]));
    match mode {
        CompressMode::None => unreachable!("handled above"),
        CompressMode::TopK(frac) => {
            let k = CompressMode::kept(frac, n);
            let idx = &mut scratch.idx;
            idx.clear();
            idx.extend(0..n as u32);
            if k < n {
                // total order: |v| descending, lowest index wins ties —
                // the selected set is unique, so encoding is deterministic
                idx.select_nth_unstable_by(k - 1, |&a, &b| {
                    v[b as usize]
                        .abs()
                        .total_cmp(&v[a as usize].abs())
                        .then(a.cmp(&b))
                });
            }
            for i in 0..n {
                params[i] = base[i];
                residual[i] = v[i];
            }
            for &i in &idx[..k] {
                let i = i as usize;
                params[i] = base[i] + v[i];
                residual[i] = 0.0;
            }
        }
        CompressMode::Int8 => {
            let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            if max_abs == 0.0 {
                // nothing to send: the delta is exactly zero everywhere
                params.copy_from_slice(base);
                residual.fill(0.0);
            } else {
                let scale = max_abs / 127.0;
                for i in 0..n {
                    let q = (v[i] / scale).round().clamp(-127.0, 127.0);
                    let deq = q * scale;
                    params[i] = base[i] + deq;
                    residual[i] = v[i] - deq;
                }
            }
        }
    }
    mode.payload(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickprop::{property, Gen};

    #[test]
    fn parse_roundtrips_and_rejects_junk() {
        assert_eq!(CompressMode::parse("none"), Some(CompressMode::None));
        assert_eq!(CompressMode::parse("int8"), Some(CompressMode::Int8));
        assert_eq!(
            CompressMode::parse("topk:0.1"),
            Some(CompressMode::TopK(0.1))
        );
        assert_eq!(CompressMode::parse("topk:"), None);
        assert_eq!(CompressMode::parse("topk:lots"), None);
        assert_eq!(CompressMode::parse("topk:inf"), None);
        assert_eq!(CompressMode::parse("gzip"), None);
        for s in ["none", "topk:0.25", "int8"] {
            let m = CompressMode::parse(s).unwrap();
            assert_eq!(CompressMode::parse(&m.name()), Some(m));
        }
    }

    #[test]
    fn payload_shapes_and_index_packing() {
        // dense: the historical 32·P bits exactly
        let p = CompressMode::None.payload(2442);
        assert_eq!(p, Payload::dense(2442));
        assert_eq!(p.bits().to_bits(), (2442.0f64 * 32.0).to_bits());
        // top-k: bit-packed indices make the 15 %-of-dense budget reachable
        // on small models — 2442 params need 12-bit indices, not 32
        let p = CompressMode::TopK(0.1).payload(2442);
        assert_eq!(p.values, 245); // ceil(0.1 · 2442)
        assert_eq!(p.indices, 245);
        assert_eq!(p.index_bits, 12);
        assert_eq!(p.header_bytes, 8);
        assert!(p.bits() <= 0.15 * Payload::dense(2442).bits(), "{}", p.bits());
        // int8: a quarter of dense plus a fixed header
        let p = CompressMode::Int8.payload(2442);
        assert_eq!(p.bits(), 2442.0 * 8.0 + 96.0);
        // wire(): uplink compressed, downlink dense; `none` fully dense
        let w = CompressMode::TopK(0.1).wire(2442);
        assert!(w.up < w.down);
        assert_eq!(w.down, Payload::dense(2442).bits());
        let w = CompressMode::None.wire(2442);
        assert_eq!(w.up.to_bits(), WireBits::dense(2442).up.to_bits());
        assert_eq!(w.down.to_bits(), WireBits::dense(2442).down.to_bits());
    }

    #[test]
    fn ceil_log2_is_index_width() {
        for (n, bits) in [(1, 1), (2, 1), (3, 2), (4, 2), (5, 3), (2442, 12), (61_706, 16)] {
            assert_eq!(ceil_log2(n), bits, "n={n}");
            // every index 0..n fits in `bits`
            assert!(n <= 1usize << bits);
        }
    }

    #[test]
    fn kept_clamps_to_valid_range() {
        assert_eq!(CompressMode::kept(0.1, 2442), 245);
        assert_eq!(CompressMode::kept(1.0, 10), 10);
        assert_eq!(CompressMode::kept(1e-9, 10), 1);
        assert_eq!(CompressMode::kept(5.0, 10), 10);
    }

    #[test]
    fn none_mode_touches_nothing() {
        let base = vec![1.0f32, 2.0, 3.0];
        let mut params = vec![1.5f32, 1.5, 1.5];
        let before = params.clone();
        let mut residual = vec![0.25f32; 3];
        let mut scratch = CompressScratch::new();
        let p = encode_upload(
            CompressMode::None,
            &mut params,
            &base,
            &mut residual,
            &mut scratch,
        );
        assert_eq!(p, Payload::dense(3));
        assert_eq!(params, before);
        assert_eq!(residual, vec![0.25; 3]);
    }

    #[test]
    fn topk_ties_pick_lowest_index() {
        // four coordinates with equal |delta|: k = 2 must keep 0 and 1
        let base = vec![0.0f32; 4];
        let mut params = vec![1.0f32, -1.0, 1.0, -1.0];
        let mut residual = vec![0.0f32; 4];
        let mut scratch = CompressScratch::new();
        encode_upload(
            CompressMode::TopK(0.5),
            &mut params,
            &base,
            &mut residual,
            &mut scratch,
        );
        assert_eq!(params, vec![1.0, -1.0, 0.0, 0.0]);
        assert_eq!(residual, vec![0.0, 0.0, 1.0, -1.0]);
    }

    #[test]
    fn topk_error_feedback_is_bitwise_exact() {
        property("top-k: transmitted + residual′ == v bitwise", 128, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let base = g.f32_vec(n, -1.0, 1.0);
            let trained = g.f32_vec(n, -1.0, 1.0);
            let residual0 = g.f32_vec(n, -0.1, 0.1);
            let frac = g.f64_in(0.05, 1.0);
            let v: Vec<f32> = (0..n)
                .map(|i| (trained[i] - base[i]) + residual0[i])
                .collect();
            let mut params = trained.clone();
            let mut residual = residual0.clone();
            let mut scratch = CompressScratch::new();
            let p = encode_upload(
                CompressMode::TopK(frac),
                &mut params,
                &base,
                &mut residual,
                &mut scratch,
            );
            let k = CompressMode::kept(frac, n);
            assert_eq!(p.values, k);
            let mut sent = 0;
            for i in 0..n {
                if params[i].to_bits() == base[i].to_bits() {
                    // dropped coordinate: the whole delta went to residual
                    assert_eq!(residual[i].to_bits(), v[i].to_bits(), "i={i}");
                } else {
                    // kept coordinate: decoded = base + v, residual cleared
                    sent += 1;
                    assert_eq!(params[i].to_bits(), (base[i] + v[i]).to_bits(), "i={i}");
                    assert_eq!(residual[i], 0.0, "i={i}");
                }
            }
            assert!(sent <= k, "{sent} > k={k}");
        });
    }

    #[test]
    fn int8_error_bounded_by_half_step() {
        property("int8: |v − deq| ≤ scale·0.501", 128, |g: &mut Gen| {
            let n = g.usize_in(1, 64);
            let base = g.f32_vec(n, -2.0, 2.0);
            let trained = g.f32_vec(n, -2.0, 2.0);
            let residual0 = g.f32_vec(n, -0.1, 0.1);
            let v: Vec<f32> = (0..n)
                .map(|i| (trained[i] - base[i]) + residual0[i])
                .collect();
            let max_abs = v.iter().fold(0.0f32, |m, x| m.max(x.abs()));
            let mut params = trained.clone();
            let mut residual = residual0.clone();
            let mut scratch = CompressScratch::new();
            encode_upload(
                CompressMode::Int8,
                &mut params,
                &base,
                &mut residual,
                &mut scratch,
            );
            if max_abs == 0.0 {
                for i in 0..n {
                    assert_eq!(params[i], base[i]);
                    assert_eq!(residual[i], 0.0);
                }
                return;
            }
            let scale = max_abs / 127.0;
            for i in 0..n {
                // the residual is exactly the rounding error, and it is
                // bounded by (just over) half a quantisation step
                assert!(residual[i].abs() <= scale * 0.501, "i={i}: {} vs {scale}", residual[i]);
                let deq = v[i] - residual[i];
                assert_eq!(params[i].to_bits(), (base[i] + deq).to_bits(), "i={i}");
                // decoded delta is a representable code times the scale
                let q = (deq / scale).round();
                assert!(q.abs() <= 127.0, "i={i}: code {q}");
            }
        });
    }

    #[test]
    fn residuals_accumulate_across_rounds() {
        // a delta too small to survive top-k eventually ships once the
        // residual has grown past the competing coordinate — the classic
        // error-feedback liveness property
        let base = vec![0.0f32; 2];
        let mut residual = vec![0.0f32; 2];
        let mut scratch = CompressScratch::new();
        let mut shipped_small = false;
        for _ in 0..8 {
            // coordinate 0 trains a big delta, coordinate 1 a small one
            let mut params = vec![1.0f32, 0.3];
            encode_upload(
                CompressMode::TopK(0.5),
                &mut params,
                &base,
                &mut residual,
                &mut scratch,
            );
            if params[1] != 0.0 {
                shipped_small = true;
                break;
            }
        }
        assert!(shipped_small, "residual never flushed coordinate 1");
    }
}

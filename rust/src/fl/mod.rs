//! Federated-learning engine (paper §II-B): satellite clients, local
//! training (Eq. 3–4), weighted aggregation (Eq. 5 FedAvg, Eq. 12 loss-
//! quality weights), and test-set evaluation. The engine is shared by
//! FedHC and all three baselines so the accounting is apples-to-apples.

pub mod aggregate;
pub mod client;
pub mod compress;
pub mod evaluate;
pub mod local;

pub use aggregate::{fedavg_weights, fold_stale, quality_weights, stale_composed_weights, staleness_weight};
pub use client::SatClient;
pub use compress::{encode_upload, CompressMode, CompressScratch};

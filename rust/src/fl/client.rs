//! Satellite client state.

use crate::data::Dataset;

/// One satellite client: its data shard, current model, and the compute
/// heterogeneity the time/energy models consume.
#[derive(Clone, Debug)]
pub struct SatClient {
    /// Index into the constellation (position source).
    pub sat: usize,
    /// Local data shard D_i.
    pub shard: Dataset,
    /// Current local model (flat parameter vector).
    pub params: Vec<f32>,
    /// CPU frequency f_i, Hz.
    pub cpu_hz: f64,
    /// Most recent local training loss L_i (drives Eq. 12 weights).
    pub last_loss: f32,
    /// Rounds of local training performed (diagnostics).
    pub rounds_trained: usize,
}

impl SatClient {
    pub fn new(sat: usize, shard: Dataset, params: Vec<f32>, cpu_hz: f64) -> Self {
        SatClient {
            sat,
            shard,
            params,
            cpu_hz,
            last_loss: f32::INFINITY,
            rounds_trained: 0,
        }
    }

    /// |D_i|.
    pub fn data_size(&self) -> usize {
        self.shard.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_tiny;
    use crate::util::Rng;

    #[test]
    fn construction() {
        let shard = synth_tiny(12, &mut Rng::new(1));
        let c = SatClient::new(7, shard, vec![0.0; 10], 1e9);
        assert_eq!(c.sat, 7);
        assert_eq!(c.data_size(), 12);
        assert_eq!(c.rounds_trained, 0);
        assert!(c.last_loss.is_infinite());
    }
}

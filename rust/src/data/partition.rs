//! Client sharding: split a dataset across satellite clients.
//!
//! The paper partitions "the original dataset into different subsets
//! corresponding to the number of satellite clients". We provide the two
//! standard regimes: IID (random equal shards) and Dirichlet(α) label-skew
//! non-IID, which FedCE's distribution-based clustering needs to have any
//! structure to find.

use super::dataset::Dataset;
use crate::util::Rng;

/// IID partition into `clients` equal shards (remainder spread across the
/// first shards).
pub fn partition_iid(data: &Dataset, clients: usize, rng: &mut Rng) -> Vec<Dataset> {
    assert!(clients > 0);
    assert!(
        data.len() >= clients,
        "{} samples cannot cover {} clients",
        data.len(),
        clients
    );
    let mut idx: Vec<usize> = (0..data.len()).collect();
    rng.shuffle(&mut idx);
    let base = data.len() / clients;
    let extra = data.len() % clients;
    let mut shards = Vec::with_capacity(clients);
    let mut off = 0;
    for c in 0..clients {
        let take = base + usize::from(c < extra);
        shards.push(data.subset(&idx[off..off + take]));
        off += take;
    }
    shards
}

/// Dirichlet(α) label-skew partition: for each class, the class's samples
/// are split across clients by a Dirichlet draw. Small α → highly skewed.
/// Every client is guaranteed at least `min_per_client` samples by
/// stealing from the largest shard.
pub fn partition_dirichlet(
    data: &Dataset,
    clients: usize,
    alpha: f64,
    min_per_client: usize,
    rng: &mut Rng,
) -> Vec<Dataset> {
    assert!(clients > 0 && alpha > 0.0);
    let classes = data.kind.classes();
    let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); classes];
    for (i, &l) in data.labels.iter().enumerate() {
        by_class[l as usize].push(i);
    }
    let mut client_indices: Vec<Vec<usize>> = vec![Vec::new(); clients];
    for class_samples in by_class.iter_mut() {
        rng.shuffle(class_samples);
        let props = rng.dirichlet(alpha, clients);
        // convert proportions to cumulative cut points
        let n = class_samples.len();
        let mut start = 0usize;
        let mut acc = 0.0;
        for (c, &p) in props.iter().enumerate() {
            acc += p;
            let end = if c + 1 == clients {
                n
            } else {
                (acc * n as f64).round() as usize
            }
            .min(n);
            client_indices[c].extend_from_slice(&class_samples[start..end.max(start)]);
            start = end.max(start);
        }
    }
    // enforce the floor
    for c in 0..clients {
        while client_indices[c].len() < min_per_client {
            let donor = (0..clients)
                .max_by_key(|&d| client_indices[d].len())
                .unwrap();
            if donor == c || client_indices[donor].len() <= min_per_client {
                break;
            }
            let moved = client_indices[donor].pop().unwrap();
            client_indices[c].push(moved);
        }
    }
    client_indices
        .iter()
        .map(|idx| data.subset(idx))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::synth_tiny;

    #[test]
    fn iid_covers_everything_once() {
        let mut rng = Rng::new(1);
        let d = synth_tiny(103, &mut rng);
        let shards = partition_iid(&d, 10, &mut rng);
        assert_eq!(shards.len(), 10);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 103);
        // sizes differ by at most 1
        let min = shards.iter().map(|s| s.len()).min().unwrap();
        let max = shards.iter().map(|s| s.len()).max().unwrap();
        assert!(max - min <= 1);
    }

    #[test]
    fn iid_shards_have_balanced_labels() {
        let mut rng = Rng::new(2);
        let d = synth_tiny(2000, &mut rng);
        let shards = partition_iid(&d, 4, &mut rng);
        for s in &shards {
            let h = s.label_histogram();
            for &p in &h {
                assert!((p - 0.1).abs() < 0.05, "{h:?}");
            }
        }
    }

    #[test]
    fn dirichlet_covers_everything_once() {
        let mut rng = Rng::new(3);
        let d = synth_tiny(500, &mut rng);
        let shards = partition_dirichlet(&d, 8, 0.5, 5, &mut rng);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, 500);
        assert!(shards.iter().all(|s| s.len() >= 5));
    }

    #[test]
    fn small_alpha_skews_more_than_large() {
        fn mean_hist_l2_from_uniform(shards: &[Dataset]) -> f64 {
            let mut tot = 0.0;
            for s in shards {
                let h = s.label_histogram();
                tot += h.iter().map(|p| (p - 0.1) * (p - 0.1)).sum::<f64>().sqrt();
            }
            tot / shards.len() as f64
        }
        let mut rng = Rng::new(4);
        let d = synth_tiny(3000, &mut rng);
        let skewed = partition_dirichlet(&d, 10, 0.1, 1, &mut rng);
        let mild = partition_dirichlet(&d, 10, 100.0, 1, &mut rng);
        let s_skew = mean_hist_l2_from_uniform(&skewed);
        let s_mild = mean_hist_l2_from_uniform(&mild);
        assert!(
            s_skew > 2.0 * s_mild,
            "alpha=0.1 skew {s_skew} vs alpha=100 skew {s_mild}"
        );
    }

    #[test]
    fn deterministic_given_seed() {
        let d = synth_tiny(200, &mut Rng::new(5));
        let a = partition_dirichlet(&d, 5, 0.5, 2, &mut Rng::new(9));
        let b = partition_dirichlet(&d, 5, 0.5, 2, &mut Rng::new(9));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.labels, y.labels);
        }
    }
}

//! Loaders for the real benchmark files when they are available:
//! * MNIST IDX (`train-images-idx3-ubyte`, `train-labels-idx1-ubyte`),
//! * CIFAR-10 binary batches (`data_batch_N.bin`, 1 + 3072 bytes/record).
//!
//! `load_or_synth` is the single entry point: it probes `data/<name>/` and
//! falls back to the synthetic generator (DESIGN.md §3 substitution).

use super::dataset::{Dataset, DatasetKind};
use super::synth;
use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

#[derive(Debug)]
pub enum IdxError {
    Io(std::io::Error),
    Format(String),
}

impl std::fmt::Display for IdxError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            IdxError::Io(e) => write!(f, "idx io error: {e}"),
            IdxError::Format(m) => write!(f, "idx format error: {m}"),
        }
    }
}

impl std::error::Error for IdxError {}

impl From<std::io::Error> for IdxError {
    fn from(e: std::io::Error) -> Self {
        IdxError::Io(e)
    }
}

fn read_u32_be(b: &[u8], off: usize) -> u32 {
    u32::from_be_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

/// Parse an IDX file into (dims, payload bytes).
pub fn parse_idx(bytes: &[u8]) -> Result<(Vec<usize>, &[u8]), IdxError> {
    if bytes.len() < 4 {
        return Err(IdxError::Format("truncated header".into()));
    }
    if bytes[0] != 0 || bytes[1] != 0 {
        return Err(IdxError::Format("bad magic".into()));
    }
    if bytes[2] != 0x08 {
        return Err(IdxError::Format(format!(
            "unsupported dtype 0x{:02x} (only u8)",
            bytes[2]
        )));
    }
    let ndim = bytes[3] as usize;
    let header = 4 + 4 * ndim;
    if bytes.len() < header {
        return Err(IdxError::Format("truncated dims".into()));
    }
    let dims: Vec<usize> = (0..ndim)
        .map(|i| read_u32_be(bytes, 4 + 4 * i) as usize)
        .collect();
    let expect: usize = dims.iter().product();
    let payload = &bytes[header..];
    if payload.len() != expect {
        return Err(IdxError::Format(format!(
            "payload {} != dims product {}",
            payload.len(),
            expect
        )));
    }
    Ok((dims, payload))
}

/// Load an MNIST-format pair of IDX files.
pub fn load_mnist_idx(images_path: &Path, labels_path: &Path) -> Result<Dataset, IdxError> {
    let img_bytes = fs::read(images_path)?;
    let lbl_bytes = fs::read(labels_path)?;
    let (idims, ipay) = parse_idx(&img_bytes)?;
    let (ldims, lpay) = parse_idx(&lbl_bytes)?;
    if idims.len() != 3 || idims[1] != 28 || idims[2] != 28 {
        return Err(IdxError::Format(format!("unexpected image dims {idims:?}")));
    }
    if ldims.len() != 1 || ldims[0] != idims[0] {
        return Err(IdxError::Format("label/image count mismatch".into()));
    }
    let images: Vec<f32> = ipay.iter().map(|&b| b as f32 / 255.0).collect();
    Ok(Dataset::new(DatasetKind::Mnist, images, lpay.to_vec()))
}

/// Load CIFAR-10 binary batches (each record: 1 label byte + 3072 pixels).
pub fn load_cifar_bin(paths: &[PathBuf]) -> Result<Dataset, IdxError> {
    const REC: usize = 1 + 3072;
    let mut images = Vec::new();
    let mut labels = Vec::new();
    for p in paths {
        let mut bytes = Vec::new();
        fs::File::open(p)?.read_to_end(&mut bytes)?;
        if bytes.len() % REC != 0 {
            return Err(IdxError::Format(format!(
                "{} not a multiple of {REC}",
                bytes.len()
            )));
        }
        for rec in bytes.chunks_exact(REC) {
            labels.push(rec[0]);
            images.extend(rec[1..].iter().map(|&b| b as f32 / 255.0));
        }
    }
    if labels.is_empty() {
        return Err(IdxError::Format("no records".into()));
    }
    Ok(Dataset::new(DatasetKind::Cifar10, images, labels))
}

/// Probe for real data under `root`; otherwise synthesize (train, test).
pub fn load_or_synth(
    kind: DatasetKind,
    root: &Path,
    train_n: usize,
    test_n: usize,
    seed: u64,
) -> (Dataset, Dataset, bool) {
    match kind {
        DatasetKind::Mnist => {
            let d = root.join("mnist");
            let ti = d.join("train-images-idx3-ubyte");
            let tl = d.join("train-labels-idx1-ubyte");
            let si = d.join("t10k-images-idx3-ubyte");
            let sl = d.join("t10k-labels-idx1-ubyte");
            if ti.exists() && tl.exists() && si.exists() && sl.exists() {
                if let (Ok(tr), Ok(te)) = (load_mnist_idx(&ti, &tl), load_mnist_idx(&si, &sl)) {
                    return (tr, te, true);
                }
            }
        }
        DatasetKind::Cifar10 => {
            let d = root.join("cifar-10-batches-bin");
            let train: Vec<PathBuf> = (1..=5).map(|i| d.join(format!("data_batch_{i}.bin"))).collect();
            let test = vec![d.join("test_batch.bin")];
            if train.iter().all(|p| p.exists()) && test[0].exists() {
                if let (Ok(tr), Ok(te)) = (load_cifar_bin(&train), load_cifar_bin(&test)) {
                    return (tr, te, true);
                }
            }
        }
        DatasetKind::Tiny => {}
    }
    let (tr, te) = synth::generate(kind, train_n, test_n, seed);
    (tr, te, false)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn make_idx(dims: &[u32], payload: &[u8]) -> Vec<u8> {
        let mut v = vec![0, 0, 0x08, dims.len() as u8];
        for d in dims {
            v.extend_from_slice(&d.to_be_bytes());
        }
        v.extend_from_slice(payload);
        v
    }

    #[test]
    fn parse_idx_roundtrip() {
        let payload: Vec<u8> = (0..24).collect();
        let bytes = make_idx(&[2, 3, 4], &payload);
        let (dims, pay) = parse_idx(&bytes).unwrap();
        assert_eq!(dims, vec![2, 3, 4]);
        assert_eq!(pay, &payload[..]);
    }

    #[test]
    fn parse_idx_rejects_bad_magic() {
        let mut bytes = make_idx(&[4], &[1, 2, 3, 4]);
        bytes[0] = 9;
        assert!(parse_idx(&bytes).is_err());
    }

    #[test]
    fn parse_idx_rejects_size_mismatch() {
        let bytes = make_idx(&[5], &[1, 2, 3]);
        assert!(parse_idx(&bytes).is_err());
    }

    #[test]
    fn load_mnist_idx_from_temp_files() {
        let dir = std::env::temp_dir().join("fedhc_idx_test");
        fs::create_dir_all(&dir).unwrap();
        let n = 7;
        let images = make_idx(&[n, 28, 28], &vec![128u8; (n * 28 * 28) as usize]);
        let labels = make_idx(&[n], &(0..n as u8).collect::<Vec<u8>>());
        let ip = dir.join("imgs");
        let lp = dir.join("lbls");
        fs::write(&ip, &images).unwrap();
        fs::write(&lp, &labels).unwrap();
        let d = load_mnist_idx(&ip, &lp).unwrap();
        assert_eq!(d.len(), 7);
        assert!((d.images[0] - 128.0 / 255.0).abs() < 1e-6);
        assert_eq!(d.labels, (0..7).collect::<Vec<u8>>());
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_cifar_bin_from_temp_file() {
        let dir = std::env::temp_dir().join("fedhc_cifar_test");
        fs::create_dir_all(&dir).unwrap();
        let mut bytes = Vec::new();
        for lbl in 0..3u8 {
            bytes.push(lbl);
            bytes.extend(std::iter::repeat(255u8).take(3072));
        }
        let p = dir.join("batch.bin");
        fs::write(&p, &bytes).unwrap();
        let d = load_cifar_bin(&[p]).unwrap();
        assert_eq!(d.len(), 3);
        assert_eq!(d.labels, vec![0, 1, 2]);
        assert_eq!(d.images[0], 1.0);
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_synth_falls_back() {
        let (tr, te, real) = load_or_synth(
            DatasetKind::Tiny,
            Path::new("/nonexistent"),
            40,
            10,
            1,
        );
        assert!(!real);
        assert_eq!(tr.len(), 40);
        assert_eq!(te.len(), 10);
    }
}

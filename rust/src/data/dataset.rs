//! In-memory image-classification dataset with shard views.

/// Which benchmark geometry a dataset follows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    /// 1×28×28 grayscale, 10 classes (MNIST geometry).
    Mnist,
    /// 3×32×32 color, 10 classes (CIFAR-10 geometry).
    Cifar10,
    /// 1×8×8, 10 classes — tiny synthetic used by fast tests.
    Tiny,
}

impl DatasetKind {
    pub fn dims(&self) -> (usize, usize, usize) {
        match self {
            DatasetKind::Mnist => (1, 28, 28),
            DatasetKind::Cifar10 => (3, 32, 32),
            DatasetKind::Tiny => (1, 8, 8),
        }
    }

    pub fn classes(&self) -> usize {
        10
    }

    pub fn sample_len(&self) -> usize {
        let (c, h, w) = self.dims();
        c * h * w
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetKind::Mnist => "mnist",
            DatasetKind::Cifar10 => "cifar10",
            DatasetKind::Tiny => "tiny",
        }
    }

    pub fn parse(s: &str) -> Option<DatasetKind> {
        match s {
            "mnist" => Some(DatasetKind::Mnist),
            "cifar10" | "cifar" => Some(DatasetKind::Cifar10),
            "tiny" => Some(DatasetKind::Tiny),
            _ => None,
        }
    }
}

/// A dense dataset: images flattened row-major as `[n, c*h*w]` f32 in
/// [0, 1] (normalised), labels in `[0, classes)`.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub kind: DatasetKind,
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
}

impl Dataset {
    pub fn new(kind: DatasetKind, images: Vec<f32>, labels: Vec<u8>) -> Self {
        assert_eq!(images.len(), labels.len() * kind.sample_len());
        Dataset {
            kind,
            images,
            labels,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Slice of one sample's pixels.
    pub fn image(&self, i: usize) -> &[f32] {
        let s = self.kind.sample_len();
        &self.images[i * s..(i + 1) * s]
    }

    /// Gather a sub-dataset by indices (used by the partitioner).
    pub fn subset(&self, indices: &[usize]) -> Dataset {
        let s = self.kind.sample_len();
        let mut images = Vec::with_capacity(indices.len() * s);
        let mut labels = Vec::with_capacity(indices.len());
        for &i in indices {
            images.extend_from_slice(self.image(i));
            labels.push(self.labels[i]);
        }
        Dataset {
            kind: self.kind,
            images,
            labels,
        }
    }

    /// Normalised label histogram (the FedCE clustering feature).
    pub fn label_histogram(&self) -> Vec<f64> {
        let mut h = vec![0.0f64; self.kind.classes()];
        for &l in &self.labels {
            h[l as usize] += 1.0;
        }
        let n = self.len().max(1) as f64;
        for v in h.iter_mut() {
            *v /= n;
        }
        h
    }

    /// Copy batch `b` (of size `bs`, wrapping around the end) into the
    /// provided buffers — allocation-free hot path for the trainer.
    pub fn fill_batch(&self, b: usize, bs: usize, xs: &mut [f32], ys: &mut [f32]) {
        assert!(!self.is_empty());
        let s = self.kind.sample_len();
        assert_eq!(xs.len(), bs * s);
        assert_eq!(ys.len(), bs);
        let n = self.len();
        for j in 0..bs {
            let i = (b * bs + j) % n;
            xs[j * s..(j + 1) * s].copy_from_slice(self.image(i));
            ys[j] = self.labels[i] as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n: usize) -> Dataset {
        let s = DatasetKind::Tiny.sample_len();
        let images: Vec<f32> = (0..n * s).map(|i| (i % 7) as f32 / 7.0).collect();
        let labels: Vec<u8> = (0..n).map(|i| (i % 10) as u8).collect();
        Dataset::new(DatasetKind::Tiny, images, labels)
    }

    #[test]
    fn dims_and_lengths() {
        assert_eq!(DatasetKind::Mnist.sample_len(), 784);
        assert_eq!(DatasetKind::Cifar10.sample_len(), 3072);
        assert_eq!(DatasetKind::Tiny.sample_len(), 64);
        let d = tiny(30);
        assert_eq!(d.len(), 30);
        assert_eq!(d.image(3).len(), 64);
    }

    #[test]
    fn subset_gathers_right_rows() {
        let d = tiny(20);
        let s = d.subset(&[3, 7, 11]);
        assert_eq!(s.len(), 3);
        assert_eq!(s.labels, vec![3, 7, 1]);
        assert_eq!(s.image(1), d.image(7));
    }

    #[test]
    fn histogram_sums_to_one() {
        let d = tiny(25);
        let h = d.label_histogram();
        assert_eq!(h.len(), 10);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fill_batch_wraps() {
        let d = tiny(5);
        let s = d.kind.sample_len();
        let mut xs = vec![0.0; 4 * s];
        let mut ys = vec![0.0; 4];
        d.fill_batch(1, 4, &mut xs, &mut ys); // rows 4,0,1,2
        assert_eq!(ys, vec![4.0, 0.0, 1.0, 2.0]);
        assert_eq!(&xs[0..s], d.image(4));
        assert_eq!(&xs[s..2 * s], d.image(0));
    }

    #[test]
    #[should_panic]
    fn mismatched_sizes_panic() {
        Dataset::new(DatasetKind::Tiny, vec![0.0; 10], vec![0, 1]);
    }
}

//! Dataset substrate.
//!
//! The paper trains LeNet on MNIST and CIFAR-10. This module provides:
//! * loaders for the real files when present (`idx`: MNIST IDX format and
//!   the CIFAR-10 binary batches),
//! * procedural synthetic substitutes with identical geometry
//!   (`synth`) for the offline image — see DESIGN.md §3,
//! * client sharding, IID and Dirichlet non-IID (`partition`).

pub mod dataset;
pub mod idx;
pub mod partition;
pub mod synth;

pub use dataset::{Dataset, DatasetKind};
pub use partition::{partition_dirichlet, partition_iid};

//! Procedural synthetic datasets with MNIST / CIFAR-10 geometry.
//!
//! The offline image cannot download the real corpora, so we generate
//! class-conditional data whose *learning dynamics* match what the FL
//! framework exercises: 10 visually distinct classes, intra-class variation
//! (affine jitter + noise), and difficulty calibrated so LeNet reaches the
//! paper's target accuracies (MNIST 80 %, CIFAR-10 40 %) in a comparable
//! number of rounds. If real MNIST/CIFAR files are present under
//! `data/` they are used instead (see `idx.rs`).
//!
//! * MNIST-like: 10 glyph templates (coarse 7×7 digit strokes) upsampled to
//!   28×28, randomly shifted ±3 px, scaled, with Gaussian pixel noise.
//! * CIFAR-like: 3×32×32 class-conditional color Gabor textures with random
//!   phase/orientation jitter and heavier noise (harder task, mirroring
//!   CIFAR-10's difficulty relative to MNIST).

use super::dataset::{Dataset, DatasetKind};
use crate::util::Rng;

/// 7×7 stroke templates, one per class (hand-drawn digit skeletons).
const GLYPHS: [[u8; 49]; 10] = [
    // 0
    [
        0, 1, 1, 1, 1, 1, 0, 1, 1, 0, 0, 0, 1, 1, 1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 0, 1, 1, 0,
        0, 0, 0, 0, 1, 1, 1, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 1
    [
        0, 0, 0, 1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 0, 1, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 2
    [
        0, 1, 1, 1, 1, 0, 0, 1, 0, 0, 0, 1, 1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0,
        1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 1,
    ],
    // 3
    [
        0, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 0, 1, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0,
        0, 0, 0, 1, 1, 1, 0, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 4
    [
        0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 0, 1, 0, 0, 0, 1, 0, 0, 1, 0, 0, 1, 0, 0, 0, 1, 0, 1, 1,
        1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0, 0, 1, 0,
    ],
    // 5
    [
        1, 1, 1, 1, 1, 1, 0, 1, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 0, 0, 1, 1, 0, 0,
        0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 6
    [
        0, 0, 1, 1, 1, 1, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 0, 1, 1, 1, 1, 1, 1, 0, 1, 1,
        0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 7
    [
        1, 1, 1, 1, 1, 1, 1, 0, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0,
        1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 0, 0, 0, 1, 0, 0, 0, 0, 0,
    ],
    // 8
    [
        0, 1, 1, 1, 1, 1, 0, 1, 1, 0, 0, 0, 1, 1, 0, 1, 1, 0, 1, 1, 0, 0, 0, 1, 1, 1, 0, 0, 0, 1,
        1, 0, 1, 1, 0, 1, 1, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 0,
    ],
    // 9
    [
        0, 1, 1, 1, 1, 1, 0, 1, 1, 0, 0, 0, 1, 1, 1, 1, 0, 0, 0, 1, 1, 0, 1, 1, 1, 1, 1, 1, 0, 0,
        0, 0, 0, 1, 1, 0, 0, 0, 0, 1, 1, 0, 0, 1, 1, 1, 1, 0, 0,
    ],
];

/// Generate an MNIST-geometry synthetic dataset.
pub fn synth_mnist(n: usize, rng: &mut Rng) -> Dataset {
    synth_glyph(DatasetKind::Mnist, n, rng, 28, 0.18)
}

/// Tiny 8×8 variant for fast unit/integration tests.
pub fn synth_tiny(n: usize, rng: &mut Rng) -> Dataset {
    synth_glyph(DatasetKind::Tiny, n, rng, 8, 0.10)
}

fn synth_glyph(kind: DatasetKind, n: usize, rng: &mut Rng, side: usize, noise: f64) -> Dataset {
    let sample = kind.sample_len();
    let mut images = vec![0.0f32; n * sample];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = rng.below_usize(10);
        labels[i] = class as u8;
        let glyph = &GLYPHS[class];
        // random affine jitter: shift up to ±10% of the side, scale 0.8–1.1
        let max_shift = (side as f64 * 0.11).floor();
        let dx = rng.uniform_in(-max_shift, max_shift);
        let dy = rng.uniform_in(-max_shift, max_shift);
        let scale = rng.uniform_in(0.85, 1.1);
        let img = &mut images[i * sample..(i + 1) * sample];
        for py in 0..side {
            for px in 0..side {
                // map the output pixel back into glyph space
                let gx = ((px as f64 - dx) / side as f64 - 0.5) / scale + 0.5;
                let gy = ((py as f64 - dy) / side as f64 - 0.5) / scale + 0.5;
                let v = sample_glyph(glyph, gx, gy);
                let noisy = v + noise * rng.normal();
                img[py * side + px] = noisy.clamp(0.0, 1.0) as f32;
            }
        }
    }
    Dataset::new(kind, images, labels)
}

/// Bilinear sample of a 7×7 glyph at normalised coordinates.
fn sample_glyph(glyph: &[u8; 49], x: f64, y: f64) -> f64 {
    if !(0.0..1.0).contains(&x) || !(0.0..1.0).contains(&y) {
        return 0.0;
    }
    let fx = x * 6.0;
    let fy = y * 6.0;
    let x0 = fx.floor() as usize;
    let y0 = fy.floor() as usize;
    let x1 = (x0 + 1).min(6);
    let y1 = (y0 + 1).min(6);
    let tx = fx - x0 as f64;
    let ty = fy - y0 as f64;
    let g = |xx: usize, yy: usize| glyph[yy * 7 + xx] as f64;
    g(x0, y0) * (1.0 - tx) * (1.0 - ty)
        + g(x1, y0) * tx * (1.0 - ty)
        + g(x0, y1) * (1.0 - tx) * ty
        + g(x1, y1) * tx * ty
}

/// Generate a CIFAR-10-geometry synthetic dataset: class-conditional color
/// Gabor textures. Harder than the glyph task by construction (overlapping
/// orientations + heavy noise), mirroring CIFAR-10 vs MNIST difficulty.
pub fn synth_cifar(n: usize, rng: &mut Rng) -> Dataset {
    let kind = DatasetKind::Cifar10;
    let side = 32usize;
    let sample = kind.sample_len();
    let mut images = vec![0.0f32; n * sample];
    let mut labels = vec![0u8; n];
    for i in 0..n {
        let class = rng.below_usize(10);
        labels[i] = class as u8;
        // class defines a base orientation, spatial frequency and color mix
        let theta0 = class as f64 * std::f64::consts::PI / 10.0;
        let freq0 = 2.0 + (class % 5) as f64;
        let color = [
            0.4 + 0.6 * ((class * 37 % 10) as f64 / 9.0),
            0.4 + 0.6 * ((class * 73 % 10) as f64 / 9.0),
            0.4 + 0.6 * ((class * 11 % 10) as f64 / 9.0),
        ];
        // sample-level jitter
        let theta = theta0 + rng.uniform_in(-0.15, 0.15);
        let freq = freq0 * rng.uniform_in(0.9, 1.1);
        let phase = rng.uniform_in(0.0, 2.0 * std::f64::consts::PI);
        let (st, ct) = theta.sin_cos();
        let img = &mut images[i * sample..(i + 1) * sample];
        for py in 0..side {
            for px in 0..side {
                let u = px as f64 / side as f64 - 0.5;
                let v = py as f64 / side as f64 - 0.5;
                let proj = u * ct + v * st;
                let tex = 0.5 + 0.5 * (2.0 * std::f64::consts::PI * freq * proj + phase).sin();
                for ch in 0..3 {
                    let val = tex * color[ch] + 0.25 * rng.normal();
                    img[ch * side * side + py * side + px] = val.clamp(0.0, 1.0) as f32;
                }
            }
        }
    }
    Dataset::new(kind, images, labels)
}

/// Generate train+test splits for a dataset kind.
pub fn generate(kind: DatasetKind, train_n: usize, test_n: usize, seed: u64) -> (Dataset, Dataset) {
    let mut rng = Rng::new(seed);
    match kind {
        DatasetKind::Mnist => (synth_mnist(train_n, &mut rng), synth_mnist(test_n, &mut rng)),
        DatasetKind::Cifar10 => (synth_cifar(train_n, &mut rng), synth_cifar(test_n, &mut rng)),
        DatasetKind::Tiny => (synth_tiny(train_n, &mut rng), synth_tiny(test_n, &mut rng)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_ranges() {
        let mut rng = Rng::new(1);
        let d = synth_mnist(50, &mut rng);
        assert_eq!(d.len(), 50);
        assert!(d.images.iter().all(|&v| (0.0..=1.0).contains(&v)));
        assert!(d.labels.iter().all(|&l| l < 10));
        let c = synth_cifar(20, &mut rng);
        assert_eq!(c.images.len(), 20 * 3072);
    }

    #[test]
    fn all_classes_present() {
        let mut rng = Rng::new(2);
        let d = synth_mnist(500, &mut rng);
        let h = d.label_histogram();
        assert!(h.iter().all(|&p| p > 0.03), "{h:?}");
    }

    #[test]
    fn deterministic_by_seed() {
        let (a, _) = generate(DatasetKind::Tiny, 30, 5, 42);
        let (b, _) = generate(DatasetKind::Tiny, 30, 5, 42);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.images, b.images);
        let (c, _) = generate(DatasetKind::Tiny, 30, 5, 43);
        assert_ne!(a.images, c.images);
    }

    #[test]
    fn classes_are_distinguishable() {
        // a nearest-class-mean classifier on raw pixels must beat chance by
        // a wide margin — otherwise the FL task would be unlearnable
        let mut rng = Rng::new(3);
        let train = synth_mnist(800, &mut rng);
        let test = synth_mnist(200, &mut rng);
        let s = train.kind.sample_len();
        let mut means = vec![vec![0.0f64; s]; 10];
        let mut counts = [0usize; 10];
        for i in 0..train.len() {
            let c = train.labels[i] as usize;
            counts[c] += 1;
            for (j, &v) in train.image(i).iter().enumerate() {
                means[c][j] += v as f64;
            }
        }
        for c in 0..10 {
            for v in means[c].iter_mut() {
                *v /= counts[c].max(1) as f64;
            }
        }
        let mut correct = 0;
        for i in 0..test.len() {
            let img = test.image(i);
            let best = (0..10)
                .min_by(|&a, &b| {
                    let da: f64 = img
                        .iter()
                        .zip(&means[a])
                        .map(|(&x, &m)| (x as f64 - m).powi(2))
                        .sum();
                    let db: f64 = img
                        .iter()
                        .zip(&means[b])
                        .map(|(&x, &m)| (x as f64 - m).powi(2))
                        .sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == test.labels[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / test.len() as f64;
        assert!(acc > 0.6, "nearest-mean accuracy only {acc}");
    }

    #[test]
    fn cifar_harder_than_mnist() {
        // same nearest-mean probe: the CIFAR-like task should be harder
        fn nm_acc(train: &Dataset, test: &Dataset) -> f64 {
            let s = train.kind.sample_len();
            let mut means = vec![vec![0.0f64; s]; 10];
            let mut counts = [0usize; 10];
            for i in 0..train.len() {
                let c = train.labels[i] as usize;
                counts[c] += 1;
                for (j, &v) in train.image(i).iter().enumerate() {
                    means[c][j] += v as f64;
                }
            }
            for c in 0..10 {
                for v in means[c].iter_mut() {
                    *v /= counts[c].max(1) as f64;
                }
            }
            let mut correct = 0;
            for i in 0..test.len() {
                let img = test.image(i);
                let best = (0..10)
                    .min_by(|&a, &b| {
                        let da: f64 = img
                            .iter()
                            .zip(&means[a])
                            .map(|(&x, &m)| (x as f64 - m).powi(2))
                            .sum();
                        let db: f64 = img
                            .iter()
                            .zip(&means[b])
                            .map(|(&x, &m)| (x as f64 - m).powi(2))
                            .sum();
                        da.partial_cmp(&db).unwrap()
                    })
                    .unwrap();
                if best == test.labels[i] as usize {
                    correct += 1;
                }
            }
            correct as f64 / test.len() as f64
        }
        let mut rng = Rng::new(4);
        let mtr = synth_mnist(600, &mut rng);
        let mte = synth_mnist(150, &mut rng);
        let ctr = synth_cifar(600, &mut rng);
        let cte = synth_cifar(150, &mut rng);
        let ma = nm_acc(&mtr, &mte);
        let ca = nm_acc(&ctr, &cte);
        assert!(ca < ma, "cifar-like ({ca}) should be harder than mnist-like ({ma})");
        assert!(ca > 0.15, "cifar-like must still beat chance: {ca}");
    }
}

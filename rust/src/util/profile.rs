//! Wall-clock phase profiling for benches and `fedhc run --profile`.
//!
//! Scoped timers ([`Scope`]) accumulate *host* nanoseconds per coarse
//! pipeline phase into process-global atomics. They are strictly an
//! observer of the wall clock: nothing here reads or writes simulated
//! time, the ledger, or any model state, so enabling profiling cannot
//! perturb a trajectory (the sim is deterministic either way — this
//! module only answers "where did the *real* time go").
//!
//! Disabled (the default), [`Scope::new`] is a single relaxed atomic
//! load and no `Instant` is ever taken, so the hooks compiled into the
//! round loop cost nothing measurable on the hot path. The bench
//! binaries call [`enable`] + [`reset`] around their timed sections and
//! dump [`to_json`] as the `ns_per_phase` section of their reports;
//! `fedhc run --profile` prints [`format_summary`] after the run.
//!
//! ```
//! use fedhc::util::profile::{self, Phase};
//! profile::enable();
//! profile::reset();
//! {
//!     let _p = profile::Scope::new(Phase::Eval);
//!     // ... timed work ...
//! }
//! let ns = profile::snapshot();
//! assert_eq!(ns.iter().find(|(n, _, _)| *n == "eval").unwrap().2, 1);
//! ```

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Instant;

use crate::util::json::Json;

/// Coarse phases of one federated round, as seen from the host clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Engine-parallel local training (the SIMD kernels).
    LocalTrain = 0,
    /// Intra-cluster aggregation: merges, staleness folds, wire encode.
    ClusterAgg = 1,
    /// Route-tree construction and per-hop walks.
    Routing = 2,
    /// Ground-station exchange and global aggregation.
    Ground = 3,
    /// Re-clustering: k-means, label alignment, MAML warm starts.
    Recluster = 4,
    /// Held-out evaluation.
    Eval = 5,
}

/// Every phase, in fixed report order.
pub const PHASES: [Phase; 6] = [
    Phase::LocalTrain,
    Phase::ClusterAgg,
    Phase::Routing,
    Phase::Ground,
    Phase::Recluster,
    Phase::Eval,
];

const N: usize = PHASES.len();

impl Phase {
    /// Stable snake_case name used in reports and `ns_per_phase` keys.
    pub fn name(self) -> &'static str {
        match self {
            Phase::LocalTrain => "local_train",
            Phase::ClusterAgg => "cluster_agg",
            Phase::Routing => "routing",
            Phase::Ground => "ground",
            Phase::Recluster => "recluster",
            Phase::Eval => "eval",
        }
    }
}

static ENABLED: AtomicBool = AtomicBool::new(false);
// `const` items holding atomics are intentional here: they are only
// array-initialiser templates, never read through.
#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);
static NS: [AtomicU64; N] = [ZERO; N];
static CALLS: [AtomicU64; N] = [ZERO; N];

/// Turn the hooks on (process-global, sticky).
pub fn enable() {
    ENABLED.store(true, Ordering::Relaxed);
}

/// Whether the hooks are live.
#[inline]
pub fn is_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Zero every accumulator (typically right after [`enable`]).
pub fn reset() {
    for i in 0..N {
        NS[i].store(0, Ordering::Relaxed);
        CALLS[i].store(0, Ordering::Relaxed);
    }
}

/// RAII phase timer: measures from construction to drop when profiling
/// is enabled, and is a no-op (no `Instant::now`) otherwise.
pub struct Scope {
    phase: Phase,
    start: Option<Instant>,
}

impl Scope {
    #[inline]
    pub fn new(phase: Phase) -> Self {
        let start = is_enabled().then(Instant::now);
        Scope { phase, start }
    }
}

impl Drop for Scope {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let i = self.phase as usize;
            NS[i].fetch_add(start.elapsed().as_nanos() as u64, Ordering::Relaxed);
            CALLS[i].fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// `(name, total_ns, calls)` per phase, in fixed report order.
pub fn snapshot() -> Vec<(&'static str, u64, u64)> {
    PHASES
        .iter()
        .map(|&p| {
            let i = p as usize;
            (
                p.name(),
                NS[i].load(Ordering::Relaxed),
                CALLS[i].load(Ordering::Relaxed),
            )
        })
        .collect()
}

/// The `ns_per_phase` report section: every phase key is always present
/// (zeros included) so report validators can pin the schema.
pub fn to_json() -> Json {
    Json::Obj(
        snapshot()
            .into_iter()
            .map(|(name, ns, calls)| {
                (
                    name.to_string(),
                    Json::obj(vec![
                        ("ns", Json::num(ns as f64)),
                        ("calls", Json::num(calls as f64)),
                    ]),
                )
            })
            .collect(),
    )
}

/// Aligned table for `fedhc run --profile` output.
pub fn format_summary() -> String {
    let mut out = String::new();
    out.push_str("wall-clock profile (host ns, sim time unaffected)\n");
    out.push_str(&format!(
        "{:<14}{:>10}{:>16}{:>14}\n",
        "phase", "calls", "total_ns", "ns/call"
    ));
    for (name, ns, calls) in snapshot() {
        let per = if calls == 0 { 0 } else { ns / calls };
        out.push_str(&format!("{name:<14}{calls:>10}{ns:>16}{per:>14}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_takes_no_timestamp() {
        // never enabled in this test binary unless another test ran
        // first; either way a fresh scope with profiling off is inert
        if !is_enabled() {
            let s = Scope::new(Phase::LocalTrain);
            assert!(s.start.is_none());
        }
    }

    #[test]
    fn enabled_scope_accumulates() {
        enable();
        reset();
        {
            let _p = Scope::new(Phase::Ground);
            std::hint::black_box(0u64);
        }
        let snap = snapshot();
        let ground = snap.iter().find(|(n, _, _)| *n == "ground").unwrap();
        assert_eq!(ground.2, 1, "one call recorded");
        let j = to_json();
        for p in PHASES {
            assert!(
                j.get(p.name()).get("ns").as_f64().is_some(),
                "phase {} missing from ns_per_phase",
                p.name()
            );
        }
        assert!(format_summary().contains("ground"));
    }
}

//! Minimal JSON parser and writer.
//!
//! Used for the AOT artifact manifest (`artifacts/manifest.json`), recorded
//! experiment series, and bench reports. Supports the full JSON grammar
//! except for `\u` surrogate pairs beyond the BMP (sufficient for our
//! machine-generated documents, which are ASCII).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are kept in a BTreeMap for deterministic
/// serialisation (stable diffs in recorded experiment files).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            b: s.as_bytes(),
            i: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors ----

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; Null for anything else.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- builders ----

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn arr_usize(v: &[usize]) -> Json {
        Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Serialise compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialise with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !o.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.i,
        }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("bad number"))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            // the four escape bytes can split a multibyte
                            // UTF-8 character in malformed input — that is
                            // a parse error, never a panic
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest
                        .chars()
                        .next()
                        .ok_or_else(|| self.err("unterminated string"))?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-17").unwrap(), Json::Num(-17.0));
        assert_eq!(Json::parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(
            Json::parse("\"hi\\nthere\"").unwrap(),
            Json::Str("hi\nthere".into())
        );
    }

    #[test]
    fn malformed_escapes_are_errors_not_panics() {
        // a multibyte character straddling the end of the four `\u` digit
        // bytes used to split the UTF-8 slice and panic; it must surface
        // as a parse error
        let e = Json::parse("\"\\u123é\"").unwrap_err();
        assert!(e.to_string().contains("\\u escape"), "{e}");
        let e = Json::parse("\"\\uée11\"").unwrap_err();
        assert!(e.to_string().contains("\\u escape"), "{e}");
        // truncated escape and bare backslash stay errors too
        assert!(Json::parse("\"\\u12\"").is_err());
        assert!(Json::parse("\"\\x\"").is_err());
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.get("a").as_arr().unwrap()[2].get("b").as_str(), Some("c"));
        assert_eq!(j.get("d"), &Json::Null);
        assert_eq!(j.get("missing"), &Json::Null);
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"agg","shapes":[[16,1234],[16]],"ok":true,"x":-1.25}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn roundtrip_pretty() {
        let j = Json::obj(vec![
            ("a", Json::arr_f64(&[1.0, 2.5])),
            ("b", Json::str("x\"y")),
        ]);
        let j2 = Json::parse(&j.to_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn integers_serialise_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn typed_accessors() {
        let j = Json::parse(r#"{"n": 5, "s": "x", "f": 1.5}"#).unwrap();
        assert_eq!(j.get("n").as_usize(), Some(5));
        assert_eq!(j.get("f").as_usize(), None);
        assert_eq!(j.get("f").as_f64(), Some(1.5));
        assert_eq!(j.get("s").as_str(), Some("x"));
    }
}

//! Summary statistics and timing helpers used by the bench harness and the
//! metrics ledger.

use super::json::Json;
use std::time::Instant;

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
    pub min: f64,
    pub max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }
}

/// Percentile of a sample (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=100.0).contains(&p));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Simple wall-clock timer for bench loops.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer {
            start: Instant::now(),
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Measure a closure `iters` times, returning per-iteration seconds
/// (after `warmup` unmeasured runs). Used by the hand-rolled bench harness.
pub fn bench_loop<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Timing statistics as the shared `BENCH_*.json` fragment (mean/p50/p95
/// milliseconds + iteration count) from [`bench_loop`]'s per-iteration
/// seconds — one schema for every bench binary.
pub fn stats_json(secs: &[f64]) -> Json {
    Json::obj(vec![
        ("mean_ms", Json::num(mean(secs) * 1e3)),
        ("p50_ms", Json::num(percentile(secs, 50.0) * 1e3)),
        ("p95_ms", Json::num(percentile(secs, 95.0) * 1e3)),
        ("iters", Json::num(secs.len() as f64)),
    ])
}

/// Format a bench result line consistently across bench binaries.
pub fn bench_report(name: &str, secs: &[f64]) -> String {
    let m = mean(secs);
    let p50 = percentile(secs, 50.0);
    let p95 = percentile(secs, 95.0);
    format!(
        "{name:<44} mean {:>10.3} ms   p50 {:>10.3} ms   p95 {:>10.3} ms   ({} iters)",
        m * 1e3,
        p50 * 1e3,
        p95 * 1e3,
        secs.len()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.5];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let naive_mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let naive_var =
            xs.iter().map(|x| (x - naive_mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - naive_mean).abs() < 1e-12);
        assert!((w.variance() - naive_var).abs() < 1e-12);
        assert_eq!(w.min, 1.0);
        assert_eq!(w.max, 6.5);
    }

    #[test]
    fn percentile_basic() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn bench_loop_runs_exactly() {
        let mut count = 0;
        let secs = bench_loop(2, 5, || count += 1);
        assert_eq!(count, 7);
        assert_eq!(secs.len(), 5);
        assert!(secs.iter().all(|&s| s >= 0.0));
    }
}

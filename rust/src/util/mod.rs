//! Foundational substrates the offline image does not provide as crates:
//! a deterministic PRNG, a JSON parser/writer (for the artifact manifest and
//! experiment records), a CLI argument parser, a leveled logger, wall-clock
//! phase profiling, a small property-testing harness, and summary statistics.

pub mod cli;
pub mod json;
pub mod logging;
pub mod profile;
pub mod quickprop;
pub mod rng;
pub mod stats;

pub use rng::Rng;

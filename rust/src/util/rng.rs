//! Deterministic pseudo-random number generation.
//!
//! The image has no `rand` crate, so we implement xoshiro256** (Blackman &
//! Vigna) seeded via splitmix64 — the standard recommendation for seeding.
//! Everything in the simulator that needs randomness derives from one of
//! these generators so experiments are exactly reproducible from a seed.

/// splitmix64 step — used for seeding and as a cheap stateless mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateless stream seed for the parallel round engine: one independent
/// RNG stream per `(round, satellite)` pair, derived from the master seed
/// alone. Unlike [`Rng::fork`], the result does not depend on how many
/// draws the parent generator has made — which is what makes the engine's
/// scatter deterministic in the worker count and the task schedule.
pub fn stream_seed(master: u64, round: u64, sat: u64) -> u64 {
    let mut s = master
        ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ sat.wrapping_mul(0xC2B2_AE3D_27D4_EB4F);
    splitmix64(&mut s)
}

/// xoshiro256** generator: fast, 256-bit state, passes BigCrush.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child generator (for per-client streams).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Unbiased integer in [0, n) via Lemire's method.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Marsaglia polar method.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        assert!(lambda > 0.0);
        -(1.0 - self.uniform()).ln() / lambda
    }

    /// Gamma(shape, scale=1) via Marsaglia–Tsang (shape >= 0).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        assert!(shape > 0.0);
        if shape < 1.0 {
            // boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let g = self.gamma(shape + 1.0);
            return g * self.uniform().powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Sample a Dirichlet(alpha) vector of length `k`.
    pub fn dirichlet(&mut self, alpha: f64, k: usize) -> Vec<f64> {
        let mut v: Vec<f64> = (0..k).map(|_| self.gamma(alpha)).collect();
        let sum: f64 = v.iter().sum();
        if sum <= 0.0 {
            // degenerate fallback: uniform
            return vec![1.0 / k as f64; k];
        }
        for x in v.iter_mut() {
            *x /= sum;
        }
        v
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.below_usize(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n), in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // partial Fisher–Yates
        for i in 0..k {
            let j = i + self.below_usize(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Weighted choice: returns index i with probability w[i] / sum(w).
    pub fn weighted_choice(&mut self, w: &[f64]) -> usize {
        let total: f64 = w.iter().sum();
        assert!(total > 0.0, "weighted_choice with non-positive total");
        let mut t = self.uniform() * total;
        for (i, &wi) in w.iter().enumerate() {
            t -= wi;
            if t <= 0.0 {
                return i;
            }
        }
        w.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(13);
        for &shape in &[0.3, 1.0, 2.5, 7.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() < 0.1 * shape.max(0.5),
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(17);
        for &alpha in &[0.1, 0.5, 1.0, 10.0] {
            let v = r.dirichlet(alpha, 10);
            assert_eq!(v.len(), 10);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
        assert!(s.iter().all(|&i| i < 50));
    }

    #[test]
    fn weighted_choice_respects_weights() {
        let mut r = Rng::new(29);
        let w = [0.0, 9.0, 1.0];
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[r.weighted_choice(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert!(counts[1] > 8 * counts[2], "{counts:?}");
    }

    #[test]
    fn stream_seed_is_stateless_and_spreads() {
        // same inputs → same seed
        assert_eq!(stream_seed(1, 2, 3), stream_seed(1, 2, 3));
        // distinct (round, sat) pairs → distinct streams in practice
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..32u64 {
            for sat in 0..32u64 {
                seen.insert(stream_seed(42, round, sat));
            }
        }
        assert_eq!(seen.len(), 32 * 32, "stream seeds collided");
        // streams from neighbouring ids are uncorrelated
        let mut a = Rng::new(stream_seed(42, 7, 0));
        let mut b = Rng::new(stream_seed(42, 7, 1));
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(31);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

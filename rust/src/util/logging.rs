//! Leveled stderr logger with wall-clock timestamps.
//!
//! Controlled by `FEDHC_LOG` (error|warn|info|debug|trace, default info) or
//! programmatically via [`set_level`]. Kept deliberately simple: a single
//! atomic level and `eprintln!` — the hot path never logs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lv = match std::env::var("FEDHC_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lv, Ordering::Relaxed);
    lv
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_from_env();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, module, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }
}

//! Leveled stderr logger with wall-clock timestamps.
//!
//! Controlled by `FEDHC_LOG` (error|warn|info|debug|trace, default info) or
//! programmatically via [`set_level`]. Kept deliberately simple: a single
//! atomic level and `eprintln!` — the hot path never logs.

use std::sync::atomic::{AtomicBool, AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised
static WARNED_BAD_ENV: AtomicBool = AtomicBool::new(false);

/// Parse a `FEDHC_LOG` value, case-insensitively. `None` for anything
/// outside the error|warn|info|debug|trace vocabulary.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.to_ascii_lowercase().as_str() {
        "error" => Some(Level::Error),
        "warn" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" => Some(Level::Debug),
        "trace" => Some(Level::Trace),
        _ => None,
    }
}

fn init_from_env() -> u8 {
    let lv = match std::env::var("FEDHC_LOG") {
        Ok(raw) => parse_level(&raw).unwrap_or_else(|| {
            // warn exactly once, whichever thread races here first
            if WARNED_BAD_ENV
                .compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
                .is_ok()
            {
                eprintln!(
                    "[WARN  fedhc] unrecognised FEDHC_LOG value {raw:?} \
                     (expected error|warn|info|debug|trace); defaulting to info"
                );
            }
            Level::Info
        }),
        Err(_) => Level::Info,
    } as u8;
    LEVEL.store(lv, Ordering::Relaxed);
    lv
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_from_env();
    }
    (level as u8) <= cur
}

pub fn log(level: Level, module: &str, msg: &str) {
    if !enabled(level) {
        return;
    }
    let t = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .unwrap_or_default();
    let tag = match level {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
        Level::Trace => "TRACE",
    };
    eprintln!("[{:>10}.{:03} {} {}] {}", t.as_secs(), t.subsec_millis(), tag, module, msg);
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Trace, module_path!(), &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
    }

    #[test]
    fn parse_level_is_case_insensitive() {
        assert_eq!(parse_level("error"), Some(Level::Error));
        assert_eq!(parse_level("ERROR"), Some(Level::Error));
        assert_eq!(parse_level("Warn"), Some(Level::Warn));
        assert_eq!(parse_level("info"), Some(Level::Info));
        assert_eq!(parse_level("DeBuG"), Some(Level::Debug));
        assert_eq!(parse_level("TRACE"), Some(Level::Trace));
        assert_eq!(parse_level("verbose"), None);
        assert_eq!(parse_level(""), None);
    }

    #[test]
    fn new_macros_route_through_the_gate() {
        // must compile and not panic at any level; no set_level here —
        // the level is process-global and other tests assert on it
        crate::error!("an error line: {}", 1);
        crate::trace!("a trace line: {}", 2);
    }
}

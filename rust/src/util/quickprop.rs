//! Minimal property-based testing harness (the image has no `proptest`).
//!
//! A property is a closure over a [`Gen`] (a seeded value source). The
//! harness runs it for `cases` random seeds; on failure it retries with the
//! same seed after shrinking the size hint, and reports the seed so the case
//! can be replayed deterministically:
//!
//! ```
//! use fedhc::util::quickprop::{property, Gen};
//! property("sum is commutative", 256, |g: &mut Gen| {
//!     let a = g.f64_in(-1e6, 1e6);
//!     let b = g.f64_in(-1e6, 1e6);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Seeded generator handed to properties, with a size hint for shrinking.
pub struct Gen {
    rng: Rng,
    /// Size hint in (0, 1]; generators should scale magnitudes by it.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
            seed,
        }
    }

    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = ((hi - lo) as f64 * self.size).ceil() as usize;
        lo + self.rng.below_usize(span.max(1).min(hi - lo + 1))
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let mid = 0.5 * (lo + hi);
        let half = 0.5 * (hi - lo) * self.size;
        self.rng.uniform_in(mid - half, mid + half)
    }

    pub fn f32_vec(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len)
            .map(|_| lo + (hi - lo) * self.rng.uniform_f32())
            .collect()
    }

    pub fn f64_vec(&mut self, len: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..len).map(|_| self.f64_in(lo, hi)).collect()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` for `cases` random cases. Panics (failing the enclosing test)
/// with the offending seed on the first failure, after attempting three
/// size-shrunk replays to report the smallest reproduction it can find.
pub fn property<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u64, prop: F) {
    // fixed master seed + case index keeps CI deterministic; override with
    // FEDHC_PROP_SEED to explore.
    let master: u64 = std::env::var("FEDHC_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_F00D);
    for case in 0..cases {
        let seed = master ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if result.is_err() {
            // try to shrink by size
            let mut smallest: Option<f64> = None;
            for &size in &[0.1, 0.25, 0.5] {
                let r = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                });
                if r.is_err() {
                    smallest = Some(size);
                    break;
                }
            }
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, smallest failing size {})",
                smallest.map(|s| s.to_string()).unwrap_or_else(|| "1.0".into())
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("abs is non-negative", 64, |g| {
            let x = g.f64_in(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports_seed() {
        // silence the inner panic output noise by keeping the body trivial
        property("always fails", 4, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        property("usize_in within bounds", 128, |g| {
            let x = g.usize_in(3, 17);
            assert!((3..=17).contains(&x));
        });
    }
}

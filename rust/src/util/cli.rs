//! Tiny CLI argument parser (the image has no `clap`).
//!
//! Grammar: `prog <subcommand> [--key value]... [--flag]... [positional]...`
//! Keys may also be given as `--key=value`. Typed getters return
//! `anyhow::Result` so a malformed flag surfaces as a usage error from the
//! binary's top-level handler instead of a panic backtrace.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (excluding argv[0]).
    /// `known_flags` lists options that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I, known_flags: &[&str]) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if known_flags.contains(&rest) {
                    out.flags.push(rest.to_string());
                } else {
                    // a trailing `--key`, or one followed by another
                    // option, parses as a flag; otherwise the next token
                    // is its value (taken without unwrap — a peeked
                    // Peekable cannot come up empty, but a usage mistake
                    // must never be able to panic the parser)
                    match it.peek() {
                        Some(next) if !next.starts_with("--") => {
                            let value = it.next().unwrap_or_default();
                            out.options.insert(rest.to_string(), value);
                        }
                        _ => out.flags.push(rest.to_string()),
                    }
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse the real process arguments.
    pub fn from_env(known_flags: &[&str]) -> Args {
        Args::parse(std::env::args().skip(1), known_flags)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name N` as usize, `default` when absent; error on a bad value.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `--name N` as u64, `default` when absent; error on a bad value.
    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .map_err(|_| anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `--name F` as f64, `default` when absent; error on a bad value.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| anyhow!("--{name} expects a number, got '{v}'")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(argv("run --clusters 5 --dataset mnist"), &[]);
        assert_eq!(a.subcommand.as_deref(), Some("run"));
        assert_eq!(a.get("clusters"), Some("5"));
        assert_eq!(a.get("dataset"), Some("mnist"));
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(argv("bench --rounds=100"), &[]);
        assert_eq!(a.get_usize("rounds", 0).unwrap(), 100);
    }

    #[test]
    fn known_flags_take_no_value() {
        let a = Args::parse(argv("run --verbose positional1"), &["verbose"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["positional1"]);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse(argv("run --fast"), &[]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = Args::parse(argv("run --quiet --k 3"), &["quiet"]);
        assert!(a.flag("quiet"));
        assert_eq!(a.get_usize("k", 0).unwrap(), 3);
    }

    #[test]
    fn typed_getters_with_defaults() {
        let a = Args::parse(argv("x --lr 0.01"), &[]);
        assert_eq!(a.get_f64("lr", 1.0).unwrap(), 0.01);
        assert_eq!(a.get_f64("missing", 2.5).unwrap(), 2.5);
        assert_eq!(a.get_u64("seed", 42).unwrap(), 42);
    }

    #[test]
    fn bad_values_are_errors_not_panics() {
        let a = Args::parse(argv("x --k five --lr fast --seed -3"), &[]);
        let e = a.get_usize("k", 0).unwrap_err();
        assert!(e.to_string().contains("--k expects an integer"), "{e}");
        let e = a.get_f64("lr", 0.1).unwrap_err();
        assert!(e.to_string().contains("--lr expects a number"), "{e}");
        assert!(a.get_u64("seed", 1).is_err(), "negative u64 must fail");
    }
}

//! Clustering-quality diagnostics used by tests, the ablation bench, and
//! the FedCE baseline (which clusters on data distributions rather than
//! positions).

/// Within-cluster sum of squares for arbitrary-dimension points.
pub fn inertia(points: &[Vec<f64>], assignment: &[usize], centroids: &[Vec<f64>]) -> f64 {
    assert_eq!(points.len(), assignment.len());
    points
        .iter()
        .zip(assignment.iter())
        .map(|(p, &c)| dist2(p, &centroids[c]))
        .sum()
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b.iter()).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// Mean silhouette coefficient (O(n²); diagnostics only, not hot path).
pub fn silhouette(points: &[Vec<f64>], assignment: &[usize], k: usize) -> f64 {
    let n = points.len();
    if n < 2 || k < 2 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut counted = 0usize;
    for i in 0..n {
        let ci = assignment[i];
        let mut intra = 0.0;
        let mut intra_n = 0usize;
        let mut inter = vec![(0.0, 0usize); k];
        for j in 0..n {
            if i == j {
                continue;
            }
            let d = dist2(&points[i], &points[j]).sqrt();
            if assignment[j] == ci {
                intra += d;
                intra_n += 1;
            } else {
                let e = &mut inter[assignment[j]];
                e.0 += d;
                e.1 += 1;
            }
        }
        if intra_n == 0 {
            continue; // singleton: silhouette undefined, skip
        }
        let a = intra / intra_n as f64;
        let b = inter
            .iter()
            .filter(|(_, n)| *n > 0)
            .map(|(s, n)| s / *n as f64)
            .fold(f64::INFINITY, f64::min);
        if !b.is_finite() {
            continue;
        }
        total += (b - a) / a.max(b);
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

/// k-means over arbitrary-dimension points (used by FedCE on label
/// histograms). Returns (assignment, centroids).
pub fn kmeans_nd(
    points: &[Vec<f64>],
    k: usize,
    iters: usize,
    rng: &mut crate::util::Rng,
) -> (Vec<usize>, Vec<Vec<f64>>) {
    let n = points.len();
    assert!(n >= k && k >= 1);
    let dim = points[0].len();
    // seed with distinct random points
    let seeds = rng.sample_indices(n, k);
    let mut centroids: Vec<Vec<f64>> = seeds.iter().map(|&i| points[i].clone()).collect();
    let mut assignment = vec![0usize; n];
    for _ in 0..iters {
        for (i, p) in points.iter().enumerate() {
            assignment[i] = (0..k)
                .min_by(|&a, &b| {
                    dist2(p, &centroids[a])
                        .partial_cmp(&dist2(p, &centroids[b]))
                        .unwrap()
                })
                .unwrap();
        }
        let mut sums = vec![vec![0.0; dim]; k];
        let mut counts = vec![0usize; k];
        for (i, p) in points.iter().enumerate() {
            let c = assignment[i];
            counts[c] += 1;
            for d in 0..dim {
                sums[c][d] += p[d];
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                for d in 0..dim {
                    centroids[c][d] = sums[c][d] / counts[c] as f64;
                }
            }
        }
    }
    (assignment, centroids)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn inertia_zero_when_points_are_centroids() {
        let pts = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let cents = pts.clone();
        assert_eq!(inertia(&pts, &[0, 1], &cents), 0.0);
    }

    #[test]
    fn silhouette_high_for_separated_blobs() {
        let mut rng = Rng::new(3);
        let mut pts = Vec::new();
        let mut asg = Vec::new();
        for (c, center) in [[0.0, 0.0], [100.0, 0.0]].iter().enumerate() {
            for _ in 0..20 {
                pts.push(vec![center[0] + rng.normal(), center[1] + rng.normal()]);
                asg.push(c);
            }
        }
        let s = silhouette(&pts, &asg, 2);
        assert!(s > 0.9, "silhouette {s}");
    }

    #[test]
    fn silhouette_low_for_random_labels() {
        let mut rng = Rng::new(4);
        let pts: Vec<Vec<f64>> = (0..60)
            .map(|_| vec![rng.uniform() * 10.0, rng.uniform() * 10.0])
            .collect();
        let asg: Vec<usize> = (0..60).map(|_| rng.below_usize(3)).collect();
        let s = silhouette(&pts, &asg, 3);
        assert!(s < 0.25, "silhouette {s}");
    }

    #[test]
    fn kmeans_nd_separates_histograms() {
        // two groups of label histograms: classes 0-4 heavy vs 5-9 heavy
        let mut rng = Rng::new(5);
        let mut pts = Vec::new();
        for g in 0..2 {
            for _ in 0..15 {
                let mut h = vec![0.02; 10];
                for c in 0..5 {
                    h[g * 5 + c] = 0.18 + 0.02 * rng.uniform();
                }
                pts.push(h);
            }
        }
        let (asg, _) = kmeans_nd(&pts, 2, 20, &mut rng);
        let first = asg[0];
        assert!(asg[..15].iter().all(|&a| a == first));
        assert!(asg[15..].iter().all(|&a| a != first));
    }
}

//! The paper's satellite-clustered parameter-server selection algorithm
//! (§III-B, Eq. 13–15) and the re-clustering trigger (§III-A, Algorithm 1
//! lines 14–18): k-means over satellite positions, PS choice by centroid
//! proximity with a communication tie-break, clustering-quality
//! diagnostics, and the dropout-rate policy with label alignment across
//! re-clustering events.
//!
//! The k-means entry point is pure and deterministic given a seed:
//!
//! ```
//! use fedhc::clustering::KMeans;
//! use fedhc::util::Rng;
//!
//! // two well-separated pairs of "satellites" (features in km)
//! let points = vec![
//!     [0.0, 0.0, 0.0],
//!     [0.1, 0.0, 0.0],
//!     [9.0, 9.0, 9.0],
//!     [9.1, 9.0, 9.0],
//! ];
//! let res = KMeans::new(2).run(&points, &mut Rng::new(7)).unwrap();
//! assert_eq!(res.assignment.len(), 4);
//! assert_eq!(res.assignment[0], res.assignment[1]);
//! assert_eq!(res.assignment[2], res.assignment[3]);
//! assert_ne!(res.assignment[0], res.assignment[3]);
//! ```

pub mod kmeans;
pub mod ps_select;
pub mod quality;
pub mod recluster;

pub use kmeans::{KMeans, KMeansResult};
pub use ps_select::select_parameter_servers;
pub use recluster::ReclusterPolicy;

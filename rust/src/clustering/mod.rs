//! The paper's satellite-clustered parameter-server selection algorithm
//! (§III-B, Eq. 13–15) and the re-clustering trigger (§III-A, Algorithm 1
//! lines 14–18).

pub mod kmeans;
pub mod ps_select;
pub mod quality;
pub mod recluster;

pub use kmeans::{KMeans, KMeansResult};
pub use ps_select::select_parameter_servers;
pub use recluster::ReclusterPolicy;

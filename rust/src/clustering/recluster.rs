//! Re-clustering trigger (Algorithm 1 lines 14–18): during aggregation each
//! cluster monitors its dropout rate `d_r = C^d / C^k`; when `d_r > Z` the
//! constellation is re-clustered and newly-assigned satellites are
//! warm-started via MAML (handled by the coordinator).
//!
//! A re-cluster event is also the constellation plane's mid-round index
//! refresh point: topology is rebuilt at the post-aggregation epoch, so
//! the coordinator re-syncs its [`crate::orbit::index::ConstellationIndex`]
//! before the k-means pass (see `coordinator::fedhc::run_staged`). Label
//! alignment below is geometry-free and needs no index: the contingency
//! table is O(k²) and the mega-scale path (k > 8) uses the greedy
//! matching, not the factorial-exact search.

use anyhow::{bail, Result};

/// Dropout-threshold policy.
#[derive(Clone, Copy, Debug)]
pub struct ReclusterPolicy {
    /// Z — dropout-rate threshold that triggers re-clustering.
    pub threshold: f64,
}

impl Default for ReclusterPolicy {
    fn default() -> Self {
        // the paper does not state Z; 0.25 makes churn events meaningful but
        // not constant at LEO orbital rates (configurable)
        ReclusterPolicy { threshold: 0.25 }
    }
}

/// Dropout observation for one cluster in one round.
#[derive(Clone, Copy, Debug, Default)]
pub struct DropoutStats {
    /// C^k — cluster membership at the start of the round.
    pub members: usize,
    /// C^d — members that left (lost ISL contact / drifted to another
    /// cluster's region) during the round.
    pub dropped: usize,
}

impl DropoutStats {
    /// `d_r = C^d / C^k` (0 for an empty cluster).
    pub fn dropout_rate(&self) -> f64 {
        if self.members == 0 {
            0.0
        } else {
            self.dropped as f64 / self.members as f64
        }
    }
}

impl ReclusterPolicy {
    /// Build a policy, rejecting out-of-range thresholds as usage errors
    /// (the CLI/config error-handling style — no panics on bad input).
    pub fn new(threshold: f64) -> Result<Self> {
        if !(0.0..=1.0).contains(&threshold) {
            bail!("recluster threshold Z must be in [0, 1], got {threshold}");
        }
        Ok(ReclusterPolicy { threshold })
    }

    /// Whether any cluster's dropout rate exceeds Z.
    pub fn should_recluster(&self, stats: &[DropoutStats]) -> bool {
        stats.iter().any(|s| s.dropout_rate() > self.threshold)
    }

    /// Clusters that individually breached the threshold (for logging).
    pub fn breached(&self, stats: &[DropoutStats]) -> Vec<usize> {
        stats
            .iter()
            .enumerate()
            .filter(|(_, s)| s.dropout_rate() > self.threshold)
            .map(|(i, _)| i)
            .collect()
    }
}

/// Diff two assignments: satellites whose cluster id changed — these are the
/// "newly joined" members that receive the MAML warm start (§III-C).
pub fn changed_members(old: &[usize], new: &[usize]) -> Vec<usize> {
    assert_eq!(old.len(), new.len());
    old.iter()
        .zip(new.iter())
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(i, _)| i)
        .collect()
}

/// Relabel `new` cluster ids to maximise overlap with `old` clusters
/// (maximum-weight matching on the contingency table — exact via
/// permutation search for k ≤ 8, greedy beyond). Keeps cluster identities
/// stable across re-clustering so per-cluster model state carries over to
/// the successor cluster; the exact matching guarantees relabelled churn
/// never exceeds raw churn.
pub fn align_labels(old: &[usize], new: &[usize], k: usize) -> Vec<usize> {
    assert_eq!(old.len(), new.len());
    let mut table = vec![vec![0usize; k]; k]; // [new][old] overlap counts
    for (&o, &n) in old.iter().zip(new.iter()) {
        if o < k && n < k {
            table[n][o] += 1;
        }
    }
    let mapping = if k <= 8 {
        best_permutation(&table, k)
    } else {
        greedy_matching(&table, k)
    };
    new.iter().map(|&n| mapping[n]).collect()
}

/// Exact maximum-overlap assignment: search all k! mappings new→old.
fn best_permutation(table: &[Vec<usize>], k: usize) -> Vec<usize> {
    let mut perm: Vec<usize> = (0..k).collect();
    let mut best = perm.clone();
    let mut best_score = score(table, &perm);
    // Heap's algorithm, iterative
    let mut c = vec![0usize; k];
    let mut i = 0;
    while i < k {
        if c[i] < i {
            if i % 2 == 0 {
                perm.swap(0, i);
            } else {
                perm.swap(c[i], i);
            }
            let s = score(table, &perm);
            if s > best_score {
                best_score = s;
                best = perm.clone();
            }
            c[i] += 1;
            i = 0;
        } else {
            c[i] = 0;
            i += 1;
        }
    }
    best
}

fn score(table: &[Vec<usize>], perm: &[usize]) -> usize {
    perm.iter().enumerate().map(|(n, &o)| table[n][o]).sum()
}

fn greedy_matching(table: &[Vec<usize>], k: usize) -> Vec<usize> {
    let mut mapping = vec![usize::MAX; k]; // new label -> old label
    let mut used_old = vec![false; k];
    for _ in 0..k {
        let mut best = (0usize, 0usize, 0usize); // (count, new, old)
        let mut found = false;
        for n in 0..k {
            if mapping[n] != usize::MAX {
                continue;
            }
            for o in 0..k {
                if used_old[o] {
                    continue;
                }
                if !found || table[n][o] >= best.0 {
                    best = (table[n][o], n, o);
                    found = true;
                }
            }
        }
        mapping[best.1] = best.2;
        used_old[best.2] = true;
    }
    mapping
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dropout_rate_formula() {
        let s = DropoutStats {
            members: 20,
            dropped: 5,
        };
        assert!((s.dropout_rate() - 0.25).abs() < 1e-12);
        assert_eq!(DropoutStats::default().dropout_rate(), 0.0);
    }

    #[test]
    fn rejects_out_of_range_thresholds() {
        assert!(ReclusterPolicy::new(-0.01).is_err());
        assert!(ReclusterPolicy::new(1.01).is_err());
        assert!(ReclusterPolicy::new(f64::NAN).is_err());
        assert!(ReclusterPolicy::new(0.0).is_ok());
        assert!(ReclusterPolicy::new(1.0).is_ok());
    }

    #[test]
    fn trigger_fires_above_threshold_only() {
        let p = ReclusterPolicy::new(0.25).unwrap();
        let below = [DropoutStats {
            members: 20,
            dropped: 5,
        }];
        // exactly Z does NOT trigger (paper: d_r > Z)
        assert!(!p.should_recluster(&below));
        let above = [DropoutStats {
            members: 20,
            dropped: 6,
        }];
        assert!(p.should_recluster(&above));
    }

    #[test]
    fn any_cluster_can_trigger() {
        let p = ReclusterPolicy::default();
        let stats = [
            DropoutStats {
                members: 10,
                dropped: 0,
            },
            DropoutStats {
                members: 10,
                dropped: 9,
            },
        ];
        assert!(p.should_recluster(&stats));
        assert_eq!(p.breached(&stats), vec![1]);
    }

    #[test]
    fn changed_members_diff() {
        let old = [0, 0, 1, 1, 2];
        let new = [0, 1, 1, 2, 2];
        assert_eq!(changed_members(&old, &new), vec![1, 3]);
        assert!(changed_members(&old, &old).is_empty());
    }

    #[test]
    fn align_labels_recovers_permutation() {
        // new labels are a pure permutation of old: alignment should undo it
        let old = [0, 0, 0, 1, 1, 1, 2, 2, 2];
        let new = [2, 2, 2, 0, 0, 0, 1, 1, 1];
        let aligned = align_labels(&old, &new, 3);
        assert_eq!(aligned.to_vec(), old.to_vec());
        assert!(changed_members(&old, &aligned).is_empty());
    }

    #[test]
    fn align_labels_minimises_churn() {
        // one satellite truly moved; after alignment only that one differs
        let old = [0, 0, 0, 0, 1, 1, 1, 1];
        let new = [1, 1, 1, 0, 0, 0, 0, 0]; // labels flipped + sat 3 moved
        let aligned = align_labels(&old, &new, 2);
        let changed = changed_members(&old, &aligned);
        assert_eq!(changed, vec![3]);
    }
}

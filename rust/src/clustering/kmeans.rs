//! Lloyd's k-means over satellite positions (paper Eq. 13–15).
//!
//! Initialisation is k-means++ seeded by the experiment RNG; assignment
//! uses the Euclidean metric of Eq. 13; the update step is the centroid
//! mean of Eq. 14; convergence is the summed squared centroid displacement
//! of Eq. 15.

use crate::util::Rng;

/// Configuration for a k-means run.
#[derive(Clone, Copy, Debug)]
pub struct KMeans {
    pub k: usize,
    /// Eq. 15 convergence threshold ε on Σ‖K_new − K_old‖².
    pub epsilon: f64,
    pub max_iters: usize,
}

/// Result of a clustering run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub centroids: Vec<[f64; 3]>,
    /// Cluster id per point.
    pub assignment: Vec<usize>,
    pub iterations: usize,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
}

impl Default for KMeans {
    fn default() -> Self {
        KMeans {
            k: 3,
            epsilon: 1e-6,
            max_iters: 200,
        }
    }
}

#[inline]
fn d2(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

impl KMeans {
    pub fn new(k: usize) -> Self {
        KMeans {
            k,
            ..KMeans::default()
        }
    }

    /// Run Lloyd's algorithm on `points` (e.g. satellite positions in km).
    pub fn run(&self, points: &[[f64; 3]], rng: &mut Rng) -> KMeansResult {
        let n = points.len();
        assert!(self.k >= 1, "k must be >= 1");
        assert!(
            n >= self.k,
            "cannot form {} clusters from {} points",
            self.k,
            n
        );

        let mut centroids = self.init_pp(points, rng);
        let mut assignment = vec![0usize; n];
        let mut iterations = 0;

        loop {
            iterations += 1;
            // assignment step (Eq. 13)
            for (i, p) in points.iter().enumerate() {
                let mut best = 0;
                let mut best_d = f64::INFINITY;
                for (c, cent) in centroids.iter().enumerate() {
                    let d = d2(p, cent);
                    if d < best_d {
                        best_d = d;
                        best = c;
                    }
                }
                assignment[i] = best;
            }
            // update step (Eq. 14)
            let mut sums = vec![[0.0f64; 3]; self.k];
            let mut counts = vec![0usize; self.k];
            for (i, p) in points.iter().enumerate() {
                let c = assignment[i];
                sums[c][0] += p[0];
                sums[c][1] += p[1];
                sums[c][2] += p[2];
                counts[c] += 1;
            }
            let mut shift = 0.0;
            for c in 0..self.k {
                let new = if counts[c] == 0 {
                    // empty cluster: re-seed at the point farthest from its centroid
                    let far = points
                        .iter()
                        .enumerate()
                        .max_by(|(_, a), (_, b)| {
                            d2(a, &centroids[assignment_of(a, &centroids)])
                                .partial_cmp(&d2(b, &centroids[assignment_of(b, &centroids)]))
                                .unwrap()
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    points[far]
                } else {
                    [
                        sums[c][0] / counts[c] as f64,
                        sums[c][1] / counts[c] as f64,
                        sums[c][2] / counts[c] as f64,
                    ]
                };
                shift += d2(&centroids[c], &new);
                centroids[c] = new;
            }
            // convergence (Eq. 15)
            if shift < self.epsilon || iterations >= self.max_iters {
                break;
            }
        }

        // final assignment + inertia under the converged centroids
        let mut inertia = 0.0;
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = d2(p, cent);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            assignment[i] = best;
            inertia += best_d;
        }

        KMeansResult {
            centroids,
            assignment,
            iterations,
            inertia,
        }
    }

    /// k-means++ seeding.
    fn init_pp(&self, points: &[[f64; 3]], rng: &mut Rng) -> Vec<[f64; 3]> {
        let n = points.len();
        let mut centroids = Vec::with_capacity(self.k);
        centroids.push(points[rng.below_usize(n)]);
        let mut dist = vec![f64::INFINITY; n];
        while centroids.len() < self.k {
            let last = centroids.last().unwrap();
            for (i, p) in points.iter().enumerate() {
                dist[i] = dist[i].min(d2(p, last));
            }
            let total: f64 = dist.iter().sum();
            let next = if total <= 0.0 {
                rng.below_usize(n)
            } else {
                let mut t = rng.uniform() * total;
                let mut pick = n - 1;
                for (i, &d) in dist.iter().enumerate() {
                    t -= d;
                    if t <= 0.0 {
                        pick = i;
                        break;
                    }
                }
                pick
            };
            centroids.push(points[next]);
        }
        centroids
    }
}

fn assignment_of(p: &[f64; 3], centroids: &[[f64; 3]]) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (c, cent) in centroids.iter().enumerate() {
        let d = d2(p, cent);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

impl KMeansResult {
    /// Members of each cluster.
    pub fn clusters(&self) -> Vec<Vec<usize>> {
        let k = self.centroids.len();
        let mut out = vec![Vec::new(); k];
        for (i, &c) in self.assignment.iter().enumerate() {
            out[c].push(i);
        }
        out
    }

    /// Size of each cluster.
    pub fn sizes(&self) -> Vec<usize> {
        let k = self.centroids.len();
        let mut out = vec![0usize; k];
        for &c in &self.assignment {
            out[c] += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[[f64; 3]], per: usize, spread: f64) -> Vec<[f64; 3]> {
        let mut pts = Vec::new();
        for c in centers {
            for _ in 0..per {
                pts.push([
                    c[0] + spread * rng.normal(),
                    c[1] + spread * rng.normal(),
                    c[2] + spread * rng.normal(),
                ]);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let centers = [[0.0, 0.0, 0.0], [100.0, 0.0, 0.0], [0.0, 100.0, 0.0]];
        let pts = blobs(&mut rng, &centers, 40, 2.0);
        let res = KMeans::new(3).run(&pts, &mut rng);
        // every blob should map to a single cluster
        for b in 0..3 {
            let ids: Vec<usize> = (b * 40..(b + 1) * 40).map(|i| res.assignment[i]).collect();
            assert!(ids.iter().all(|&c| c == ids[0]), "blob {b} split: {ids:?}");
        }
        // and each centroid should be near a true center
        for c in &res.centroids {
            let nearest = centers
                .iter()
                .map(|t| d2(c, t))
                .fold(f64::INFINITY, f64::min);
            assert!(nearest < 4.0, "centroid {c:?} off by {nearest}");
        }
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let mut rng = Rng::new(2);
        let pts = blobs(&mut rng, &[[0.0; 3], [50.0, 0.0, 0.0]], 30, 5.0);
        let res = KMeans::new(2).run(&pts, &mut rng);
        for (i, p) in pts.iter().enumerate() {
            let assigned = res.assignment[i];
            for (c, cent) in res.centroids.iter().enumerate() {
                assert!(
                    d2(p, &res.centroids[assigned]) <= d2(p, cent) + 1e-9,
                    "point {i} nearer to {c}"
                );
            }
        }
    }

    #[test]
    fn k_equals_n_gives_zero_inertia() {
        let mut rng = Rng::new(3);
        let pts = vec![[0.0, 0.0, 0.0], [10.0, 0.0, 0.0], [0.0, 10.0, 0.0]];
        let res = KMeans::new(3).run(&pts, &mut rng);
        assert!(res.inertia < 1e-9);
        let mut sizes = res.sizes();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 1, 1]);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let mut rng = Rng::new(4);
        let pts = vec![[0.0, 0.0, 0.0], [2.0, 4.0, 6.0]];
        let res = KMeans::new(1).run(&pts, &mut rng);
        assert!((res.centroids[0][0] - 1.0).abs() < 1e-9);
        assert!((res.centroids[0][1] - 2.0).abs() < 1e-9);
        assert!((res.centroids[0][2] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn inertia_nonincreasing_in_k() {
        let mut rng = Rng::new(5);
        let pts = blobs(
            &mut rng,
            &[[0.0; 3], [30.0, 0.0, 0.0], [0.0, 30.0, 0.0], [0.0, 0.0, 30.0]],
            25,
            4.0,
        );
        let mut prev = f64::INFINITY;
        for k in 1..=6 {
            // best of 3 restarts to smooth out seeding luck
            let best = (0..3)
                .map(|s| {
                    let mut r = Rng::new(100 + s);
                    KMeans::new(k).run(&pts, &mut r).inertia
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                best <= prev * 1.05,
                "inertia went up at k={k}: {best} > {prev}"
            );
            prev = best;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let pts = blobs(&mut Rng::new(8), &[[0.0; 3], [20.0, 0.0, 0.0]], 50, 3.0);
        let a = KMeans::new(2).run(&pts, &mut r1);
        let b = KMeans::new(2).run(&pts, &mut r2);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn every_cluster_nonempty_on_spread_data() {
        let mut rng = Rng::new(10);
        let pts: Vec<[f64; 3]> = (0..200)
            .map(|_| [rng.uniform() * 100.0, rng.uniform() * 100.0, rng.uniform() * 100.0])
            .collect();
        let res = KMeans::new(5).run(&pts, &mut rng);
        assert!(res.sizes().iter().all(|&s| s > 0), "{:?}", res.sizes());
    }
}

//! Lloyd's k-means over satellite positions (paper Eq. 13–15).
//!
//! Initialisation is k-means++ seeded by the experiment RNG; assignment
//! uses the Euclidean metric of Eq. 13; the update step is the centroid
//! mean of Eq. 14; convergence is the summed squared centroid displacement
//! of Eq. 15.
//!
//! The assignment step (the O(N·K) hot loop) can be served by the
//! constellation plane's sphere grid ([`crate::orbit::index::SphereGrid`]):
//! [`KMeans::run_indexed`] prunes the centroid candidates per grid cell
//! and is **bit-identical** to the exhaustive scan — same winners, same
//! lowest-index tie-breaks — so the index is purely a speed knob (pinned
//! by `tests/proptests.rs::prop_sphere_grid_assignment_is_exact`).

use crate::orbit::index::{assign_nearest_brute, d2, SphereGrid};
use crate::util::Rng;
use anyhow::{bail, Result};

/// Configuration for a k-means run.
#[derive(Clone, Copy, Debug)]
pub struct KMeans {
    pub k: usize,
    /// Eq. 15 convergence threshold ε on Σ‖K_new − K_old‖².
    pub epsilon: f64,
    pub max_iters: usize,
}

/// Result of a clustering run.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub centroids: Vec<[f64; 3]>,
    /// Cluster id per point.
    pub assignment: Vec<usize>,
    pub iterations: usize,
    /// Final within-cluster sum of squares.
    pub inertia: f64,
}

impl Default for KMeans {
    fn default() -> Self {
        KMeans {
            k: 3,
            epsilon: 1e-6,
            max_iters: 200,
        }
    }
}

impl KMeans {
    pub fn new(k: usize) -> Self {
        KMeans {
            k,
            ..KMeans::default()
        }
    }

    /// Run Lloyd's algorithm on `points` (e.g. satellite positions in km)
    /// with the exhaustive assignment scan. An infeasible `k` (zero, or
    /// more clusters than points — e.g. a mega preset with an aggressive
    /// `--k` override) is a usage error, not a panic.
    pub fn run(&self, points: &[[f64; 3]], rng: &mut Rng) -> Result<KMeansResult> {
        self.run_indexed(points, rng, None)
    }

    /// Like [`KMeans::run`], with the assignment step optionally served by
    /// a sphere grid built over exactly `points` (same epoch, same order).
    /// Results are bit-identical either way.
    pub fn run_indexed(
        &self,
        points: &[[f64; 3]],
        rng: &mut Rng,
        grid: Option<&SphereGrid>,
    ) -> Result<KMeansResult> {
        let n = points.len();
        if self.k < 1 {
            bail!("k-means needs at least 1 cluster, got k = {}", self.k);
        }
        if n < self.k {
            bail!(
                "cannot form {} clusters from {} points — lower --k or grow the constellation",
                self.k,
                n
            );
        }
        if let Some(g) = grid {
            // full equality, not a sample: a stale or reordered grid must
            // never silently break the bit-identity guarantee (O(N) once
            // per run, negligible next to the Lloyd iterations)
            if g.feats() != points {
                bail!(
                    "spatial index does not cover the clustering input \
                     ({} indexed vs {} points) — refresh the index for this epoch",
                    g.len(),
                    n
                );
            }
        }

        let mut centroids = self.init_pp(points, rng);
        let mut assignment = vec![0usize; n];
        let mut iterations = 0;

        loop {
            iterations += 1;
            // assignment step (Eq. 13), index-pruned when a grid is given
            assign_step(points, &centroids, grid, &mut assignment);
            // update step (Eq. 14)
            let mut sums = vec![[0.0f64; 3]; self.k];
            let mut counts = vec![0usize; self.k];
            for (i, p) in points.iter().enumerate() {
                let c = assignment[i];
                sums[c][0] += p[0];
                sums[c][1] += p[1];
                sums[c][2] += p[2];
                counts[c] += 1;
            }
            let mut shift = 0.0;
            for c in 0..self.k {
                let new = if counts[c] == 0 {
                    // empty cluster: re-seed at the point farthest from its centroid
                    let far = points
                        .iter()
                        .enumerate()
                        .max_by(|(_, a), (_, b)| {
                            d2(a, &centroids[assignment_of(a, &centroids)])
                                .partial_cmp(&d2(b, &centroids[assignment_of(b, &centroids)]))
                                .unwrap()
                        })
                        .map(|(i, _)| i)
                        .unwrap();
                    points[far]
                } else {
                    [
                        sums[c][0] / counts[c] as f64,
                        sums[c][1] / counts[c] as f64,
                        sums[c][2] / counts[c] as f64,
                    ]
                };
                shift += d2(&centroids[c], &new);
                centroids[c] = new;
            }
            // convergence (Eq. 15)
            if shift < self.epsilon || iterations >= self.max_iters {
                break;
            }
        }

        // final assignment + inertia under the converged centroids
        assign_step(points, &centroids, grid, &mut assignment);
        let mut inertia = 0.0;
        for (i, p) in points.iter().enumerate() {
            inertia += d2(p, &centroids[assignment[i]]);
        }

        Ok(KMeansResult {
            centroids,
            assignment,
            iterations,
            inertia,
        })
    }

    /// k-means++ seeding.
    fn init_pp(&self, points: &[[f64; 3]], rng: &mut Rng) -> Vec<[f64; 3]> {
        let n = points.len();
        let mut centroids = Vec::with_capacity(self.k);
        centroids.push(points[rng.below_usize(n)]);
        let mut dist = vec![f64::INFINITY; n];
        while centroids.len() < self.k {
            let last = centroids.last().unwrap();
            for (i, p) in points.iter().enumerate() {
                dist[i] = dist[i].min(d2(p, last));
            }
            let total: f64 = dist.iter().sum();
            let next = if total <= 0.0 {
                rng.below_usize(n)
            } else {
                let mut t = rng.uniform() * total;
                let mut pick = n - 1;
                for (i, &d) in dist.iter().enumerate() {
                    t -= d;
                    if t <= 0.0 {
                        pick = i;
                        break;
                    }
                }
                pick
            };
            centroids.push(points[next]);
        }
        centroids
    }
}

/// One Eq. 13 assignment pass: index-pruned when a grid is available,
/// [`assign_nearest_brute`] otherwise. Both paths score candidates with
/// [`d2`] in ascending centroid order under a strict `<`, so they agree
/// bit for bit.
fn assign_step(
    points: &[[f64; 3]],
    centroids: &[[f64; 3]],
    grid: Option<&SphereGrid>,
    assignment: &mut Vec<usize>,
) {
    match grid {
        Some(g) => g.assign_nearest(centroids, assignment),
        None => assign_nearest_brute(points, centroids, assignment),
    }
}

fn assignment_of(p: &[f64; 3], centroids: &[[f64; 3]]) -> usize {
    let mut best = 0;
    let mut best_d = f64::INFINITY;
    for (c, cent) in centroids.iter().enumerate() {
        let d = d2(p, cent);
        if d < best_d {
            best_d = d;
            best = c;
        }
    }
    best
}

impl KMeansResult {
    /// Members of each cluster.
    pub fn clusters(&self) -> Vec<Vec<usize>> {
        let k = self.centroids.len();
        let mut out = vec![Vec::new(); k];
        for (i, &c) in self.assignment.iter().enumerate() {
            out[c].push(i);
        }
        out
    }

    /// Size of each cluster.
    pub fn sizes(&self) -> Vec<usize> {
        let k = self.centroids.len();
        let mut out = vec![0usize; k];
        for &c in &self.assignment {
            out[c] += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[[f64; 3]], per: usize, spread: f64) -> Vec<[f64; 3]> {
        let mut pts = Vec::new();
        for c in centers {
            for _ in 0..per {
                pts.push([
                    c[0] + spread * rng.normal(),
                    c[1] + spread * rng.normal(),
                    c[2] + spread * rng.normal(),
                ]);
            }
        }
        pts
    }

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let centers = [[0.0, 0.0, 0.0], [100.0, 0.0, 0.0], [0.0, 100.0, 0.0]];
        let pts = blobs(&mut rng, &centers, 40, 2.0);
        let res = KMeans::new(3).run(&pts, &mut rng).unwrap();
        // every blob should map to a single cluster
        for b in 0..3 {
            let ids: Vec<usize> = (b * 40..(b + 1) * 40).map(|i| res.assignment[i]).collect();
            assert!(ids.iter().all(|&c| c == ids[0]), "blob {b} split: {ids:?}");
        }
        // and each centroid should be near a true center
        for c in &res.centroids {
            let nearest = centers
                .iter()
                .map(|t| d2(c, t))
                .fold(f64::INFINITY, f64::min);
            assert!(nearest < 4.0, "centroid {c:?} off by {nearest}");
        }
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let mut rng = Rng::new(2);
        let pts = blobs(&mut rng, &[[0.0; 3], [50.0, 0.0, 0.0]], 30, 5.0);
        let res = KMeans::new(2).run(&pts, &mut rng).unwrap();
        for (i, p) in pts.iter().enumerate() {
            let assigned = res.assignment[i];
            for (c, cent) in res.centroids.iter().enumerate() {
                assert!(
                    d2(p, &res.centroids[assigned]) <= d2(p, cent) + 1e-9,
                    "point {i} nearer to {c}"
                );
            }
        }
    }

    #[test]
    fn infeasible_k_is_a_usage_error() {
        let mut rng = Rng::new(11);
        let pts = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [2.0, 0.0, 0.0]];
        let e = KMeans::new(4).run(&pts, &mut rng).unwrap_err();
        assert!(
            e.to_string().contains("cannot form 4 clusters from 3 points"),
            "{e}"
        );
        let e = KMeans::new(0).run(&pts, &mut rng).unwrap_err();
        assert!(e.to_string().contains("at least 1 cluster"), "{e}");
        // the boundary itself stays fine
        assert!(KMeans::new(3).run(&pts, &mut rng).is_ok());
    }

    #[test]
    fn stale_index_is_rejected() {
        let mut rng = Rng::new(12);
        let pts = blobs(&mut rng, &[[7000.0, 0.0, 0.0], [0.0, 7000.0, 0.0]], 10, 30.0);
        let other = blobs(&mut rng, &[[0.0, 0.0, 7000.0]], 20, 30.0);
        let grid = SphereGrid::build(&other, 4);
        let e = KMeans::new(2)
            .run_indexed(&pts, &mut rng, Some(&grid))
            .unwrap_err();
        assert!(e.to_string().contains("spatial index"), "{e}");
    }

    #[test]
    fn indexed_run_is_bit_identical_to_brute_force() {
        // shell-like points so the sphere grid is meaningful
        let mut rng = Rng::new(13);
        let pts: Vec<[f64; 3]> = (0..200)
            .map(|_| {
                let v = [rng.normal(), rng.normal(), rng.normal()];
                let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt().max(1e-9);
                let r = 7000.0 + 100.0 * rng.normal();
                [v[0] / n * r, v[1] / n * r, v[2] / n * r]
            })
            .collect();
        for bands in [1usize, 3, 8] {
            let grid = SphereGrid::build(&pts, bands);
            let mut r1 = Rng::new(99);
            let mut r2 = Rng::new(99);
            let brute = KMeans::new(5).run(&pts, &mut r1).unwrap();
            let indexed = KMeans::new(5)
                .run_indexed(&pts, &mut r2, Some(&grid))
                .unwrap();
            assert_eq!(brute.assignment, indexed.assignment, "bands={bands}");
            assert_eq!(brute.iterations, indexed.iterations, "bands={bands}");
            assert_eq!(
                brute.inertia.to_bits(),
                indexed.inertia.to_bits(),
                "bands={bands}"
            );
            for (a, b) in brute.centroids.iter().zip(&indexed.centroids) {
                assert_eq!(a, b, "bands={bands}");
            }
        }
    }

    #[test]
    fn k_equals_n_gives_zero_inertia() {
        let mut rng = Rng::new(3);
        let pts = vec![[0.0, 0.0, 0.0], [10.0, 0.0, 0.0], [0.0, 10.0, 0.0]];
        let res = KMeans::new(3).run(&pts, &mut rng).unwrap();
        assert!(res.inertia < 1e-9);
        let mut sizes = res.sizes();
        sizes.sort_unstable();
        assert_eq!(sizes, vec![1, 1, 1]);
    }

    #[test]
    fn k_one_centroid_is_mean() {
        let mut rng = Rng::new(4);
        let pts = vec![[0.0, 0.0, 0.0], [2.0, 4.0, 6.0]];
        let res = KMeans::new(1).run(&pts, &mut rng).unwrap();
        assert!((res.centroids[0][0] - 1.0).abs() < 1e-9);
        assert!((res.centroids[0][1] - 2.0).abs() < 1e-9);
        assert!((res.centroids[0][2] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn inertia_nonincreasing_in_k() {
        let mut rng = Rng::new(5);
        let pts = blobs(
            &mut rng,
            &[[0.0; 3], [30.0, 0.0, 0.0], [0.0, 30.0, 0.0], [0.0, 0.0, 30.0]],
            25,
            4.0,
        );
        let mut prev = f64::INFINITY;
        for k in 1..=6 {
            // best of 3 restarts to smooth out seeding luck
            let best = (0..3)
                .map(|s| {
                    let mut r = Rng::new(100 + s);
                    KMeans::new(k).run(&pts, &mut r).unwrap().inertia
                })
                .fold(f64::INFINITY, f64::min);
            assert!(
                best <= prev * 1.05,
                "inertia went up at k={k}: {best} > {prev}"
            );
            prev = best;
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let pts = blobs(&mut Rng::new(8), &[[0.0; 3], [20.0, 0.0, 0.0]], 50, 3.0);
        let a = KMeans::new(2).run(&pts, &mut r1).unwrap();
        let b = KMeans::new(2).run(&pts, &mut r2).unwrap();
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn every_cluster_nonempty_on_spread_data() {
        let mut rng = Rng::new(10);
        let pts: Vec<[f64; 3]> = (0..200)
            .map(|_| [rng.uniform() * 100.0, rng.uniform() * 100.0, rng.uniform() * 100.0])
            .collect();
        let res = KMeans::new(5).run(&pts, &mut rng).unwrap();
        assert!(res.sizes().iter().all(|&s| s > 0), "{:?}", res.sizes());
    }
}

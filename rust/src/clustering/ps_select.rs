//! Parameter-server selection (§III-B final step): within each cluster the
//! satellite nearest the converged centroid becomes the PS; ties and
//! communication quality are broken by the achievable-rate the candidate
//! offers to its cluster peers ("strong communication capabilities").
//!
//! [`select_parameter_servers`] is the historical exact criterion (every
//! cluster peer counts toward the rate tie-break, Earth occlusion
//! ignored). [`select_parameter_servers_los`] is the mega-constellation
//! variant: the tie-break only credits peers the candidate can actually
//! reach — inside ISL range *and* with a clear line of sight — with the
//! neighbor sets served by the constellation plane's sphere grid
//! ([`crate::orbit::index::SphereGrid::los_neighbors`], exactness-pinned
//! against the brute-force scan). The default coordinator path keeps the
//! historical criterion so committed trajectories stay byte-stable.

use super::kmeans::KMeansResult;
use crate::network::LinkModel;
use crate::orbit::index::{los_neighbors_brute, SphereGrid};
use crate::orbit::Vec3;

/// Per-cluster parameter-server choice.
#[derive(Clone, Debug, PartialEq)]
pub struct PsChoice {
    pub cluster: usize,
    pub ps: usize,
    /// Distance from the PS to the centroid, km.
    pub centroid_dist_km: f64,
}

/// How the rate tie-break counts a candidate's cluster peers.
enum PeerRule<'a> {
    /// Every other member (the paper's implicit assumption at 96-sat
    /// scale, where clusters are small).
    All,
    /// Only members within `max_range_m` with a clear line of sight, via
    /// the sphere grid when one is supplied (brute-force scan otherwise).
    Los {
        grid: Option<&'a SphereGrid>,
        max_range_m: f64,
    },
}

/// Select one PS per cluster. `positions` are ECI meters (same order as the
/// clustering input), `result.centroids` are km (features space).
///
/// Score: primarily centroid proximity (the paper's criterion), with the
/// mean achievable rate to cluster members as a tie-breaker within a 5 %
/// distance band — this encodes the paper's "strong communication
/// capabilities" qualifier.
pub fn select_parameter_servers(
    result: &KMeansResult,
    positions: &[Vec3],
    link: &LinkModel,
) -> Vec<PsChoice> {
    select_with_rule(result, positions, link, &PeerRule::All)
}

/// Like [`select_parameter_servers`], but the rate tie-break only counts
/// peers the candidate can reach over an ISL: within `max_range_m` and
/// with a line of sight clearing the Earth. `grid` (built from the same
/// epoch's positions) prunes the neighbor scan; `None` falls back to the
/// exhaustive scan with identical results.
pub fn select_parameter_servers_los(
    result: &KMeansResult,
    positions: &[Vec3],
    link: &LinkModel,
    grid: Option<&SphereGrid>,
    max_range_m: f64,
) -> Vec<PsChoice> {
    select_with_rule(result, positions, link, &PeerRule::Los { grid, max_range_m })
}

fn select_with_rule(
    result: &KMeansResult,
    positions: &[Vec3],
    link: &LinkModel,
    rule: &PeerRule,
) -> Vec<PsChoice> {
    let clusters = result.clusters();
    let mut out = Vec::with_capacity(clusters.len());
    let mut neighbors: Vec<usize> = Vec::new();
    for (c, members) in clusters.iter().enumerate() {
        assert!(!members.is_empty(), "cluster {c} is empty");
        let cent = result.centroids[c];
        let cent_m = Vec3::new(cent[0] * 1e3, cent[1] * 1e3, cent[2] * 1e3);

        // distance of every member to the centroid
        let dists: Vec<f64> = members
            .iter()
            .map(|&i| positions[i].dist(cent_m))
            .collect();
        let min_d = dists.iter().cloned().fold(f64::INFINITY, f64::min);
        let band = min_d * 1.05 + 1.0;

        // among near-minimal candidates, pick the best mean rate to peers
        let mut best: Option<(usize, f64)> = None;
        for (mi, &i) in members.iter().enumerate() {
            if dists[mi] > band {
                continue;
            }
            let mean_rate = match rule {
                PeerRule::All => {
                    if members.len() == 1 {
                        f64::INFINITY
                    } else {
                        members
                            .iter()
                            .filter(|&&j| j != i)
                            .map(|&j| link.rate(positions[i].dist(positions[j])))
                            .sum::<f64>()
                            / (members.len() - 1) as f64
                    }
                }
                // a singleton has no peers — skip the neighbor scan
                PeerRule::Los { .. } if members.len() == 1 => f64::INFINITY,
                PeerRule::Los { grid, max_range_m } => {
                    match grid {
                        Some(g) => g.los_neighbors(i, *max_range_m, positions, &mut neighbors),
                        None => los_neighbors_brute(i, *max_range_m, positions, &mut neighbors),
                    }
                    // restrict the (whole-constellation) neighbor set to
                    // this candidate's own cluster
                    let mut sum = 0.0f64;
                    let mut n_peers = 0usize;
                    for &j in &neighbors {
                        if result.assignment[j] == c {
                            sum += link.rate(positions[i].dist(positions[j]));
                            n_peers += 1;
                        }
                    }
                    if n_peers == 0 {
                        // a candidate that reaches nobody offers no rate
                        0.0
                    } else {
                        sum / n_peers as f64
                    }
                }
            };
            if best.map(|(_, r)| mean_rate > r).unwrap_or(true) {
                best = Some((i, mean_rate));
            }
        }
        let (ps, _) = best.unwrap();
        let mi = members.iter().position(|&i| i == ps).unwrap();
        out.push(PsChoice {
            cluster: c,
            ps,
            centroid_dist_km: dists[mi] / 1e3,
        });
    }
    out
}

/// Full PS ranking of one cluster's members, best candidate first — the
/// recovery plane's failover order. Rank 0 reproduces the
/// [`select_parameter_servers`] choice bit-identically (pinned by the
/// tests below): in-band candidates (within the 5 % centroid-distance
/// band) come first, ordered by descending mean peer rate with the
/// stable sort preserving the selection loop's first-seen-wins ties;
/// out-of-band members follow by ascending centroid distance. A crashed
/// PS promotes the next not-crashed, reachable entry.
pub fn rank_cluster_ps(
    members: &[usize],
    centroid_km: &[f64; 3],
    positions: &[Vec3],
    link: &LinkModel,
) -> Vec<usize> {
    assert!(!members.is_empty(), "ranking an empty cluster");
    let cent_m = Vec3::new(centroid_km[0] * 1e3, centroid_km[1] * 1e3, centroid_km[2] * 1e3);
    let dists: Vec<f64> = members.iter().map(|&i| positions[i].dist(cent_m)).collect();
    let min_d = dists.iter().cloned().fold(f64::INFINITY, f64::min);
    let band = min_d * 1.05 + 1.0;
    let in_band: Vec<bool> = dists.iter().map(|&d| d <= band).collect();
    // the same mean-rate tie-break the selection loop computes (only for
    // in-band candidates — it is what orders them)
    let rates: Vec<f64> = members
        .iter()
        .enumerate()
        .map(|(mi, &i)| {
            if !in_band[mi] {
                0.0
            } else if members.len() == 1 {
                f64::INFINITY
            } else {
                members
                    .iter()
                    .filter(|&&j| j != i)
                    .map(|&j| link.rate(positions[i].dist(positions[j])))
                    .sum::<f64>()
                    / (members.len() - 1) as f64
            }
        })
        .collect();
    let mut order: Vec<usize> = (0..members.len()).collect();
    order.sort_by(|&a, &b| match (in_band[a], in_band[b]) {
        (true, false) => std::cmp::Ordering::Less,
        (false, true) => std::cmp::Ordering::Greater,
        (true, true) => rates[b].total_cmp(&rates[a]),
        (false, false) => dists[a].total_cmp(&dists[b]),
    });
    order.into_iter().map(|mi| members[mi]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clustering::kmeans::KMeans;
    use crate::network::params::NetworkParams;
    use crate::util::Rng;

    fn setup(n_blob: usize) -> (KMeansResult, Vec<Vec3>, LinkModel) {
        let mut rng = Rng::new(77);
        let centers = [[0.0f64, 0.0, 7000.0], [7000.0, 0.0, 0.0]];
        let mut pts_km = Vec::new();
        for c in &centers {
            for _ in 0..n_blob {
                pts_km.push([
                    c[0] + 50.0 * rng.normal(),
                    c[1] + 50.0 * rng.normal(),
                    c[2] + 50.0 * rng.normal(),
                ]);
            }
        }
        let res = KMeans::new(2).run(&pts_km, &mut rng).unwrap();
        let pos: Vec<Vec3> = pts_km
            .iter()
            .map(|p| Vec3::new(p[0] * 1e3, p[1] * 1e3, p[2] * 1e3))
            .collect();
        (res, pos, LinkModel::new(NetworkParams::default()))
    }

    #[test]
    fn one_ps_per_cluster() {
        let (res, pos, link) = setup(20);
        let ps = select_parameter_servers(&res, &pos, &link);
        assert_eq!(ps.len(), 2);
        assert_ne!(ps[0].ps, ps[1].ps);
    }

    #[test]
    fn ps_belongs_to_its_cluster() {
        let (res, pos, link) = setup(20);
        for choice in select_parameter_servers(&res, &pos, &link) {
            assert_eq!(res.assignment[choice.ps], choice.cluster);
        }
    }

    #[test]
    fn ps_is_near_centroid() {
        let (res, pos, link) = setup(30);
        for choice in select_parameter_servers(&res, &pos, &link) {
            // the PS must be within the 5% band of the minimal distance
            let members: Vec<usize> = res
                .assignment
                .iter()
                .enumerate()
                .filter(|(_, &c)| c == choice.cluster)
                .map(|(i, _)| i)
                .collect();
            let cent = res.centroids[choice.cluster];
            let cent_m = Vec3::new(cent[0] * 1e3, cent[1] * 1e3, cent[2] * 1e3);
            let min_d = members
                .iter()
                .map(|&i| pos[i].dist(cent_m))
                .fold(f64::INFINITY, f64::min);
            let d_ps = pos[choice.ps].dist(cent_m);
            assert!(d_ps <= min_d * 1.05 + 1.0, "ps {d_ps} vs min {min_d}");
        }
    }

    #[test]
    fn singleton_cluster_ps_is_member() {
        let mut rng = Rng::new(5);
        let pts = vec![[0.0, 0.0, 0.0], [1000.0, 0.0, 0.0]];
        let res = KMeans::new(2).run(&pts, &mut rng).unwrap();
        let pos: Vec<Vec3> = pts
            .iter()
            .map(|p| Vec3::new(p[0] * 1e3, p[1] * 1e3, p[2] * 1e3))
            .collect();
        let link = LinkModel::new(NetworkParams::default());
        let ps = select_parameter_servers(&res, &pos, &link);
        assert_eq!(ps.len(), 2);
        let mut ids: Vec<usize> = ps.iter().map(|p| p.ps).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1]);
    }

    #[test]
    fn los_variant_matches_classic_when_all_peers_reachable() {
        // tight LEO blobs: every intra-cluster pair is within range and
        // unoccluded, so the LoS rule counts exactly the classic peer set
        let (res, pos, link) = setup(15);
        let feats: Vec<[f64; 3]> = pos.iter().map(|p| [p.x / 1e3, p.y / 1e3, p.z / 1e3]).collect();
        let grid = SphereGrid::build(&feats, 6);
        let classic = select_parameter_servers(&res, &pos, &link);
        let with_grid = select_parameter_servers_los(&res, &pos, &link, Some(&grid), 1e9);
        let with_brute = select_parameter_servers_los(&res, &pos, &link, None, 1e9);
        assert_eq!(classic, with_grid);
        assert_eq!(classic, with_brute);
    }

    #[test]
    fn failover_rank_zero_reproduces_the_selection() {
        let (res, pos, link) = setup(20);
        let picks = select_parameter_servers(&res, &pos, &link);
        for (c, members) in res.clusters().iter().enumerate() {
            let rank = rank_cluster_ps(members, &res.centroids[c], &pos, &link);
            // a permutation of the membership, led by the selected PS
            assert_eq!(rank.len(), members.len());
            let mut sorted = rank.clone();
            sorted.sort_unstable();
            let mut expect = members.clone();
            expect.sort_unstable();
            assert_eq!(sorted, expect);
            assert_eq!(rank[0], picks[c].ps, "rank 0 must be the elected PS");
        }
    }

    #[test]
    fn failover_rank_handles_singletons_and_is_deterministic() {
        let (res, pos, link) = setup(12);
        let clusters = res.clusters();
        let one = vec![clusters[0][0]];
        let rank = rank_cluster_ps(&one, &res.centroids[0], &pos, &link);
        assert_eq!(rank, one);
        let a = rank_cluster_ps(&clusters[1], &res.centroids[1], &pos, &link);
        let b = rank_cluster_ps(&clusters[1], &res.centroids[1], &pos, &link);
        assert_eq!(a, b);
    }

    #[test]
    fn los_variant_still_picks_a_member_when_nobody_is_reachable() {
        let (res, pos, link) = setup(10);
        // a 1 m range leaves every candidate peerless (rate 0): selection
        // must still return one member per cluster, inside the 5% band
        let out = select_parameter_servers_los(&res, &pos, &link, None, 1.0);
        assert_eq!(out.len(), 2);
        for choice in out {
            assert_eq!(res.assignment[choice.ps], choice.cluster);
        }
    }
}

//! Walker-delta constellation generation.
//!
//! The paper distributes satellites "evenly across each orbit" at a common
//! altitude/inclination — exactly a Walker-delta pattern i:T/P/F with T
//! total satellites in P equally-spaced planes and an inter-plane phasing
//! factor F.

use super::elements::OrbitalElements;
use std::f64::consts::PI;

/// A Walker-delta constellation specification.
#[derive(Clone, Debug)]
pub struct WalkerConstellation {
    pub altitude_m: f64,
    pub inclination_deg: f64,
    /// Number of orbital planes (P).
    pub planes: usize,
    /// Satellites per plane (S); total T = P * S.
    pub sats_per_plane: usize,
    /// Phasing factor F in [0, P).
    pub phasing: usize,
}

impl WalkerConstellation {
    pub fn new(
        altitude_m: f64,
        inclination_deg: f64,
        planes: usize,
        sats_per_plane: usize,
        phasing: usize,
    ) -> Self {
        assert!(planes > 0 && sats_per_plane > 0);
        assert!(phasing < planes.max(1));
        WalkerConstellation {
            altitude_m,
            inclination_deg,
            planes,
            sats_per_plane,
            phasing,
        }
    }

    /// A shell at arbitrary altitude/inclination with the standard F=1
    /// inter-plane phasing (F=0 for a single plane).
    pub fn shell(
        altitude_m: f64,
        inclination_deg: f64,
        planes: usize,
        sats_per_plane: usize,
    ) -> Self {
        WalkerConstellation::new(
            altitude_m,
            inclination_deg,
            planes,
            sats_per_plane,
            1.min(planes - 1),
        )
    }

    /// The paper's testbed shell: 1300 km, 53°. Planes/sats chosen by the
    /// caller to hit the desired client count.
    pub fn paper_shell(planes: usize, sats_per_plane: usize) -> Self {
        WalkerConstellation::shell(1_300_000.0, 53.0, planes, sats_per_plane)
    }

    /// A mega-constellation shell (Starlink-class first shell: 550 km,
    /// 53°). `mega_shell(40, 125)` is the 5 000-satellite geometry behind
    /// the `mega-dense` preset; `mega_shell(40, 25)` the 1 000-satellite
    /// `mega-sparse` tier.
    pub fn mega_shell(planes: usize, sats_per_plane: usize) -> Self {
        WalkerConstellation::shell(550_000.0, 53.0, planes, sats_per_plane)
    }

    pub fn total(&self) -> usize {
        self.planes * self.sats_per_plane
    }

    /// Generate the orbital elements of every satellite. Satellite index
    /// `p * sats_per_plane + s` is slot `s` of plane `p`.
    pub fn elements(&self) -> Vec<OrbitalElements> {
        let mut out = Vec::with_capacity(self.total());
        let t_total = self.total() as f64;
        for p in 0..self.planes {
            let raan = 2.0 * PI * p as f64 / self.planes as f64;
            for s in 0..self.sats_per_plane {
                // in-plane spacing + Walker phasing offset between planes
                let phase = 2.0 * PI
                    * (s as f64 / self.sats_per_plane as f64
                        + self.phasing as f64 * p as f64 / t_total);
                out.push(OrbitalElements::circular(
                    self.altitude_m,
                    self.inclination_deg,
                    raan,
                    phase,
                ));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_count() {
        let w = WalkerConstellation::paper_shell(8, 12);
        assert_eq!(w.total(), 96);
        assert_eq!(w.elements().len(), 96);
    }

    #[test]
    fn planes_have_distinct_raan() {
        let w = WalkerConstellation::paper_shell(6, 4);
        let els = w.elements();
        for p in 0..6 {
            let raan = els[p * 4].raan;
            for s in 1..4 {
                assert_eq!(els[p * 4 + s].raan, raan);
            }
            if p > 0 {
                assert!((els[p * 4].raan - els[0].raan).abs() > 1e-6);
            }
        }
    }

    #[test]
    fn in_plane_spacing_uniform() {
        let w = WalkerConstellation::paper_shell(3, 10);
        let els = w.elements();
        let gap = 2.0 * PI / 10.0;
        for s in 1..10 {
            let d = els[s].phase - els[s - 1].phase;
            assert!((d - gap).abs() < 1e-9);
        }
    }

    #[test]
    fn all_sats_at_same_altitude_and_inclination() {
        let w = WalkerConstellation::paper_shell(5, 5);
        for e in w.elements() {
            assert!((e.semi_major_axis - (super::super::EARTH_RADIUS + 1_300_000.0)).abs() < 1e-6);
            assert!((e.inclination - 53f64.to_radians()).abs() < 1e-12);
        }
    }

    #[test]
    fn mega_shell_geometry() {
        let w = WalkerConstellation::mega_shell(40, 125);
        assert_eq!(w.total(), 5000);
        let e = &w.elements()[0];
        assert!((e.semi_major_axis - (super::super::EARTH_RADIUS + 550_000.0)).abs() < 1e-6);
        assert!((e.inclination - 53f64.to_radians()).abs() < 1e-12);
        // a single-plane shell degenerates to F=0 without panicking
        let single = WalkerConstellation::shell(550_000.0, 53.0, 1, 10);
        assert_eq!(single.phasing, 0);
        assert_eq!(single.total(), 10);
    }

    #[test]
    fn satellites_spread_in_space() {
        // at t=0 no two satellites should be co-located
        let w = WalkerConstellation::paper_shell(4, 6);
        let pos: Vec<_> = w.elements().iter().map(|e| e.position_eci(0.0)).collect();
        for i in 0..pos.len() {
            for j in (i + 1)..pos.len() {
                assert!(pos[i].dist(pos[j]) > 1_000.0, "sats {i},{j} co-located");
            }
        }
    }

    use std::f64::consts::PI;
}

//! Geometry primitives: 3-vectors, ECI↔ECEF conversion, geodetic ground
//! stations, and elevation angles.

use super::{EARTH_OMEGA, EARTH_RADIUS};

/// A 3-vector in meters (frame documented at each use site).
#[derive(Clone, Copy, Debug, PartialEq, Default)]
pub struct Vec3 {
    pub x: f64,
    pub y: f64,
    pub z: f64,
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 {
        x: 0.0,
        y: 0.0,
        z: 0.0,
    };

    pub fn new(x: f64, y: f64, z: f64) -> Vec3 {
        Vec3 { x, y, z }
    }

    #[inline]
    pub fn add(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x + o.x, self.y + o.y, self.z + o.z)
    }

    #[inline]
    pub fn sub(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x - o.x, self.y - o.y, self.z - o.z)
    }

    #[inline]
    pub fn scale(self, k: f64) -> Vec3 {
        Vec3::new(self.x * k, self.y * k, self.z * k)
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f64 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn norm(self) -> f64 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn dist(self, o: Vec3) -> f64 {
        self.sub(o).norm()
    }

    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        assert!(n > 0.0, "normalizing zero vector");
        self.scale(1.0 / n)
    }
}

/// Rotate an ECI position into the Earth-fixed (ECEF) frame at time `t`
/// seconds after frame alignment (Greenwich angle = EARTH_OMEGA * t).
pub fn eci_to_ecef(p: Vec3, t: f64) -> Vec3 {
    let theta = EARTH_OMEGA * t;
    let (s, c) = theta.sin_cos();
    Vec3::new(c * p.x + s * p.y, -s * p.x + c * p.y, p.z)
}

/// Rotate an ECEF position into ECI at time `t`.
pub fn ecef_to_eci(p: Vec3, t: f64) -> Vec3 {
    let theta = EARTH_OMEGA * t;
    let (s, c) = theta.sin_cos();
    Vec3::new(c * p.x - s * p.y, s * p.x + c * p.y, p.z)
}

/// Geodetic ground station (spherical-Earth model — adequate for link
/// budgets and visibility windows at LEO altitudes).
#[derive(Clone, Debug)]
pub struct GroundStation {
    pub id: usize,
    pub name: String,
    /// Latitude in degrees, +north.
    pub lat_deg: f64,
    /// Longitude in degrees, +east.
    pub lon_deg: f64,
    /// Minimum elevation angle for a usable link, degrees.
    pub min_elevation_deg: f64,
}

impl GroundStation {
    pub fn new(id: usize, name: &str, lat_deg: f64, lon_deg: f64, min_elevation_deg: f64) -> Self {
        GroundStation {
            id,
            name: name.to_string(),
            lat_deg,
            lon_deg,
            min_elevation_deg,
        }
    }

    /// Position in the Earth-fixed frame (constant).
    pub fn ecef(&self) -> Vec3 {
        let lat = self.lat_deg.to_radians();
        let lon = self.lon_deg.to_radians();
        Vec3::new(
            EARTH_RADIUS * lat.cos() * lon.cos(),
            EARTH_RADIUS * lat.cos() * lon.sin(),
            EARTH_RADIUS * lat.sin(),
        )
    }

    /// Position in ECI at time `t`.
    pub fn eci(&self, t: f64) -> Vec3 {
        ecef_to_eci(self.ecef(), t)
    }

    /// Elevation angle (radians) of a satellite at ECI position `sat` as
    /// seen from this station at time `t`. Negative when below horizon.
    pub fn elevation(&self, sat: Vec3, t: f64) -> f64 {
        let gs = self.eci(t);
        let up = gs.normalized();
        let rel = sat.sub(gs);
        let r = rel.norm();
        if r == 0.0 {
            return std::f64::consts::FRAC_PI_2;
        }
        (rel.dot(up) / r).asin()
    }

    /// Whether the satellite is visible (elevation above the mask).
    pub fn sees(&self, sat: Vec3, t: f64) -> bool {
        self.elevation(sat, t) >= self.min_elevation_deg.to_radians()
    }

    /// Slant range to the satellite, meters.
    pub fn range(&self, sat: Vec3, t: f64) -> f64 {
        sat.dist(self.eci(t))
    }
}

/// A small default ground-segment: three stations spread in longitude, all
/// with the paper's 10° elevation mask.
pub fn default_ground_segment() -> Vec<GroundStation> {
    vec![
        GroundStation::new(0, "wuhan", 30.6, 114.3, 10.0),
        GroundStation::new(1, "melbourne", -37.8, 145.0, 10.0),
        GroundStation::new(2, "svalbard", 78.2, 15.4, 10.0),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::f64::consts::PI;

    #[test]
    fn vec3_algebra() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-1.0, 0.5, 2.0);
        assert_eq!(a.add(b), Vec3::new(0.0, 2.5, 5.0));
        assert_eq!(a.sub(b), Vec3::new(2.0, 1.5, 1.0));
        assert!((a.dot(b) - (-1.0 + 1.0 + 6.0)).abs() < 1e-12);
        let c = a.cross(b);
        // orthogonality of the cross product
        assert!(c.dot(a).abs() < 1e-12);
        assert!(c.dot(b).abs() < 1e-12);
    }

    #[test]
    fn eci_ecef_roundtrip() {
        let p = Vec3::new(7.0e6, -1.2e6, 3.3e6);
        for &t in &[0.0, 100.0, 5000.0, 86400.0] {
            let q = ecef_to_eci(eci_to_ecef(p, t), t);
            assert!(p.dist(q) < 1e-6, "t={t}");
        }
    }

    #[test]
    fn ecef_rotation_preserves_norm_and_z() {
        let p = Vec3::new(7.0e6, -1.2e6, 3.3e6);
        let q = eci_to_ecef(p, 1234.0);
        assert!((p.norm() - q.norm()).abs() < 1e-6);
        assert_eq!(p.z, q.z);
    }

    #[test]
    fn ground_station_on_sphere() {
        for gs in default_ground_segment() {
            assert!((gs.ecef().norm() - EARTH_RADIUS).abs() < 1e-6);
        }
    }

    #[test]
    fn equator_station_position() {
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        let p = gs.ecef();
        assert!((p.x - EARTH_RADIUS).abs() < 1e-6);
        assert!(p.y.abs() < 1e-6);
        assert!(p.z.abs() < 1e-6);
    }

    #[test]
    fn zenith_satellite_has_90deg_elevation() {
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        // directly overhead at t=0: along +x
        let sat = Vec3::new(EARTH_RADIUS + 1_300_000.0, 0.0, 0.0);
        let el = gs.elevation(sat, 0.0);
        assert!((el - PI / 2.0).abs() < 1e-9);
        assert!(gs.sees(sat, 0.0));
    }

    #[test]
    fn antipodal_satellite_below_horizon() {
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        let sat = Vec3::new(-(EARTH_RADIUS + 1_300_000.0), 0.0, 0.0);
        assert!(gs.elevation(sat, 0.0) < 0.0);
        assert!(!gs.sees(sat, 0.0));
    }

    #[test]
    fn elevation_mask_boundary() {
        // a satellite exactly on the geometric horizon has elevation ~0,
        // which fails a 10° mask but passes a -5° mask.
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        let horizon_sat = Vec3::new(EARTH_RADIUS, 2_000_000.0, 0.0);
        assert!(!gs.sees(horizon_sat, 0.0));
        let gs_loose = GroundStation::new(0, "eq", 0.0, 0.0, -45.0);
        assert!(gs_loose.sees(horizon_sat, 0.0));
    }

    #[test]
    fn station_rotates_with_earth() {
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        let p0 = gs.eci(0.0);
        // quarter sidereal day later the station has rotated ~90°
        let quarter = 0.25 * 2.0 * PI / EARTH_OMEGA;
        let p1 = gs.eci(quarter);
        assert!(p0.normalized().dot(p1.normalized()).abs() < 1e-6);
    }
}

//! LEO orbital-mechanics substrate.
//!
//! The paper evaluates FedHC on a simulated LEO constellation (1300 km
//! altitude, 53° inclination, ground stations with a 10° minimum elevation
//! angle). This module provides everything the coordinator consumes from
//! that testbed: satellite positions over time (circular Keplerian
//! propagation in an Earth-centered inertial frame), Walker-delta
//! constellation generation, ground-station geometry, elevation-angle
//! visibility, and satellite–satellite / satellite–ground ranges.

pub mod elements;
pub mod geo;
pub mod index;
pub mod propagate;
pub mod visibility;
pub mod walker;

pub use elements::OrbitalElements;
pub use geo::{GroundStation, Vec3};
pub use index::{ConstellationIndex, SphereGrid};
pub use propagate::Constellation;
pub use walker::WalkerConstellation;

/// Standard gravitational parameter of Earth, m^3/s^2.
pub const MU_EARTH: f64 = 3.986_004_418e14;
/// Mean Earth radius, m.
pub const EARTH_RADIUS: f64 = 6_371_000.0;
/// Earth rotation rate, rad/s (sidereal).
pub const EARTH_OMEGA: f64 = 7.292_115_0e-5;
/// Speed of light, m/s.
pub const SPEED_OF_LIGHT: f64 = 299_792_458.0;

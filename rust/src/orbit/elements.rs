//! Circular Keplerian orbital elements and single-satellite propagation.
//!
//! The paper's constellation is a uniform LEO shell (circular orbits at a
//! fixed altitude/inclination), so a circular two-body propagator is exact
//! for the quantities the coordinator consumes (positions, periods,
//! visibility). Eccentric orbits, J2 drift, and drag are out of scope and
//! documented as such in DESIGN.md.

use super::geo::Vec3;
use super::{EARTH_RADIUS, MU_EARTH};

/// Circular orbit elements.
#[derive(Clone, Copy, Debug)]
pub struct OrbitalElements {
    /// Semi-major axis (= orbit radius for circular), meters.
    pub semi_major_axis: f64,
    /// Inclination, radians.
    pub inclination: f64,
    /// Right ascension of the ascending node, radians.
    pub raan: f64,
    /// Argument of latitude at epoch (true anomaly + arg of perigee for a
    /// circular orbit), radians.
    pub phase: f64,
}

impl OrbitalElements {
    /// Construct from altitude above the mean Earth radius.
    pub fn circular(altitude_m: f64, inclination_deg: f64, raan_rad: f64, phase_rad: f64) -> Self {
        assert!(altitude_m > 0.0, "altitude must be positive");
        OrbitalElements {
            semi_major_axis: EARTH_RADIUS + altitude_m,
            inclination: inclination_deg.to_radians(),
            raan: raan_rad,
            phase: phase_rad,
        }
    }

    /// Orbital period, seconds: 2π√(a³/μ).
    pub fn period(&self) -> f64 {
        2.0 * std::f64::consts::PI * (self.semi_major_axis.powi(3) / MU_EARTH).sqrt()
    }

    /// Mean motion, rad/s.
    pub fn mean_motion(&self) -> f64 {
        (MU_EARTH / self.semi_major_axis.powi(3)).sqrt()
    }

    /// Orbital speed, m/s (circular: v = √(μ/a)).
    pub fn speed(&self) -> f64 {
        (MU_EARTH / self.semi_major_axis).sqrt()
    }

    /// ECI position at time `t` seconds after epoch.
    ///
    /// Perifocal position for a circular orbit is (a·cos u, a·sin u, 0) with
    /// argument of latitude u = phase + n·t; rotate by inclination about x,
    /// then by RAAN about z.
    pub fn position_eci(&self, t: f64) -> Vec3 {
        let u = self.phase + self.mean_motion() * t;
        let (su, cu) = u.sin_cos();
        let (si, ci) = self.inclination.sin_cos();
        let (so, co) = self.raan.sin_cos();
        let a = self.semi_major_axis;
        // in-plane
        let xp = a * cu;
        let yp = a * su;
        // rotate: R_z(raan) * R_x(inc) * [xp, yp, 0]
        Vec3::new(
            co * xp - so * ci * yp,
            so * xp + co * ci * yp,
            si * yp,
        )
    }

    /// ECI velocity at time `t` (analytic derivative of `position_eci`).
    pub fn velocity_eci(&self, t: f64) -> Vec3 {
        let n = self.mean_motion();
        let u = self.phase + n * t;
        let (su, cu) = u.sin_cos();
        let (si, ci) = self.inclination.sin_cos();
        let (so, co) = self.raan.sin_cos();
        let v = self.semi_major_axis * n;
        let xp = -v * su;
        let yp = v * cu;
        Vec3::new(
            co * xp - so * ci * yp,
            so * xp + co * ci * yp,
            si * yp,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leo() -> OrbitalElements {
        // the paper's shell: 1300 km, 53°
        OrbitalElements::circular(1_300_000.0, 53.0, 0.3, 1.1)
    }

    #[test]
    fn period_is_about_111_minutes() {
        // a = 7671 km → T ≈ 2π√(a³/μ) ≈ 6700 s
        let t = leo().period();
        assert!((6500.0..7000.0).contains(&t), "T={t}");
    }

    #[test]
    fn radius_constant_over_orbit() {
        let e = leo();
        for i in 0..100 {
            let t = i as f64 * 70.0;
            let r = e.position_eci(t).norm();
            assert!((r - e.semi_major_axis).abs() < 1e-3, "t={t} r={r}");
        }
    }

    #[test]
    fn periodicity() {
        let e = leo();
        let p0 = e.position_eci(0.0);
        let p1 = e.position_eci(e.period());
        assert!(p0.dist(p1) < 1.0, "drift {}", p0.dist(p1));
    }

    #[test]
    fn inclination_bounds_latitude() {
        let e = leo();
        let max_z = e.semi_major_axis * e.inclination.sin();
        for i in 0..200 {
            let z = e.position_eci(i as f64 * 33.0).z.abs();
            assert!(z <= max_z + 1e-3);
        }
    }

    #[test]
    fn equatorial_orbit_stays_in_plane() {
        let e = OrbitalElements::circular(500_000.0, 0.0, 0.0, 0.0);
        for i in 0..50 {
            assert!(e.position_eci(i as f64 * 100.0).z.abs() < 1e-9);
        }
    }

    #[test]
    fn velocity_is_tangential_and_correct_magnitude() {
        let e = leo();
        for &t in &[0.0, 500.0, 3000.0] {
            let p = e.position_eci(t);
            let v = e.velocity_eci(t);
            // circular: velocity ⟂ position
            assert!(p.dot(v).abs() / (p.norm() * v.norm()) < 1e-9);
            assert!((v.norm() - e.speed()).abs() < 1e-6);
        }
    }

    #[test]
    fn velocity_matches_finite_difference() {
        let e = leo();
        let t = 777.0;
        let h = 1e-3;
        let fd = e
            .position_eci(t + h)
            .sub(e.position_eci(t - h))
            .scale(1.0 / (2.0 * h));
        let v = e.velocity_eci(t);
        assert!(fd.dist(v) < 1e-2, "fd={fd:?} v={v:?}");
    }
}

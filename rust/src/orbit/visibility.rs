//! Visibility computation: which satellites a ground station can see, when,
//! and which satellite pairs have line-of-sight (for intra-cluster links).

use super::geo::{GroundStation, Vec3};
use super::propagate::Constellation;
use super::EARTH_RADIUS;

/// A contiguous interval during which a station sees a satellite.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Window {
    pub sat: usize,
    pub start: f64,
    pub end: f64,
}

impl Window {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Indices of satellites visible from `gs` at time `t`.
pub fn visible_sats(gs: &GroundStation, c: &Constellation, t: f64) -> Vec<usize> {
    c.elements
        .iter()
        .enumerate()
        .filter(|(_, e)| gs.sees(e.position_eci(t), t))
        .map(|(i, _)| i)
        .collect()
}

/// Compute visibility windows for every satellite from `gs` over
/// `[t0, t1]`, sampling every `dt` seconds and refining each edge by
/// bisection to sub-second accuracy.
pub fn windows(
    gs: &GroundStation,
    c: &Constellation,
    t0: f64,
    t1: f64,
    dt: f64,
) -> Vec<Window> {
    assert!(t1 > t0 && dt > 0.0);
    let mut out = Vec::new();
    for (i, e) in c.elements.iter().enumerate() {
        let vis = |t: f64| gs.sees(e.position_eci(t), t);
        let mut t = t0;
        let mut prev = vis(t0);
        let mut start = if prev { Some(t0) } else { None };
        while t < t1 {
            let tn = (t + dt).min(t1);
            let cur = vis(tn);
            if cur != prev {
                let edge = bisect_edge(&vis, t, tn);
                if cur {
                    start = Some(edge);
                } else if let Some(s) = start.take() {
                    out.push(Window {
                        sat: i,
                        start: s,
                        end: edge,
                    });
                }
            }
            prev = cur;
            t = tn;
        }
        if let Some(s) = start {
            out.push(Window {
                sat: i,
                start: s,
                end: t1,
            });
        }
    }
    out.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
    out
}

fn bisect_edge(vis: &dyn Fn(f64) -> bool, mut lo: f64, mut hi: f64) -> f64 {
    // invariant: vis(lo) != vis(hi)
    for _ in 0..30 {
        let mid = 0.5 * (lo + hi);
        if vis(mid) == vis(lo) {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 0.25 {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// Line-of-sight between two ECI points: the segment must clear the Earth
/// (with a small atmosphere margin). Used for inter-satellite links.
pub fn has_line_of_sight(a: Vec3, b: Vec3) -> bool {
    const MARGIN: f64 = 80_000.0; // atmosphere grazing margin, m
    let ab = b.sub(a);
    let len2 = ab.dot(ab);
    if len2 == 0.0 {
        return true;
    }
    // closest point of the segment to the geocenter
    let t = (-a.dot(ab) / len2).clamp(0.0, 1.0);
    let closest = a.add(ab.scale(t));
    closest.norm() >= EARTH_RADIUS + MARGIN
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::walker::WalkerConstellation;

    #[test]
    fn los_for_adjacent_sats() {
        let r = EARTH_RADIUS + 1_300_000.0;
        let a = Vec3::new(r, 0.0, 0.0);
        let b = Vec3::new(r * 0.9, r * 0.43, 0.0);
        assert!(has_line_of_sight(a, b));
    }

    #[test]
    fn no_los_through_earth() {
        let r = EARTH_RADIUS + 1_300_000.0;
        let a = Vec3::new(r, 0.0, 0.0);
        let b = Vec3::new(-r, 0.0, 0.0);
        assert!(!has_line_of_sight(a, b));
    }

    #[test]
    fn los_is_symmetric_and_reflexive() {
        let r = EARTH_RADIUS + 800_000.0;
        let a = Vec3::new(r, 100.0, -5.0);
        let b = Vec3::new(0.0, r, 0.0);
        assert_eq!(has_line_of_sight(a, b), has_line_of_sight(b, a));
        assert!(has_line_of_sight(a, a));
    }

    #[test]
    fn some_sats_visible_from_ground() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        // with 96 sats in a 53° shell an equatorial station sees a few
        let v = visible_sats(&gs, &c, 0.0);
        assert!(!v.is_empty(), "no satellites visible");
        assert!(v.len() < c.len(), "all satellites visible is impossible");
    }

    #[test]
    fn windows_are_well_formed() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(3, 4));
        let gs = GroundStation::new(0, "mid", 45.0, 10.0, 10.0);
        let period = c.min_period();
        let ws = windows(&gs, &c, 0.0, 2.0 * period, 30.0);
        assert!(!ws.is_empty(), "no visibility windows in two periods");
        for w in &ws {
            assert!(w.end > w.start, "{w:?}");
            assert!(w.duration() < period, "window longer than an orbit: {w:?}");
            // midpoint of a window must be visible
            let mid = 0.5 * (w.start + w.end);
            assert!(gs.sees(c.elements[w.sat].position_eci(mid), mid));
        }
    }

    #[test]
    fn window_edges_are_tight() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(3, 4));
        let gs = GroundStation::new(0, "mid", 45.0, 10.0, 10.0);
        let ws = windows(&gs, &c, 0.0, c.min_period(), 30.0);
        for w in ws.iter().take(5) {
            if w.start > 0.0 {
                // just before the start the satellite is not visible
                let t = w.start - 1.0;
                assert!(!gs.sees(c.elements[w.sat].position_eci(t), t), "{w:?}");
            }
        }
    }
}

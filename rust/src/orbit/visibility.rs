//! Visibility computation: which satellites a ground station can see, when,
//! and which satellite pairs have line-of-sight (for intra-cluster links).

use super::elements::OrbitalElements;
use super::geo::{GroundStation, Vec3};
use super::propagate::Constellation;
use super::EARTH_RADIUS;

/// A contiguous interval during which a station sees a satellite.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Window {
    pub sat: usize,
    pub start: f64,
    pub end: f64,
}

impl Window {
    pub fn duration(&self) -> f64 {
        self.end - self.start
    }
}

/// Indices of satellites visible from `gs` at time `t` (exhaustive scan —
/// the brute-force fallback of [`visible_sats_indexed`]).
pub fn visible_sats(gs: &GroundStation, c: &Constellation, t: f64) -> Vec<usize> {
    c.elements
        .iter()
        .enumerate()
        .filter(|(_, e)| gs.sees(e.position_eci(t), t))
        .map(|(i, _)| i)
        .collect()
}

/// Index-pruned visibility probe: bit-identical to [`visible_sats`] over
/// the snapshot's constellation (the sphere grid only prunes cells that
/// provably cannot hold a visible satellite — see [`crate::orbit::index`]),
/// sub-linear in N for realistic elevation masks. Takes the epoch's
/// already-propagated [`Snapshot`] — the per-round cost the coordinator
/// pays anyway — so the probe itself touches only footprint cells; `grid`
/// must be built from the same snapshot.
pub fn visible_sats_indexed(
    gs: &GroundStation,
    snap: &crate::orbit::propagate::Snapshot,
    grid: &crate::orbit::index::SphereGrid,
) -> Vec<usize> {
    let mut out = Vec::new();
    grid.visible_from(gs, &snap.positions, snap.t, &mut out);
    out
}

/// Compute visibility windows for every satellite from `gs` over
/// `[t0, t1]`, sampling every `dt` seconds and refining each edge by
/// bisection to sub-second accuracy.
pub fn windows(
    gs: &GroundStation,
    c: &Constellation,
    t0: f64,
    t1: f64,
    dt: f64,
) -> Vec<Window> {
    assert!(t1 > t0 && dt > 0.0);
    let mut out = Vec::new();
    for (i, e) in c.elements.iter().enumerate() {
        let vis = |t: f64| gs.sees(e.position_eci(t), t);
        let mut t = t0;
        let mut prev = vis(t0);
        let mut start = if prev { Some(t0) } else { None };
        while t < t1 {
            let tn = (t + dt).min(t1);
            let cur = vis(tn);
            if cur != prev {
                let edge = bisect_edge(&vis, t, tn);
                if cur {
                    start = Some(edge);
                } else if let Some(s) = start.take() {
                    out.push(Window {
                        sat: i,
                        start: s,
                        end: edge,
                    });
                }
            }
            prev = cur;
            t = tn;
        }
        if let Some(s) = start {
            out.push(Window {
                sat: i,
                start: s,
                end: t1,
            });
        }
    }
    out.sort_by(|a, b| a.start.total_cmp(&b.start));
    out
}

/// Earliest visibility window of satellite `e` from `gs` at or after `t`,
/// searched up to `t + horizon` with sampling step `dt` and
/// bisection-refined edges. Returns `(open, close)` with `open == t`
/// exactly when the satellite is already visible; `close` is capped at
/// `open + horizon` when the window outlives the search. `None` when the
/// satellite stays invisible for the whole horizon. Windows shorter than
/// `dt` can be missed by the sampling (and their `close` edge is only
/// `dt`-accurate when caught) — pick `dt` below the shortest pass the
/// geometry can produce, as [`windows`] does.
///
/// This is the event timeline's gate: a cluster PS whose next window opens
/// after `t` *waits* until `open` before its ground exchange, and goes
/// stale when this returns `None`.
pub fn next_window_open(
    gs: &GroundStation,
    e: &OrbitalElements,
    t: f64,
    horizon: f64,
    dt: f64,
) -> Option<(f64, f64)> {
    assert!(horizon > 0.0 && dt > 0.0);
    let vis = |x: f64| gs.sees(e.position_eci(x), x);
    let t_end = t + horizon;
    let open = if vis(t) {
        t
    } else {
        let mut x = t;
        let mut open = None;
        while x < t_end {
            let xn = (x + dt).min(t_end);
            if vis(xn) {
                open = Some(bisect_edge(&vis, x, xn));
                break;
            }
            x = xn;
        }
        open?
    };
    // closing edge: scan at most one horizon past the opening
    let close_end = open + horizon;
    let mut x = open;
    while x < close_end {
        let xn = (x + dt).min(close_end);
        if !vis(xn) {
            return Some((open, bisect_edge(&vis, x, xn)));
        }
        x = xn;
    }
    Some((open, close_end))
}

fn bisect_edge(vis: &dyn Fn(f64) -> bool, mut lo: f64, mut hi: f64) -> f64 {
    // invariant: vis(lo) != vis(hi)
    for _ in 0..30 {
        let mid = 0.5 * (lo + hi);
        if vis(mid) == vis(lo) {
            lo = mid;
        } else {
            hi = mid;
        }
        if hi - lo < 0.25 {
            break;
        }
    }
    0.5 * (lo + hi)
}

/// Line-of-sight between two ECI points: the segment must clear the Earth
/// (with a small atmosphere margin). Used for inter-satellite links.
pub fn has_line_of_sight(a: Vec3, b: Vec3) -> bool {
    const MARGIN: f64 = 80_000.0; // atmosphere grazing margin, m
    let ab = b.sub(a);
    let len2 = ab.dot(ab);
    if len2 == 0.0 {
        return true;
    }
    // closest point of the segment to the geocenter
    let t = (-a.dot(ab) / len2).clamp(0.0, 1.0);
    let closest = a.add(ab.scale(t));
    closest.norm() >= EARTH_RADIUS + MARGIN
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::walker::WalkerConstellation;

    #[test]
    fn los_for_adjacent_sats() {
        let r = EARTH_RADIUS + 1_300_000.0;
        let a = Vec3::new(r, 0.0, 0.0);
        let b = Vec3::new(r * 0.9, r * 0.43, 0.0);
        assert!(has_line_of_sight(a, b));
    }

    #[test]
    fn no_los_through_earth() {
        let r = EARTH_RADIUS + 1_300_000.0;
        let a = Vec3::new(r, 0.0, 0.0);
        let b = Vec3::new(-r, 0.0, 0.0);
        assert!(!has_line_of_sight(a, b));
    }

    #[test]
    fn los_is_symmetric_and_reflexive() {
        let r = EARTH_RADIUS + 800_000.0;
        let a = Vec3::new(r, 100.0, -5.0);
        let b = Vec3::new(0.0, r, 0.0);
        assert_eq!(has_line_of_sight(a, b), has_line_of_sight(b, a));
        assert!(has_line_of_sight(a, a));
    }

    #[test]
    fn some_sats_visible_from_ground() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        // with 96 sats in a 53° shell an equatorial station sees a few
        let v = visible_sats(&gs, &c, 0.0);
        assert!(!v.is_empty(), "no satellites visible");
        assert!(v.len() < c.len(), "all satellites visible is impossible");
    }

    #[test]
    fn windows_are_well_formed() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(3, 4));
        let gs = GroundStation::new(0, "mid", 45.0, 10.0, 10.0);
        let period = c.min_period();
        let ws = windows(&gs, &c, 0.0, 2.0 * period, 30.0);
        assert!(!ws.is_empty(), "no visibility windows in two periods");
        for w in &ws {
            assert!(w.end > w.start, "{w:?}");
            assert!(w.duration() < period, "window longer than an orbit: {w:?}");
            // midpoint of a window must be visible
            let mid = 0.5 * (w.start + w.end);
            assert!(gs.sees(c.elements[w.sat].position_eci(mid), mid));
        }
    }

    /// Equatorial satellite at 500 km that is directly over an equatorial
    /// station at t = 0 — a geometry whose pass times are easy to reason
    /// about (synodic period ≈ 6076 s, one pass per period).
    fn overhead_pair() -> (GroundStation, Constellation) {
        let gs = GroundStation::new(0, "eq", 0.0, 0.0, 10.0);
        let sat = OrbitalElements::circular(500_000.0, 0.0, 0.0, 0.0);
        (gs, Constellation::new(vec![sat]))
    }

    #[test]
    fn window_open_at_t0_and_close_at_t1_are_exact() {
        // visible at t0 and still visible at t1 (the 10° footprint spans
        // roughly ±237 s around the overhead pass): the window must be
        // clamped to the query interval, byte-exactly
        let (gs, c) = overhead_pair();
        let ws = windows(&gs, &c, 0.0, 100.0, 10.0);
        assert_eq!(ws.len(), 1, "{ws:?}");
        assert_eq!(ws[0].start, 0.0, "open edge must clamp to t0");
        assert_eq!(ws[0].end, 100.0, "close edge must clamp to t1");
    }

    #[test]
    fn never_visible_satellite_has_no_windows() {
        // an equatorial orbit never rises above 10° for a polar station
        let gs = GroundStation::new(0, "polar", 85.0, 0.0, 10.0);
        let sat = OrbitalElements::circular(500_000.0, 0.0, 0.0, 0.0);
        let c = Constellation::new(vec![sat]);
        // a full synodic period: every geometry repeats after this
        let ws = windows(&gs, &c, 0.0, 6100.0, 30.0);
        assert!(ws.is_empty(), "{ws:?}");
        assert_eq!(next_window_open(&gs, &c.elements[0], 0.0, 6100.0, 30.0), None);
    }

    #[test]
    fn window_shorter_than_sampling_step() {
        // with an 85° mask the overhead pass lasts ~12 s (footprint
        // half-angle ≈ 0.37°): a 100 s sampling step can straddle and miss
        // it entirely, while a 1 s step finds and bisects it
        let (mut gs, c) = overhead_pair();
        gs.min_elevation_deg = 85.0;
        let coarse = windows(&gs, &c, -550.0, 550.0, 100.0);
        assert!(coarse.is_empty(), "coarse sampling should miss: {coarse:?}");
        let fine = windows(&gs, &c, -550.0, 550.0, 1.0);
        assert_eq!(fine.len(), 1, "{fine:?}");
        let w = fine[0];
        assert!(w.duration() > 1.0 && w.duration() < 100.0, "{w:?}");
        assert!(w.start < 0.0 && w.end > 0.0, "pass is centred on t=0: {w:?}");
    }

    #[test]
    fn next_window_is_immediate_when_visible() {
        let (gs, c) = overhead_pair();
        let (open, close) = next_window_open(&gs, &c.elements[0], 3.0, 600.0, 30.0).unwrap();
        assert_eq!(open, 3.0, "already-visible window must open exactly at t");
        assert!(close > open, "open {open} close {close}");
    }

    #[test]
    fn next_window_waits_for_the_following_pass() {
        // at t=300 the overhead pass is over; the next one is a synodic
        // period (~6076 s) after the first, so the PS must wait ~5.5 ks
        let (gs, c) = overhead_pair();
        let (open, close) = next_window_open(&gs, &c.elements[0], 300.0, 7000.0, 30.0).unwrap();
        assert!(open > 300.0, "open {open}");
        assert!((5000.0..6500.0).contains(&open), "open {open}");
        assert!(close > open);
        // the refined edge is genuinely an edge: visible just inside it
        assert!(gs.sees(c.elements[0].position_eci(open + 1.0), open + 1.0));
        // nothing within a too-short horizon
        assert_eq!(next_window_open(&gs, &c.elements[0], 300.0, 1000.0, 30.0), None);
    }

    #[test]
    fn window_edges_are_tight() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(3, 4));
        let gs = GroundStation::new(0, "mid", 45.0, 10.0, 10.0);
        let ws = windows(&gs, &c, 0.0, c.min_period(), 30.0);
        for w in ws.iter().take(5) {
            if w.start > 0.0 {
                // just before the start the satellite is not visible
                let t = w.start - 1.0;
                assert!(!gs.sees(c.elements[w.sat].position_eci(t), t), "{w:?}");
            }
        }
    }
}

//! Sphere-grid spatial index — the constellation plane's geometry engine.
//!
//! Every geometry query the coordinator issues was brute force until this
//! module existed: k-means assignment scanned all K centroids per
//! satellite (`clustering::kmeans`), ground-station visibility probes
//! scanned all N satellites (`orbit::visibility::visible_sats`), and
//! line-of-sight neighbor checks were all-pairs. None of that survives
//! mega-constellation scale (N ≥ 5 000), which is the regime the paper
//! targets and the `mega-sparse`/`mega-dense` presets model.
//!
//! [`SphereGrid`] bins satellites into latitude/longitude cells over the
//! unit sphere (equal-height latitude bands, per-band longitude sectors
//! sized by `cos φ` so cells are roughly equal-area). Each cell carries two
//! conservative bounding volumes computed from its *actual* members at
//! build time:
//!
//! * a Euclidean ball (mean member position + max member distance, km) —
//!   prunes nearest-centroid candidates by the triangle inequality;
//! * an angular cap (mean member direction + max member angle) — prunes
//!   visibility and LoS queries against analytic footprint bounds.
//!
//! ```text
//!        lat bands                 one cell's bounds
//!   ┌───┬───────┬───┐           center ●──ρ──┐  Euclidean ball (km)
//!   │ ∙ │ ∙∙  ∙ │   │              dir ↗ ⌒⌒ │  angular cap (rad)
//!   ├───┼───┬───┼───┤           query prunes a cell iff its bound
//!   │∙  │ ∙ │∙ ∙│ ∙ │           provably cannot contain a winner
//!   └───┴───┴───┴───┘
//! ```
//!
//! **Exactness guarantee.** Index-pruned searches return the bit-identical
//! result of the exhaustive scan — same winners, same tie-breaks, same
//! float comparisons — because pruning only decides *which candidates are
//! examined*, never how they are scored, and every bound is conservative
//! (a small epsilon absorbs the rounding of the bound itself). The
//! guarantee is pinned by property tests over random Walker geometries and
//! cell resolutions, including the degenerate single-cell grid
//! (`tests/proptests.rs::prop_sphere_grid_*`), and is what lets the index
//! default to **on** without perturbing the committed golden trajectories.
//!
//! [`ConstellationIndex`] is the coordinator-facing wrapper: built once
//! per epoch from `orbit::propagate` positions and incrementally refreshed
//! (allocation-reusing rebuild) when the simulated clock moves — each
//! round start, and again on re-cluster events, which rebuild topology at
//! a later in-round epoch.

use super::geo::{GroundStation, Vec3};
use super::propagate::Constellation;
use super::visibility::has_line_of_sight;
use super::EARTH_RADIUS;
use std::f64::consts::{FRAC_PI_2, PI};

/// Absolute slack (km) on Euclidean pruning bounds: orders of magnitude
/// above the rounding of a `sqrt`+`add` chain at LEO scales (~1e-8 km),
/// orders below any real geometry margin.
const ASSIGN_EPS_KM: f64 = 1e-6;
/// Absolute slack (rad) on angular pruning bounds (`acos` of a unit-dot
/// rounds at ~1e-8 near the poles of its domain).
const ANG_EPS: f64 = 1e-6;

/// Squared Euclidean distance with a fixed operation order — the one
/// metric every exact geometry comparison in the crate goes through
/// (k-means Eq. 13, churn's nearest-centroid fold, the index's candidate
/// scoring), so index-pruned and brute-force searches score candidates
/// bit-identically.
#[inline]
pub fn d2(a: &[f64; 3], b: &[f64; 3]) -> f64 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// Angle between two unit vectors, radians.
#[inline]
fn angle_between(a: Vec3, b: Vec3) -> f64 {
    a.dot(b).clamp(-1.0, 1.0).acos()
}

/// A lat/lon cell grid over the unit sphere holding one epoch's satellite
/// positions (clustering feature space, km). See the module docs for the
/// pruning scheme and the exactness guarantee.
#[derive(Clone, Debug)]
pub struct SphereGrid {
    bands: usize,
    /// Longitude sectors per latitude band.
    band_sectors: Vec<usize>,
    /// First cell id of each band (+ one trailing entry = total cells).
    band_start: Vec<usize>,
    /// Member point indices per cell (rebuilt in place on refresh).
    members: Vec<Vec<usize>>,
    /// Per-cell Euclidean mean of member features, km.
    center_km: Vec<[f64; 3]>,
    /// Per-cell max Euclidean distance from the center to a member, km.
    radius_km: Vec<f64>,
    /// Per-cell unit direction of the angular-cap center.
    dir: Vec<Vec3>,
    /// Per-cell max angle between `dir` and a member direction, rad.
    ang_radius: Vec<f64>,
    /// The indexed features (km) — the clustering feature space.
    feats: Vec<[f64; 3]>,
    /// Unit direction of every indexed point.
    point_dir: Vec<Vec3>,
    r_min_km: f64,
    r_max_km: f64,
}

impl SphereGrid {
    /// Default band count for an N-point constellation: coarse enough that
    /// cell-header scans stay cheap against small K, fine enough that
    /// candidate lists stay short at mega scale.
    pub fn auto_bands(n: usize) -> usize {
        (((n as f64) / 20.0).sqrt().ceil() as usize).clamp(1, 64)
    }

    /// Build a grid with `bands` latitude bands over `feats` (km).
    /// `bands == 1` is the degenerate single-cell grid (every query
    /// degrades to the brute-force scan, exactly).
    pub fn build(feats: &[[f64; 3]], bands: usize) -> SphereGrid {
        let bands = bands.max(1);
        let mut band_sectors = Vec::with_capacity(bands);
        let mut band_start = Vec::with_capacity(bands + 1);
        let mut cells = 0usize;
        for b in 0..bands {
            let lat_center = -FRAC_PI_2 + (b as f64 + 0.5) * PI / bands as f64;
            let sectors = if bands == 1 {
                1
            } else {
                ((2.0 * bands as f64 * lat_center.cos()).round() as usize).max(1)
            };
            band_start.push(cells);
            band_sectors.push(sectors);
            cells += sectors;
        }
        band_start.push(cells);
        let mut g = SphereGrid {
            bands,
            band_sectors,
            band_start,
            members: vec![Vec::new(); cells],
            center_km: vec![[0.0; 3]; cells],
            radius_km: vec![0.0; cells],
            dir: vec![Vec3::new(0.0, 0.0, 1.0); cells],
            ang_radius: vec![0.0; cells],
            feats: Vec::new(),
            point_dir: Vec::new(),
            r_min_km: f64::INFINITY,
            r_max_km: 0.0,
        };
        g.rebuild(feats);
        g
    }

    /// Re-index a new epoch's features in place, reusing every allocation
    /// (cell lists, point arrays). Grid geometry (bands/sectors) is fixed
    /// at construction.
    pub fn rebuild(&mut self, feats: &[[f64; 3]]) {
        for m in &mut self.members {
            m.clear();
        }
        self.feats.clear();
        self.feats.extend_from_slice(feats);
        self.point_dir.clear();
        self.r_min_km = f64::INFINITY;
        self.r_max_km = 0.0;
        for (i, f) in feats.iter().enumerate() {
            let v = Vec3::new(f[0], f[1], f[2]);
            let r = v.norm();
            assert!(r > 0.0, "point {i} at the geocenter cannot be indexed");
            let d = v.scale(1.0 / r);
            self.r_min_km = self.r_min_km.min(r);
            self.r_max_km = self.r_max_km.max(r);
            let cell = self.cell_of(d);
            self.members[cell].push(i);
            self.point_dir.push(d);
        }
        for cell in 0..self.cells() {
            let members = &self.members[cell];
            if members.is_empty() {
                self.radius_km[cell] = 0.0;
                self.ang_radius[cell] = 0.0;
                continue;
            }
            let inv = 1.0 / members.len() as f64;
            let mut center = [0.0f64; 3];
            let mut dir_sum = Vec3::ZERO;
            for &i in members {
                for (c, f) in center.iter_mut().zip(&self.feats[i]) {
                    *c += *f * inv;
                }
                dir_sum = dir_sum.add(self.point_dir[i]);
            }
            // a degenerate direction sum (antipodal members in one huge
            // cell) falls back to a whole-sphere cap — still conservative
            let (dir, mut ang) = if dir_sum.norm() > 1e-9 {
                (dir_sum.scale(1.0 / dir_sum.norm()), 0.0)
            } else {
                (self.point_dir[members[0]], PI)
            };
            let mut radius = 0.0f64;
            for &i in members {
                radius = radius.max(d2(&self.feats[i], &center).sqrt());
                ang = ang.max(angle_between(dir, self.point_dir[i]));
            }
            self.center_km[cell] = center;
            self.radius_km[cell] = radius;
            self.dir[cell] = dir;
            self.ang_radius[cell] = ang;
        }
    }

    /// Indexed point count.
    pub fn len(&self) -> usize {
        self.feats.len()
    }

    pub fn is_empty(&self) -> bool {
        self.feats.is_empty()
    }

    /// Total cell count (latitude bands × per-band longitude sectors).
    pub fn cells(&self) -> usize {
        self.band_start[self.bands]
    }

    /// Latitude band count the grid was built with.
    pub fn bands(&self) -> usize {
        self.bands
    }

    /// The indexed features (km), in point order — the exact values every
    /// pruned search scores against.
    pub fn feats(&self) -> &[[f64; 3]] {
        &self.feats
    }

    /// Cell id of a unit direction.
    fn cell_of(&self, d: Vec3) -> usize {
        let lat = d.z.clamp(-1.0, 1.0).asin();
        let band = (((lat / PI) + 0.5) * self.bands as f64).floor() as usize;
        let band = band.min(self.bands - 1);
        let sectors = self.band_sectors[band];
        let lon = d.y.atan2(d.x);
        let sector = (((lon / (2.0 * PI)) + 0.5) * sectors as f64).floor() as usize;
        self.band_start[band] + sector.min(sectors - 1)
    }

    /// Nearest-centroid assignment of every indexed point (the k-means
    /// Eq. 13 step and the churn model's natural-assignment fold),
    /// bit-identical to [`assign_nearest_brute`] over the same features.
    ///
    /// Per cell: centroids farther from the cell's center than
    /// `d_nearest + 2ρ` cannot win for any member (triangle inequality),
    /// so members only score the surviving candidates — in ascending
    /// centroid order with a strict `<`, reproducing the brute-force
    /// lowest-index tie-break exactly.
    pub fn assign_nearest(&self, centroids: &[[f64; 3]], out: &mut Vec<usize>) {
        assert!(!centroids.is_empty(), "no centroids to assign to");
        out.clear();
        out.resize(self.feats.len(), 0);
        let mut dists: Vec<f64> = Vec::with_capacity(centroids.len());
        let mut cand: Vec<usize> = Vec::with_capacity(centroids.len());
        for cell in 0..self.cells() {
            let members = &self.members[cell];
            if members.is_empty() {
                continue;
            }
            let center = &self.center_km[cell];
            dists.clear();
            let mut d_min = f64::INFINITY;
            for c in centroids {
                let d = d2(center, c).sqrt();
                dists.push(d);
                d_min = d_min.min(d);
            }
            let bound = d_min + 2.0 * self.radius_km[cell] + ASSIGN_EPS_KM;
            cand.clear();
            for (ci, d) in dists.iter().enumerate() {
                if *d <= bound {
                    cand.push(ci);
                }
            }
            for &i in members {
                let p = &self.feats[i];
                let mut best = cand[0];
                let mut best_d = d2(p, &centroids[cand[0]]);
                for &ci in &cand[1..] {
                    let d = d2(p, &centroids[ci]);
                    if d < best_d {
                        best_d = d;
                        best = ci;
                    }
                }
                out[i] = best;
            }
        }
    }

    /// Indices of indexed satellites visible from `gs` at time `t`,
    /// ascending — bit-identical to scanning every satellite with
    /// [`GroundStation::sees`]. `positions` are the ECI meter positions of
    /// the same epoch the grid was built from (`Snapshot::positions`).
    ///
    /// Pruning: a satellite at radius r is visible above elevation mask e
    /// only within Earth-central angle `λ*(r) = acos((Re/r)·cos e) − e` of
    /// the station's zenith direction; cells whose angular cap lies wholly
    /// outside `λ*(r_max)` cannot contain a visible satellite.
    pub fn visible_from(
        &self,
        gs: &GroundStation,
        positions: &[Vec3],
        t: f64,
        out: &mut Vec<usize>,
    ) {
        assert_eq!(
            positions.len(),
            self.feats.len(),
            "positions do not cover the indexed constellation"
        );
        out.clear();
        let gdir = gs.eci(t).normalized();
        let e_min = gs.min_elevation_deg.to_radians();
        let lam_max = if e_min <= -FRAC_PI_2 {
            PI
        } else {
            let c = (EARTH_RADIUS / 1e3 / self.r_max_km) * e_min.cos();
            (c.clamp(-1.0, 1.0).acos() - e_min).clamp(0.0, PI)
        };
        for cell in 0..self.cells() {
            let members = &self.members[cell];
            if members.is_empty() {
                continue;
            }
            if angle_between(gdir, self.dir[cell]) > lam_max + self.ang_radius[cell] + ANG_EPS {
                continue;
            }
            for &i in members {
                if gs.sees(positions[i], t) {
                    out.push(i);
                }
            }
        }
        out.sort_unstable();
    }

    /// Indices of satellites within `max_range_m` of satellite `i` with a
    /// clear line of sight, ascending — bit-identical to
    /// [`los_neighbors_brute`]. `positions` are the epoch's ECI meter
    /// positions.
    ///
    /// Pruning: for shell radii ≥ r_min, chord distance d implies angular
    /// separation `θ ≤ acos(1 − d²/(2·r_min²))`; cells whose cap lies
    /// wholly outside that angle of satellite `i`'s direction cannot hold
    /// a neighbor.
    pub fn los_neighbors(
        &self,
        i: usize,
        max_range_m: f64,
        positions: &[Vec3],
        out: &mut Vec<usize>,
    ) {
        assert_eq!(
            positions.len(),
            self.feats.len(),
            "positions do not cover the indexed constellation"
        );
        out.clear();
        let d_km = max_range_m / 1e3;
        let cos_theta = 1.0 - (d_km * d_km) / (2.0 * self.r_min_km * self.r_min_km);
        let theta = cos_theta.clamp(-1.0, 1.0).acos();
        let qdir = self.point_dir[i];
        for cell in 0..self.cells() {
            let members = &self.members[cell];
            if members.is_empty() {
                continue;
            }
            if angle_between(qdir, self.dir[cell]) > theta + self.ang_radius[cell] + ANG_EPS {
                continue;
            }
            for &j in members {
                if j != i
                    && positions[i].dist(positions[j]) <= max_range_m
                    && has_line_of_sight(positions[i], positions[j])
                {
                    out.push(j);
                }
            }
        }
        out.sort_unstable();
    }
}

/// Exhaustive nearest-centroid assignment — the brute-force fallback and
/// the oracle [`SphereGrid::assign_nearest`] is pinned against.
pub fn assign_nearest_brute(feats: &[[f64; 3]], centroids: &[[f64; 3]], out: &mut Vec<usize>) {
    assert!(!centroids.is_empty(), "no centroids to assign to");
    out.clear();
    out.reserve(feats.len());
    for p in feats {
        let mut best = 0;
        let mut best_d = f64::INFINITY;
        for (c, cent) in centroids.iter().enumerate() {
            let d = d2(p, cent);
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        out.push(best);
    }
}

/// Exhaustive LoS neighbor scan — the brute-force fallback and the oracle
/// [`SphereGrid::los_neighbors`] is pinned against.
pub fn los_neighbors_brute(i: usize, max_range_m: f64, positions: &[Vec3], out: &mut Vec<usize>) {
    out.clear();
    for (j, &p) in positions.iter().enumerate() {
        if j != i
            && positions[i].dist(p) <= max_range_m
            && has_line_of_sight(positions[i], p)
        {
            out.push(j);
        }
    }
}

/// Coordinator-facing epoch cache: one [`SphereGrid`] rebuilt in place
/// whenever the simulated clock moves (round starts and re-cluster
/// events), never rebuilt twice for the same epoch.
pub struct ConstellationIndex {
    /// Requested band count; 0 = [`SphereGrid::auto_bands`], resolved at
    /// the first refresh (when the constellation size is known).
    requested_bands: usize,
    grid: SphereGrid,
    epoch: Option<f64>,
    feats_scratch: Vec<[f64; 3]>,
}

impl ConstellationIndex {
    /// `bands == 0` selects [`SphereGrid::auto_bands`] lazily at the first
    /// refresh (when the constellation size is known).
    pub fn new(bands: usize) -> ConstellationIndex {
        ConstellationIndex {
            requested_bands: bands,
            grid: SphereGrid::build(&[], bands.max(1)),
            epoch: None,
            feats_scratch: Vec::new(),
        }
    }

    /// Ensure the grid reflects `c` at epoch `t`; a repeated call for the
    /// same epoch is free. Propagates the constellation itself — when the
    /// caller already holds this epoch's positions (the coordinator
    /// snapshots every round anyway), [`ConstellationIndex::refresh_positions`]
    /// skips the duplicate Kepler pass.
    pub fn refresh(&mut self, c: &Constellation, t: f64) {
        if self.epoch == Some(t) && self.grid.len() == c.len() {
            return;
        }
        let snap = c.snapshot(t);
        self.refresh_positions(&snap.positions, t);
    }

    /// Like [`ConstellationIndex::refresh`], from already-propagated ECI
    /// meter positions of epoch `t`. Features are derived exactly as
    /// `Snapshot::features_km` does (position / 1e3 per component), so
    /// pruned searches score the same bits the brute-force paths see.
    pub fn refresh_positions(&mut self, positions: &[Vec3], t: f64) {
        if self.epoch == Some(t) && self.grid.len() == positions.len() {
            return;
        }
        let want = if self.requested_bands == 0 {
            SphereGrid::auto_bands(positions.len())
        } else {
            self.requested_bands
        };
        if self.grid.bands() != want {
            self.grid = SphereGrid::build(&[], want);
        }
        self.feats_scratch.clear();
        for p in positions {
            self.feats_scratch.push([p.x / 1e3, p.y / 1e3, p.z / 1e3]);
        }
        self.grid.rebuild(&self.feats_scratch);
        self.epoch = Some(t);
    }

    /// The current epoch's grid.
    pub fn grid(&self) -> &SphereGrid {
        &self.grid
    }

    /// Epoch of the last refresh, if any.
    pub fn epoch(&self) -> Option<f64> {
        self.epoch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::orbit::walker::WalkerConstellation;

    fn shell_feats(planes: usize, spp: usize, t: f64) -> (Constellation, Vec<[f64; 3]>, Vec<Vec3>) {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let snap = c.snapshot(t);
        let feats = snap.features_km();
        let pos = snap.positions.clone();
        (c, feats, pos)
    }

    #[test]
    fn every_point_lands_in_exactly_one_cell() {
        let (_, feats, _) = shell_feats(8, 12, 0.0);
        for bands in [1usize, 2, 5, 16] {
            let g = SphereGrid::build(&feats, bands);
            assert_eq!(g.len(), feats.len());
            let total: usize = (0..g.cells()).map(|c| g.members[c].len()).sum();
            assert_eq!(total, feats.len(), "bands={bands}");
        }
    }

    #[test]
    fn single_cell_grid_has_one_cell() {
        let (_, feats, _) = shell_feats(3, 4, 0.0);
        let g = SphereGrid::build(&feats, 1);
        assert_eq!(g.cells(), 1);
        assert_eq!(g.members[0].len(), feats.len());
        // the cap of a whole-shell cell must cover (almost) the sphere
        assert!(g.ang_radius[0] > 1.0);
    }

    #[test]
    fn cell_bounds_contain_their_members() {
        let (_, feats, _) = shell_feats(6, 9, 432.1);
        let g = SphereGrid::build(&feats, 7);
        for cell in 0..g.cells() {
            for &i in &g.members[cell] {
                let d = d2(&feats[i], &g.center_km[cell]).sqrt();
                assert!(d <= g.radius_km[cell] + 1e-9, "cell {cell} point {i}");
                let a = angle_between(g.dir[cell], g.point_dir[i]);
                assert!(a <= g.ang_radius[cell] + 1e-12, "cell {cell} point {i}");
            }
        }
    }

    #[test]
    fn assignment_matches_brute_force() {
        let (_, feats, _) = shell_feats(8, 12, 1234.5);
        let cents = [
            [7000.0, 0.0, 0.0],
            [-3000.0, 5000.0, 1000.0],
            [0.0, 0.0, -7400.0],
        ];
        let mut brute = Vec::new();
        assign_nearest_brute(&feats, &cents, &mut brute);
        for bands in [1usize, 2, 4, 9, 32] {
            let g = SphereGrid::build(&feats, bands);
            let mut idx = Vec::new();
            g.assign_nearest(&cents, &mut idx);
            assert_eq!(idx, brute, "bands={bands}");
        }
    }

    #[test]
    fn visibility_matches_brute_force() {
        use crate::orbit::visibility::visible_sats;
        let (c, feats, pos) = shell_feats(8, 12, 777.0);
        for mask in [-95.0f64, -45.0, 0.0, 10.0, 45.0, 85.0] {
            let gs = GroundStation::new(0, "p", 38.0, -77.0, mask);
            let brute = visible_sats(&gs, &c, 777.0);
            for bands in [1usize, 3, 12] {
                let g = SphereGrid::build(&feats, bands);
                let mut idx = Vec::new();
                g.visible_from(&gs, &pos, 777.0, &mut idx);
                assert_eq!(idx, brute, "mask={mask} bands={bands}");
            }
        }
    }

    #[test]
    fn los_neighbors_match_brute_force() {
        let (_, feats, pos) = shell_feats(8, 12, 55.5);
        for range in [500e3f64, 2_000e3, 6_000e3, 20_000e3] {
            let mut brute = Vec::new();
            los_neighbors_brute(17, range, &pos, &mut brute);
            for bands in [1usize, 4, 16] {
                let g = SphereGrid::build(&feats, bands);
                let mut idx = Vec::new();
                g.los_neighbors(17, range, &pos, &mut idx);
                assert_eq!(idx, brute, "range={range} bands={bands}");
            }
        }
    }

    #[test]
    fn refresh_tracks_the_epoch_and_reuses_the_grid() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(4, 6));
        let mut ix = ConstellationIndex::new(3);
        assert_eq!(ix.epoch(), None);
        ix.refresh(&c, 0.0);
        assert_eq!(ix.epoch(), Some(0.0));
        assert_eq!(ix.grid().len(), c.len());
        assert_eq!(ix.grid().feats(), c.snapshot(0.0).features_km().as_slice());
        // same epoch: a no-op; new epoch: features move
        ix.refresh(&c, 0.0);
        ix.refresh(&c, 600.0);
        assert_eq!(ix.epoch(), Some(600.0));
        assert_eq!(
            ix.grid().feats(),
            c.snapshot(600.0).features_km().as_slice()
        );
    }

    #[test]
    fn auto_band_request_resolves_at_first_refresh() {
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(8, 12));
        let mut ix = ConstellationIndex::new(0);
        ix.refresh(&c, 0.0);
        assert_eq!(ix.grid().bands(), SphereGrid::auto_bands(c.len()));
        assert_eq!(ix.grid().len(), c.len());
    }

    #[test]
    fn auto_bands_scale_with_size() {
        assert_eq!(SphereGrid::auto_bands(1), 1);
        assert!(SphereGrid::auto_bands(96) <= SphereGrid::auto_bands(1000));
        assert!(SphereGrid::auto_bands(1000) <= SphereGrid::auto_bands(5000));
        assert!(SphereGrid::auto_bands(1_000_000) <= 64);
    }

    #[test]
    fn d2_matches_manual_expansion() {
        let a = [1.0, -2.0, 3.5];
        let b = [0.5, 4.0, -1.0];
        let manual = (a[0] - b[0]) * (a[0] - b[0])
            + (a[1] - b[1]) * (a[1] - b[1])
            + (a[2] - b[2]) * (a[2] - b[2]);
        assert_eq!(d2(&a, &b).to_bits(), manual.to_bits());
    }
}

//! Constellation-level propagation: snapshot every satellite position at a
//! simulated time, cached per epoch for the coordinator's clustering step.

use super::elements::OrbitalElements;
use super::geo::Vec3;
use super::walker::WalkerConstellation;

/// A propagatable set of satellites.
#[derive(Clone, Debug)]
pub struct Constellation {
    pub elements: Vec<OrbitalElements>,
}

/// Positions of every satellite at one instant.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub t: f64,
    pub positions: Vec<Vec3>,
}

impl Constellation {
    pub fn new(elements: Vec<OrbitalElements>) -> Self {
        assert!(!elements.is_empty(), "empty constellation");
        Constellation { elements }
    }

    pub fn from_walker(w: &WalkerConstellation) -> Self {
        Constellation::new(w.elements())
    }

    pub fn len(&self) -> usize {
        self.elements.len()
    }

    pub fn is_empty(&self) -> bool {
        self.elements.is_empty()
    }

    /// ECI positions of all satellites at time `t`.
    pub fn snapshot(&self, t: f64) -> Snapshot {
        Snapshot {
            t,
            positions: self.elements.iter().map(|e| e.position_eci(t)).collect(),
        }
    }

    /// Shortest orbital period in the set (used to pick simulation steps).
    pub fn min_period(&self) -> f64 {
        self.elements
            .iter()
            .map(|e| e.period())
            .fold(f64::INFINITY, f64::min)
    }

    /// Range between two satellites at time `t`, meters.
    pub fn range_between(&self, i: usize, j: usize, t: f64) -> f64 {
        self.elements[i]
            .position_eci(t)
            .dist(self.elements[j].position_eci(t))
    }
}

impl Snapshot {
    /// Flattened `[n,3]` position matrix in kilometers — the feature space
    /// the clustering algorithm operates on (Eq. 13 of the paper).
    pub fn features_km(&self) -> Vec<[f64; 3]> {
        self.positions
            .iter()
            .map(|p| [p.x / 1e3, p.y / 1e3, p.z / 1e3])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Constellation {
        Constellation::from_walker(&WalkerConstellation::paper_shell(4, 5))
    }

    #[test]
    fn snapshot_has_all_sats() {
        let c = small();
        let s = c.snapshot(123.0);
        assert_eq!(s.positions.len(), 20);
        assert_eq!(s.t, 123.0);
    }

    #[test]
    fn snapshot_changes_over_time() {
        let c = small();
        let a = c.snapshot(0.0);
        let b = c.snapshot(60.0);
        // LEO at ~7.2 km/s moves ~430 km in a minute
        for (p, q) in a.positions.iter().zip(&b.positions) {
            let d = p.dist(*q);
            assert!((300_000.0..600_000.0).contains(&d), "moved {d}");
        }
    }

    #[test]
    fn features_in_km() {
        let c = small();
        let f = c.snapshot(0.0).features_km();
        // |r| = 7671 km for the paper shell
        for row in f {
            let n = (row[0] * row[0] + row[1] * row[1] + row[2] * row[2]).sqrt();
            assert!((n - 7671.0).abs() < 5.0, "norm {n}");
        }
    }

    #[test]
    fn min_period_uniform_shell() {
        let c = small();
        let p0 = c.elements[0].period();
        assert!((c.min_period() - p0).abs() < 1e-9);
    }

    #[test]
    fn range_between_is_symmetric() {
        let c = small();
        assert!((c.range_between(1, 7, 55.0) - c.range_between(7, 1, 55.0)).abs() < 1e-9);
        assert_eq!(c.range_between(3, 3, 55.0), 0.0);
    }
}

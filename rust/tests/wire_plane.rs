//! Wire-plane integration tests: compressed uploads must keep every
//! determinism contract the dense path has. Encoding runs on the
//! coordinator thread in member order, so metrics are byte-identical at
//! any worker count, in both the sync and the buffered aggregation
//! planes, and the pooled (bounded-memory) mode stays a pure memory
//! optimisation. `--compress none` byte-identity to the pre-compression
//! behaviour is pinned separately by the committed golden trajectories.

use fedhc::config::{AggregationMode, ExperimentConfig};
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::fl::CompressMode;
use fedhc::runtime::{Manifest, ModelRuntime};

fn run_with(cfg: ExperimentConfig) -> RunResult {
    let manifest = Manifest::host();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    run_clustered(&mut trial, Strategy::fedhc()).unwrap()
}

fn tiny_with(mode: CompressMode, workers: usize) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 5;
    cfg.workers = workers;
    cfg.compress = mode;
    cfg.target_accuracy = None;
    cfg
}

fn assert_bitwise(a: &RunResult, b: &RunResult, label: &str) {
    assert_eq!(a.ledger.records.len(), b.ledger.records.len(), "{label}");
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert_eq!(x.round, y.round, "{label}");
        assert_eq!(x.time_s.to_bits(), y.time_s.to_bits(), "{label} round {}", x.round);
        assert_eq!(x.energy_j.to_bits(), y.energy_j.to_bits(), "{label} round {}", x.round);
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{label} round {}", x.round);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{label} round {}", x.round);
    }
    assert_eq!(a.ledger.wire_bytes.to_bits(), b.ledger.wire_bytes.to_bits(), "{label}");
    assert_eq!(a.ledger.reclusters, b.ledger.reclusters, "{label}");
    assert_eq!(a.final_accuracy.to_bits(), b.final_accuracy.to_bits(), "{label}");
}

#[test]
fn compressed_metrics_identical_across_worker_counts() {
    for mode in [CompressMode::TopK(0.1), CompressMode::Int8] {
        let base = run_with(tiny_with(mode, 1));
        assert!(base.ledger.wire_bytes > 0.0, "{mode:?} billed no bytes");
        for workers in [4usize, 8] {
            let other = run_with(tiny_with(mode, workers));
            assert_bitwise(&base, &other, &format!("{mode:?} workers={workers}"));
        }
    }
}

#[test]
fn buffered_compressed_metrics_identical_across_worker_counts() {
    // the buffered plane encodes at send time (contribution creation),
    // still on the coordinator thread — the event-driven merge schedule
    // must not let the worker count leak into the wire format
    let cfg_for = |workers: usize| {
        let mut cfg = tiny_with(CompressMode::TopK(0.25), workers);
        cfg.aggregation = AggregationMode::Buffered;
        cfg.buffer_size = 2;
        cfg
    };
    let base = run_with(cfg_for(1));
    assert!(base.ledger.buffered_merges > 0, "buffered plane never merged");
    let other = run_with(cfg_for(8));
    assert_bitwise(&base, &other, "buffered topk:0.25 workers=8");
}

#[test]
fn pooled_mode_matches_resident_under_compression() {
    // resident mode keeps the *decoded* member params after encoding;
    // that is inspection-only state, so the pooled (bounded-memory) mode
    // must produce the identical ledger
    let mut cfg = tiny_with(CompressMode::Int8, 2);
    let resident = run_with(cfg.clone());
    cfg.resident_params = false;
    let pooled = run_with(cfg);
    assert_bitwise(&resident, &pooled, "pooled vs resident int8");
}

//! Aggregation-plane acceptance tests (host backend — these always run).
//!
//! The differential harness behind `--aggregation`:
//!
//! 1. `sync` is the pre-existing path, byte for byte: its serialised ledger
//!    is identical to a default-config run and every buffered-plane counter
//!    stays at zero.
//! 2. `buffered` with the auto buffer size (goal = cluster size) collapses
//!    onto `sync` bit-exactly — same accuracy/loss/time/energy trajectory,
//!    same recluster and MAML counters — because a full buffer merges at
//!    the last arrival with all-fresh weights, which short-circuits to the
//!    sync weight vector.
//! 3. A partial buffer genuinely changes the semantics: parked
//!    contributions go stale, the staleness histogram fills, and the
//!    discount `1/(1+τ)^β` bends the trajectory away from sync.
//! 4. `buffered` and `async` keep the engine's worker-count determinism:
//!    the full metrics JSON is byte-identical across `--workers 1|4`.

use fedhc::config::{AggregationMode, ExperimentConfig, Timeline};
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::metrics::recorder;
use fedhc::orbit::GroundStation;
use fedhc::runtime::{Manifest, ModelRuntime};

/// Run FedHC under the given aggregation mode; `all_visible` swaps the
/// ground segment for a single station that sees every satellite always.
fn run(cfg: &ExperimentConfig, mode: AggregationMode, all_visible: bool) -> RunResult {
    let manifest = Manifest::host();
    let mut cfg = cfg.clone();
    cfg.aggregation = mode;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    if all_visible {
        // below the geometric minimum of -90°: always visible, everywhere
        trial.ground = vec![GroundStation::new(0, "everywhere", 0.0, 0.0, -91.0)];
    }
    run_clustered(&mut trial, Strategy::fedhc()).unwrap()
}

fn base_cfg(timeline: Timeline) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 6;
    cfg.target_accuracy = None;
    cfg.timeline = timeline;
    cfg
}

/// `--aggregation sync` *is* the pre-PR engine: a default config (which
/// never mentions aggregation at all) serialises to the same bytes, and
/// none of the buffered-plane counters ever move.
#[test]
fn sync_mode_is_byte_identical_to_the_default_config() {
    for timeline in [Timeline::Analytic, Timeline::Event] {
        let cfg = base_cfg(timeline);
        assert_eq!(cfg.aggregation, AggregationMode::Sync, "presets default to sync");
        let default = run(&cfg, cfg.aggregation, false);
        let explicit = run(&cfg, AggregationMode::Sync, false);
        let a = recorder::to_json(&default.ledger).to_pretty();
        let b = recorder::to_json(&explicit.ledger).to_pretty();
        assert_eq!(a, b, "{}: explicit sync drifted from the default path", timeline.name());
        assert_eq!(default.ledger.buffered_merges, 0);
        assert_eq!(default.ledger.idle_s, 0.0);
        assert_eq!(default.ledger.stale_s, 0.0);
        assert_eq!(default.ledger.staleness_hist, [0; 5]);
    }
}

/// The degeneracy pin: buffered aggregation with the auto buffer size
/// waits for every present member, so the merge happens at the last
/// arrival with all-fresh (τ = 0) weights — the learning trajectory, the
/// simulated clock, and the energy ledger match sync bit for bit. Only the
/// collection-plane bookkeeping (merge count, idle seconds) is new.
#[test]
fn buffered_auto_goal_degenerates_to_sync_bit_exactly() {
    for timeline in [Timeline::Analytic, Timeline::Event] {
        let cfg = base_cfg(timeline);
        let sync = run(&cfg, AggregationMode::Sync, true);
        let buffered = run(&cfg, AggregationMode::Buffered, true);
        assert_eq!(
            sync.ledger.records.len(),
            buffered.ledger.records.len(),
            "{}: record counts diverged",
            timeline.name()
        );
        for (s, b) in sync.ledger.records.iter().zip(&buffered.ledger.records) {
            assert_eq!(s.round, b.round);
            assert_eq!(s.accuracy, b.accuracy, "round {}: accuracy diverged", s.round);
            assert_eq!(s.loss, b.loss, "round {}: loss diverged", s.round);
            assert_eq!(s.time_s, b.time_s, "round {}: time diverged", s.round);
            assert_eq!(s.energy_j, b.energy_j, "round {}: energy diverged", s.round);
            assert_eq!(s.reclustered, b.reclustered, "round {}", s.round);
        }
        assert_eq!(sync.ledger.time_s, buffered.ledger.time_s);
        assert_eq!(sync.ledger.energy_j, buffered.ledger.energy_j);
        assert_eq!(sync.ledger.reclusters, buffered.ledger.reclusters);
        assert_eq!(sync.ledger.maml_adaptations, buffered.ledger.maml_adaptations);
        assert_eq!(sync.final_accuracy, buffered.final_accuracy);
        // the collection plane did run: one merge per cluster-round, and
        // everyone but the last arrival sat idle in the buffer
        assert!(buffered.ledger.buffered_merges > 0, "no buffered merge ever fired");
        assert!(buffered.ledger.idle_s > 0.0, "a full buffer still has early arrivals");
        // a full buffer is all-fresh: nothing ever went stale
        assert_eq!(buffered.ledger.staleness_hist[1..], [0; 4]);
        assert_eq!(buffered.ledger.stale_s, 0.0);
    }
}

/// A partial buffer is *not* sync: with goal 2 the merge fires at the
/// second arrival, later uploads park across rounds, and the staleness
/// discount reweights them when they finally merge.
#[test]
fn partial_buffers_go_stale_and_bend_the_trajectory() {
    let cfg = base_cfg(Timeline::Analytic);
    let sync = run(&cfg, AggregationMode::Sync, true);
    let mut part = cfg.clone();
    part.buffer_size = 2;
    let buffered = run(&part, AggregationMode::Buffered, true);
    let stale: usize = buffered.ledger.staleness_hist[1..].iter().sum();
    assert!(stale > 0, "goal 2 on larger clusters must park someone past a version bump");
    assert!(buffered.ledger.stale_s > 0.0);
    assert!(
        buffered.final_accuracy != sync.final_accuracy
            || buffered.ledger.time_s != sync.ledger.time_s,
        "a partial buffer must change either the trajectory or the clock"
    );
}

/// Buffered and async drains are event-ordered (timestamp, then FIFO
/// sequence), never thread-ordered: the full metrics JSON is byte-identical
/// across worker counts.
#[test]
fn buffered_and_async_are_deterministic_across_worker_counts() {
    for mode in [AggregationMode::Buffered, AggregationMode::Async] {
        let run_workers = |workers: usize| {
            let mut cfg = base_cfg(Timeline::Event);
            cfg.workers = workers;
            cfg.buffer_size = 2;
            run(&cfg, mode, false)
        };
        let a = run_workers(1);
        let b = run_workers(4);
        let aj = recorder::to_json(&a.ledger).to_pretty();
        let bj = recorder::to_json(&b.ledger).to_pretty();
        assert_eq!(aj, bj, "{mode:?}: trajectory depends on worker count");
        assert_eq!(a.final_accuracy, b.final_accuracy);
    }
}

//! Golden-trajectory regression tests (host backend — these always run).
//!
//! Each golden file under `tests/golden/` is the full metrics JSON
//! (accuracy/time/energy series plus every ledger counter) of one method on
//! the tiny preset under one `--timeline` mode, exactly as
//! `metrics::recorder::to_json` serialises it. The test re-runs each
//! configuration and diffs the serialisation **byte for byte** — any change
//! to the training numerics, the time/energy accounting, the scenario
//! plane's nominal behaviour, or the JSON encoding shows up as a diff.
//!
//! Regenerating after an intentional change:
//!
//! ```sh
//! UPDATE_GOLDEN=1 cargo test --test golden_trajectories
//! git diff rust/tests/golden/   # review what actually moved
//! ```
//!
//! A missing golden file is written on first run (self-seeding snapshot,
//! reported via stderr) so fresh checkouts and new configurations
//! bootstrap without a separate tool; committed files then pin every
//! subsequent run.

use fedhc::baselines::run_cfedavg;
use fedhc::config::{AggregationMode, ExperimentConfig, RoutingMode, Timeline};
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::fl::CompressMode;
use fedhc::metrics::recorder;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::sim::scenario::{ScenarioConfig, ScenarioKind};
use std::path::PathBuf;

const METHODS: [&str; 4] = ["fedhc", "hbase", "fedce", "cfedavg"];

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/golden")
}

/// The pinned configuration: the tiny preset, 5 rounds, no early stop.
/// Everything else (seed, scenario, outage rate) stays at preset defaults
/// so the snapshot also pins the nominal scenario plane.
fn golden_cfg(timeline: Timeline) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 5;
    cfg.target_accuracy = None;
    cfg.timeline = timeline;
    cfg
}

fn run_one(method: &str, timeline: Timeline) -> String {
    let manifest = Manifest::host();
    let cfg = golden_cfg(timeline);
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = match method {
        "fedhc" => run_clustered(&mut trial, Strategy::fedhc()).unwrap(),
        "hbase" => run_clustered(&mut trial, Strategy::hbase()).unwrap(),
        "fedce" => run_clustered(&mut trial, Strategy::fedce()).unwrap(),
        "cfedavg" => run_cfedavg(&mut trial).unwrap(),
        other => unreachable!("unknown golden method {other}"),
    };
    recorder::to_json(&res.ledger).to_pretty() + "\n"
}

#[test]
fn golden_trajectories_match_exactly() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    let mut seeded = Vec::new();
    for method in METHODS {
        for timeline in [Timeline::Analytic, Timeline::Event] {
            let name = format!("{method}_{}.json", timeline.name());
            let path = dir.join(&name);
            let got = run_one(method, timeline);
            if update || !path.exists() {
                std::fs::write(&path, &got).unwrap();
                if !update {
                    seeded.push(name);
                }
                continue;
            }
            let want = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                got, want,
                "golden trajectory drifted for {method}/{} — if the change is \
                 intentional, regenerate with `UPDATE_GOLDEN=1 cargo test \
                 --test golden_trajectories` and review the diff",
                timeline.name()
            );
        }
    }
    if !seeded.is_empty() {
        eprintln!("seeded {} golden file(s): {seeded:?} — commit them to pin", seeded.len());
    }
}

/// The aggregation plane gets its own snapshots: FedHC and C-FedAvg under
/// `--aggregation buffered` and `--aggregation async` with an explicit
/// `--buffer-size 2`, so parking, staleness discounts, and the idle/stale
/// ledger columns all genuinely engage (the auto buffer size would collapse
/// onto the sync snapshots above and pin nothing new).
fn run_aggregation(method: &str, mode: AggregationMode) -> String {
    let manifest = Manifest::host();
    let mut cfg = golden_cfg(Timeline::Event);
    cfg.aggregation = mode;
    cfg.buffer_size = 2;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = match method {
        "fedhc" => run_clustered(&mut trial, Strategy::fedhc()).unwrap(),
        "cfedavg" => run_cfedavg(&mut trial).unwrap(),
        other => unreachable!("unknown aggregation golden method {other}"),
    };
    recorder::to_json(&res.ledger).to_pretty() + "\n"
}

#[test]
fn golden_aggregation_trajectories_match_exactly() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    let mut seeded = Vec::new();
    for method in ["fedhc", "cfedavg"] {
        for mode in [AggregationMode::Buffered, AggregationMode::Async] {
            let name = format!("{method}_{}.json", mode.name());
            let path = dir.join(&name);
            let got = run_aggregation(method, mode);
            if update || !path.exists() {
                std::fs::write(&path, &got).unwrap();
                if !update {
                    seeded.push(name);
                }
                continue;
            }
            let want = std::fs::read_to_string(&path).unwrap();
            assert_eq!(
                got, want,
                "golden trajectory drifted for {method}/{} — if the change is \
                 intentional, regenerate with `UPDATE_GOLDEN=1 cargo test \
                 --test golden_trajectories` and review the diff",
                mode.name()
            );
        }
    }
    if !seeded.is_empty() {
        eprintln!("seeded {} golden file(s): {seeded:?} — commit them to pin", seeded.len());
    }
}

/// The wire plane gets its own snapshots: FedHC under `--compress topk:0.1`
/// and `--compress int8` on the analytic timeline. These pin the bit-packed
/// payload maths, the per-sender error-feedback residuals, and the billed
/// time/energy folds byte for byte.
fn run_compressed(mode: CompressMode) -> String {
    let manifest = Manifest::host();
    let mut cfg = golden_cfg(Timeline::Analytic);
    cfg.compress = mode;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    recorder::to_json(&res.ledger).to_pretty() + "\n"
}

#[test]
fn golden_compressed_trajectories_match_exactly() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    let mut seeded = Vec::new();
    for (stem, mode) in [
        ("fedhc_topk01", CompressMode::TopK(0.1)),
        ("fedhc_int8", CompressMode::Int8),
    ] {
        let name = format!("{stem}.json");
        let path = dir.join(&name);
        let got = run_compressed(mode);
        if update || !path.exists() {
            std::fs::write(&path, &got).unwrap();
            if !update {
                seeded.push(name);
            }
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            got, want,
            "golden trajectory drifted for fedhc/{stem} — if the change is \
             intentional, regenerate with `UPDATE_GOLDEN=1 cargo test \
             --test golden_trajectories` and review the diff"
        );
    }
    if !seeded.is_empty() {
        eprintln!("seeded {} golden file(s): {seeded:?} — commit them to pin", seeded.len());
    }
}

/// The recovery plane gets its own snapshots: FedHC under the
/// `noisy-links` preset on the analytic timeline (hot bursts so the
/// detect/retry/backoff loop genuinely engages within 5 rounds), FedHC
/// under `ps-crash` on the event timeline (mid-round failover through the
/// visibility-gated pass plan), and C-FedAvg under `ps-crash` (the
/// central-server failover analogue). These pin the corruption draws, the
/// per-attempt retry billing, and the failover re-collection byte for
/// byte.
fn run_recovery(stem: &str) -> String {
    let manifest = Manifest::host();
    let (method, timeline, kind) = match stem {
        "fedhc_noisy_links" => ("fedhc", Timeline::Analytic, ScenarioKind::NoisyLinks),
        "fedhc_ps_crash" => ("fedhc", Timeline::Event, ScenarioKind::PsCrash),
        "cfedavg_ps_crash" => ("cfedavg", Timeline::Analytic, ScenarioKind::PsCrash),
        other => unreachable!("unknown recovery golden stem {other}"),
    };
    let mut cfg = golden_cfg(timeline);
    cfg.scenario = ScenarioConfig::preset(kind);
    match kind {
        // BER up to 5e-2 per burst: corruption is certain in-run
        ScenarioKind::NoisyLinks => cfg.scenario.link_noise_ber_nano = 50_000_000,
        ScenarioKind::PsCrash => {
            cfg.scenario.ps_fail_prob = 0.5;
            cfg.ground_every = 1;
        }
        _ => unreachable!(),
    }
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = match method {
        "fedhc" => run_clustered(&mut trial, Strategy::fedhc()).unwrap(),
        "cfedavg" => run_cfedavg(&mut trial).unwrap(),
        other => unreachable!("unknown recovery golden method {other}"),
    };
    recorder::to_json(&res.ledger).to_pretty() + "\n"
}

#[test]
fn golden_recovery_trajectories_match_exactly() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    let mut seeded = Vec::new();
    for stem in ["fedhc_noisy_links", "fedhc_ps_crash", "cfedavg_ps_crash"] {
        let name = format!("{stem}.json");
        let path = dir.join(&name);
        let got = run_recovery(stem);
        if update || !path.exists() {
            std::fs::write(&path, &got).unwrap();
            if !update {
                seeded.push(name);
            }
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            got, want,
            "golden trajectory drifted for {stem} — if the change is \
             intentional, regenerate with `UPDATE_GOLDEN=1 cargo test \
             --test golden_trajectories` and review the diff"
        );
    }
    if !seeded.is_empty() {
        eprintln!("seeded {} golden file(s): {seeded:?} — commit them to pin", seeded.len());
    }
}

/// `--strict-float` (scalar reference kernels) and `--compress none` (dense
/// wire) must serialise byte-identically to the default run: SIMD blocking
/// is drift-free by construction and the dense wire path bills exactly the
/// historical `4·P`-byte folds.
#[test]
fn strict_float_dense_wire_matches_default() {
    let default = run_one("fedhc", Timeline::Analytic);
    let manifest = Manifest::host();
    let mut cfg = golden_cfg(Timeline::Analytic);
    cfg.strict_float = true;
    cfg.compress = CompressMode::None;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    let strict = recorder::to_json(&res.ledger).to_pretty() + "\n";
    assert_eq!(strict, default, "--strict-float drifted from the SIMD default run");
    let path = golden_dir().join("fedhc_analytic.json");
    if path.exists() && std::env::var("UPDATE_GOLDEN").is_err() {
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(strict, want, "--strict-float drifted from the committed golden");
    }
}

/// The snapshots themselves must be reproducible: serialising the same run
/// twice yields identical bytes (guards against nondeterministic encoding
/// sneaking into the golden diffs).
#[test]
fn golden_serialisation_is_deterministic() {
    let a = run_one("fedhc", Timeline::Analytic);
    let b = run_one("fedhc", Timeline::Analytic);
    assert_eq!(a, b, "same run serialised differently");
}

/// The routing plane gets its own snapshots: FedHC with the whole tiny
/// shell as one cluster at 9000 km ISL range, so each orbital plane forms
/// a 6-ring and `--routing isl` genuinely store-and-forwards (up to three
/// hops, partial aggregation at the relays), plus the `isl:ring`
/// all-reduce on the same geometry. These pin the route-tree construction,
/// the per-hop billing, and the in-route merge folds byte for byte.
fn run_routed(routing: RoutingMode) -> String {
    let manifest = Manifest::host();
    let mut cfg = golden_cfg(Timeline::Analytic);
    cfg.clusters = 1;
    cfg.isl_range_km = 9000.0;
    cfg.routing = routing;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    recorder::to_json(&res.ledger).to_pretty() + "\n"
}

#[test]
fn golden_routed_trajectories_match_exactly() {
    let dir = golden_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let update = std::env::var("UPDATE_GOLDEN").is_ok();
    let mut seeded = Vec::new();
    for (stem, routing) in [
        ("fedhc_isl", RoutingMode::Isl),
        ("fedhc_ring", RoutingMode::Ring),
    ] {
        let name = format!("{stem}.json");
        let path = dir.join(&name);
        let got = run_routed(routing);
        if update || !path.exists() {
            std::fs::write(&path, &got).unwrap();
            if !update {
                seeded.push(name);
            }
            continue;
        }
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(
            got, want,
            "golden trajectory drifted for {stem} — if the change is \
             intentional, regenerate with `UPDATE_GOLDEN=1 cargo test \
             --test golden_trajectories` and review the diff"
        );
    }
    if !seeded.is_empty() {
        eprintln!("seeded {} golden file(s): {seeded:?} — commit them to pin", seeded.len());
    }
}

/// `--routing isl` at the default 2000 km ISL range must serialise
/// byte-identically to the committed direct-routing golden: in-plane
/// neighbours sit ≥ 7600 km apart and the only sub-2000 km links are
/// isolated plane-crossing encounters (min 1880 km, never two sharing a
/// node at any epoch), so every route tree stays flat and degenerates to
/// the one-hop teleport accounting bit for bit.
#[test]
fn sparse_isl_routing_matches_the_direct_golden() {
    let default = run_one("fedhc", Timeline::Analytic);
    let manifest = Manifest::host();
    let mut cfg = golden_cfg(Timeline::Analytic);
    cfg.routing = RoutingMode::Isl;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    let routed = recorder::to_json(&res.ledger).to_pretty() + "\n";
    assert_eq!(routed, default, "--routing isl drifted on a flat-tree shell");
    let path = golden_dir().join("fedhc_analytic.json");
    if path.exists() && std::env::var("UPDATE_GOLDEN").is_err() {
        let want = std::fs::read_to_string(&path).unwrap();
        assert_eq!(routed, want, "--routing isl drifted from the committed golden");
    }
}

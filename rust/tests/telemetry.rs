//! Telemetry-plane integration tests on the built-in host backend.
//!
//! The contracts under test, in order of importance:
//! 1. a `--trace` export is **byte-identical** across worker counts
//!    (emission happens on the coordinator thread, keyed by sim time);
//! 2. enabling the tracer/registry does not perturb the simulated
//!    trajectory — the metrics a telemetry run records equal a plain
//!    run's, record for record;
//! 3. disabled telemetry records nothing and exports empty documents;
//! 4. the JSONL and Chrome `trace_event` exports are well-formed
//!    (every line parses with `t`/`kind`/`entity`; metadata-first
//!    Chrome shape), and the registry/recorder JSON schemas hold on a
//!    real run, not just the unit fixtures.

use fedhc::config::{AggregationMode, ExperimentConfig};
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::metrics::recorder;
use fedhc::metrics::report::format_hotspots;
use fedhc::runtime::{Manifest, ModelRuntime};
use fedhc::util::json::Json;

/// One traced tiny-preset FedHC run; returns the JSONL export, the
/// pretty-printed Chrome export, the run result, and the registry dump.
fn traced_run(
    workers: usize,
    tweak: &dyn Fn(&mut ExperimentConfig),
) -> (String, String, RunResult, Json) {
    let manifest = Manifest::host();
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 5;
    cfg.workers = workers;
    cfg.target_accuracy = None;
    tweak(&mut cfg);
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
    trial.trace.enable();
    trial.registry.enable(cfg.clients, cfg.clusters);
    let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    let jsonl = trial.trace.to_jsonl();
    let chrome = trial.trace.to_chrome().to_pretty();
    let registry = trial.registry.to_json();
    (jsonl, chrome, res, registry)
}

#[test]
fn trace_bytes_identical_across_worker_counts() {
    // a BER floor forces the retry plane (and its trace instants) live
    let noisy = |cfg: &mut ExperimentConfig| cfg.ber = 1e-6;
    let (jsonl_1, chrome_1, _, reg_1) = traced_run(1, &noisy);
    let (jsonl_4, chrome_4, _, reg_4) = traced_run(4, &noisy);
    assert!(!jsonl_1.is_empty(), "traced run emitted nothing");
    assert_eq!(jsonl_1, jsonl_4, "JSONL trace differs across --workers 1|4");
    assert_eq!(chrome_1, chrome_4, "Chrome trace differs across --workers 1|4");
    assert_eq!(reg_1, reg_4, "registry dump differs across --workers 1|4");
}

#[test]
fn buffered_trace_bytes_identical_across_worker_counts() {
    let buffered = |cfg: &mut ExperimentConfig| {
        cfg.aggregation = AggregationMode::Buffered;
        cfg.buffer_size = 2;
    };
    let (jsonl_1, chrome_1, _, _) = traced_run(1, &buffered);
    let (jsonl_4, chrome_4, _, _) = traced_run(4, &buffered);
    assert!(!jsonl_1.is_empty(), "buffered traced run emitted nothing");
    assert_eq!(jsonl_1, jsonl_4, "buffered JSONL differs across workers");
    assert_eq!(chrome_1, chrome_4, "buffered Chrome trace differs across workers");
}

#[test]
fn telemetry_does_not_perturb_the_trajectory() {
    // plain run (telemetry disabled end to end)
    let manifest = Manifest::host();
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 5;
    cfg.target_accuracy = None;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut plain = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
    let base = run_clustered(&mut plain, Strategy::fedhc()).unwrap();
    assert!(plain.trace.is_empty(), "disabled tracer recorded events");
    assert_eq!(plain.trace.to_jsonl(), "", "disabled tracer exported bytes");
    assert!(
        format_hotspots(&plain.registry, 5).is_empty(),
        "disabled registry rendered a hotspot table"
    );

    // identical config with every telemetry sink on
    let (_, _, traced, _) = traced_run(1, &|_| {});
    assert_eq!(base.ledger.records.len(), traced.ledger.records.len());
    for (a, b) in base.ledger.records.iter().zip(&traced.ledger.records) {
        assert!(
            a.round == b.round
                && a.time_s == b.time_s
                && a.energy_j == b.energy_j
                && a.accuracy == b.accuracy
                && a.loss == b.loss,
            "telemetry perturbed round {}: {a:?} vs {b:?}",
            a.round
        );
    }
    assert_eq!(base.final_accuracy, traced.final_accuracy);
    assert_eq!(base.ledger.time_s, traced.ledger.time_s);
}

#[test]
fn jsonl_export_is_line_parseable_with_required_keys() {
    let (jsonl, _, _, _) = traced_run(1, &|_| {});
    let mut kinds: Vec<String> = Vec::new();
    for line in jsonl.lines() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("bad trace line {line:?}: {e}"));
        let t = j.get("t").as_f64().expect("t missing");
        assert!(t.is_finite() && t >= 0.0, "bad sim time {t}");
        kinds.push(j.get("kind").as_str().expect("kind missing").to_string());
        let entity = j.get("entity").as_str().expect("entity missing");
        assert!(
            entity == "run"
                || entity.starts_with("sat:")
                || entity.starts_with("cluster:")
                || entity.starts_with("gs:"),
            "unknown entity id {entity}"
        );
    }
    for expected in ["round", "cluster_stage", "cluster_round", "upload", "merge", "eval"] {
        assert!(
            kinds.iter().any(|k| k == expected),
            "trace is missing any '{expected}' event"
        );
    }
}

#[test]
fn chrome_export_is_metadata_first_and_well_formed() {
    let (_, chrome, _, _) = traced_run(1, &|_| {});
    let doc = Json::parse(&chrome).expect("chrome export parses");
    let events = doc.get("traceEvents").as_arr().expect("traceEvents array");
    assert!(!events.is_empty());
    assert_eq!(events[0].get("ph").as_str(), Some("M"), "metadata records come first");
    let mut spans = 0usize;
    for ev in events {
        match ev.get("ph").as_str().expect("ph missing") {
            "M" => {
                assert_eq!(ev.get("name").as_str(), Some("thread_name"));
                assert!(ev.get("args").get("name").as_str().is_some());
            }
            "X" => {
                spans += 1;
                assert!(ev.get("ts").as_f64().is_some());
                assert!(ev.get("dur").as_f64().is_some());
            }
            "i" => assert_eq!(ev.get("s").as_str(), Some("t")),
            other => panic!("unexpected phase {other:?}"),
        }
        assert!(ev.get("pid").as_usize().is_some());
        assert!(ev.get("tid").as_usize().is_some());
    }
    assert!(spans > 0, "no complete spans in the Chrome export");
}

#[test]
fn registry_dump_reflects_the_run() {
    let (_, _, res, registry) = traced_run(1, &|_| {});
    let sats = registry.get("sats").as_arr().expect("sats array");
    let clusters = registry.get("clusters").as_arr().expect("clusters array");
    assert!(!sats.is_empty() && !clusters.is_empty());
    let uploads: f64 = sats.iter().map(|s| s.get("uploads").as_f64().unwrap()).sum();
    let merges: f64 = clusters.iter().map(|c| c.get("merges").as_f64().unwrap()).sum();
    assert!(uploads > 0.0, "no uploads recorded");
    assert!(merges >= res.ledger.records.len() as f64, "fewer merges than rounds");
    for name in ["comm_s", "retries", "staleness", "hops", "bytes"] {
        let h = registry.get("histograms").get(name);
        let edges = h.get("edges").as_arr().expect("edges").len();
        let counts = h.get("counts").as_arr().expect("counts").len();
        assert_eq!(counts, edges + 1, "histogram {name} shape");
    }
    // the comm-time histogram saw every upload
    let comm_total: f64 = registry
        .get("histograms")
        .get("comm_s")
        .get("counts")
        .as_arr()
        .unwrap()
        .iter()
        .map(|c| c.as_f64().unwrap())
        .sum();
    assert_eq!(comm_total, uploads, "histogram samples != uploads");
}

#[test]
fn hotspot_table_renders_for_an_enabled_registry() {
    let manifest = Manifest::host();
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 3;
    cfg.target_accuracy = None;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
    trial.registry.enable(cfg.clients, cfg.clusters);
    run_clustered(&mut trial, Strategy::fedhc()).unwrap();
    let table = format_hotspots(&trial.registry, 3);
    assert!(table.contains("Hotspots (top-3 satellites by comm time)"), "{table}");
    assert!(table.contains("sat:") && table.contains("cluster:"), "{table}");
}

#[test]
fn recorder_schema_shape_is_pinned_on_a_real_run() {
    let (_, _, res, _) = traced_run(1, &|_| {});
    let keys_of = |doc: &Json| -> Vec<String> {
        let records = doc.get("records").as_arr().expect("records array");
        records[0].as_obj().expect("record object").keys().cloned().collect()
    };
    let default_doc = recorder::to_json(&res.ledger);
    assert_eq!(
        keys_of(&default_doc),
        ["accuracy", "energy_j", "loss", "reclustered", "round", "time_s"],
        "default per-record schema drifted"
    );
    let extended_doc = recorder::to_json_extended(&res.ledger);
    let extended_keys = keys_of(&extended_doc);
    assert_eq!(
        extended_keys,
        [
            "accuracy",
            "d_retransmits",
            "d_route_hops",
            "d_wire_bytes",
            "energy_j",
            "loss",
            "reclustered",
            "round",
            "time_s"
        ],
        "--record-extended per-record schema drifted"
    );
}

//! Timeline acceptance tests (host backend — these always run):
//!
//! 1. Under an always-visible constellation the analytic and event
//!    timelines are **bit-identical** — same accuracy trajectory, same
//!    simulated time and energy. The event machinery (queue scheduling,
//!    window search, antenna serialization) must collapse exactly onto the
//!    closed-form Eq. 7 folds when no PS ever waits.
//! 2. With real visibility windows (the Fig. 3 / mnist preset's Walker
//!    shell and ground segment) the event timeline reports strictly more
//!    cumulative simulated time: PSes genuinely wait for their windows
//!    instead of teleporting parameters to the ground station.
//! 3. The event timeline keeps the engine's worker-count determinism.

use fedhc::config::{ExperimentConfig, Timeline};
use fedhc::coordinator::{run_clustered, RunResult, Strategy, Trial};
use fedhc::orbit::GroundStation;
use fedhc::runtime::{Manifest, ModelRuntime};

/// Run a strategy under the given timeline; `all_visible` swaps the
/// ground segment for a single station that sees every satellite always.
fn run(cfg: &ExperimentConfig, timeline: Timeline, all_visible: bool) -> RunResult {
    let manifest = Manifest::host();
    let mut cfg = cfg.clone();
    cfg.timeline = timeline;
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
    if all_visible {
        // a -91° elevation mask is below the geometric minimum of -90°,
        // so every satellite is visible from everywhere at every time
        trial.ground = vec![GroundStation::new(0, "everywhere", 0.0, 0.0, -91.0)];
    }
    run_clustered(&mut trial, Strategy::fedhc()).unwrap()
}

#[test]
fn timelines_identical_under_always_visible_geometry() {
    let mut cfg = ExperimentConfig::tiny();
    cfg.rounds = 6;
    cfg.target_accuracy = None;
    let analytic = run(&cfg, Timeline::Analytic, true);
    let event = run(&cfg, Timeline::Event, true);
    assert_eq!(
        analytic.ledger.records.len(),
        event.ledger.records.len(),
        "record counts diverged"
    );
    for (a, e) in analytic.ledger.records.iter().zip(&event.ledger.records) {
        assert_eq!(a.round, e.round);
        assert_eq!(a.accuracy, e.accuracy, "round {}: accuracy diverged", a.round);
        assert_eq!(a.loss, e.loss, "round {}: loss diverged", a.round);
        assert_eq!(a.time_s, e.time_s, "round {}: time diverged", a.round);
        assert_eq!(a.energy_j, e.energy_j, "round {}: energy diverged", a.round);
    }
    // no PS ever waited or went stale under the open sky
    assert_eq!(event.ledger.ground_wait_s, 0.0);
    assert_eq!(event.ledger.stale_passes, 0);
    assert_eq!(analytic.final_accuracy, event.final_accuracy);
}

/// The Fig. 3 preset (mnist geometry: 8×12 Walker shell, the default
/// three-station ground segment) with a budget shrunk enough to run as a
/// test but with a ground pass every round — plenty of opportunities for
/// a PS to miss its station.
fn fig3_preset_small() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::mnist();
    cfg.clients = 24;
    cfg.train_samples = 3072;
    cfg.test_samples = 256;
    cfg.rounds = 10;
    cfg.ground_every = 1;
    cfg.eval_every = 10;
    cfg.eval_batches = 2;
    cfg.target_accuracy = None;
    // a generous staleness bound: a PS prefers waiting (simulated time!)
    // over skipping the pass, which is exactly what the claim measures
    cfg.max_ground_wait_s = 20_000.0;
    cfg
}

#[test]
fn event_timeline_costs_strictly_more_under_real_visibility() {
    let cfg = fig3_preset_small();
    let analytic = run(&cfg, Timeline::Analytic, false);
    let event = run(&cfg, Timeline::Event, false);
    assert!(
        event.ledger.ground_wait_s > 0.0,
        "no PS ever waited for a window across {} ground passes",
        cfg.rounds
    );
    assert!(
        event.ledger.time_s > analytic.ledger.time_s,
        "event timeline must cost more than analytic: {} vs {}",
        event.ledger.time_s,
        analytic.ledger.time_s
    );
    // waiting is simulated time, not energy: a pass consumes transmit
    // energy only for the exchanges it actually serves
    assert!(event.ledger.energy_j.is_finite() && event.ledger.energy_j > 0.0);
}

#[test]
fn event_timeline_is_deterministic_across_worker_counts() {
    let manifest = Manifest::host();
    let run_workers = |workers: usize| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 4;
        cfg.workers = workers;
        cfg.timeline = Timeline::Event;
        cfg.target_accuracy = None;
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        let mut trial = Trial::new(cfg, &manifest, &rt).unwrap();
        run_clustered(&mut trial, Strategy::fedhc()).unwrap()
    };
    let a = run_workers(1);
    let b = run_workers(8);
    assert_eq!(a.ledger.records.len(), b.ledger.records.len());
    for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
        assert_eq!(x.accuracy, y.accuracy);
        assert_eq!(x.time_s, y.time_s);
        assert_eq!(x.energy_j, y.energy_j);
    }
    assert_eq!(a.ledger.ground_wait_s, b.ledger.ground_wait_s);
    assert_eq!(a.ledger.stale_passes, b.ledger.stale_passes);
}

//! Property-based tests on coordinator invariants (quickprop harness —
//! the offline image ships no proptest crate; see util::quickprop).

use fedhc::clustering::kmeans::KMeans;
use fedhc::clustering::ps_select::select_parameter_servers;
use fedhc::clustering::recluster::{align_labels, changed_members, DropoutStats, ReclusterPolicy};
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::fedhc::{build_topology, Strategy};
use fedhc::coordinator::Trial;
use fedhc::data::synth::synth_tiny;
use fedhc::data::{partition_dirichlet, partition_iid};
use fedhc::fl::aggregate::{
    aggregate, fedavg_weights, fold_stale, quality_weights, stale_composed_weights,
    staleness_weight,
};
use fedhc::network::{LinkModel, NetworkParams};
use fedhc::orbit::index::{assign_nearest_brute, los_neighbors_brute, SphereGrid};
use fedhc::orbit::propagate::{Constellation, Snapshot};
use fedhc::orbit::visibility::{visible_sats, visible_sats_indexed};
use fedhc::orbit::walker::WalkerConstellation;
use fedhc::orbit::{GroundStation, Vec3};
use fedhc::runtime::host_model::reference;
use fedhc::runtime::{HostModel, HostScratch, Manifest, ModelRuntime};
use fedhc::sim::events::{Event, EventQueue, Scheduled};
use fedhc::sim::faults::{Fault, FaultState};
use fedhc::sim::scenario::{ScenarioConfig, ScenarioEngine, ScenarioKind};
use fedhc::util::quickprop::{property, Gen};
use fedhc::util::Rng;

#[test]
fn prop_kmeans_partitions_all_points() {
    property("kmeans partitions", 40, |g: &mut Gen| {
        let n = g.usize_in(10, 120);
        let k = g.usize_in(1, 6).min(n);
        let pts: Vec<[f64; 3]> = (0..n)
            .map(|_| {
                [
                    g.f64_in(-1000.0, 1000.0),
                    g.f64_in(-1000.0, 1000.0),
                    g.f64_in(-1000.0, 1000.0),
                ]
            })
            .collect();
        let res = KMeans::new(k).run(&pts, g.rng()).unwrap();
        assert_eq!(res.assignment.len(), n);
        assert!(res.assignment.iter().all(|&a| a < k));
        assert_eq!(res.centroids.len(), k, "centroid count must equal k");
        assert_eq!(res.sizes().iter().sum::<usize>(), n);
        assert!(res.inertia >= 0.0);
    });
}

#[test]
fn prop_label_alignment_never_increases_churn() {
    property("alignment reduces churn", 60, |g: &mut Gen| {
        let n = g.usize_in(4, 80);
        let k = g.usize_in(2, 5);
        let old: Vec<usize> = (0..n).map(|_| g.rng().below_usize(k)).collect();
        let new: Vec<usize> = (0..n).map(|_| g.rng().below_usize(k)).collect();
        let aligned = align_labels(&old, &new, k);
        let raw = changed_members(&old, &new).len();
        let after = changed_members(&old, &aligned).len();
        assert!(
            after <= raw,
            "alignment increased churn {raw} -> {after} (n={n}, k={k})"
        );
        // alignment is a relabeling: cluster contents are preserved
        for c in 0..k {
            let members_new: Vec<usize> =
                (0..n).filter(|&i| new[i] == c).collect();
            if members_new.is_empty() {
                continue;
            }
            let mapped = aligned[members_new[0]];
            assert!(
                members_new.iter().all(|&i| aligned[i] == mapped),
                "relabeling split a cluster"
            );
        }
    });
}

#[test]
fn prop_recluster_trigger_monotone_in_dropouts() {
    property("trigger monotone", 60, |g: &mut Gen| {
        let members = g.usize_in(1, 50);
        let dropped = g.rng().below_usize(members + 1);
        let z = g.f64_in(0.0, 1.0);
        let policy = ReclusterPolicy::new(z).unwrap();
        let s = DropoutStats { members, dropped };
        if policy.should_recluster(&[s]) {
            // adding more dropouts keeps it triggered
            let worse = DropoutStats {
                members,
                dropped: members.min(dropped + 1),
            };
            assert!(policy.should_recluster(&[worse]));
        }
    });
}

#[test]
fn prop_recluster_boundary_is_strict() {
    // Algorithm 1's trigger is d_r > Z: a dropout rate exactly equal to Z
    // must NOT fire, one more dropout must, and empty clusters never do
    property("d_r == Z never triggers, d_r > Z always does", 60, |g: &mut Gen| {
        let members = g.usize_in(1, 60);
        let dropped = g.rng().below_usize(members + 1);
        // Z set to the exact observed rate: same division, same bits
        let z = dropped as f64 / members as f64;
        let policy = ReclusterPolicy::new(z).unwrap();
        let s = DropoutStats { members, dropped };
        assert!(
            !policy.should_recluster(&[s]),
            "d_r == Z fired (members={members}, dropped={dropped})"
        );
        assert!(policy.breached(&[s]).is_empty());
        if dropped < members {
            let worse = DropoutStats {
                members,
                dropped: dropped + 1,
            };
            assert!(
                policy.should_recluster(&[worse]),
                "d_r > Z did not fire (members={members}, dropped={})",
                dropped + 1
            );
        }
        // an empty cluster has d_r = 0 by definition: no trigger even at
        // the lowest threshold, alone or alongside the observed cluster
        let empty = DropoutStats::default();
        assert!(!ReclusterPolicy::new(0.0).unwrap().should_recluster(&[empty]));
        assert!(!policy.should_recluster(&[empty]));
    });
}

/// A random Walker geometry at a random epoch, plus a sphere grid over a
/// random cell resolution — `bands == 1` is the degenerate single-cell
/// grid, which must degrade to the brute-force scan exactly.
fn random_walker_grid(g: &mut Gen) -> (Constellation, Vec<[f64; 3]>, Vec<Vec3>, SphereGrid, f64) {
    let planes = g.usize_in(1, 8);
    let spp = g.usize_in(1, 12);
    let alt = g.f64_in(400_000.0, 2_500_000.0);
    let incl = g.f64_in(0.0, 98.0);
    let phasing = g.rng().below_usize(planes);
    let w = WalkerConstellation::new(alt, incl, planes, spp, phasing);
    let c = Constellation::from_walker(&w);
    let t = g.f64_in(0.0, 20_000.0);
    let snap = c.snapshot(t);
    let feats = snap.features_km();
    let pos = snap.positions.clone();
    let bands = g.usize_in(1, 24);
    let grid = SphereGrid::build(&feats, bands);
    (c, feats, pos, grid, t)
}

#[test]
fn prop_sphere_grid_assignment_is_exact() {
    // the constellation plane's exactness guarantee, query (a): the
    // cell-pruned nearest-centroid search returns the bit-identical winner
    // of the exhaustive scan, for arbitrary centroid sets (k-means puts
    // centroids off the shell — even inside the Earth — after Eq. 14)
    property("sphere-grid nearest centroid == brute force", 40, |g: &mut Gen| {
        let (_, feats, _, grid, _) = random_walker_grid(g);
        let k = g.usize_in(1, 8);
        let cents: Vec<[f64; 3]> = (0..k)
            .map(|_| {
                [
                    g.f64_in(-9000.0, 9000.0),
                    g.f64_in(-9000.0, 9000.0),
                    g.f64_in(-9000.0, 9000.0),
                ]
            })
            .collect();
        let mut pruned = Vec::new();
        grid.assign_nearest(&cents, &mut pruned);
        let mut brute = Vec::new();
        assign_nearest_brute(&feats, &cents, &mut brute);
        assert_eq!(pruned, brute, "bands={}", grid.bands());
    });
}

#[test]
fn prop_sphere_grid_visibility_is_exact() {
    // query (b): the cap-pruned visibility probe returns exactly the
    // brute-force visible set, across elevation masks including the
    // always-visible (< -90°) and never-visible extremes
    property("sphere-grid visibility == brute force", 40, |g: &mut Gen| {
        let (c, _, pos, grid, t) = random_walker_grid(g);
        let gs = GroundStation::new(
            0,
            "probe",
            g.f64_in(-88.0, 88.0),
            g.f64_in(-180.0, 180.0),
            g.f64_in(-95.0, 85.0),
        );
        let snap = Snapshot { t, positions: pos };
        let brute = visible_sats(&gs, &c, t);
        let pruned = visible_sats_indexed(&gs, &snap, &grid);
        assert_eq!(pruned, brute, "mask={} bands={}", gs.min_elevation_deg, grid.bands());
    });
}

#[test]
fn prop_sphere_grid_los_neighbors_are_exact() {
    // query (c): the cap-pruned LoS neighbor list equals the brute-force
    // scan — same range cut, same Earth-grazing test, same order
    property("sphere-grid LoS neighbors == brute force", 40, |g: &mut Gen| {
        let (c, _, pos, grid, _) = random_walker_grid(g);
        let i = g.rng().below_usize(c.len());
        let range = g.f64_in(50_000.0, 12_000_000.0);
        let mut pruned = Vec::new();
        grid.los_neighbors(i, range, &pos, &mut pruned);
        let mut brute = Vec::new();
        los_neighbors_brute(i, range, &pos, &mut brute);
        assert_eq!(pruned, brute, "i={i} range={range} bands={}", grid.bands());
    });
}

#[test]
fn prop_ps_select_returns_a_member_of_its_own_cluster() {
    property("ps belongs to its cluster", 20, |g: &mut Gen| {
        // random blob geometry: k well-separated centers, a few satellites
        // around each, so every cluster is non-empty after k-means
        let k = g.usize_in(2, 4);
        let mut pts_km: Vec<[f64; 3]> = Vec::new();
        for c in 0..k {
            let theta = c as f64 / k as f64 * std::f64::consts::TAU;
            let center = [7000.0 * theta.cos(), 7000.0 * theta.sin(), 0.0];
            for _ in 0..g.usize_in(2, 8) {
                pts_km.push([
                    center[0] + 80.0 * g.rng().normal(),
                    center[1] + 80.0 * g.rng().normal(),
                    center[2] + 80.0 * g.rng().normal(),
                ]);
            }
        }
        let res = KMeans::new(k).run(&pts_km, g.rng()).unwrap();
        if res.sizes().iter().any(|&s| s == 0) {
            return; // degenerate local optimum: ps_select's precondition fails
        }
        let positions: Vec<Vec3> = pts_km
            .iter()
            .map(|p| Vec3::new(p[0] * 1e3, p[1] * 1e3, p[2] * 1e3))
            .collect();
        let link = LinkModel::new(NetworkParams::default());
        let choices = select_parameter_servers(&res, &positions, &link);
        assert_eq!(choices.len(), k);
        for choice in &choices {
            assert_eq!(
                res.assignment[choice.ps], choice.cluster,
                "PS {} is not a member of cluster {}",
                choice.ps, choice.cluster
            );
        }
    });
}

#[test]
fn prop_topology_partitions_every_satellite_once() {
    // the clustering invariants the coordinator relies on, across every
    // strategy: each satellite lands in exactly one of k clusters, the
    // centroid/PS/model counts equal k, and every PS is a member of the
    // cluster it serves (host backend — no artifacts needed)
    let manifest = Manifest::host();
    property("topology is a k-partition with member PSes", 8, |g: &mut Gen| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.clients = g.usize_in(8, 24);
        cfg.clusters = g.usize_in(2, 4);
        cfg.train_samples = cfg.clients * 16;
        cfg.test_samples = 32;
        cfg.seed = g.u64();
        let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
        for strategy in [Strategy::fedhc(), Strategy::hbase(), Strategy::fedce()] {
            let mut trial = Trial::new(cfg.clone(), &manifest, &rt).unwrap();
            let global = trial.init.clone();
            let topo = build_topology(&mut trial, &strategy, &global, None).unwrap();
            let k = cfg.clusters;
            assert_eq!(topo.assignment.len(), cfg.clients, "{}", strategy.name);
            assert!(
                topo.assignment.iter().all(|&a| a < k),
                "{}: assignment out of range",
                strategy.name
            );
            assert_eq!(topo.centroids_km.len(), k, "{}", strategy.name);
            assert_eq!(topo.ps.len(), k, "{}", strategy.name);
            assert_eq!(topo.models.len(), k, "{}", strategy.name);
            // clusters() groups each satellite exactly once
            let clusters = topo.clusters(k);
            let total: usize = clusters.iter().map(|m| m.len()).sum();
            assert_eq!(total, cfg.clients, "{}: lost/duplicated members", strategy.name);
            for (c, members) in clusters.iter().enumerate() {
                for &m in members {
                    assert_eq!(topo.assignment[m], c);
                }
                assert_eq!(
                    topo.assignment[topo.ps[c]], c,
                    "{}: PS of cluster {c} is an outsider",
                    strategy.name
                );
            }
        }
    });
}

#[test]
fn prop_partitions_preserve_every_sample() {
    property("partitions are exact covers", 25, |g: &mut Gen| {
        let n = g.usize_in(50, 400);
        let clients = g.usize_in(2, 12).min(n / 4).max(1);
        let data = synth_tiny(n, g.rng());
        let shards = if g.bool() {
            partition_iid(&data, clients, g.rng())
        } else {
            partition_dirichlet(&data, clients, g.f64_in(0.05, 5.0), 1, g.rng())
        };
        assert_eq!(shards.len(), clients);
        let total: usize = shards.iter().map(|s| s.len()).sum();
        assert_eq!(total, n, "partition lost/duplicated samples");
        // label mass is conserved
        let mut global = vec![0usize; 10];
        for &l in &data.labels {
            global[l as usize] += 1;
        }
        let mut shard_sum = vec![0usize; 10];
        for s in &shards {
            for &l in &s.labels {
                shard_sum[l as usize] += 1;
            }
        }
        assert_eq!(global, shard_sum);
    });
}

#[test]
fn prop_weight_schemes_are_distributions_and_ordered() {
    property("weights well-formed", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 30);
        let sizes: Vec<usize> = (0..n).map(|_| g.usize_in(1, 1000)).collect();
        let w = fedavg_weights(&sizes);
        assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        // bigger shard → no smaller weight
        for i in 0..n {
            for j in 0..n {
                if sizes[i] > sizes[j] {
                    assert!(w[i] >= w[j] - 1e-6);
                }
            }
        }
        let losses: Vec<f32> = (0..n).map(|_| g.f64_in(0.01, 10.0) as f32).collect();
        let q = quality_weights(&losses);
        assert!((q.iter().sum::<f32>() - 1.0).abs() < 1e-3);
        for i in 0..n {
            for j in 0..n {
                if losses[i] < losses[j] {
                    assert!(q[i] >= q[j] - 1e-6, "lower loss must not get less weight");
                }
            }
        }
    });
}

#[test]
fn prop_constellation_radius_invariant_under_time() {
    property("orbit radius conserved", 30, |g: &mut Gen| {
        let planes = g.usize_in(2, 10);
        let spp = g.usize_in(2, 10);
        let c = Constellation::from_walker(&WalkerConstellation::paper_shell(planes, spp));
        let t = g.f64_in(0.0, 100_000.0);
        let r0 = c.elements[0].semi_major_axis;
        for p in c.snapshot(t).positions {
            assert!((p.norm() - r0).abs() < 1.0, "radius drifted at t={t}");
        }
    });
}

#[test]
fn prop_dirichlet_floor_respected() {
    property("dirichlet floor", 25, |g: &mut Gen| {
        let clients = g.usize_in(2, 10);
        let floor = g.usize_in(1, 8);
        let n = clients * floor * 4;
        let data = synth_tiny(n, g.rng());
        let shards = partition_dirichlet(&data, clients, 0.1, floor, g.rng());
        for (i, s) in shards.iter().enumerate() {
            assert!(
                s.len() >= floor,
                "client {i} got {} < floor {floor}",
                s.len()
            );
        }
    });
}

#[test]
fn prop_quality_weights_match_eq12_closed_form() {
    // Eq. 12 is p_i = (1/L_i) / Σ(1/L_j) — check against direct computation
    property("eq12 closed form", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 20);
        let losses: Vec<f32> = (0..n).map(|_| g.f64_in(0.05, 8.0) as f32).collect();
        let w = quality_weights(&losses);
        let inv_sum: f64 = losses.iter().map(|&l| 1.0 / l as f64).sum();
        for (i, &l) in losses.iter().enumerate() {
            let want = (1.0 / l as f64) / inv_sum;
            assert!(
                (w[i] as f64 - want).abs() < 1e-5,
                "w[{i}]={} want {want}",
                w[i]
            );
        }
    });
}

#[test]
fn prop_blocked_kernels_bit_identical_to_scalar_reference() {
    // the compute plane's contract: the cache-blocked in-place kernels
    // must reproduce the seed's scalar kernels bit for bit on every
    // geometry — same params, same loss, no tolerance
    property("in-place kernels == seed kernels", 30, |g: &mut Gen| {
        let m = HostModel {
            input: g.usize_in(1, 20),
            hidden: g.usize_in(1, 12),
            classes: g.usize_in(2, 6),
            batch: g.usize_in(1, 4),
            chunk_steps: g.usize_in(1, 2),
        };
        let params = m.init_params(g.u64());
        let mut rng = Rng::new(g.u64());
        let n = m.batch;
        let mut x = vec![0.0f32; n * m.input];
        let mut y = vec![0.0f32; n];
        for i in 0..n {
            let c = rng.below_usize(m.classes);
            y[i] = c as f32;
            for k in 0..m.input {
                x[i * m.input + k] = 0.4 * rng.normal() as f32;
            }
        }
        let mut scratch = HostScratch::new();

        let (p_ref, l_ref) = reference::train_step(&m, &params, &x, &y, 0.2).unwrap();
        let mut p_new = params.clone();
        let l_new = m.train_step_into(&mut p_new, &x, &y, 0.2, &mut scratch).unwrap();
        assert_eq!(p_ref, p_new, "train_step params diverged");
        assert_eq!(l_ref.to_bits(), l_new.to_bits(), "train_step loss diverged");

        let (q_ref, ql_ref) =
            reference::maml_step(&m, &params, &x, &y, &x, &y, 0.05, 0.02).unwrap();
        let mut q_new = params.clone();
        let ql_new = m
            .maml_step_into(&mut q_new, &x, &y, &x, &y, 0.05, 0.02, &mut scratch)
            .unwrap();
        assert_eq!(q_ref, q_new, "maml_step params diverged");
        assert_eq!(ql_ref.to_bits(), ql_new.to_bits(), "maml query loss diverged");
    });
}

#[test]
fn prop_event_queue_pops_non_decreasing_with_fifo_ties() {
    // the buffered plane's ordering contract: pops come out in
    // non-decreasing time, and same-timestamp events keep their insertion
    // order — a coarse time grid forces plenty of exact ties
    property("event queue time order + FIFO ties", 60, |g: &mut Gen| {
        let n = g.usize_in(1, 200);
        let mut q = EventQueue::new();
        for member in 0..n {
            let at = g.usize_in(0, 8) as f64 * 0.5;
            q.push(at, Event::UploadReady { member, cluster: 0 });
        }
        let mut last: Option<Scheduled> = None;
        let mut popped = 0usize;
        while let Some(s) = q.pop() {
            if let Some(prev) = &last {
                assert!(s.at >= prev.at, "time went backwards: {} after {}", s.at, prev.at);
                if s.at == prev.at {
                    assert!(s.seq > prev.seq, "FIFO tie order violated at t={}", s.at);
                }
            }
            last = Some(s);
            popped += 1;
        }
        assert_eq!(popped, n, "queue lost or duplicated events");
        assert!(q.is_empty());
    });
}

#[test]
fn prop_staleness_discount_is_bounded_and_composes_to_a_distribution() {
    property("staleness discount well-formed", 60, |g: &mut Gen| {
        let beta = g.f64_in(0.0, 4.0);
        let tau = g.usize_in(0, 40) as f64;
        let w = staleness_weight(tau, beta);
        assert!(w > 0.0 && w <= 1.0, "w({tau},{beta}) = {w}");
        assert!(
            staleness_weight(tau + 1.0, beta) <= w,
            "discount rose with staleness"
        );
        // freshness is an exact identity: pow(1, β) == 1 in IEEE 754
        assert_eq!(staleness_weight(0.0, beta).to_bits(), 1.0f32.to_bits());
        // composition with arbitrary staleness stays a distribution
        let n = g.usize_in(1, 12);
        let sizes: Vec<usize> = (0..n).map(|_| g.usize_in(1, 500)).collect();
        let staleness: Vec<f64> = (0..n).map(|_| g.usize_in(0, 6) as f64).collect();
        let composed = stale_composed_weights(&fedavg_weights(&sizes), &staleness, beta);
        assert!((composed.iter().sum::<f32>() - 1.0).abs() < 1e-4);
        assert!(composed.iter().all(|&x| x > 0.0));
    });
}

#[test]
fn prop_merging_an_already_agreed_model_is_an_exact_identity() {
    // the fixed points the buffered/async planes lean on. A lone buffered
    // contribution renormalises to weight exactly 1.0 (v/v == 1 for any
    // finite nonzero v) so aggregate() hands the model back bit for bit;
    // the async fold's u − m vanishes bitwise at any step size.
    let manifest = Manifest::host();
    let cfg = ExperimentConfig::tiny();
    let rt = ModelRuntime::load(&manifest, cfg.variant()).unwrap();
    property("identical-params merge fixed point", 16, |g: &mut Gen| {
        let p = rt.spec.param_count;
        let model: Vec<f32> = (0..p).map(|_| g.f64_in(-1.5, 1.5) as f32).collect();
        let tau = g.usize_in(0, 6) as f64;
        let beta = g.f64_in(0.0, 3.0);
        let weights =
            stale_composed_weights(&fedavg_weights(&[g.usize_in(1, 400)]), &[tau], beta);
        assert_eq!(
            weights[0].to_bits(),
            1.0f32.to_bits(),
            "lone weight must renormalise to exactly 1"
        );
        let rows = [model.as_slice()];
        let mut out = Vec::new();
        aggregate(&rt, &rows, &weights, &mut out).unwrap();
        for (a, b) in out.iter().zip(&model) {
            assert_eq!(a.to_bits(), b.to_bits(), "merge moved an already-agreed model");
        }
        let mut folded = model.clone();
        fold_stale(&mut folded, &model, staleness_weight(tau, beta));
        for (a, b) in folded.iter().zip(&model) {
            assert_eq!(a.to_bits(), b.to_bits(), "async fold moved an already-agreed model");
        }
    });
}

#[test]
fn prop_fractional_scenario_advances_never_double_fire() {
    // the continuous-time fault plane must be the *same machine* as the
    // round-indexed one: sampling the interval (r-1, r) at arbitrary
    // fractional times before landing on the boundary yields the same
    // availability fold and the same onset count as one whole-round step —
    // no onset, recovery, or transient outage fires twice or goes missing
    property("advance_to == advance_round at boundaries", 12, |g: &mut Gen| {
        let n_sats = g.usize_in(4, 24);
        let n_stations = g.usize_in(1, 3);
        let seed = g.u64();
        let kind = if g.bool() { ScenarioKind::Churn } else { ScenarioKind::Stragglers };
        let outage = g.f64_in(0.0, 0.3);
        let positions = vec![Vec3::new(7.0e6, 0.0, 0.0); n_sats];
        let mk = || {
            ScenarioEngine::new(ScenarioConfig::preset(kind), outage, seed, n_sats, n_stations)
                .unwrap()
        };
        let (mut whole, mut frac) = (mk(), mk());
        let rounds = g.usize_in(2, 10) as u64;
        for r in 1..=rounds {
            let aw = whole.advance_round(r, &positions);
            let mut frac_faults = 0usize;
            let mut t = (r - 1) as f64;
            for _ in 0..g.usize_in(0, 4) {
                t = (t + g.f64_in(0.0, 0.2)).min(r as f64);
                frac_faults += frac.advance_to(t, &positions).faults_injected;
            }
            let af = frac.advance_to(r as f64, &positions);
            frac_faults += af.faults_injected;
            assert_eq!(aw.unreachable, af.unreachable, "round {r}: availability diverged");
            assert_eq!(aw.ground_down, af.ground_down, "round {r}: ground fold diverged");
            assert_eq!(aw.link_factor, af.link_factor, "round {r}: link fold diverged");
            assert_eq!(
                aw.compute_slowdown, af.compute_slowdown,
                "round {r}: slowdown fold diverged"
            );
            assert_eq!(
                aw.faults_injected, frac_faults,
                "round {r}: onsets double-fired or went missing"
            );
        }
    });
}

#[test]
fn prop_onset_recovery_stacks_round_trip_to_nominal_bits() {
    // the recovery plane's availability contract: a random stack of onset
    // faults — overlapping hard failures, PS crashes, noise bursts piling
    // on the same satellite — unwound by each onset's own `recovery()`
    // leaves the fold bit-identical to a fresh FaultState. Factor faults
    // (link degrade, slowdown) get at most one active onset per satellite:
    // their restore divides by exactly the factor its onset multiplied,
    // which is only a bitwise identity against a nominal 1.0 base.
    property("onset stack + LIFO recovery == nominal", 60, |g: &mut Gen| {
        let n_sats = g.usize_in(2, 12);
        let n_stations = g.usize_in(1, 3);
        let nominal = FaultState::new(n_sats, n_stations);
        let mut s = FaultState::new(n_sats, n_stations);
        let mut factored = vec![false; n_sats];
        let mut onsets: Vec<Fault> = Vec::new();
        for _ in 0..g.usize_in(1, 24) {
            let sat = g.rng().below_usize(n_sats);
            let f = match g.rng().below_usize(6) {
                0 => Fault::SatFail { sat },
                1 => Fault::GroundOutage { station: g.rng().below_usize(n_stations) },
                2 => Fault::PsFailure { sat },
                3 => Fault::LinkNoise {
                    sat,
                    ber_nano: 1 + g.rng().below_usize(1_000_000) as u32,
                },
                // this satellite already carries a factor fault: stack a
                // depth fault instead of a second multiplier
                _ if factored[sat] => Fault::LinkNoise { sat, ber_nano: 1 },
                4 => {
                    factored[sat] = true;
                    Fault::LinkDegrade { sat, milli: 1 + g.rng().below_usize(999) as u32 }
                }
                _ => {
                    factored[sat] = true;
                    Fault::SlowdownStart {
                        sat,
                        milli: 1001 + g.rng().below_usize(9_000) as u32,
                    }
                }
            };
            assert!(f.is_onset(), "{f:?} drawn as an onset");
            s.apply(f).unwrap();
            onsets.push(f);
        }
        for f in onsets.iter().rev() {
            let r = f.recovery();
            assert!(!r.is_onset(), "{f:?} paired with onset {r:?}");
            assert_eq!(r.recovery(), r, "recovery of a restore is itself");
            s.apply(r).unwrap();
        }
        assert_eq!(s.sat_down, nominal.sat_down, "hard-failure depth leaked");
        assert_eq!(s.ground_down, nominal.ground_down, "outage depth leaked");
        assert_eq!(s.ber_nano, nominal.ber_nano, "noise bursts leaked");
        assert_eq!(s.ps_failed, nominal.ps_failed, "PS crash depth leaked");
        for (got, want) in s.link_factor.iter().zip(&nominal.link_factor) {
            assert_eq!(got.to_bits(), want.to_bits(), "link factor drifted");
        }
        for (got, want) in s.compute_slowdown.iter().zip(&nominal.compute_slowdown) {
            assert_eq!(got.to_bits(), want.to_bits(), "slowdown factor drifted");
        }
    });
}

#[test]
fn prop_rng_streams_do_not_collide() {
    property("fork independence", 20, |g: &mut Gen| {
        let mut root = Rng::new(g.u64());
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let collisions = (0..128).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(collisions < 3);
    });
}

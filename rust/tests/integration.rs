//! Integration tests over the full stack (artifacts → PJRT → coordinator).
//! All tests skip gracefully when artifacts are missing so `cargo test`
//! stays usable before `make artifacts`; CI runs them via `make test`.

use fedhc::baselines::run_cfedavg;
use fedhc::config::ExperimentConfig;
use fedhc::coordinator::{run_clustered, Strategy, Trial};
use fedhc::runtime::{Manifest, ModelRuntime};

fn with_runtime<F: FnOnce(&Manifest, &ModelRuntime)>(f: F) {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let m = Manifest::load(&dir).unwrap();
    let rt = ModelRuntime::load(&m, "tiny_mlp").unwrap();
    f(&m, &rt);
}

#[test]
fn all_four_methods_complete_and_learn() {
    with_runtime(|m, rt| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 8;
        cfg.target_accuracy = None;
        let run = |method: &str| {
            let mut trial = Trial::new(cfg.clone(), m, rt).unwrap();
            match method {
                "cfedavg" => run_cfedavg(&mut trial).unwrap(),
                "fedhc" => run_clustered(&mut trial, Strategy::fedhc()).unwrap(),
                "hbase" => run_clustered(&mut trial, Strategy::hbase()).unwrap(),
                "fedce" => run_clustered(&mut trial, Strategy::fedce()).unwrap(),
                _ => unreachable!(),
            }
        };
        for method in ["cfedavg", "fedhc", "hbase", "fedce"] {
            let res = run(method);
            assert!(!res.ledger.records.is_empty(), "{method}: no records");
            let first = res.ledger.records.first().unwrap().accuracy;
            assert!(
                res.final_accuracy > first,
                "{method}: accuracy {first} -> {} did not improve",
                res.final_accuracy
            );
            assert!(res.ledger.time_s > 0.0 && res.ledger.energy_j > 0.0);
        }
    });
}

#[test]
fn paper_orderings_hold_on_tiny() {
    with_runtime(|m, rt| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 10;
        cfg.target_accuracy = None;
        let time_of = |strategy: Option<Strategy>| {
            let mut trial = Trial::new(cfg.clone(), m, rt).unwrap();
            let res = match strategy {
                Some(s) => run_clustered(&mut trial, s).unwrap(),
                None => run_cfedavg(&mut trial).unwrap(),
            };
            res.ledger.time_s
        };
        let t_central = time_of(None);
        let t_fedhc = time_of(Some(Strategy::fedhc()));
        let t_hbase = time_of(Some(Strategy::hbase()));
        // headline orderings: hierarchy beats centralised; geographic
        // clustering beats random clustering on round time
        assert!(t_fedhc < t_central, "fedhc {t_fedhc} vs central {t_central}");
        assert!(t_fedhc < t_hbase, "fedhc {t_fedhc} vs hbase {t_hbase}");
    });
}

#[test]
fn runs_are_deterministic_given_seed() {
    with_runtime(|m, rt| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 5;
        cfg.target_accuracy = None;
        let run = || {
            let mut trial = Trial::new(cfg.clone(), m, rt).unwrap();
            run_clustered(&mut trial, Strategy::fedhc()).unwrap()
        };
        let a = run();
        let b = run();
        assert_eq!(a.ledger.records.len(), b.ledger.records.len());
        for (x, y) in a.ledger.records.iter().zip(&b.ledger.records) {
            assert_eq!(x.accuracy, y.accuracy);
            assert_eq!(x.time_s, y.time_s);
            assert_eq!(x.energy_j, y.energy_j);
        }
        // different seed → different trajectory
        let mut cfg2 = cfg.clone();
        cfg2.seed = 777;
        let mut trial = Trial::new(cfg2, m, rt).unwrap();
        let c = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        assert!(
            a.ledger
                .records
                .iter()
                .zip(&c.ledger.records)
                .any(|(x, y)| x.accuracy != y.accuracy || x.time_s != y.time_s),
            "different seeds produced identical runs"
        );
    });
}

#[test]
fn churn_triggers_recluster_and_maml() {
    with_runtime(|m, rt| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.rounds = 12;
        cfg.outage_prob = 0.30;
        cfg.recluster_threshold = 0.10;
        cfg.target_accuracy = None;
        let mut trial = Trial::new(cfg.clone(), m, rt).unwrap();
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        assert!(res.ledger.reclusters > 0, "no re-clustering under 30% churn");
        assert!(res.ledger.maml_adaptations > 0, "no MAML warm-starts fired");
        // without MAML the same churn must produce zero adaptations
        let mut trial = Trial::new(cfg, m, rt).unwrap();
        let res2 = run_clustered(&mut trial, Strategy::fedhc_no_maml()).unwrap();
        assert!(res2.ledger.reclusters > 0);
        assert_eq!(res2.ledger.maml_adaptations, 0);
    });
}

#[test]
fn k_sweep_is_stable() {
    with_runtime(|m, rt| {
        for k in [2usize, 3, 5, 8] {
            let mut cfg = ExperimentConfig::tiny();
            cfg.clusters = k;
            cfg.rounds = 4;
            cfg.target_accuracy = None;
            let mut trial = Trial::new(cfg, m, rt).unwrap();
            let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
            assert!(res.ledger.records.len() >= 4, "K={k}: missing records");
            assert!(res.ledger.time_s.is_finite() && res.ledger.energy_j.is_finite());
        }
    });
}

#[test]
fn non_iid_sharding_still_learns() {
    with_runtime(|m, rt| {
        let mut cfg = ExperimentConfig::tiny();
        cfg.dirichlet_alpha = 0.1; // heavy label skew
        cfg.rounds = 12;
        cfg.target_accuracy = None;
        let mut trial = Trial::new(cfg, m, rt).unwrap();
        let res = run_clustered(&mut trial, Strategy::fedhc()).unwrap();
        let first = res.ledger.records.first().unwrap().accuracy;
        assert!(
            res.final_accuracy > first + 0.1,
            "non-IID: {first} -> {}",
            res.final_accuracy
        );
    });
}
